#!/usr/bin/env python3
"""Docs gate: every link, anchor, and file reference in docs/ + README
resolves.

Checks, stdlib only (CI runs this before any dependency install matters):

- every relative markdown link ``[text](path)`` points at a file or
  directory that exists in the repo;
- every fragment link ``[text](file.md#anchor)`` points at a heading that
  actually renders that anchor (GitHub slugging: lowercase, spaces to
  dashes, punctuation dropped);
- every intra-file ``[text](#anchor)`` matches a heading in the same file;
- inline code spans that look like repo paths (``src/repro/...``,
  ``results/...``, ``docs/...``, ``benchmarks/...``, ``tests/...``,
  ``.github/...``, ``examples/...``) exist, so prose can't drift from the
  tree it describes.

Exit 0 when clean; exit 1 listing every failure (file:line what).

Usage::

    python results/check_docs.py            # checks docs/*.md + README.md
    python results/check_docs.py FILE...    # explicit file list
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excludes images (![...]) via the lookbehind; target may
# carry an optional #fragment and an optional "title"
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$")
_CODE_FENCE = re.compile(r"^(```|~~~)")
# inline `code` that names a repo path we can verify exists
_PATH_SPAN = re.compile(
    r"`((?:src|docs|results|benchmarks|tests|examples|\.github)/"
    r"[A-Za-z0-9_./-]+)`")
_EXTERNAL = ("http://", "https://", "mailto:")


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:
        return str(path)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a markdown heading."""
    # drop inline-code backticks and link syntax, keep the text
    text = heading.replace("`", "")
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = text.strip().lower()
    # spaces -> dashes; drop everything that isn't word, dash, or space
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set:
    """All heading anchors a markdown file renders (GitHub rules,
    including the -1, -2 suffixes for duplicate headings)."""
    seen: dict = {}
    out = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check_file(path: Path) -> list:
    """All failures in one markdown file as (lineno, message) tuples."""
    failures = []
    in_fence = False
    for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(_EXTERNAL):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            if target:
                dest = (path.parent / target).resolve()
                if not dest.exists():
                    failures.append((lineno, f"broken link: {m.group(1)}"))
                    continue
            else:
                dest = path  # pure-fragment link into this file
            if frag is not None:
                if dest.is_dir() or dest.suffix.lower() != ".md":
                    failures.append(
                        (lineno, f"fragment on non-markdown target: "
                                 f"{m.group(1)}"))
                elif frag not in anchors_of(dest):
                    failures.append(
                        (lineno, f"missing anchor: {m.group(1)} "
                                 f"(no heading slugs to '{frag}' in "
                                 f"{_rel(dest)})"))
        for m in _PATH_SPAN.finditer(line):
            if not (REPO / m.group(1)).exists():
                failures.append(
                    (lineno, f"path in prose does not exist: {m.group(1)}"))
    return failures


def main(argv) -> int:
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    missing = [f for f in files if not f.exists()]
    if missing:
        for f in missing:
            print(f"docs gate: no such file: {f}", file=sys.stderr)
        return 1
    total = 0
    for f in files:
        for lineno, msg in check_file(f):
            print(f"{_rel(f)}:{lineno}: {msg}", file=sys.stderr)
            total += 1
    n = len(files)
    if total:
        print(f"docs gate FAILED: {total} broken reference(s) "
              f"across {n} file(s)", file=sys.stderr)
        return 1
    print(f"docs gate passed: {n} file(s), all links/anchors/paths resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
