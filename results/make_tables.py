"""Render EXPERIMENTS.md tables from results/*.jsonl."""

import json
import sys


def load(path):
    rows = []
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        d = json.loads(line)
        if "manifest" in d:
            # run-manifest header (see repro.obs.manifest) — provenance,
            # not a data row
            continue
        rows.append(d)
    return rows


def roofline_table(rows, mesh="single"):
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | MODEL_FLOPS | useful | roofline | peak mem/dev |",
           "|---|---|---:|---:|---:|---|---:|---:|---:|---:|"]
    skips = []
    for r in rows:
        if r.get("mesh") != mesh and r.get("status") == "ok":
            continue
        if r["status"] == "skipped":
            if r.get("mesh", "single") == mesh or "mesh" not in r:
                skips.append(r)
            continue
        if r["status"] != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} "
            f"| {r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} "
            f"| **{r['dominant']}** | {r['model_flops']:.2e} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['peak_memory_bytes']/2**30:.1f} GiB |")
    return "\n".join(out), skips


def fabric_table(rows):
    """Figs. 8/10/11 companion: per-PE columns next to array-accurate ones.

    Rows are AppCost records (dataclasses.asdict) written by a DSE sweep
    run with ``fabric=FabricOptions(...)``; the per-tile columns reproduce
    the paper's figures, the fabric columns add what place-and-route sees —
    routed wirelength, array utilization, interconnect-inclusive energy/op —
    and the sim columns what the time-domain subsystem *measured*: achieved
    initiation interval (vs its lower bound), sustained throughput, and
    energy/op including idle cycles (0 values mean that stage was not run).
    """
    out = ["| app | PE | pes | e/op (pJ) | area (kum2) | "
           "fab e/op (pJ) | fab area (kum2) | wirelen | util | fab fmax | "
           "II | minII | Gops | sim e/op (pJ) | ok |",
           "|---|---|---:|---:|---:|---:|---:|---:|---:|---:"
           "|---:|---:|---:|---:|---|"]
    for r in rows:
        verified = {1: "Y", 0: "N"}.get(r.get("sim_verified", -1), "-")
        out.append(
            f"| {r['app']} | {r['pe_name']} | {r['n_pes']} "
            f"| {r['energy_per_op_pj']:.4f} | {r['total_area_um2']/1e3:.1f} "
            f"| {r.get('fabric_energy_per_op_pj', 0.0):.4f} "
            f"| {r.get('fabric_area_um2', 0.0)/1e3:.1f} "
            f"| {r.get('fabric_wirelength', 0)} "
            f"| {r.get('fabric_utilization', 0.0):.2f} "
            f"| {r.get('fabric_fmax_ghz', 0.0):.2f} "
            f"| {r.get('sim_ii', 0)} | {r.get('sim_min_ii', 0)} "
            f"| {r.get('sim_throughput_gops', 0.0):.1f} "
            f"| {r.get('sim_energy_per_op_pj', 0.0):.4f} "
            f"| {verified} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | status | compile (s) | collectives "
           "(count) | collective bytes/dev | notes |",
           "|---|---|---|---|---:|---:|---:|---|"]
    for r in rows:
        if r["status"] == "ok":
            note = ""
            cb = f"{r['collective_bytes_per_device']/2**30:.1f} GiB"
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                       f"| {r.get('t_compile_s', 0):.0f} "
                       f"| {r.get('collective_count', 0):.0f} | {cb} | {note} |")
        elif r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped | - | - | - | {r['reason'][:60]}... |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR | - | - | - | {r['error'][:60]} |")
    return "\n".join(out)


def stages_table(path):
    """Markdown stage-timing table from a pipeline trace (Chrome JSON or
    flat jsonl, as written by ``python -m repro.explore --trace``)."""
    from repro.obs.report import load_trace_rows, stage_table
    return stage_table(load_trace_rows(path), markdown=True)


#: trend columns shown first when present (the headline numbers)
_TREND_PREFERRED = ("speedup", "serial_s", "grouped_s")
_TREND_MAX_COLS = 8


def trend_table(history_dir, limit=12):
    """Per-bench markdown trend tables from ``results/history/*.jsonl``
    (rows appended by ``python -m repro.obs.regress --append``): one table
    per benchmark, newest ``limit`` commits, headline metrics as columns.
    """
    import glob
    import os

    out = []
    for path in sorted(glob.glob(os.path.join(history_dir, "*.jsonl"))):
        rows = [r for r in load(path) if isinstance(r, dict)][-limit:]
        if not rows:
            continue
        bench = rows[-1].get("bench", os.path.basename(path))
        numeric = sorted({k for r in rows
                          for k, v in (r.get("metrics") or {}).items()
                          if isinstance(v, (int, float))
                          and not isinstance(v, bool)})
        keys = [k for k in _TREND_PREFERRED if k in numeric]
        keys += [k for k in numeric
                 if k not in keys][:_TREND_MAX_COLS - len(keys)]
        out.append(f"### {bench}")
        out.append("| sha | mode | ts | " + " | ".join(keys) + " |")
        out.append("|---|---|---|" + "---:|" * len(keys))
        for r in rows:
            cells = []
            for k in keys:
                v = (r.get("metrics") or {}).get(k)
                cells.append(f"{v:.4g}" if isinstance(v, (int, float))
                             and not isinstance(v, bool) else "-")
            ts = r.get("ts")
            if isinstance(ts, (int, float)):
                import datetime
                ts = datetime.datetime.fromtimestamp(
                    ts, datetime.timezone.utc).strftime("%Y-%m-%d")
            out.append(f"| {str(r.get('sha', ''))[:10]} "
                       f"| {r.get('mode', '')} | {ts} | "
                       + " | ".join(cells) + " |")
        out.append("")
    return "\n".join(out) if out else "(no history rows yet)"


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "results/baseline.jsonl"
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if which == "stages":
        print(stages_table(path))
        sys.exit(0)
    if which == "trend":
        print(trend_table(path))
        sys.exit(0)
    rows = load(path)
    if which == "roofline":
        table, skips = roofline_table(rows)
        print(table)
    elif which == "fabric":
        print(fabric_table(rows))
    else:
        print(dryrun_table(rows))
