#!/usr/bin/env python
"""CI bench gate: assert the committed performance contract on BENCH_*.json.

Loads every benchmark artifact the CI bench jobs produce and fails the job
on regression.  Gates are *ratios* (batched-vs-serial speedups must stay
>= 1.0) and *bit-identity flags* (batched paths must stay bit-identical to
their per-pair references) — never absolute wall-clock, so shared-runner
noise cannot flake the gate.

Every known benchmark schema has an explicit rule below; an unknown
BENCH_*.json fails loudly, so adding a benchmark artifact to CI forces
adding its gate in the same change.

Run:  python results/check_bench.py results/BENCH_*.json
      python results/check_bench.py            # globs results/BENCH_*.json
"""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def _flag(data: Dict, path: str, key: str, errors: List[str]) -> None:
    if data.get(key) is not True:
        errors.append(f"{path}: {key} is {data.get(key)!r}, expected true")


def _ratio(data: Dict, path: str, key: str, errors: List[str],
           floor: float = 1.0) -> None:
    val = data.get(key)
    if not isinstance(val, (int, float)) or val < floor:
        errors.append(f"{path}: {key}={val!r}, expected >= {floor}")


#: every key a BENCH metrics block may carry — sourced from the explorer's
#: repro.obs metrics registry by benchmarks/explore_bench.py.  An unknown
#: key fails the gate loudly, so renaming a counter forces updating the
#: contract (and the committed artifacts) in the same change.
METRIC_KEYS = frozenset({
    "pnr_dispatch", "sim_dispatch", "sched_group", "sched_attempts",
    "sched_rounds", "sched_scans", "sched_backtracks",
    "memo_hit", "memo_miss", "compile_events", "compile_secs",
    "host_peak_bytes", "device_bytes",
    "serve_requests", "serve_batches", "serve_cache_hits",
})

#: the run-manifest contract, mirrored from src/repro/obs/manifest.py —
#: this gate runs stdlib-only in CI (no PYTHONPATH), so the contract is
#: restated here; drift between the two fails the gate on regenerated
#: artifacts, which is the point.
MANIFEST_SCHEMA = 1
MANIFEST_KEYS = frozenset({
    "schema", "git_sha", "python", "jax", "jaxlib", "platform",
    "device_kind", "backend", "cpu_count", "xla_cache",
})
XLA_CACHE_STATES = ("off", "cold", "warm")

#: keys of one summarize_repeats() entry in a ``repeats`` block
REPEAT_STAT_KEYS = frozenset({"n", "median", "iqr", "min", "max"})


def _manifest(data: Dict, path: str, errors: List[str]) -> None:
    """Every BENCH artifact must say what environment produced it."""
    man = data.get("manifest")
    if not isinstance(man, dict):
        errors.append(f"{path}: missing manifest block (regenerate the "
                      f"artifact — perf numbers without provenance are "
                      f"not comparable)")
        return
    for key in sorted(set(man) - MANIFEST_KEYS):
        errors.append(f"{path}: unknown manifest key {key!r} — update "
                      f"MANIFEST_KEYS in results/check_bench.py to match "
                      f"src/repro/obs/manifest.py")
    for key in sorted(MANIFEST_KEYS - set(man)):
        errors.append(f"{path}: manifest missing key {key!r}")
    if man.get("schema") != MANIFEST_SCHEMA:
        errors.append(f"{path}: manifest schema {man.get('schema')!r}, "
                      f"expected {MANIFEST_SCHEMA}")
    cpus = man.get("cpu_count")
    if "cpu_count" in man and (not isinstance(cpus, int) or cpus < 1):
        errors.append(f"{path}: manifest cpu_count={cpus!r}, expected a "
                      f"positive int")
    if "xla_cache" in man and man.get("xla_cache") not in XLA_CACHE_STATES:
        errors.append(f"{path}: manifest xla_cache={man.get('xla_cache')!r},"
                      f" expected one of {XLA_CACHE_STATES}")


def _repeat_stats(block: Dict, where: str, errors: List[str]) -> None:
    for key, val in sorted(block.items()):
        if key == "n":
            if not isinstance(val, int) or val < 1:
                errors.append(f"{where}: repeats n={val!r}, expected a "
                              f"positive int")
            continue
        if not isinstance(val, dict) or set(val) != REPEAT_STAT_KEYS:
            errors.append(f"{where}: repeats[{key!r}] must be a "
                          f"{{n, median, iqr, min, max}} summary, got "
                          f"{val!r}")
            continue
        bad = [k for k in ("median", "iqr", "min", "max")
               if not isinstance(val[k], (int, float)) or val[k] < 0]
        for k in bad:
            errors.append(f"{where}: repeats[{key!r}][{k!r}]={val[k]!r}, "
                          f"expected a non-negative number")


def _repeats(data: Dict, path: str, errors: List[str]) -> None:
    """Wall-clocks must be medians over repeats, never a lone sample."""
    block = data.get("repeats")
    if not isinstance(block, dict) or "n" not in block:
        errors.append(f"{path}: missing repeats block (regenerate with "
                      f"--repeats; single-shot wall-clocks are not "
                      f"accepted)")
        return
    _repeat_stats(block, path, errors)


def _metrics(data: Dict, path: str, errors: List[str],
             expect: Dict[str, str]) -> None:
    """Validate the registry-sourced ``metrics`` block.

    Unknown keys fail loudly; every value must be a non-negative number;
    ``expect`` maps metric keys to top-level fields they must agree with
    (the CI-claimed dispatch counts come from the metrics registry, so a
    drift between the two means the instrumentation lies).
    """
    block = data.get("metrics")
    if not isinstance(block, dict):
        errors.append(f"{path}: missing metrics block (regenerate with "
                      f"benchmarks/explore_bench.py)")
        return
    for key, val in sorted(block.items()):
        if key not in METRIC_KEYS:
            errors.append(f"{path}: unknown metric key {key!r} — add it to "
                          f"METRIC_KEYS in results/check_bench.py")
        elif not isinstance(val, (int, float)) or val < 0:
            errors.append(f"{path}: metrics[{key!r}]={val!r}, expected a "
                          f"non-negative number")
    for key, field in expect.items():
        if key in block and block[key] != data.get(field):
            errors.append(f"{path}: metrics[{key!r}]={block[key]!r} != "
                          f"{field}={data.get(field)!r}")


def check_explore_pnr(data: Dict, path: str, errors: List[str]) -> str:
    """Batched pnr must beat the serial loop and never add dispatches."""
    _manifest(data, path, errors)
    _repeats(data, path, errors)
    _ratio(data, path, "speedup", errors)
    if data.get("grouped_dispatches", 0) > data.get("serial_dispatches", 0):
        errors.append(f"{path}: grouped used more dispatches than serial")
    _metrics(data, path, errors,
             expect={"pnr_dispatch": "grouped_dispatches"})
    return (f"speedup={data.get('speedup')}x "
            f"({data.get('serial_dispatches')}->"
            f"{data.get('grouped_dispatches')} dispatches)")


def check_explore_sim(data: Dict, path: str, errors: List[str]) -> str:
    """Batched schedule/simulate must beat serial AND stay bit-identical."""
    _manifest(data, path, errors)
    _repeats(data, path, errors)
    _ratio(data, path, "speedup", errors)
    _flag(data, path, "bit_identical", errors)
    _flag(data, path, "ii_identical", errors)
    _flag(data, path, "verified", errors)
    _metrics(data, path, errors,
             expect={"sim_dispatch": "grouped_sim_dispatches",
                     "sched_group": "grouped_sched_groups"})
    return (f"speedup={data.get('speedup')}x "
            f"({data.get('serial_compiles')}->"
            f"{data.get('grouped_sim_dispatches')} dispatches, bit-exact)")


def check_pnr_bench(data: Dict, path: str, errors: List[str]) -> str:
    """Delta scoring must stay bit-identical to full recompute at every
    size (the delta-vs-full *speedup* is only gated at sizes where it is
    not smoke-budget noise)."""
    _manifest(data, path, errors)
    _repeats(data, path, errors)
    sizes = data.get("sizes", [])
    if not sizes:
        errors.append(f"{path}: no sizes[] entries")
    for s in sizes:
        where = f"{path}:{s.get('rows')}x{s.get('cols')}"
        if isinstance(s.get("repeats"), dict):
            _repeat_stats(s["repeats"], where, errors)
        else:
            errors.append(f"{where}: missing per-size repeats block")
        if s.get("bit_identical") is not True:
            errors.append(f"{path}: {s.get('rows')}x{s.get('cols')} "
                          f"delta/full not bit-identical")
        if s.get("n_cells", 0) >= 200:       # >= 16x16: delta must win
            _ratio(s, f"{path}:{s.get('rows')}x{s.get('cols')}", "speedup",
                   errors)
    a64 = data.get("anneal64")
    if a64 is not None and a64.get("completed") is not True:
        errors.append(f"{path}: 64x64 anneal did not complete")
    return f"{len(sizes)} sizes bit-identical"


HIER_LEVELS = ("cluster", "detail", "deblock", "final")
#: hierarchical must beat flat wall-clock from this array size up; below
#: it the two-level overhead legitimately dominates
HIER_SPEEDUP_ROWS = 128


def check_pnr_bench_v3(data: Dict, path: str, errors: List[str]) -> str:
    """v2's gates plus the hierarchical section: every placement must
    complete, delta/full must stay bit-identical at *every level*,
    cluster_grid=1 must reproduce the flat placer, and hierarchical must
    beat flat wall-clock at >= HIER_SPEEDUP_ROWS."""
    base = check_pnr_bench(data, path, errors)
    hier = data.get("hier", [])
    if not hier:
        errors.append(f"{path}: no hier[] entries")
    for h in hier:
        where = f"{path}:hier:{h.get('rows')}x{h.get('cols')}"
        if h.get("completed") is not True:
            errors.append(f"{where}: placement did not complete")
        levels = h.get("bit_identical_levels")
        if not isinstance(levels, dict):
            errors.append(f"{where}: missing bit_identical_levels")
        else:
            for lvl in HIER_LEVELS:
                if levels.get(lvl) is not True:
                    errors.append(f"{where}: level {lvl!r} delta/full not "
                                  f"bit-identical "
                                  f"({levels.get(lvl)!r})")
        if isinstance(h.get("repeats"), dict):
            _repeat_stats(h["repeats"], where, errors)
        else:
            errors.append(f"{where}: missing per-size repeats block")
        if h.get("rows", 0) >= HIER_SPEEDUP_ROWS and "flat_wall_s" in h:
            _ratio(h, where, "speedup_vs_flat", errors)
    c1 = data.get("hier_cluster1")
    if not isinstance(c1, dict) or c1.get("cluster1_identical") is not True:
        errors.append(f"{path}: hier_cluster1 check missing or false")
    return (f"{base}; {len(hier)} hier sizes level-identical, "
            f"cluster1 == flat")


def check_serve(data: Dict, path: str, errors: List[str]) -> str:
    """Concurrent serving must beat serial clients, stay bit-identical
    to solo runs (the serving guarantee), and amortize dispatches: N
    overlapping clients must cost < 1.5x a *single* union client's
    dispatch count and never more than serving them serially."""
    _manifest(data, path, errors)
    _repeats(data, path, errors)
    _ratio(data, path, "speedup", errors)
    _flag(data, path, "bit_identical", errors)
    _ratio(data, path, "cache_speedup", errors, floor=10.0)
    n = data.get("n_clients")
    if not isinstance(n, int) or n < 4:
        errors.append(f"{path}: n_clients={n!r}, expected >= 4")
    single = data.get("single_dispatches", 0)
    batched = data.get("batched_dispatches")
    if not isinstance(batched, (int, float)) or batched > 1.5 * single:
        errors.append(f"{path}: batched_dispatches={batched!r} exceeds "
                      f"1.5x single client's {single!r}")
    if batched is not None and batched > data.get("serial_dispatches", 0):
        errors.append(f"{path}: batched serving used more dispatches than "
                      f"serial clients")
    _metrics(data, path, errors, expect={})
    block = data.get("metrics", {})
    if isinstance(block, dict):
        reqs = block.get("serve_requests", 0)
        if isinstance(n, int) and reqs < n:
            errors.append(f"{path}: metrics[serve_requests]={reqs!r} < "
                          f"n_clients={n!r}")
    return (f"speedup={data.get('speedup')}x, {n} clients at "
            f"{data.get('dispatch_ratio')}x one client's dispatches, "
            f"bit-exact, cache {data.get('cache_speedup')}x")


CHECKS = {
    "explore_pnr_batch": check_explore_pnr,
    "explore_sim_batch": check_explore_sim,
    "pnr_bench/v2": check_pnr_bench,
    "pnr_bench/v3": check_pnr_bench_v3,
    "serve_bench/v1": check_serve,
}


def check_failures_block(data: dict, path: str,
                         errors: List[str]) -> None:
    """Committed artifacts must come from clean runs: a ``failures``
    block, when present, must be an empty list — a benchmark measured on
    a degraded (fault-isolated) run is not a performance contract."""
    if "failures" not in data:
        return          # pre-robustness artifacts carry no block
    block = data["failures"]
    if not isinstance(block, list):
        errors.append(f"{path}: failures block is {type(block).__name__}, "
                      f"expected a list")
    elif block:
        stages = sorted({str(f.get("stage", "?")) for f in block
                         if isinstance(f, dict)})
        errors.append(f"{path}: artifact produced by a degraded run — "
                      f"{len(block)} StageFailure row(s) in stages "
                      f"{stages}; benchmarks must be measured clean")


def check_file(path: str, errors: List[str]) -> None:
    with open(path) as f:
        data = json.load(f)
    kind = data.get("bench") or data.get("schema")
    checker = CHECKS.get(kind)
    if checker is None:
        errors.append(f"{path}: unknown benchmark kind {kind!r} — add a "
                      f"gate rule to results/check_bench.py")
        return
    before = len(errors)
    check_failures_block(data, path, errors)
    summary = checker(data, path, errors)
    status = "OK " if len(errors) == before else "FAIL"
    print(f"  {status} {path:<40} [{kind}] {summary}")


def main(argv: List[str]) -> int:
    paths = argv or sorted(glob.glob(
        os.path.join(os.path.dirname(__file__) or ".", "BENCH_*.json")))
    if not paths:
        print("bench gate: no BENCH_*.json artifacts found", file=sys.stderr)
        return 2
    errors: List[str] = []
    print(f"bench gate: checking {len(paths)} artifact(s)")
    for path in paths:
        check_file(path, errors)
    if errors:
        print(f"\nbench gate FAILED ({len(errors)} violation(s)):",
              file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
