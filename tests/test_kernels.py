"""Per-kernel shape/dtype sweeps vs. the ref.py pure-jnp oracles
(interpret=True executes the Pallas bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphir import pattern_from_spec
from repro.kernels import (attention, fused_pe_apply, matmul_fused,
                           selective_scan)
from repro.kernels.ref import (ref_attention, ref_gemm_pe, ref_mamba_scan,
                               ref_pe)

RNG = np.random.default_rng(42)

PE_PATTERNS = {
    "muladd": pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))]),
    "conv_relu": pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1)),
                                    ("const", ()), ("max", (1, 2))]),
    "harris_resp": pattern_from_spec([("mul", (-1, -1)), ("mul", (-1, -1)),
                                      ("sub", (0, 1)), ("abs", (2,))]),
    "swiglu_core": pattern_from_spec([("sigmoid", (-1,)), ("mul", (0, -1)),
                                      ("mul", (1, -1))]),
}


@pytest.mark.parametrize("name", sorted(PE_PATTERNS))
@pytest.mark.parametrize("shape", [(16, 16), (33, 77), (128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pe_fused_sweep(name, shape, dtype):
    pat = PE_PATTERNS[name]
    from repro.graphir.graph import free_in_ports
    n_in = len(free_in_ports(pat))
    xs = [jnp.asarray(RNG.uniform(-1.5, 1.5, shape), dtype)
          for _ in range(n_in)]
    got = fused_pe_apply(pat, *xs, block=(64, 128), interpret=True)
    exp = ref_pe(pat, *[np.asarray(x, np.float64) for x in xs])
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float64), exp,
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("s,hq,hkv,d", [(128, 4, 4, 32), (256, 4, 2, 32),
                                        (192, 8, 2, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(s, hq, hkv, d, causal):
    q = jnp.asarray(RNG.normal(size=(2, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, hkv, s, d)), jnp.float32)
    got = attention(q, k, v, causal=causal, bq=64, bk=64, interpret=True)
    exp = ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (48, 0.0), (0, 30.0),
                                            (64, 20.0)])
def test_flash_attention_window_softcap(window, softcap):
    s, hq, hkv, d = 256, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(1, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, hkv, s, d)), jnp.float32)
    got = attention(q, k, v, causal=True, window=window, softcap=softcap,
                    bq=64, bk=64, interpret=True)
    exp = ref_attention(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    s, hq, hkv, d = 128, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(1, hq, s, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, hkv, s, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, hkv, s, d)), jnp.bfloat16)
    got = attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    exp = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("s,d,n", [(64, 32, 4), (96, 64, 8), (128, 128, 16)])
def test_mamba_scan_sweep(s, d, n):
    b = 2
    a = jnp.asarray(RNG.uniform(0.6, 0.999, (b, s, d, n)), jnp.float32)
    bx = jnp.asarray(RNG.normal(size=(b, s, d, n)) * 0.1, jnp.float32)
    c = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    got = selective_scan(a, bx, c, bs=32, bd=32, interpret=True)
    exp = ref_mamba_scan(a, bx, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (100, 70, 50),
                                   (256, 128, 192)])
def test_gemm_plain_sweep(m, k, n):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    got = matmul_fused(x, w, bm=64, bn=64, bk=64, interpret=True)
    exp = ref_gemm_pe(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_gemm_bias_relu_epilogue():
    epi = pattern_from_spec([("add", (-1, -1)), ("const", ()),
                             ("max", (0, 1))])
    x = jnp.asarray(RNG.normal(size=(100, 70)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(70, 50)), jnp.float32)
    bias = jnp.asarray(RNG.normal(size=(50,)), jnp.float32)
    got = matmul_fused(x, w, bias, epilogue=epi, extra_kinds=("vec",),
                       bm=64, bn=64, bk=64, interpret=True)
    exp = ref_gemm_pe(x, w, bias, epilogue=epi, extra_kinds=("vec",))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_gemm_residual_epilogue():
    epi = pattern_from_spec([("add", (-1, -1))])      # acc + residual
    x = jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)
    res = jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)
    got = matmul_fused(x, w, res, epilogue=epi, extra_kinds=("full",),
                       bm=32, bn=32, bk=32, interpret=True)
    exp = ref_gemm_pe(x, w, res, epilogue=epi, extra_kinds=("full",))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_mined_pattern_to_kernel_end_to_end():
    """DSE output drives kernel generation: mine the conv app, take the top
    subgraph, generate the fused kernel, check against the oracle."""
    from repro.core import MiningConfig, mine_and_rank
    from repro.graphir import trace_scalar

    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c
    g = trace_scalar(conv4, ["i0", "i1", "i2", "i3",
                             "w0", "w1", "w2", "w3", "c"])
    ranked = mine_and_rank(g, MiningConfig(min_support=2,
                                           max_pattern_nodes=4))
    pat = ranked[0].pattern
    from repro.graphir.graph import free_in_ports
    n_in = len(free_in_ports(pat))
    xs = [jnp.asarray(RNG.uniform(0.5, 1.5, (32, 64)), jnp.float32)
          for _ in range(n_in)]
    got = fused_pe_apply(pat, *xs, block=(32, 64), interpret=True)
    exp = ref_pe(pat, *[np.asarray(x, np.float64) for x in xs])
    outs = got if isinstance(got, tuple) else (got,)
    exps = exp if isinstance(exp, tuple) else (exp,)
    for o, e in zip(outs, exps):
        np.testing.assert_allclose(np.asarray(o, np.float64), e, rtol=1e-5)
