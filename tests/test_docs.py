"""Docs stay true: the link/anchor gate passes, and the CLI flag surface
and the documentation never drift apart.

The drift test is two-directional: every flag argparse defines must be
documented in docs/pipeline-reference.md, and every ``--flag`` the docs
mention in a CLI section must actually exist in that CLI.  This is the
regression test for the class of bug where a flag is added (or renamed)
and the reference keeps describing the old world.
"""

import importlib.util
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"
REFERENCE = DOCS / "pipeline-reference.md"

_ADD_ARG = re.compile(r"add_argument\(\s*\"(--[\w-]+)\"")
_FLAG = re.compile(r"(--[a-z][\w-]*)")


def _source_flags(module_path: Path) -> set:
    """Every long option argparse defines in one CLI module."""
    flags = set(_ADD_ARG.findall(module_path.read_text(encoding="utf-8")))
    assert flags, f"no add_argument calls found in {module_path}"
    return flags


def _doc_sections(path: Path) -> dict:
    """Markdown split into {heading: body} on ## headings."""
    sections = {}
    current, lines = "_preamble", []
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.startswith("## "):
            sections[current] = "\n".join(lines)
            current, lines = line[3:].strip(), []
        else:
            lines.append(line)
    sections[current] = "\n".join(lines)
    return sections


def _cli_section(sections: dict, needle: str) -> str:
    hits = [body for title, body in sections.items() if needle in title]
    assert hits, f"no section titled with {needle!r} in {REFERENCE}"
    return "\n".join(hits)


def test_docs_link_gate():
    """python results/check_docs.py passes (same gate CI runs)."""
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "results" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.main([]) == 0


def test_docs_exist():
    for name in ("architecture.md", "pipeline-reference.md",
                 "placement.md", "observability.md"):
        assert (DOCS / name).is_file(), f"missing docs/{name}"


def test_explore_flags_all_documented():
    flags = _source_flags(REPO / "src" / "repro" / "explore" / "__main__.py")
    doc = REFERENCE.read_text(encoding="utf-8")
    undocumented = {f for f in flags if f not in doc}
    assert not undocumented, (
        f"explore CLI flags missing from docs/pipeline-reference.md: "
        f"{sorted(undocumented)}")


def test_serve_flags_all_documented():
    flags = _source_flags(REPO / "src" / "repro" / "serve" / "__main__.py")
    doc = REFERENCE.read_text(encoding="utf-8")
    undocumented = {f for f in flags if f not in doc}
    assert not undocumented, (
        f"serve CLI flags missing from docs/pipeline-reference.md: "
        f"{sorted(undocumented)}")


def test_documented_explore_flags_exist():
    """Every --flag named in the explore CLI section is a real flag."""
    flags = _source_flags(REPO / "src" / "repro" / "explore" / "__main__.py")
    section = _cli_section(_doc_sections(REFERENCE), "repro.explore")
    phantom = set(_FLAG.findall(section)) - flags
    assert not phantom, (
        f"docs/pipeline-reference.md documents explore flags that don't "
        f"exist: {sorted(phantom)}")


def test_documented_serve_flags_exist():
    flags = _source_flags(REPO / "src" / "repro" / "serve" / "__main__.py")
    section = _cli_section(_doc_sections(REFERENCE), "repro.serve")
    phantom = set(_FLAG.findall(section)) - flags
    assert not phantom, (
        f"docs/pipeline-reference.md documents serve flags that don't "
        f"exist: {sorted(phantom)}")


def test_readme_flags_exist():
    """--flags mentioned anywhere in the README exist in some CLI."""
    explore = _source_flags(
        REPO / "src" / "repro" / "explore" / "__main__.py")
    serve = _source_flags(REPO / "src" / "repro" / "serve" / "__main__.py")
    known = explore | serve
    text = (REPO / "README.md").read_text(encoding="utf-8")
    # link targets (anchor slugs like #cli-python--m-reproserve) are not
    # flag mentions
    text = re.sub(r"(?<=\])\([^)]*\)", "", text)
    # only prose/backtick mentions; strip fenced code blocks of pytest etc.
    phantom = {f for f in _FLAG.findall(text)
               if f not in known and f not in ("--smoke",)} - {
        # pytest/pip options shown in the quick start are not our CLIs
        "--upgrade"}
    phantom = {f for f in phantom if f not in ("-m",)}
    assert not phantom, f"README mentions unknown flags: {sorted(phantom)}"


def test_config_fields_all_documented():
    """Every ExploreConfig / FabricOptions field appears in the
    reference's tables."""
    from dataclasses import fields

    from repro.explore import ExploreConfig
    from repro.fabric import FabricOptions

    doc = REFERENCE.read_text(encoding="utf-8")
    missing = [f.name for cls in (ExploreConfig, FabricOptions)
               for f in fields(cls) if f"`{f.name}`" not in doc]
    assert not missing, (
        f"config fields missing from docs/pipeline-reference.md: {missing}")


def test_epilog_references_docs_not_readme_sections():
    """The CLI epilog must not point at README sections that moved."""
    src = (REPO / "src" / "repro" / "explore" /
           "__main__.py").read_text(encoding="utf-8")
    assert 'README "' not in src, (
        "explore CLI epilog references a README section; point it at "
        "docs/ instead")
