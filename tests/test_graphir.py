"""Graph IR: tracing, interpretation, canonical labels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphir import (Graph, interpret, pattern_from_spec, trace_fn,
                           trace_scalar)
from repro.graphir.graph import free_in_ports, sink_nodes
from repro.graphir.symtrace import fmax, fsel, fshr


def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
    return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c


NAMES = ["i0", "i1", "i2", "i3", "w0", "w1", "w2", "w3", "c"]


def test_scalar_trace_matches_eval():
    g = trace_scalar(conv4, NAMES)
    rng = np.random.default_rng(0)
    for _ in range(5):
        vals = {n: float(rng.normal()) for n in NAMES}
        out = interpret(g, vals)
        assert np.allclose(out[0], conv4(*[vals[n] for n in NAMES]))


def test_scalar_trace_structure():
    g = trace_scalar(conv4, NAMES)
    hist = g.op_histogram()
    assert hist["mul"] == 4 and hist["add"] == 4
    assert hist["input"] == 9


def test_trace_with_sel_and_shift():
    def f(a, b):
        return fsel(a > b, fshr(a + b, 1.0), fmax(a, b))
    g = trace_scalar(f, ["a", "b"])
    for a, b in [(1.0, 5.0), (5.0, 1.0), (2.0, 2.0)]:
        out = interpret(g, {"a": a, "b": b})[0]
        expect = max(a, b) if a > b else (a + b) / 2
        assert np.allclose(out, expect)


def test_jaxpr_trace_rmsnorm():
    def rms(x, w):
        v = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * (1.0 / jnp.sqrt(v + 1e-6)) * w
    g = trace_fn(rms, jnp.ones((4, 8)), jnp.ones((8,)))
    hist = g.op_histogram()
    assert hist.get("mul", 0) >= 3
    assert hist.get("rsum", 0) == 1 or hist.get("rmean", 0) == 1
    assert "sqrt" in hist or "rsqrt" in hist


def test_jaxpr_trace_inlines_custom_jvp():
    g = trace_fn(jax.nn.silu, jnp.ones((4,)))
    assert "sigmoid" in g.op_histogram()
    assert "opaque" not in g.op_histogram()


def test_canonical_label_isomorphism_invariance():
    g1 = pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))])
    # same graph built in a different node order
    g2 = Graph()
    b = g2.add_node("add")
    a = g2.add_node("mul")
    g2.add_edge(a, b, 1)  # commutative: port collapses
    assert g1.canonical_label() == g2.canonical_label()


def test_canonical_label_distinguishes():
    g1 = pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))])
    g2 = pattern_from_spec([("add", (-1, -1)), ("mul", (0, -1))])
    assert g1.canonical_label() != g2.canonical_label()


def test_noncommutative_ports_matter():
    g1 = pattern_from_spec([("mul", (-1, -1)), ("sub", (0, -1))])   # m - ?
    g2 = Graph()
    m = g2.add_node("mul")
    s = g2.add_node("sub")
    g2.add_edge(m, s, 1)                                            # ? - m
    assert g1.canonical_label() != g2.canonical_label()


def test_free_ports_and_sinks():
    g = pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))])
    free = free_in_ports(g)
    assert len(free) == 3          # mul has 2, add has 1
    assert sink_nodes(g) == [1]


def test_topo_order_cycle_detection():
    g = Graph()
    a = g.add_node("add")
    b = g.add_node("add")
    g.add_edge(a, b, 0)
    g.add_edge(b, a, 0)
    with pytest.raises(ValueError):
        g.topo_order()
