"""Explorer-as-a-service: batching, caching, backpressure, containment.

The serving layer's contract under test:

* bit-identity — a request's records are byte-identical whether served
  solo, batched with strangers, coalesced, or answered from cache;
* amortization — N overlapping clients cost one union run's JAX
  dispatches, not N solo runs' (asserted via the metrics registry);
* bounded admission — a full queue sheds load (``QueueFull``) or
  applies backpressure, never grows without bound;
* containment — one poisoned request degrades to its own StageFailure
  rows; batchmates stay bit-identical to their healthy solo runs.
"""

import asyncio
import json

import pytest

from faults import armed
from repro.core.mining import MiningConfig
from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec
from repro.graphir import trace_scalar
from repro.obs.metrics import MetricsRegistry
from repro.serve import (ExploreService, ProtocolError, QueueFull,
                         ServeRequest, encode_request, parse_request_line,
                         request_key)


def _conv():
    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c
    return trace_scalar(conv4, ["i0", "i1", "i2", "i3",
                                "w0", "w1", "w2", "w3", "c"])


def _fir():
    def fir4(i0, i1, i2, i3, w0, w1, w2, w3):
        return ((i0 * w0) + (i1 * w1)) + ((i2 * w2) + (i3 * w3))
    return trace_scalar(fir4, ["i0", "i1", "i2", "i3",
                               "w0", "w1", "w2", "w3"])


def _blur():
    def blur4(a, b, c, d, w):
        return ((a + b) + (c + d)) * w
    return trace_scalar(blur4, ["a", "b", "c", "d", "w"])


#: mine..map only — no fabric, no JAX: cheap scheduling-behavior cases
LIGHT_CFG = ExploreConfig(
    mode="per_app", mining=MiningConfig(min_support=2, max_pattern_nodes=5),
    max_merge=2)

#: the full pipeline on a 4x4 fabric — the amortization/bit-identity case
FABRIC_CFG = LIGHT_CFG.replace(
    fabric=FabricOptions(spec=FabricSpec(rows=4, cols=4),
                         chains=2, sweeps=4, simulate=True))


def _solo_lines(apps, cfg):
    res = Explorer(apps, cfg).run()
    return [json.dumps(r.to_dict()) for r in res.records()]


def _dispatches(stats):
    return stats["pnr_dispatch"] + stats["sim_dispatch"]


# ---------------------------------------------------------------------------
# bit-identity + cross-request amortization (the tentpole guarantee)
# ---------------------------------------------------------------------------
def test_concurrent_clients_bit_identical_and_amortized():
    conv, fir, blur = _conv(), _fir(), _blur()
    clients = [("r1", {"conv": conv}),
               ("r2", {"conv": conv, "fir": fir}),
               ("r3", {"fir": fir, "blur": blur})]
    solo = {}
    solo_dispatches = 0
    for rid, apps in clients:
        ex = Explorer(apps, FABRIC_CFG)
        res = ex.run()
        solo[rid] = [json.dumps(r.to_dict()) for r in res.records()]
        solo_dispatches += _dispatches(ex.stats)
    union_ex = Explorer({"conv": conv, "fir": fir, "blur": blur},
                        FABRIC_CFG)
    union_ex.run()
    union_dispatches = _dispatches(union_ex.stats)

    async def go():
        async with ExploreService(max_batch_apps=3, max_wait_ms=200,
                                  queue_limit=8) as svc:
            resps = await asyncio.gather(*[
                svc.explore(rid, apps, FABRIC_CFG)
                for rid, apps in clients])
            return resps, svc.metrics

    resps, metrics = asyncio.run(go())
    for (rid, _apps), resp in zip(clients, resps):
        assert resp.ok, f"{rid}: {resp.error}"
        assert resp.record_lines() == solo[rid], \
            f"{rid}: batched records != solo records"
        assert not resp.failures
    stats = metrics.view()
    served = _dispatches(stats)
    # all three clients ride ONE union run: same dispatch count as a
    # single client exploring the union, strictly fewer than solo x3
    assert served == union_dispatches
    assert served < solo_dispatches
    assert stats["mine"] == 3                 # each unique app mined once
    assert metrics.counter("serve.batches") == 1
    assert metrics.histogram("serve.batch_apps").vmax == 3


def test_cache_hit_fast_path():
    conv = _conv()

    async def go():
        async with ExploreService(max_batch_apps=4, max_wait_ms=10) as svc:
            first = await svc.explore("r1", {"conv": conv}, FABRIC_CFG)
            before = _dispatches(svc.metrics.view())
            again = await svc.explore("r2", {"conv": conv}, FABRIC_CFG)
            after = _dispatches(svc.metrics.view())
            return first, again, before, after, svc.metrics

    first, again, before, after, metrics = asyncio.run(go())
    assert first.ok and not first.cached
    assert again.ok and again.cached
    assert again.record_lines() == first.record_lines()
    assert after == before                    # zero JAX work on the hit
    assert metrics.counter("serve.cache_hit") == 1
    hist = metrics.histogram("serve.cache_hit_ms")
    assert hist.count == 1
    assert hist.vmax < 1000                   # ms, vs seconds for a run


def test_identical_inflight_requests_coalesce():
    conv = _conv()

    async def go():
        async with ExploreService(max_batch_apps=4, max_wait_ms=50) as svc:
            r1, r2 = await asyncio.gather(
                svc.explore("r1", {"conv": conv}, LIGHT_CFG),
                svc.explore("r2", {"conv": conv}, LIGHT_CFG))
            return r1, r2, svc.metrics

    r1, r2, metrics = asyncio.run(go())
    assert r1.ok and r2.ok
    assert r1.record_lines() == r2.record_lines()
    assert metrics.counter("serve.coalesced") == 1
    assert metrics.counter("mine") == 1       # one computation for both


# ---------------------------------------------------------------------------
# scheduler behavior (no fabric: cheap)
# ---------------------------------------------------------------------------
def test_deadline_flush_without_full_batch():
    conv = _conv()

    async def go():
        async with ExploreService(max_batch_apps=100,
                                  max_wait_ms=40) as svc:
            t0 = asyncio.get_event_loop().time()
            resp = await svc.explore("r1", {"conv": conv}, LIGHT_CFG)
            waited = asyncio.get_event_loop().time() - t0
            return resp, waited, svc.metrics

    resp, waited, metrics = asyncio.run(go())
    assert resp.ok and resp.records
    # the batch never filled (100 apps) — the deadline flushed it
    assert metrics.counter("serve.batches") == 1
    assert waited >= 0.03                     # sat out most of max_wait
    q = metrics.histogram("serve.time_in_queue_ms")
    assert q.count == 1 and q.vmax >= 30


def test_bounded_queue_backpressure():
    conv, fir = _conv(), _fir()

    async def go():
        # max_wait so long nothing flushes on its own: r1 parks in the
        # queue, filling it
        async with ExploreService(max_batch_apps=100, max_wait_ms=60_000,
                                  queue_limit=1) as svc:
            t1 = asyncio.ensure_future(
                svc.explore("r1", {"conv": conv}, LIGHT_CFG))
            await asyncio.sleep(0.05)         # let r1 into the queue
            assert svc.batcher.queue_depth == 1
            with pytest.raises(QueueFull):
                await svc.explore("r2", {"fir": fir}, LIGHT_CFG,
                                  block=False)
            rejected = svc.metrics.counter("serve.rejected")
            gauge = svc.metrics.gauge("serve.queue_depth")
            # draining on close flushes the parked ticket
            return t1, rejected, gauge, svc

    async def run():
        t1, rejected, gauge, svc = await go()
        r1 = await t1
        return r1, rejected, gauge, svc.metrics

    r1, rejected, gauge, metrics = asyncio.run(run())
    assert r1.ok and r1.records               # backpressured, not dropped
    assert rejected == 1
    assert gauge == 1                         # depth never exceeded limit
    assert metrics.gauge("serve.queue_depth") == 0   # drained


def test_blocking_submit_waits_out_full_queue():
    conv, fir = _conv(), _fir()

    async def go():
        async with ExploreService(max_batch_apps=1, max_wait_ms=10,
                                  queue_limit=1) as svc:
            resps = await asyncio.gather(*[
                svc.explore(f"r{i}", apps, LIGHT_CFG)
                for i, apps in enumerate(
                    [{"conv": conv}, {"fir": fir},
                     {"conv": conv, "fir": fir}])])
            return resps

    resps = asyncio.run(go())
    assert all(r.ok and r.records for r in resps)


def test_same_app_name_different_graph_defers_not_merges():
    conv, fir = _conv(), _fir()
    solo_conv = _solo_lines({"x": conv}, LIGHT_CFG)
    solo_fir = _solo_lines({"x": fir}, LIGHT_CFG)
    assert solo_conv != solo_fir

    async def go():
        async with ExploreService(max_batch_apps=4, max_wait_ms=30) as svc:
            r1, r2 = await asyncio.gather(
                svc.explore("r1", {"x": conv}, LIGHT_CFG),
                svc.explore("r2", {"x": fir}, LIGHT_CFG))
            return r1, r2, svc.metrics

    r1, r2, metrics = asyncio.run(go())
    assert r1.ok and r2.ok
    assert r1.record_lines() == solo_conv
    assert r2.record_lines() == solo_fir
    assert metrics.counter("serve.deferred_conflict") >= 1
    assert metrics.counter("serve.batches") == 2


# ---------------------------------------------------------------------------
# fault containment: a poisoned request degrades ALONE
# ---------------------------------------------------------------------------
def test_poisoned_request_degrades_alone():
    conv, fir, blur = _conv(), _fir(), _blur()
    solo_r1 = _solo_lines({"conv": conv}, LIGHT_CFG)
    solo_r3 = _solo_lines({"fir": fir}, LIGHT_CFG)

    async def go():
        async with ExploreService(max_batch_apps=3, max_wait_ms=100) as svc:
            # ctx-scoped injection: only the app named "poison" fails
            # (twice — the isolate retry path too), everyone else is
            # untouched even inside the same merged batch
            with armed("mine:exc:0+:app=poison",
                       "mine.retry:exc:0+:app=poison"):
                r1, r2, r3 = await asyncio.gather(
                    svc.explore("r1", {"conv": conv}, LIGHT_CFG),
                    svc.explore("r2", {"poison": blur}, LIGHT_CFG),
                    svc.explore("r3", {"fir": fir}, LIGHT_CFG))
            return r1, r2, r3, svc.metrics

    r1, r2, r3, metrics = asyncio.run(go())
    # the poisoned request: ok (not an exception), but degraded —
    # zero records, one structured StageFailure row naming its app
    assert r2.ok
    assert r2.records == []
    assert len(r2.failures) == 1
    assert r2.failures[0]["stage"] == "mine"
    assert r2.failures[0]["app"] == "poison"
    assert r2.failures[0]["error_type"] == "InjectedFault"
    # batchmates: healthy and bit-identical to their no-fault solo runs
    assert r1.ok and r1.record_lines() == solo_r1 and not r1.failures
    assert r3.ok and r3.record_lines() == solo_r3 and not r3.failures
    assert metrics.counter("serve.batches") == 1   # they DID share a batch


# ---------------------------------------------------------------------------
# wire protocol (no service needed)
# ---------------------------------------------------------------------------
def test_protocol_round_trip_and_request_key():
    conv, fir = _conv(), _fir()
    apps = {"conv": conv, "fir": fir}
    line = encode_request("r9", apps, LIGHT_CFG)
    req = parse_request_line(json.loads(json.dumps(line)))
    assert req.rid == "r9"
    assert sorted(req.apps) == ["conv", "fir"]
    assert req.config == LIGHT_CFG
    # decoded graphs are structurally identical: same request key
    assert req.key() == request_key(apps, LIGHT_CFG)
    # key is insertion-order independent but content sensitive
    assert request_key({"fir": fir, "conv": conv}, LIGHT_CFG) == req.key()
    assert request_key({"conv": conv}, LIGHT_CFG) != req.key()


def test_protocol_rejects_malformed_requests():
    conv = _conv()
    good = encode_request("r1", {"conv": conv}, LIGHT_CFG)
    for breakage in [
            lambda d: d.pop("id"),
            lambda d: d.update(id=7),
            lambda d: d.pop("config"),
            lambda d: d.update(op="decode"),
            lambda d: d.pop("apps"),
            lambda d: d.update(apps={"conv": {"nodes": "nope"}}),
            lambda d: d.update(suite="no-such-suite")]:
        bad = json.loads(json.dumps(good))
        breakage(bad)
        with pytest.raises(ProtocolError):
            parse_request_line(bad)


def test_malformed_line_gets_error_response_not_crash():
    async def go():
        async with ExploreService(max_wait_ms=10) as svc:
            bad_json = await svc.handle_line(b"{oops")
            bad_req = await svc.handle_line(json.dumps(
                {"id": "rX", "op": "explore"}))
            return bad_json, bad_req, svc.metrics

    bad_json, bad_req, metrics = asyncio.run(go())
    assert bad_json["ok"] is False and "bad JSON" in bad_json["error"]
    assert bad_req["ok"] is False and bad_req["id"] == "rX"
    assert metrics.counter("serve.protocol_errors") == 2
    assert metrics.counter("serve.requests") == 0   # never admitted


def test_serve_request_normalized_to_isolate():
    conv = _conv()
    raising = LIGHT_CFG.replace(on_error="raise")

    async def go():
        async with ExploreService(max_wait_ms=10) as svc:
            with armed("mine:exc:0+:app=conv",
                       "mine.retry:exc:0+:app=conv"):
                resp = await svc.explore("r1", {"conv": conv}, raising)
            return resp

    resp = asyncio.run(go())
    # on_error="raise" would have thrown; the service isolates instead
    assert resp.ok
    assert resp.records == []
    assert resp.failures and resp.failures[0]["app"] == "conv"
