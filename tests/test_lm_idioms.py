"""The paper's technique applied to the assigned LM architectures: mine
their layer graphs and generate fused kernels from the mined idioms
(DESIGN.md §4 arch-applicability)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.lm import lm_idiom_graphs
from repro.core import MiningConfig, mine_and_rank
from repro.core.merge import is_pe_pattern
from repro.graphir.graph import free_in_ports
from repro.kernels import fused_pe_apply
from repro.kernels.ref import ref_pe

CFG = MiningConfig(min_support=2, max_pattern_nodes=5, time_budget_s=15,
                   max_patterns_per_level=40)


@pytest.fixture(scope="module")
def graphs():
    return lm_idiom_graphs()


def test_lm_layers_trace(graphs):
    for name, g in graphs.items():
        assert g.num_compute_nodes() >= 5, name
        assert "opaque" not in g.op_histogram(), name


def test_lm_idioms_mined(graphs):
    """RMSNorm/SwiGLU/softcap/SSM chains show up as frequent subgraphs."""
    ranked = mine_and_rank(graphs["lm_dense"], CFG)
    assert ranked, "dense layer must yield frequent idioms"
    ops_seen = set()
    for m in ranked:
        ops_seen |= set(m.pattern.op_histogram())
    # the rsqrt-normalization and silu-gate chains are minable
    assert "mul" in ops_seen
    ranked_ssm = mine_and_rank(graphs["lm_ssm"], CFG)
    assert ranked_ssm


def test_mined_lm_idiom_becomes_kernel(graphs):
    """End-to-end: a mined LM idiom compiles into a fused PE kernel that
    matches the graph oracle."""
    rng = np.random.default_rng(0)
    for name in ("lm_dense", "lm_ssm"):
        ranked = [m for m in mine_and_rank(graphs[name], CFG)
                  if is_pe_pattern(m.pattern)]
        if not ranked:
            pytest.skip(f"no PE-compatible pattern for {name}")
        pat = ranked[0].pattern
        n_in = len(free_in_ports(pat))
        xs = [jnp.asarray(rng.uniform(0.1, 1.0, (16, 32)), jnp.float32)
              for _ in range(n_in)]
        got = fused_pe_apply(pat, *xs, block=(16, 32), interpret=True)
        exp = ref_pe(pat, *[np.asarray(x) for x in xs])
        gots = got if isinstance(got, tuple) else (got,)
        exps = exp if isinstance(exp, tuple) else (exp,)
        for g_, e_ in zip(gots, exps):
            np.testing.assert_allclose(np.asarray(g_, np.float64), e_,
                                       rtol=1e-5, atol=1e-6)
