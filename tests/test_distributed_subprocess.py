"""Multi-device behaviors that need fake device counts (subprocesses)."""

import os
import subprocess
import sys
import textwrap

import pytest

# each test spawns a fresh interpreter and compiles against 8 fake devices;
# excluded from the default tier-1 run (pytest -m slow to include)
pytestmark = pytest.mark.slow

ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(code: str, timeout: int = 420, quarantine: bool = False) -> str:
    """Run ``code`` in a fresh interpreter.

    A hung subprocess is killed at ``timeout`` and the test *skips* with
    the reason recorded — a fake-device compile that stalls on one
    runner must never wedge the whole suite.  ``quarantine=True`` (the
    env-dependent dryrun/compression tests) extends that to any nonzero
    exit: the failure is recorded in the skip reason instead of failing
    a run it says nothing about.  A genuinely broken build still fails
    the non-quarantined tests.
    """
    try:
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=ENV,
                             cwd="/root/repo", timeout=timeout)
    except subprocess.TimeoutExpired:
        pytest.skip(f"quarantined: subprocess exceeded {timeout}s "
                    f"(env-dependent fake-device compile; see ROADMAP)")
    if out.returncode != 0 and quarantine:
        tail = (out.stderr or out.stdout or "").strip().splitlines()
        pytest.skip(f"quarantined: env-dependent failure "
                    f"(rc={out.returncode}): {tail[-1] if tail else '?'}")
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


def test_compressed_allreduce_matches_mean():
    out = _run(quarantine=True, code="""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.sharding.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 512)),
                        jnp.float32)
        got = shard_map(lambda xl: compressed_psum(xl, ("data",)),
                        mesh=mesh, in_specs=(P("data"),),
                        out_specs=P("data"), check_rep=False)(x)
        # every shard receives the (quantized) mean over shards
        want = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                x.shape)
        err = float(jnp.max(jnp.abs(got - want)))
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert err <= scale * 1.5, (err, scale)
        print("COMPRESS_OK", err)
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_single_cell():
    """Deliverable (e) machinery: one real lower+compile against the
    256-chip mesh in a fresh process."""
    out = _run(timeout=560, quarantine=True, code="""
        import sys
        sys.argv = ["dryrun", "--arch", "llama3.2-1b",
                    "--shape", "decode_32k", "--mesh", "single"]
        from repro.launch.dryrun import main
        try:
            main()
        except SystemExit as e:
            assert not e.code, e.code
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in out
    assert "dry-run cells: 1 ok" in out


def test_shard_map_moe_under_mesh():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.models.config import MoEConfig
        from repro.models.moe import moe_mlp, moe_mlp_shardmap
        moe = MoEConfig(n_experts=8, top_k=2, d_expert=16,
                        capacity_factor=8.0)
        rng = np.random.default_rng(0)
        d = 32
        params = {
          "w_router": jnp.asarray(rng.normal(size=(d, 8)) * .5, jnp.float32),
          "wg": jnp.asarray(rng.normal(size=(8, d, 16)) * .2, jnp.float32),
          "wu": jnp.asarray(rng.normal(size=(8, d, 16)) * .2, jnp.float32),
          "wd": jnp.asarray(rng.normal(size=(8, 16, d)) * .2, jnp.float32),
        }
        x = jnp.asarray(rng.normal(size=(4, 16, d)), jnp.float32)
        y1 = moe_mlp(x, params, moe)
        y2 = jax.jit(lambda x: moe_mlp_shardmap(x, params, moe, mesh,
                                                ("data",)))(x)
        err = float(jnp.max(jnp.abs(y1 - y2)))
        assert err < 1e-4, err
        print("MOE_OK", err)
    """)
    assert "MOE_OK" in out
