"""Serving engine + sharding-spec structure + HLO cost analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.models.model import init_cache
from repro.serve import Request, ServeEngine
from repro.sharding import batch_pspecs, cache_pspecs, param_pspecs


def test_serve_engine_batched_requests():
    cfg = get_config("llama3.2-1b").reduced(n_layers=1, d_model=32,
                                            d_ff=64, vocab=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, smax=48)
    rng = np.random.default_rng(0)
    for rid in range(4):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, 8,
                                             dtype=np.int32), max_new=5))
    outs = eng.run(max_steps=64)
    assert len(outs) == 4
    for rid, toks in outs.items():
        assert len(toks) == 5
        assert all(0 <= t < cfg.vocab for t in toks)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-moe-235b-a22b",
                                  "falcon-mamba-7b", "gemma3-27b",
                                  "llama-3.2-vision-90b"])
def test_param_pspecs_structure_and_divisibility(arch):
    from repro.models.transformer import param_shapes
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    specs = param_pspecs(cfg)
    flat_shapes = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_specs = {tuple(str(k) for k in p): s for p, s in
                  jax.tree_util.tree_flatten_with_path(
                      specs, is_leaf=lambda x: hasattr(x, "index"))[0]}
    assert len(flat_shapes) == len(flat_specs)
    for path, sds in flat_shapes:
        key = tuple(str(k) for k in path)
        spec = flat_specs[key]
        assert len(spec) <= len(sds.shape)
        for dim, axis in zip(sds.shape, tuple(spec)):
            if axis == "model":
                assert dim % 16 == 0, (key, sds.shape, spec)


@pytest.mark.parametrize("arch,batch", [("llama3.2-1b", 128),
                                        ("falcon-mamba-7b", 128),
                                        ("hymba-1.5b", 1),
                                        ("gemma3-27b", 1),
                                        ("llama-3.2-vision-90b", 128)])
def test_cache_pspecs_match_cache_structure(arch, batch):
    cfg = get_config(arch)
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, 64))
    specs = cache_pspecs(cfg, multi_pod=False, batch=batch)
    assert set(specs) == set(cache)
    for key, sds in cache.items():
        if key == "len":
            continue
        assert len(tuple(specs[key])) <= len(sds.shape), key


def test_hlo_cost_trip_weighting():
    """The analyzer must multiply scan bodies by their trip count."""
    from repro.launch.hlo_cost import analyze

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    lowered = jax.jit(jax.grad(f)).lower(
        jax.ShapeDtypeStruct((16, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32))
    cost = analyze(lowered.compile().as_text())
    # fwd: 16 x 2*8*64*64 = 1.05e6; bwd adds ~2x -> ~3.1e6 dot flops
    assert 2.0e6 < cost.flops < 8.0e6, cost.flops


@pytest.mark.slow
def test_gpipe_subprocess():
    """GPipe over 4 stages in a subprocess with 4 fake devices."""
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.sharding.pipeline import gpipe, stage_split
        mesh = jax.make_mesh((4,), ("pod",))
        L, D = 8, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1
        def stage_fn(params, x):   # params: (L/4, D, D)
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, params)
            return h
        apply = gpipe(stage_fn, mesh, axis="pod")
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, D))  # 6 micro
        y = apply(stage_split(ws, 4), x)
        # reference: run all layers sequentially per microbatch
        def ref_one(xm):
            h = xm
            for i in range(L):
                h = jnp.tanh(h @ ws[i])
            return h
        ref = jnp.stack([ref_one(x[i]) for i in range(6)])
        err = float(jnp.max(jnp.abs(y - ref)))
        assert err < 1e-5, err
        print("GPIPE_OK", err)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                         "PYTHONPATH": "src"},
                         cwd="/root/repo", timeout=300)
    assert "GPIPE_OK" in out.stdout, out.stdout + out.stderr
