"""Subgraph merging (Sec. III-C, Fig. 5) + application mapping (Sec. IV)."""

import numpy as np
import pytest

from repro.core import (Datapath, add_pattern, baseline_datapath,
                        map_application, single_op_pattern, validate_config)
from repro.core.clique import max_weight_clique
from repro.graphir import pattern_from_spec, trace_scalar


def test_merge_shares_units():
    """Two patterns using adders+const must share hardware (Fig. 5e)."""
    gA = pattern_from_spec([("const", ()), ("add", (0, -1)), ("add", (1, -1))])
    gB = pattern_from_spec([("const", ()), ("mul", (-1, -1)),
                            ("add", (1, -1)), ("add", (2, 0))])
    dp = Datapath()
    add_pattern(dp, gA, "A")
    units_after_a = len(dp.units)
    add_pattern(dp, gB, "B")
    # B adds only the multiplier; adders and const are merged
    assert len(dp.units) == units_after_a + 1
    assert len(dp.mux_ways()) >= 1          # at least one config mux appears


def test_merged_configs_execute_correctly():
    gA = pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))])
    gB = pattern_from_spec([("mul", (-1, -1)), ("sub", (0, -1)),
                            ("max", (1, -1))])
    dp = Datapath()
    cfgA = add_pattern(dp, gA, "A")
    cfgB = add_pattern(dp, gB, "B")
    for cfg in (cfgA, cfgB):
        ok, msg = validate_config(dp, cfg, trials=8)
        assert ok, msg


def test_merge_is_cheaper_than_disjoint():
    gA = pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))])
    gB = pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1)),
                            ("add", (1, -1))])
    merged = Datapath()
    add_pattern(merged, gA, "A")
    add_pattern(merged, gB, "B")
    disjoint = Datapath()
    add_pattern(disjoint, gA, "A")
    # build B without sharing by using a fresh datapath
    only_b = Datapath()
    add_pattern(only_b, gB, "B")
    assert merged.area_um2() < disjoint.area_um2() + only_b.area_um2()


def test_baseline_pe_structure():
    dp = baseline_datapath()
    units = sorted(u.unit for u in dp.units.values())
    assert "adder" in units and "multiplier" in units and "lut" in units
    # every config still validates through the muxes
    for name, cfg in list(dp.configs.items())[:6]:
        ok, msg = validate_config(dp, cfg)
        assert ok, (name, msg)


def test_max_weight_clique_exact():
    # triangle 0-1-2 with big weights plus isolated heavy vertex 3
    weights = [5.0, 4.0, 3.0, 10.0]
    adj = [{1, 2}, {0, 2}, {0, 1}, set()]
    best = max_weight_clique(weights, adj)
    assert sorted(best) == [0, 1, 2]          # 12 beats the single 10
    weights2 = [5.0, 4.0, 3.0, 13.0]
    assert max_weight_clique(weights2, adj) == [3]


def test_mapper_covers_everything():
    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c
    g = trace_scalar(conv4, ["i0", "i1", "i2", "i3",
                             "w0", "w1", "w2", "w3", "c"])
    dp = baseline_datapath({"add", "mul"})
    add_pattern(dp, pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))]),
                "sg:muladd")
    m = map_application(dp, g)
    assert not m.unmapped
    assert m.total_ops == g.num_compute_nodes() - \
        sum(1 for op in g.nodes.values() if op == "const")
    # non-overlap over hard (non-const) nodes
    seen = set()
    for inst in m.instances:
        assert not (inst.covered & seen)
        seen |= inst.covered
    # the merged config is actually used
    assert any(i.config == "sg:muladd" for i in m.instances)
    assert m.ops_per_pe > 1.0


def test_mapper_const_variants():
    from repro.graphir.symtrace import Tracer
    t = Tracer()
    x = t.input("x")
    t.output(x * 3.0)
    dp = baseline_datapath({"mul"})
    m = map_application(dp, t.graph)
    assert not m.unmapped
    assert m.instances[0].config in ("op:mul_c1", "op:mul")
