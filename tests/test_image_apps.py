"""Paper application suites: traced graphs match their scalar oracles on
real data, and have the structure Sec. V describes."""

import numpy as np
import pytest

from repro.apps import image, mlkernels
from repro.graphir import interpret


@pytest.mark.parametrize("name", sorted(image.APPS))
def test_traced_graph_matches_oracle_on_image(name):
    spec = image.APPS[name]
    g = image.build_graph(name)
    rng = np.random.default_rng(1)
    k = spec["window"]
    for _ in range(3):
        window = {n: float(v) for n, v in
                  zip(spec["inputs"], rng.uniform(0, 1023, k * k))}
        got = interpret(g, window)
        exp = spec["fn"](*[window[n] for n in spec["inputs"]])
        exps = exp if isinstance(exp, tuple) else (exp,)
        for o, e in zip(got, exps):
            np.testing.assert_allclose(o, e, rtol=1e-9)


@pytest.mark.parametrize("name", sorted(mlkernels.ML_APPS))
def test_ml_kernel_graph_matches_oracle(name):
    spec = mlkernels.ML_APPS[name]
    g = mlkernels.build_graph(name)
    rng = np.random.default_rng(2)
    vals = {n: float(v) for n, v in
            zip(spec["inputs"], rng.uniform(-2, 2, len(spec["inputs"])))}
    got = interpret(g, vals)
    exp = spec["fn"](*[vals[n] for n in spec["inputs"]])
    np.testing.assert_allclose(got[0], exp, rtol=1e-9)


def test_camera_is_most_complex():
    """Sec. V-A: camera pipeline is the most complex of the four apps."""
    sizes = {n: image.build_graph(n).num_compute_nodes()
             for n in image.APPS}
    assert max(sizes, key=sizes.get) == "camera"
    assert sizes["camera"] > 200      # paper: 221 ops per output pixel


def test_conv_kernel_is_mac_chain():
    g = mlkernels.build_graph("conv")
    hist = g.op_histogram()
    assert hist["mul"] == 18 and hist["add"] >= 17   # 2ch x 3x3 MACs
    assert hist["max"] == 1                           # ReLU


def test_gaussian_blur_end_to_end_image():
    img = np.arange(100, dtype=np.float64).reshape(10, 10)
    out = image.run_reference("gaussian", img)
    assert out.shape == (8, 8)
    # blur of a linear ramp stays a ramp away from borders
    assert np.all(np.diff(out[4]) > 0)
