"""Per-architecture smoke tests: reduced config, one forward + one train
step + prefill/decode on CPU; shapes asserted, no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import decode_step, forward, init_params, prefill
from repro.train import AdamWConfig, build_train_step, init_opt_state

KEY = jax.random.PRNGKey(0)
ARCHS = list_archs()


def _batch(cfg, b=2, s=16):
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"inputs": inputs,
             "targets": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.n_cross_layers:
        batch["enc"] = jax.random.normal(KEY, (b, cfg.encoder_len,
                                               cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits = forward(params, cfg, batch["inputs"], enc=batch.get("enc"))
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_updates(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    step = build_train_step(cfg, opt_cfg)
    batch = _batch(cfg)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt.step) == 1
    # at least one parameter moved
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits = forward(params, cfg, batch["inputs"], enc=batch.get("enc"))
    lp, cache = prefill(params, cfg, batch["inputs"], smax=24,
                        enc=batch.get("enc"))
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=1e-3, atol=1e-3)
    tok = (jax.random.normal(KEY, (2, cfg.d_model))
           if cfg.input_mode == "embeddings"
           else jnp.argmax(lp, -1).astype(jnp.int32))
    l2, cache2 = decode_step(params, cfg, tok, cache)
    assert l2.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(l2.astype(jnp.float32)).all())
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "falcon-mamba-7b",
                                  "hymba-1.5b"])
def test_decode_matches_teacher_forcing(arch):
    """Decoding token-by-token must reproduce the teacher-forced logits."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab)
    full = forward(params, cfg, toks)
    lp, cache = prefill(params, cfg, toks[:, :8], smax=16)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(full[:, 7], np.float32),
                               rtol=2e-2, atol=2e-2)   # bf16 compute path
    logits = lp
    for t in range(8, 12):
        logits, cache = decode_step(params, cfg, toks[:, t], cache)
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=2e-2, atol=2e-2)
