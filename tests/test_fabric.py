"""Deterministic-seed tests for the fabric place-and-route subsystem."""

import numpy as np
import pytest

from repro.apps import image_graphs
from repro.core import baseline_datapath, map_application
from repro.core.dse import app_ops
from repro.fabric import (FabricSpec, extract_netlist, place,
                          place_and_route, route_nets, synthetic_netlist)
from repro.fabric.place import anneal_jax, anneal_python, lower, \
    net_incidence
from repro.kernels.pnr_cost import (hpwl, hpwl_batched, hpwl_delta,
                                    hpwl_delta_pallas, hpwl_pallas,
                                    hpwl_reference, net_hpwl)

SPEC = FabricSpec(rows=8, cols=8)


@pytest.fixture(scope="module")
def harris():
    app = image_graphs()["harris"]
    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, "harris")
    netlist = extract_netlist(mapping, app, SPEC)
    return dp, mapping, app, netlist


# ---------------------------------------------------------------------------
# netlist
# ---------------------------------------------------------------------------
def test_netlist_const_folding_and_shape(harris):
    dp, mapping, app, nl = harris
    assert len(nl.pe_cells) == mapping.n_pes
    # consts are folded into PE constant registers: no cell carries one and
    # no net is driven by one
    const_nodes = {n for n, op in app.nodes.items() if op == "const"}
    for c in nl.io_cells:
        assert not (set(c.signals) & const_nodes)
    for n in nl.nets:
        assert n.signal not in const_nodes
        assert n.driver in nl.cells
        assert all(s in nl.cells for s in n.sinks)
        assert n.driver not in n.sinks
    # every net carries at least driver + one sink
    assert all(n.degree >= 2 for n in nl.nets)


def test_io_grouping_respects_capacity(harris):
    _, _, _, nl = harris
    for c in nl.io_cells:
        assert 1 <= len(c.signals) <= SPEC.io_capacity


# ---------------------------------------------------------------------------
# placement legality
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["python", "jax"])
def test_placement_legal(harris, backend):
    _, _, _, nl = harris
    pl = place(nl, SPEC, backend=backend, chains=4, sweeps=8, seed=1)
    coords = pl.coords
    # one cell per tile
    assert len(set(coords.values())) == len(coords)
    for cell in nl.pe_cells:
        assert SPEC.is_pe(coords[cell.name]), (cell.name, coords[cell.name])
    for cell in nl.io_cells:
        assert SPEC.is_io(coords[cell.name]), (cell.name, coords[cell.name])


def test_placement_deterministic(harris):
    _, _, _, nl = harris
    a = place(nl, SPEC, backend="jax", chains=4, sweeps=8, seed=3)
    b = place(nl, SPEC, backend="jax", chains=4, sweeps=8, seed=3)
    assert a.coords == b.coords and a.cost == b.cost


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------
def test_routing_connects_endpoints_within_capacity(harris):
    _, _, _, nl = harris
    pl = place(nl, SPEC, backend="jax", chains=8, sweeps=16, seed=0)
    rr = route_nets(nl, pl, SPEC)
    assert rr.success and rr.overflow == 0
    caps = SPEC.routing_edges()
    for e, u in rr.edge_usage.items():
        assert u <= caps[e], (e, u, caps[e])
    by_name = {n.name: n for n in rr.nets}
    for net in nl.nets:
        routed = by_name[net.name]
        # the routed tree must connect the placed driver to every sink
        reach = {pl.coords[net.driver]}
        frontier = True
        while frontier:
            frontier = False
            for (a, b) in routed.edges:
                if a in reach and b not in reach:
                    reach.add(b)
                    frontier = True
        for s in net.sinks:
            assert pl.coords[s] in reach, (net.name, s)
        assert set(routed.sink_hops) == {pl.coords[s] for s in net.sinks}
        assert all(h >= 1 for h in routed.sink_hops.values())


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def test_fabric_cost_monotone_in_wirelength(harris):
    from repro.fabric.cost import evaluate_fabric

    dp, mapping, app, nl = harris
    good = place(nl, SPEC, backend="jax", chains=8, sweeps=16, seed=0)
    bad = place(nl, SPEC, backend="python", chains=1, sweeps=1, seed=9,
                t0=50.0, t1=49.0)   # hot chain = near-random placement
    rg = route_nets(nl, good, SPEC)
    rb = route_nets(nl, bad, SPEC)
    assert rg.wirelength < rb.wirelength
    cg = evaluate_fabric(dp, mapping, nl, good, rg, SPEC)
    cb = evaluate_fabric(dp, mapping, nl, bad, rb, SPEC)
    # same netlist: PE and IO energy identical; routing energy scales
    # exactly with hops, so total energy is monotone in wirelength
    assert cg.pe_energy_pj == cb.pe_energy_pj
    assert cg.io_energy_pj == cb.io_energy_pj
    assert cb.route_energy_pj - cg.route_energy_pj == pytest.approx(
        SPEC.hop_energy_pj * (rb.wirelength - rg.wirelength))
    assert cg.total_energy_pj < cb.total_energy_pj
    assert cg.energy_per_op_pj < cb.energy_per_op_pj


# ---------------------------------------------------------------------------
# HPWL kernels
# ---------------------------------------------------------------------------
def test_hpwl_jax_matches_python_reference(harris):
    _, _, _, nl = harris
    problem = lower(nl, SPEC)
    rng = np.random.default_rng(7)
    for _ in range(5):
        slot_of = np.concatenate([
            rng.permutation(problem.n_pe_slots),
            problem.n_pe_slots + rng.permutation(problem.n_io_slots)])
        pos = problem.slot_xy[slot_of]
        want = hpwl_reference(pos, problem.net_pins, problem.net_mask)
        got = float(hpwl(pos, problem.net_pins, problem.net_mask))
        assert got == pytest.approx(want)
        got_pl = float(hpwl_pallas(pos, problem.net_pins, problem.net_mask,
                                   interpret=True))
        assert got_pl == pytest.approx(want)


def test_hpwl_batched_matches_per_chain(harris):
    _, _, _, nl = harris
    problem = lower(nl, SPEC)
    rng = np.random.default_rng(3)
    pos = np.stack([problem.slot_xy[np.concatenate([
        rng.permutation(problem.n_pe_slots),
        problem.n_pe_slots + rng.permutation(problem.n_io_slots)])]
        for _ in range(6)])
    batched = np.asarray(hpwl_batched(pos, problem.net_pins,
                                      problem.net_mask))
    for c in range(pos.shape[0]):
        assert batched[c] == pytest.approx(
            hpwl_reference(pos[c], problem.net_pins, problem.net_mask))


def test_jax_annealer_improves_over_initial(harris):
    import random

    from repro.fabric.place import _init_slots

    _, _, _, nl = harris
    problem = lower(nl, SPEC)
    slots, costs = anneal_jax(problem, chains=4, seed=0, sweeps=8)
    # reconstruct the chains' initial states (same seed stream as anneal_jax)
    rng = random.Random(0)
    init_costs = []
    for _ in range(4):
        pos0 = problem.slot_xy[_init_slots(problem, rng)]
        init_costs.append(hpwl_reference(pos0, problem.net_pins,
                                         problem.net_mask))
    for c in range(slots.shape[0]):
        # results are consistent: reported cost == HPWL of returned state
        pos = problem.slot_xy[slots[c]]
        assert float(costs[c]) == pytest.approx(
            hpwl_reference(pos, problem.net_pins, problem.net_mask))
        # best-so-far tracking can never end worse than the initial state
        assert float(costs[c]) <= init_costs[c]
    # and annealing actually improves at least the best chain
    assert float(min(costs)) < min(init_costs)
    py_slot, py_cost = anneal_python(problem, seed=0, sweeps=8)
    # both engines land in the same quality ballpark on this small problem
    assert min(costs) < 2.0 * py_cost + 1.0


# ---------------------------------------------------------------------------
# delta (incremental) move scoring
# ---------------------------------------------------------------------------
def test_net_incidence_table(harris):
    _, _, _, nl = harris
    p = lower(nl, SPEC)
    n_nets = p.net_pins.shape[0]
    table = p.ent_nets
    assert table.shape[0] == p.n_entities
    for e in range(p.n_entities):
        want = sorted(i for i in range(n_nets)
                      if e in p.net_pins[i][p.net_mask[i]])
        got = sorted(int(i) for i in table[e] if i < n_nets)
        assert got == want, e
    # padding entries are exactly N so out-of-range gathers drop them
    assert table.min() >= 0 and table.max() <= n_nets


@pytest.mark.parametrize("kernel", ["jnp", "pallas"])
def test_hpwl_delta_matches_full_recompute(harris, kernel):
    import jax.numpy as jnp

    _, _, _, nl = harris
    p = lower(nl, SPEC)
    n_nets = p.net_pins.shape[0]
    rng = np.random.default_rng(11)
    slot_of = np.concatenate([
        rng.permutation(p.n_pe_slots),
        p.n_pe_slots + rng.permutation(p.n_io_slots)]).astype(np.int32)
    pnc = np.asarray(net_hpwl(p.slot_xy[slot_of], p.net_pins, p.net_mask))
    k = p.ent_nets.shape[1]
    for _ in range(10):
        a, b = rng.integers(0, p.n_entities, 2)
        cand = slot_of.copy()
        cand[a], cand[b] = cand[b], cand[a]
        touched = np.full(2 * k, n_nets, np.int32)
        nets = sorted({int(i) for i in np.concatenate(
            [p.ent_nets[a], p.ent_nets[b]]) if i < n_nets})
        touched[:len(nets)] = nets
        if kernel == "jnp":
            new_vals, delta = hpwl_delta(
                jnp.asarray(p.slot_xy), jnp.asarray(cand),
                jnp.asarray(p.net_pins), jnp.asarray(p.net_mask),
                jnp.asarray(pnc), jnp.asarray(touched))
        else:
            new_vals, delta = hpwl_delta_pallas(
                jnp.asarray(p.slot_xy), jnp.asarray(slot_of),
                jnp.asarray(p.net_pins), jnp.asarray(p.net_mask),
                jnp.asarray(pnc), jnp.asarray(touched),
                jnp.int32(a), jnp.int32(b), interpret=True)
        want = hpwl_reference(p.slot_xy[cand], p.net_pins, p.net_mask)
        assert pnc.sum() + float(delta) == pytest.approx(want)
        # returned per-net values are the candidate costs of the touched nets
        cand_pnc = np.asarray(net_hpwl(p.slot_xy[cand], p.net_pins,
                                       p.net_mask))
        for t, i in enumerate(nets):
            assert float(new_vals[t]) == pytest.approx(cand_pnc[i])


def test_delta_full_bit_identical_16x16():
    """Deterministic regression: at 16x16 every (score_mode, hpwl_backend)
    combination accepts the same move sequence and returns bit-identical
    placements and costs."""
    spec = FabricSpec(rows=16, cols=16)
    p = lower(synthetic_netlist(spec, seed=2), spec)
    runs = {}
    for mode in ("delta", "full"):
        for hb in ("jnp", "pallas"):
            runs[(mode, hb)] = anneal_jax(p, chains=2, seed=7, sweeps=2,
                                          hpwl_backend=hb, score_mode=mode)
    ref_slots, ref_costs = runs[("full", "jnp")]
    for key, (slots, costs) in runs.items():
        assert np.array_equal(slots, ref_slots), key
        assert np.array_equal(costs, ref_costs), key
    # and the reported costs are real HPWLs of the returned states
    for c in range(ref_slots.shape[0]):
        assert float(ref_costs[c]) == pytest.approx(hpwl_reference(
            p.slot_xy[ref_slots[c]], p.net_pins, p.net_mask))


def test_place_rejects_unknown_score_mode(harris):
    _, _, _, nl = harris
    with pytest.raises(ValueError, match="score_mode"):
        place(nl, SPEC, score_mode="incremental")


def test_synthetic_netlist_is_deterministic_and_legal():
    spec = FabricSpec(rows=8, cols=8)
    a = synthetic_netlist(spec, seed=5)
    b = synthetic_netlist(spec, seed=5)
    assert [(n.name, n.driver, n.sinks) for n in a.nets] == \
           [(n.name, n.driver, n.sinks) for n in b.nets]
    assert len(a.pe_cells) <= spec.n_pe_tiles
    assert len(a.io_cells) <= spec.n_io_sites
    for n in a.nets:
        assert n.driver not in n.sinks and n.degree >= 2
        assert n.driver in a.cells
        assert all(s in a.cells for s in n.sinks)


# ---------------------------------------------------------------------------
# end to end + sizing
# ---------------------------------------------------------------------------
def test_spec_fit_grows_to_demand():
    s = FabricSpec(rows=2, cols=2)
    big = s.fit(30, 10)
    assert big.n_pe_tiles >= 30 and big.n_io_sites >= 10
    assert big.channel_width == s.channel_width
    assert s.fit(4, 8) is s


def test_place_and_route_end_to_end_auto_size(harris):
    dp, mapping, app, _ = harris
    pnr = place_and_route(dp, mapping, app, FabricSpec(rows=2, cols=2),
                          backend="python", chains=1, sweeps=8, seed=0)
    assert pnr.spec.n_pe_tiles >= mapping.n_pes
    assert pnr.routes.overflow == 0
    assert pnr.cost.energy_per_op_pj > 0
    assert 0 < pnr.cost.utilization <= 1.0
    assert pnr.cost.fmax_ghz > 0


def test_dse_fabric_integration():
    from repro.core.dse import PEVariant, evaluate_variants

    app = image_graphs()["gaussian"]
    dp = baseline_datapath(app_ops(app))
    v = PEVariant("PE1", dp)
    evaluate_variants([v], {"gaussian": app}, fabric=FabricSpec(8, 8),
                      fabric_backend="python", fabric_chains=1,
                      fabric_sweeps=8)
    c = v.costs["gaussian"]
    f = v.fabric_costs["gaussian"]
    assert c.fabric_energy_per_op_pj == pytest.approx(f.energy_per_op_pj)
    assert c.fabric_area_um2 == pytest.approx(f.fabric_area_um2)
    assert c.fabric_wirelength == f.wirelength_hops
    # array view adds interconnect: array e/op dominates PE-core e/op
    assert f.energy_per_op_pj > c.energy_per_op_pj
