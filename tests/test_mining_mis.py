"""Frequent-subgraph mining + MIS analysis (paper Sec. III-A/B, Figs. 3-4)."""

import numpy as np
import pytest

from repro.core import (MiningConfig, count_occurrences, find_embeddings,
                        maximal_independent_set, mine_frequent_subgraphs,
                        rank_by_mis)
from repro.graphir import pattern_from_spec, trace_scalar


def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
    return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c


NAMES = ["i0", "i1", "i2", "i3", "w0", "w1", "w2", "w3", "c"]


@pytest.fixture(scope="module")
def conv_graph():
    return trace_scalar(conv4, NAMES)


@pytest.fixture(scope="module")
def mined(conv_graph):
    cfg = MiningConfig(min_support=2, max_pattern_nodes=4)
    return rank_by_mis(mine_frequent_subgraphs(conv_graph, cfg))


def test_fig3b_mul_add_found(mined):
    """Paper Fig. 3b: mul->add occurs 4x... with MNI 3+ and MIS >= 3."""
    muladd = [m for m in mined
              if m.pattern.op_histogram() == {"mul": 1, "add": 1}]
    assert muladd, "mul->add pattern must be mined"
    assert muladd[0].occurrences >= 3
    assert muladd[0].mis_size >= 3


def test_fig3d_overlap_collapse(mined):
    """Paper Fig. 3d: add->add has overlapping occurrences; MIS halves."""
    addadd = [m for m in mined
              if m.pattern.op_histogram() == {"add": 2}]
    assert addadd
    m = addadd[0]
    assert m.occurrences == 3           # chain of 4 adds: 3 adjacent pairs
    assert m.mis_size == 2              # overlaps collapse to 2 (Fig. 4)


def test_support_verified_independently(mined, conv_graph):
    """Every mined pattern really occurs >= its reported count."""
    for m in mined[:10]:
        occ = count_occurrences(m.pattern, conv_graph)
        assert occ == m.occurrences


def test_ranking_is_by_mis(mined):
    sizes = [m.mis_size for m in mined]
    assert sizes == sorted(sizes, reverse=True)


def test_mis_basic_overlap():
    sets = [frozenset({1, 2}), frozenset({2, 3}), frozenset({3, 4}),
            frozenset({4, 5})]
    picked = maximal_independent_set(sets)
    chosen = [sets[i] for i in picked]
    # independence
    for i in range(len(chosen)):
        for j in range(i + 1, len(chosen)):
            assert not (chosen[i] & chosen[j])
    assert len(picked) == 2


def test_mis_disjoint_keeps_all():
    sets = [frozenset({i}) for i in range(7)]
    assert len(maximal_independent_set(sets)) == 7


def test_commutative_matching_counts_swapped_operands():
    """a*b + b*a style swaps must count as the same pattern."""
    from repro.graphir.symtrace import Tracer
    t = Tracer()
    a, b, c, d = [t.input(n) for n in "abcd"]
    t.output(a * b + c)      # mul feeds add port 0
    t.output(d + (a * c))    # mul feeds add port 1 (swapped)
    g = t.graph
    pat = pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))])
    assert count_occurrences(pat, g) == 2
