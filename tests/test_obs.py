"""The observability subsystem (repro.obs): span trees, Chrome export,
metrics registry, anneal/scheduler telemetry, post-pnr analyzer — and the
load-bearing invariant that turning any of it on changes zero bits."""

import json

import numpy as np
import pytest

from repro import obs
from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec
from repro.graphir import trace_scalar
from repro.obs import trace as trace_mod
from repro.obs.metrics import (CounterView, Histogram, MetricsRegistry,
                               global_registry, reset_global_registry)
from repro.obs.report import aggregate_stages, load_trace_rows, stage_table


@pytest.fixture
def tracer():
    """A process-global tracer that is always torn down."""
    trace_mod.disable()
    t = trace_mod.enable()
    yield t
    trace_mod.disable()


def conv_app():
    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c
    return trace_scalar(conv4, ["i0", "i1", "i2", "i3",
                                "w0", "w1", "w2", "w3", "c"])


def small_cfg(**kw):
    from repro.core import MiningConfig
    fabric = FabricOptions(spec=FabricSpec(rows=4, cols=4), chains=2,
                           sweeps=4, **{k: v for k, v in kw.items()
                                        if k in ("seed", "simulate")})
    return ExploreConfig(
        mode="per_app",
        mining=MiningConfig(min_support=2, max_pattern_nodes=5),
        max_merge=kw.get("max_merge", 2), fabric=fabric)


# ---------------------------------------------------------------------------
# span tree
# ---------------------------------------------------------------------------
def test_span_tree_nesting_and_paths(tracer):
    with obs.span("a", k=1):
        with obs.span("b"):
            pass
        with obs.span("c"):
            obs.event("m", x=2)
    walked = [(path, depth) for _, depth, path in tracer.iter_spans()]
    assert walked == [("a", 0), ("a/b", 1), ("a/c", 1), ("a/c/m", 2)]
    spans = {path: sp for sp, _, path in tracer.iter_spans()}
    assert spans["a"].attrs == {"k": 1}
    assert spans["a/c/m"].dur == 0.0                      # event: zero width
    assert spans["a"].t0 <= spans["a/b"].t0
    assert spans["a/b"].t1 <= spans["a/c"].t0 <= spans["a/c"].t1
    assert spans["a/c"].t1 <= spans["a"].t1
    assert tracer.span_names() == {"a", "b", "c", "m"}


def test_span_exception_safety(tracer):
    with pytest.raises(ValueError, match="boom"):       # never suppressed
        with obs.span("outer"):
            with obs.span("inner"):
                raise ValueError("boom")
    # both spans closed despite the raise; the error is recorded
    spans = {path: sp for sp, _, path in tracer.iter_spans()}
    assert set(spans) == {"outer", "outer/inner"}
    assert spans["outer/inner"].error == "ValueError: boom"
    assert not tracer._stack
    # the tracer still works afterwards
    with obs.span("after"):
        pass
    assert "after" in tracer.span_names()


def test_disabled_tracing_is_free_and_inert():
    trace_mod.disable()
    # one shared no-op context manager: no allocation per call
    assert obs.span("x", a=1) is obs.span("y")
    assert obs.event("z") is None
    assert trace_mod.current() is None
    with obs.span("x"):
        pass                                   # still a working `with`


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------
def test_chrome_export_schema_and_containment(tracer, tmp_path):
    with obs.span("root", app="conv"):
        with obs.span("kid"):
            pass
    tracer.add_complete("backend_compile", 0.001, 0.005, track="jax-compile",
                        event="/jax/x")
    doc = tracer.to_chrome()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(meta) + len(xs) == len(events)
    # one thread_name per track: pipeline + jax-compile
    assert {m["args"]["name"] for m in meta} == {"pipeline", "jax-compile"}
    by_name = {e["name"]: e for e in xs}
    for e in xs:
        assert e["pid"] == 1 and e["cat"] == "repro"
        assert e["ts"] >= 0 and e["dur"] >= 0      # microseconds
    root, kid = by_name["root"], by_name["kid"]
    assert root["tid"] == kid["tid"] == 1
    assert by_name["backend_compile"]["tid"] == 2
    # nesting is encoded by time containment (rounded to 1ns in export)
    assert kid["ts"] >= root["ts"] - 1e-3
    assert kid["ts"] + kid["dur"] <= root["ts"] + root["dur"] + 2e-3
    assert root["args"] == {"app": "conv"}

    path = str(tmp_path / "t.trace.json")
    tracer.write_chrome(path)
    written = json.load(open(path))                # valid JSON round trip
    # the written file additionally embeds the run manifest
    assert written["traceEvents"] == doc["traceEvents"]
    assert written["displayTimeUnit"] == doc["displayTimeUnit"]
    man = written["metadata"]["manifest"]
    assert man["schema"] == 1 and man["xla_cache"] in ("off", "cold", "warm")


def test_jsonl_export_and_report_loaders(tracer, tmp_path):
    with obs.span("stage", pe="PE1"):
        with obs.span("work"):
            pass
    tracer.add_complete("compile", 0.0, 0.002, track="jax-compile")
    jl = str(tmp_path / "t.jsonl")
    ch = str(tmp_path / "t.trace.json")
    tracer.write_jsonl(jl)
    tracer.write_chrome(ch)

    rows_jl = load_trace_rows(jl)
    rows_ch = load_trace_rows(ch)
    assert [r["name"] for r in rows_jl] == ["stage", "work", "compile"]
    assert rows_jl[0]["path"] == "stage" and rows_jl[1]["path"] == "stage/work"
    assert rows_jl[2]["track"] == "jax-compile"
    # both formats aggregate to the same per-name counts
    agg_jl = {a["name"]: a["count"] for a in aggregate_stages(rows_jl)}
    agg_ch = {a["name"]: a["count"] for a in aggregate_stages(rows_ch)}
    assert agg_jl == agg_ch == {"stage": 1, "work": 1, "compile": 1}
    md = stage_table(rows_jl, markdown=True)
    assert md.startswith("| span |") and "| stage | 1 |" in md
    assert "work" in stage_table(rows_jl, limit=3)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_histogram_pow2_buckets():
    h = Histogram()
    for v in (0, 1, 3, 4, 5, 100):
        h.observe(v)
    assert h.count == 6 and h.total == 113
    assert (h.vmin, h.vmax) == (0, 100)
    assert h.buckets == {0: 1, 1: 1, 4: 2, 8: 1, 128: 1}
    assert h.mean == pytest.approx(113 / 6)


def test_counter_view_is_counter_compatible():
    reg = MetricsRegistry()
    view = reg.view()
    assert view["missing"] == 0                    # Counter-style default
    view["pnr_dispatch"] += 1
    view["pnr_dispatch"] += 2
    assert reg.counter("pnr_dispatch") == 3
    reg.inc("sched_group")
    assert dict(view) == {"pnr_dispatch": 3, "sched_group": 1}
    assert len(view) == 2 and "sched_group" in view
    # prefixed views window the same storage
    sub = reg.view("memo.hit.")
    sub["mine"] += 5
    assert reg.counter("memo.hit.mine") == 5
    assert dict(sub) == {"mine": 5}
    assert "memo.hit.mine" not in dict(sub)
    del sub["mine"]
    assert reg.counter("memo.hit.mine") == 0
    assert view.registry is reg


def test_registry_export_and_merge(tmp_path):
    a = MetricsRegistry()
    a.inc("c", 2)
    a.set_gauge("g", [1.0, 2.0])
    a.observe("h", 4)
    b = MetricsRegistry()
    b.inc("c", 3)
    b.observe("h", 9)
    a.merge_from(b)
    assert a.counter("c") == 5
    assert a.histogram("h").count == 2 and a.histogram("h").vmax == 9
    path = str(tmp_path / "m.json")
    a.write_json(path)
    doc = json.load(open(path))
    assert doc["counters"] == {"c": 5}
    assert doc["gauges"] == {"g": [1.0, 2.0]}
    assert doc["histograms"]["h"]["count"] == 2


def test_jaxprof_counts_compiles_into_registry():
    jax = pytest.importorskip("jax")
    reg = MetricsRegistry()
    assert obs.jaxprof.enable(registry=reg)
    try:
        # a fresh lambda forces a fresh trace+compile
        jax.jit(lambda x: x * 2 + 1)(np.float32(3))
    finally:
        obs.jaxprof.disable()
    assert reg.counter("jax.compile.events") > 0
    assert reg.histogram("jax.compile.secs").count > 0
    before = reg.counter("jax.compile.events")
    jax.jit(lambda x: x * 4 + 1)(np.float32(3))    # disabled: no ticks
    assert reg.counter("jax.compile.events") == before


# ---------------------------------------------------------------------------
# pipeline integration: memo accounting, shared stores
# ---------------------------------------------------------------------------
def test_memo_hit_miss_accounting_across_with_config():
    apps = {"conv": conv_app()}
    ex = Explorer(apps, small_cfg())
    ex.map()
    assert ex.metrics.counter("memo.miss.mine") == 1
    assert ex.metrics.counter("memo.hit.mine") == 0
    ex.map()                                  # warm: all hits, no misses
    assert ex.metrics.counter("memo.miss.mine") == 1
    assert ex.metrics.counter("memo.hit.mine") >= 1
    hits0 = ex.metrics.counter("memo.hit.mine")

    # a with_config clone shares BOTH the memo store and the registry, so
    # its upstream reuse shows up as hits (not fresh misses) in one place
    ex2 = ex.with_config(max_merge=1)
    assert ex2.metrics is ex.metrics
    assert ex2.stats.registry is ex.metrics
    ex2.map()
    assert ex.metrics.counter("memo.miss.mine") == 1
    assert ex.metrics.counter("memo.hit.mine") > hits0
    assert ex.metrics.counter("memo.miss.merge") == 2   # max_merge differs


# ---------------------------------------------------------------------------
# telemetry is bit-free: enabling it changes nothing
# ---------------------------------------------------------------------------
def test_anneal_telemetry_bit_identical_and_observed():
    from repro.fabric import anneal_jax_batch, lower, synthetic_netlist
    spec = FabricSpec(rows=4, cols=4)
    probs = [lower(synthetic_netlist(spec, fill=0.8, seed=s), spec)
             for s in (1, 3)]
    plain = anneal_jax_batch(probs, chains=2, seed=0, sweeps=8,
                             nonces=[11, 22], telemetry=False)
    reg = MetricsRegistry()
    tele = anneal_jax_batch(probs, chains=2, seed=0, sweeps=8,
                            nonces=[11, 22], telemetry=True, metrics=reg)
    for (s0, c0), (s1, c1) in zip(plain, tele):
        assert np.array_equal(s0, s1)              # placements: same bits
        assert np.array_equal(c0, c1)
    h = reg.histogram("pnr.anneal.accept_rate")
    assert h.count == len(probs)
    assert 0.0 < h.vmax <= 1.0
    curves = [k for k in reg.to_dict()["gauges"]
              if k.startswith("pnr.anneal.cost_curve.")]
    assert len(curves) == len(probs)
    from repro.fabric.place import CURVE_POINTS
    for k in curves:
        curve = reg.gauge(k)
        assert len(curve) == CURVE_POINTS
        # annealing improves: the curve ends no worse than it starts
        assert curve[-1] <= curve[0]


def test_scheduler_telemetry_counters():
    apps = {"conv": conv_app()}
    ex = Explorer(apps, small_cfg(simulate=True))
    pnrs = ex.pnr()
    from repro.sim import modulo_schedule
    reset_global_registry()
    pnr = next(iter(pnrs.values()))
    sched = modulo_schedule(pnr.netlist, pnr.placement, pnr.routes, pnr.spec)
    g = global_registry()
    # one attempt per II tried, >= 1 scan round, scans >= rounds
    assert g.counter("sched_attempts") >= 1
    assert g.counter("sched_rounds") >= 1
    assert g.counter("sched_scans") >= g.counter("sched_rounds")
    assert sched.ii >= sched.min_ii


def test_tracing_and_telemetry_bit_identical_explore_records():
    """The acceptance invariant: a fully-instrumented run (tracing +
    telemetry + compile hooks) produces byte-identical ExploreRecords."""
    apps = {"conv": conv_app()}
    cfg = small_cfg(simulate=True)
    plain = Explorer(apps, cfg).run().records()

    trace_mod.disable()
    obs.enable_tracing()
    obs.enable_telemetry()
    ex = Explorer(apps, cfg)
    obs.jaxprof.enable(registry=ex.metrics)
    try:
        traced = ex.run().records()
    finally:
        tracer = trace_mod.disable()
        obs.enable_telemetry(False)
        obs.jaxprof.disable()

    assert [r.to_dict() for r in traced] == [r.to_dict() for r in plain]
    # ... and the trace actually covered the pipeline
    names = tracer.span_names()
    for stage in ("mine", "rank", "merge", "map", "pnr", "schedule",
                  "simulate"):
        assert stage in names, f"missing {stage} span"
    assert ex.metrics.counter("pnr_dispatch") >= 1


@pytest.mark.parametrize("seed,max_merge", [(1, 1), (2, 2)])
def test_tracing_bit_identity_property(seed, max_merge):
    """Tracing on vs off is bit-identical across configs (cheap cases of
    the hypothesis property below; the exhaustive version is gated)."""
    apps = {"conv": conv_app()}
    cfg = small_cfg(seed=seed, max_merge=max_merge)
    plain = Explorer(apps, cfg).run().records()
    trace_mod.disable()
    obs.enable_tracing()
    try:
        traced = Explorer(apps, cfg).run().records()
    finally:
        trace_mod.disable()
    assert [r.to_dict() for r in traced] == [r.to_dict() for r in plain]


@pytest.mark.slow
@pytest.mark.parametrize("name,ii", [("camera", 17), ("laplacian", 11)])
def test_analyzer_names_skew_critical_nets_image_suite(name, ii):
    """The acceptance question the analyzer exists to answer: which nets
    pin camera at II=17 (laplacian at II=11) on the 8x8 fabric."""
    from repro.apps import image_graphs
    from repro.core import baseline_datapath, map_application
    from repro.core.dse import app_ops
    from repro.sim import build_sim

    app = image_graphs()[name]
    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, name)
    prog, pnr = build_sim(dp, mapping, app, FabricSpec(rows=8, cols=8),
                          place_backend="jax", chains=8, sweeps=16)
    report = obs.analyze_pnr(pnr, prog.schedule)
    assert report.ii == prog.ii == ii
    crit = report.skew_critical
    assert crit, f"{name}: II={ii} but no net individually requires it"
    assert report.to_dict()["skew_critical"] == [s.net for s in crit]
    # the named nets really do imply the achieved II
    assert max(s.implied_ii for s in crit) == ii
    assert "skew-critical" in report.render()


@pytest.mark.slow
def test_tracing_bit_identity_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    apps = {"conv": conv_app()}

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 7), max_merge=st.integers(1, 2),
           simulate=st.booleans())
    def prop(seed, max_merge, simulate):
        cfg = small_cfg(seed=seed, max_merge=max_merge, simulate=simulate)
        plain = Explorer(apps, cfg).run().records()
        trace_mod.disable()
        obs.enable_tracing()
        obs.enable_telemetry()
        try:
            traced = Explorer(apps, cfg).run().records()
        finally:
            trace_mod.disable()
            obs.enable_telemetry(False)
        assert [r.to_dict() for r in traced] == [r.to_dict() for r in plain]

    try:
        prop()
    finally:
        trace_mod.disable()
        obs.enable_telemetry(False)


# ---------------------------------------------------------------------------
# post-pnr analyzer
# ---------------------------------------------------------------------------
def test_analyzer_report_and_operand_skew():
    apps = {"conv": conv_app()}
    ex = Explorer(apps, small_cfg(simulate=True))
    pnrs = ex.pnr()
    pnr = next(iter(pnrs.values()))

    report = obs.analyze_pnr(pnr)                 # schedule-free report
    assert 0.0 < report.pe_util <= 1.0
    assert 0.0 < report.io_util <= 1.0
    assert report.overflow == 0
    assert sum(report.route_depth_hist.values()) == len(pnr.routes.nets)
    assert report.ii is None and report.skews == []
    assert report.skew_critical == []
    d = report.to_dict()
    assert "ii" not in d and d["overflow"] == 0

    from repro.sim import modulo_schedule
    sched = modulo_schedule(pnr.netlist, pnr.placement, pnr.routes, pnr.spec)
    full = obs.analyze_pnr(pnr, sched)
    assert full.ii == sched.ii and full.min_ii == sched.min_ii
    assert full.latch_depth == sched.latch_depth
    assert full.skews, "conv has dependence edges; skew table empty"
    for s in full.skews:
        assert s.wait >= 1                        # operand arrives first
        assert s.wait <= s.hold                   # schedule is legal
        assert 1 <= s.implied_ii <= sched.ii      # no edge beats the II
        assert s.slack == s.hold - s.wait
    assert full.mean_latch_util <= full.max_latch_util <= 1.0
    # skew-critical = the edges that pin the achieved II
    crit = full.skew_critical
    assert all(s.implied_ii >= full.ii for s in crit)
    text = full.render()
    assert "operand-skew table" in text and str(full.ii) in text
    dd = full.to_dict()
    assert dd["ii"] == sched.ii
    assert dd["skew_critical"] == [s.net for s in crit]
