"""Hierarchical placement invariants (ISSUE 10).

Deterministic seeded tests always run; the same invariants are also
property-tested under Hypothesis when it is installed (same gating idiom
as tests/test_property.py).

Invariants:

- partition(): every PE cell lands in exactly one cluster, no cluster
  exceeds its capacity, and the clustering is deterministic;
- place_hierarchical(cluster_grid=1) is bit-identical to the flat
  place() at equal seeds (the degenerate hierarchy IS the flat placer);
- delta and full score modes are bit-identical at every hierarchical
  level (cluster / detail / deblock / final);
- the fixed-box HPWL kernels agree with a numpy reference, and the
  EMPTY_BOX sentinel is a bit-exact no-op.
"""

import numpy as np
import pytest

from repro.fabric import FabricSpec, partition, place, place_hierarchical
from repro.fabric.netlist import synthetic_netlist


def _spec(rows, cols):
    return FabricSpec(rows=rows, cols=cols)


# ---------------------------------------------------------------------------
# partition invariants (deterministic sweep)

def _check_partition(netlist, n_clusters, cap):
    cl = partition(netlist, n_clusters, cap)
    names = sorted(c.name for c in netlist.pe_cells)
    # exactly-one-cluster: the flattened clusters are a permutation of
    # the PE cells, and the inverse map agrees
    flat = sorted(n for grp in cl.clusters for n in grp)
    assert flat == names
    assert sorted(cl.cluster_of) == names
    for k, grp in enumerate(cl.clusters):
        assert len(grp) <= cap, f"cluster {k} over cap: {len(grp)} > {cap}"
        for n in grp:
            assert cl.cluster_of[n] == k
    assert cl.cut_nets >= 0 and cl.internal_nets >= 0
    return cl


@pytest.mark.parametrize("rows,cols,g,seed", [
    (8, 8, 2, 0), (8, 8, 2, 3), (12, 12, 3, 1), (16, 16, 4, 2),
])
def test_partition_invariants(rows, cols, g, seed):
    spec = _spec(rows, cols)
    net = synthetic_netlist(spec, seed=seed, locality=3)
    cap = (rows // g) * (cols // g)
    _check_partition(net, g * g, cap)


def test_partition_deterministic():
    spec = _spec(8, 8)
    net = synthetic_netlist(spec, seed=7, locality=2)
    a = partition(net, 4, 16)
    b = partition(net, 4, 16)
    assert a.clusters == b.clusters and a.cluster_of == b.cluster_of
    assert (a.cut_nets, a.internal_nets) == (b.cut_nets, b.internal_nets)


def test_partition_rejects_overfull():
    spec = _spec(8, 8)
    net = synthetic_netlist(spec, seed=0)
    n = len(net.pe_cells)
    with pytest.raises(ValueError):
        partition(net, 2, (n // 2) - 1)


# ---------------------------------------------------------------------------
# cluster_grid=1 == flat, bit for bit

def test_cluster1_bit_identical_to_flat():
    spec = _spec(8, 8)
    net = synthetic_netlist(spec, seed=5, locality=2)
    kw = dict(chains=2, sweeps=4, seed=11)
    flat = place(net, spec, backend="jax", **kw)
    hier = place_hierarchical(net, spec, cluster_grid=1, **kw)
    assert hier.cluster_grid == 1
    assert hier.coords == flat.coords
    assert hier.cost == flat.cost
    np.testing.assert_array_equal(np.asarray(hier.chain_costs),
                                  np.asarray(flat.chain_costs))


# ---------------------------------------------------------------------------
# delta == full at every level

def test_hier_levels_delta_vs_full_bit_identical():
    spec = _spec(8, 8)
    net = synthetic_netlist(spec, seed=9, locality=2)
    kw = dict(cluster_grid=2, chains=2, sweeps=4, seed=3)
    d = place_hierarchical(net, spec, score_mode="delta", **kw)
    f = place_hierarchical(net, spec, score_mode="full", **kw)
    assert d.level_costs == f.level_costs
    assert d.coords == f.coords
    assert d.cost == f.cost
    # legality: every cell on a distinct legal tile
    seen = set()
    for name, (x, y) in d.coords.items():
        assert (x, y) not in seen
        seen.add((x, y))


# ---------------------------------------------------------------------------
# fixed-box HPWL kernels vs a numpy reference

def _ref_hpwl_fixed(slot_xy, net_pins, net_mask, net_fix):
    total = 0.0
    for pins, mask, (fx0, fx1, fy0, fy1) in zip(net_pins, net_mask, net_fix):
        xs = [slot_xy[p][0] for p, m in zip(pins, mask) if m]
        ys = [slot_xy[p][1] for p, m in zip(pins, mask) if m]
        if not xs:
            continue
        xmin, xmax = min(xs + [fx0]), max(xs + [fx1])
        ymin, ymax = min(ys + [fy0]), max(ys + [fy1])
        total += (xmax - xmin) + (ymax - ymin)
    return total


def test_hpwl_fixed_matches_reference():
    from repro.kernels.pnr_cost import EMPTY_BOX, fixed_box, hpwl_fixed

    rng = np.random.default_rng(0)
    n_slots, n_nets, k = 12, 6, 4
    slot_xy = rng.integers(0, 8, size=(n_slots, 2)).astype(np.float32)
    net_pins = rng.integers(0, n_slots, size=(n_nets, k)).astype(np.int32)
    net_mask = (rng.random((n_nets, k)) < 0.8).astype(np.float32)
    net_fix = np.stack(
        [fixed_box(rng.integers(0, 8, size=(3, 2)).astype(np.float32))
         for _ in range(n_nets // 2)]
        + [np.asarray(EMPTY_BOX, np.float32)] * (n_nets - n_nets // 2)
    ).astype(np.float32)
    got = float(hpwl_fixed(slot_xy, net_pins, net_mask, net_fix))
    want = _ref_hpwl_fixed(slot_xy, net_pins, net_mask, net_fix)
    assert got == pytest.approx(want)


def test_empty_box_is_noop():
    from repro.kernels.pnr_cost import EMPTY_BOX, hpwl, hpwl_fixed

    rng = np.random.default_rng(1)
    slot_xy = rng.integers(0, 6, size=(10, 2)).astype(np.float32)
    net_pins = rng.integers(0, 10, size=(5, 3)).astype(np.int32)
    net_mask = np.ones((5, 3), np.float32)
    empties = np.tile(np.asarray(EMPTY_BOX, np.float32), (5, 1))
    assert float(hpwl_fixed(slot_xy, net_pins, net_mask, empties)) == \
        float(hpwl(slot_xy, net_pins, net_mask))


# ---------------------------------------------------------------------------
# the same partition invariants, property-tested when hypothesis exists

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                  # pragma: no cover
    _HYP = False

if _HYP:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16), g=st.sampled_from([1, 2, 4]),
           locality=st.sampled_from([None, 2, 4]))
    def test_partition_property(seed, g, locality):
        spec = _spec(8, 8)
        net = synthetic_netlist(spec, seed=seed, locality=locality)
        cap = (8 // g) * (8 // g)
        _check_partition(net, g * g, cap)
