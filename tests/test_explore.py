"""The staged exploration pipeline (repro.explore): config/record schema,
stage memoization, batch-first pnr, and bit-identical legacy shims."""

import dataclasses
import json

import numpy as np
import pytest

from repro.apps import image
from repro.core import MiningConfig, mine_and_rank, specialize_per_app
from repro.core.dse import DSEResult, PEVariant, build_variants, \
    evaluate_variants
from repro.core.costmodel import AppCost
from repro.explore import (ExploreConfig, ExploreRecord, Explorer,
                           RECORD_SCHEMA, from_jsonl, to_jsonl)
from repro.fabric import FabricOptions, FabricSpec
from repro.graphir import trace_scalar

#: fast but budget-unbound mining: deterministic run to run
FAST = MiningConfig(min_support=4, max_pattern_nodes=4, time_budget_s=120,
                    max_patterns_per_level=30)


def conv_app():
    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c
    return trace_scalar(conv4, ["i0", "i1", "i2", "i3",
                                "w0", "w1", "w2", "w3", "c"])


@pytest.fixture(scope="module")
def camera():
    return image.build_graph("camera")


# ---------------------------------------------------------------------------
# schema / serialization
# ---------------------------------------------------------------------------
#: frozen golden schema — changing ExploreRecord requires bumping
#: RECORD_SCHEMA and updating this list in the same commit
RECORD_FIELDS = [
    "schema", "mode", "config_key", "n_merged", "sim_bucket",
    "app", "pe_name", "n_pes", "total_ops", "pe_area_um2", "total_area_um2",
    "energy_pj", "energy_per_op_pj", "fmax_ghz", "ops_per_pe", "unmapped",
    "cgra_area_um2", "cgra_energy_pj", "cgra_energy_per_op_pj",
    "fabric_area_um2", "fabric_energy_per_op_pj", "fabric_fmax_ghz",
    "fabric_wirelength", "fabric_utilization",
    "sim_ii", "sim_min_ii", "sim_latency_cycles", "sim_active_frac",
    "sim_throughput_gops", "sim_energy_per_op_pj", "sim_verified",
]


def test_record_golden_schema_and_jsonl_round_trip(tmp_path):
    assert [f.name for f in dataclasses.fields(ExploreRecord)] \
        == RECORD_FIELDS
    # the AppCost column subset must track costmodel.AppCost exactly
    appcost_fields = [f.name for f in dataclasses.fields(AppCost)]
    assert RECORD_FIELDS[5:] == appcost_fields

    cost = AppCost(app="a", pe_name="PE1", n_pes=3, total_ops=7,
                   pe_area_um2=1.5, total_area_um2=4.5, energy_pj=2.0,
                   energy_per_op_pj=0.3, fmax_ghz=1.1, ops_per_pe=2.3,
                   unmapped=0)
    rec = ExploreRecord.from_cost(cost, mode="per_app", config_key="k",
                                  n_merged=2)
    assert rec.schema == RECORD_SCHEMA
    path = str(tmp_path / "r.jsonl")
    assert to_jsonl([rec], path) == 1
    back = from_jsonl(path)
    assert len(back) == 1 and back[0] == rec

    # unknown schema versions fail loudly
    bad = rec.to_dict() | {"schema": RECORD_SCHEMA + 1}
    with open(path, "w") as f:
        f.write(json.dumps(bad) + "\n")
    with pytest.raises(ValueError, match="schema"):
        from_jsonl(path)


def test_explore_config_json_round_trip():
    cfg = ExploreConfig(
        mode="domain", mining=FAST, max_merge=2, rank_mode="utility",
        per_app_subgraphs=3, domain_name="PE_X",
        fabric=FabricOptions(spec=FabricSpec(rows=6, cols=5), chains=3,
                             sweeps=9, seed=7, simulate=True),
        pnr_batch="serial", sim_batch="serial")
    blob = json.dumps(cfg.to_dict())
    assert ExploreConfig.from_dict(json.loads(blob)) == cfg
    # no-fabric config round-trips too
    cfg2 = ExploreConfig(mining=FAST)
    assert ExploreConfig.from_dict(cfg2.to_dict()) == cfg2
    with pytest.raises(ValueError, match="schema"):
        ExploreConfig.from_dict(cfg.to_dict() | {"schema": 99})
    with pytest.raises(ValueError, match="unknown"):
        ExploreConfig.from_dict(cfg2.to_dict() | {"bogus": 1})


def test_config_rejects_bad_values():
    with pytest.raises(ValueError, match="mode"):
        ExploreConfig(mode="nope")
    with pytest.raises(ValueError, match="pnr_batch"):
        ExploreConfig(pnr_batch="nope")
    with pytest.raises(ValueError, match="sim_batch"):
        ExploreConfig(sim_batch="nope")
    with pytest.raises(ValueError, match="rank_mode"):
        ExploreConfig(rank_mode="nope")


# ---------------------------------------------------------------------------
# stage memoization
# ---------------------------------------------------------------------------
def test_stage_memoization_zero_remines():
    apps = {"conv": conv_app()}
    fabric = FabricOptions(spec=FabricSpec(rows=4, cols=4), chains=2,
                           sweeps=4)
    cfg = ExploreConfig(mode="per_app",
                        mining=MiningConfig(min_support=2,
                                            max_pattern_nodes=5),
                        max_merge=2, fabric=fabric)
    ex = Explorer(apps, cfg)
    res1 = ex.run()
    assert ex.stats["mine"] == 1
    upstream = {k: ex.stats[k] for k in ("mine", "rank", "merge", "map")}
    pnr_runs = ex.stats["pnr"]

    # identical config: the whole pipeline is a cache hit
    res_again = ex.run()
    assert {k: ex.stats[k] for k in upstream} == upstream
    assert ex.stats["pnr"] == pnr_runs

    # downstream-only change (annealing budget): zero re-mines/merges/maps,
    # but the pnr stage re-runs
    ex2 = ex.with_config(fabric=dataclasses.replace(fabric, sweeps=6))
    res2 = ex2.run()
    assert {k: ex2.stats[k] for k in upstream} == upstream
    assert ex2.stats["pnr"] > pnr_runs

    # flipping simulate on reuses mine AND pnr artifacts
    pnr_runs2 = ex2.stats["pnr"]
    ex3 = ex2.with_config(
        fabric=dataclasses.replace(fabric, sweeps=6, simulate=True))
    res3 = ex3.run()
    assert {k: ex3.stats[k] for k in upstream} == upstream
    assert ex3.stats["pnr"] == pnr_runs2
    rec3 = res3.records()
    assert all(r.sim_ii > 0 and r.sim_verified == 1 for r in rec3)
    # the upstream columns are identical across the sim flip
    for a, b in zip(res2.records(), rec3):
        assert (a.app, a.pe_name, a.energy_per_op_pj,
                a.fabric_wirelength) \
            == (b.app, b.pe_name, b.energy_per_op_pj, b.fabric_wirelength)


# ---------------------------------------------------------------------------
# batch-first pnr
# ---------------------------------------------------------------------------
def test_grouped_pnr_matches_serial_structure_and_is_deterministic():
    apps = {"conv": conv_app()}
    fabric = FabricOptions(spec=FabricSpec(rows=4, cols=4), chains=2,
                           sweeps=4)
    cfg = ExploreConfig(mode="per_app",
                        mining=MiningConfig(min_support=2,
                                            max_pattern_nodes=5),
                        max_merge=2, fabric=fabric, pnr_batch="grouped")
    ex = Explorer(apps, cfg)
    grouped = ex.pnr()
    assert ex.stats["pnr_dispatch"] >= 1
    # the CI-claimed dispatch count is a metrics-registry read, not a
    # separate hand-ticked counter: stats is a live view over ex.metrics,
    # and the registry agrees with the distinct batch signatures placed
    assert ex.stats.registry is ex.metrics
    assert ex.metrics.counter("pnr_dispatch") == ex.stats["pnr_dispatch"]
    from repro.fabric import batch_signature, lower
    sigs = {batch_signature(lower(p.netlist, p.spec), cfg.fabric.sweeps)
            for p in grouped.values()}
    assert ex.metrics.counter("pnr_dispatch") == len(sigs)
    assert ex.metrics.counter("memo.miss.pnr") == len(grouped)
    serial = ex.with_config(pnr_batch="serial").pnr()
    assert set(grouped) == set(serial)
    for pair in grouped:
        g, s = grouped[pair], serial[pair]
        # same netlist and fitted grid; both legally routed
        assert (g.spec.rows, g.spec.cols) == (s.spec.rows, s.spec.cols)
        assert len(g.netlist.nets) == len(s.netlist.nets)
        assert g.routes.success and s.routes.success
        assert g.cost.energy_per_op_pj > 0
        # every placement coordinate is a distinct legal tile
        coords = list(g.placement.coords.values())
        assert len(set(coords)) == len(coords)

    # grouped placement is deterministic (fresh store, same config)
    again = Explorer(apps, cfg).pnr()
    for pair in grouped:
        assert grouped[pair].placement.coords == again[pair].placement.coords
        assert grouped[pair].cost == again[pair].cost


def test_anneal_jax_batch_grouping_independent():
    from repro.fabric import (anneal_jax_batch, batch_signature, lower,
                              synthetic_netlist)
    spec = FabricSpec(rows=4, cols=4)
    p1 = lower(synthetic_netlist(spec, fill=0.8, seed=1), spec)
    p2 = lower(synthetic_netlist(spec, fill=0.8, seed=3), spec)
    assert batch_signature(p1, 8) == batch_signature(p2, 8)
    both = anneal_jax_batch([p1, p2], chains=2, seed=0, sweeps=8,
                            nonces=[11, 22])
    solo = anneal_jax_batch([p1], chains=2, seed=0, sweeps=8, nonces=[11])
    assert np.array_equal(both[0][0], solo[0][0])
    assert np.array_equal(both[0][1], solo[0][1])
    # reported cost is the true HPWL of the returned placement
    from repro.kernels.pnr_cost import hpwl_reference
    for p, (slots, costs) in zip([p1, p2], both):
        best = int(np.argmin(costs))
        assert hpwl_reference(p.slot_xy[slots[best]], p.net_pins,
                              p.net_mask) == pytest.approx(costs[best])
        for c in range(slots.shape[0]):
            assert sorted(slots[c]) == list(range(p.n_entities))


# ---------------------------------------------------------------------------
# batch-first schedule/simulate
# ---------------------------------------------------------------------------
def test_sim_stage_grouped_matches_serial():
    """The batched schedule/simulate stages are a pure throughput change:
    II, latency, verification flags, and every record column except the
    sim_bucket provenance must match the per-pair loop exactly."""
    apps = {"conv": conv_app()}
    fabric = FabricOptions(spec=FabricSpec(rows=4, cols=4), chains=2,
                           sweeps=4, simulate=True)
    cfg = ExploreConfig(mode="per_app",
                        mining=MiningConfig(min_support=2,
                                            max_pattern_nodes=5),
                        max_merge=2, fabric=fabric)
    grouped_ex = Explorer(apps, cfg)
    grouped = grouped_ex.run()
    assert grouped_ex.stats["sim_dispatch"] >= 1
    assert grouped_ex.stats["sched_group"] >= 1
    # dispatch claims are registry reads: the sim stage's own counter and
    # the cycle-level bucket provenance must agree, and the run's result
    # carries the registry snapshot
    assert grouped_ex.stats.registry is grouped_ex.metrics
    assert grouped_ex.metrics.counter("sim.dispatch") \
        == grouped_ex.metrics.counter("sim_dispatch")
    assert grouped_ex.metrics.counter("sched_rounds") >= 1
    snap = grouped.metrics["counters"]
    assert snap["sim_dispatch"] == grouped_ex.stats["sim_dispatch"]
    assert snap["pnr_dispatch"] == grouped_ex.stats["pnr_dispatch"]
    serial = Explorer(apps, cfg.replace(sim_batch="serial")).run()

    g_rows = grouped.records()
    s_rows = serial.records()
    assert len(g_rows) == len(s_rows) > 0
    for g, s in zip(g_rows, s_rows):
        assert g.sim_ii == s.sim_ii > 0
        assert g.sim_verified == s.sim_verified == 1
        assert g.sim_bucket != "serial" and s.sim_bucket == "serial"
        gd, sd = g.to_dict(), s.to_dict()
        for d in (gd, sd):
            d.pop("sim_bucket")
            d.pop("config_key")        # differs: sim_batch is in the config
        assert gd == sd

    # flipping sim_batch re-uses every stage upstream of schedule
    upstream = {k: grouped_ex.stats[k]
                for k in ("mine", "rank", "merge", "map", "pnr")}
    ex2 = grouped_ex.with_config(sim_batch="serial")
    ex2.run()
    assert {k: ex2.stats[k] for k in upstream} == upstream


# ---------------------------------------------------------------------------
# legacy shims
# ---------------------------------------------------------------------------
def test_shim_equivalence_fig8_camera(camera):
    """old specialize_per_app == new Explorer, bit-identical (fixed seed)."""
    # the pre-redesign composition, inlined: mine+rank -> variants -> eval
    ranked = mine_and_rank(camera, FAST)
    variants = build_variants("camera", camera, ranked, max_merge=2)
    evaluate_variants(variants, {"camera": camera})
    old = [dataclasses.asdict(v.costs["camera"]) for v in variants]
    old_names = [(v.name, tuple(v.merged_subgraphs)) for v in variants]

    res = specialize_per_app({"camera": camera}, FAST, max_merge=2)["camera"]
    new = [dataclasses.asdict(v.costs["camera"]) for v in res.variants]
    new_names = [(v.name, tuple(v.merged_subgraphs)) for v in res.variants]
    assert old_names == new_names
    assert old == new
    assert [m.label for m in res.mined["camera"]] \
        == [m.label for m in ranked]


def test_shim_equivalence_with_fabric():
    apps = {"conv": conv_app()}
    mining = MiningConfig(min_support=2, max_pattern_nodes=5)
    opts = FabricOptions(spec=FabricSpec(rows=4, cols=4), chains=2,
                         sweeps=4, seed=3)
    ranked = mine_and_rank(apps["conv"], mining)
    variants = build_variants("conv", apps["conv"], ranked, max_merge=1)
    evaluate_variants(variants, apps, fabric=opts)
    old = [dataclasses.asdict(v.costs["conv"]) for v in variants]

    res = specialize_per_app(apps, mining, max_merge=1, fabric=opts)["conv"]
    new = [dataclasses.asdict(v.costs["conv"]) for v in res.variants]
    assert old == new
    assert all(r["fabric_wirelength"] > 0 for r in new)


def test_legacy_fabric_kwargs_warn_and_match():
    apps = {"conv": conv_app()}
    mining = MiningConfig(min_support=2, max_pattern_nodes=5)
    spec = FabricSpec(rows=4, cols=4)
    with pytest.warns(DeprecationWarning, match="fabric_"):
        res_legacy = specialize_per_app(apps, mining, max_merge=1,
                                        fabric=spec, fabric_chains=2,
                                        fabric_sweeps=4, fabric_seed=3)
    res_new = specialize_per_app(
        apps, mining, max_merge=1,
        fabric=FabricOptions(spec=spec, chains=2, sweeps=4, seed=3))
    old = [dataclasses.asdict(v.costs["conv"])
           for v in res_legacy["conv"].variants]
    new = [dataclasses.asdict(v.costs["conv"])
           for v in res_new["conv"].variants]
    assert old == new


# ---------------------------------------------------------------------------
# best_variant: measured energy preferred over the static estimate
# ---------------------------------------------------------------------------
def _fake_cost(app, pe, static, sim=0.0, sim_ii=0):
    return AppCost(app=app, pe_name=pe, n_pes=1, total_ops=1,
                   pe_area_um2=1, total_area_um2=1, energy_pj=static,
                   energy_per_op_pj=static, fmax_ghz=1, ops_per_pe=1,
                   unmapped=0, sim_energy_per_op_pj=sim, sim_ii=sim_ii)


def test_best_variant_prefers_measured_sim_energy():
    from repro.core.pe import Datapath
    dp = Datapath()
    # statically PE_b looks best, but measured (skew-bound) energy says PE_a
    a = PEVariant("PE_a", dp)
    a.costs["app"] = _fake_cost("app", "PE_a", static=2.0, sim=3.0, sim_ii=4)
    b = PEVariant("PE_b", dp)
    b.costs["app"] = _fake_cost("app", "PE_b", static=1.0, sim=5.0, sim_ii=9)
    res = DSEResult({}, {}, [a, b])
    assert res.best_variant("app").name == "PE_a"

    # without simulation (sim_ii == 0) the static estimate still decides
    c = PEVariant("PE_c", dp)
    c.costs["app"] = _fake_cost("app", "PE_c", static=0.5)
    res2 = DSEResult({}, {}, [a, b, c])
    assert res2.best_variant("app").name == "PE_c"
