"""Shared fault-injection helpers for the robustness tests.

Not a test module — imported by test_faults.py / test_persist.py.  The
one rule: injection state is process-global, so every armed spec must be
disarmed even when the test body throws; :func:`armed` is the only
sanctioned way to arm specs from a test.
"""

import contextlib

from repro import faultinject
from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec


@contextlib.contextmanager
def armed(*specs: str):
    """Arm ``site:kind:nth`` specs for the duration of a with-block."""
    faultinject.disarm_all()
    for s in specs:
        faultinject.arm(s)
    try:
        yield
    finally:
        faultinject.disarm_all()


def tiny_case(**fabric_kw):
    """The Fig. 3 conv on a 4x4 fabric — the cheapest full-pipeline case
    (mirrors the CLI's ``_smoke_case``; kwargs override FabricOptions)."""
    from repro.core.mining import MiningConfig
    from repro.graphir import trace_scalar

    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c

    apps = {"conv": trace_scalar(
        conv4, ["i0", "i1", "i2", "i3", "w0", "w1", "w2", "w3", "c"])}
    cfg = ExploreConfig(
        mode="per_app",
        mining=MiningConfig(min_support=2, max_pattern_nodes=5),
        max_merge=2,
        fabric=FabricOptions(spec=FabricSpec(rows=4, cols=4),
                             chains=2, sweeps=4, simulate=True,
                             **fabric_kw))
    return apps, cfg


def run_explorer(apps, cfg, *specs: str):
    """Fresh Explorer + run under armed specs; returns (explorer, result)."""
    ex = Explorer(apps, cfg)
    with armed(*specs):
        res = ex.run()
    return ex, res
