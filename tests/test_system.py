"""End-to-end behaviour of the paper's system (DSE pipeline + claims)."""

import numpy as np
import pytest

from repro.apps import image, image_graphs, ml_graphs
from repro.core import (MiningConfig, baseline_datapath, domain_pe,
                        evaluate_mapping, map_application, specialize_per_app)

FAST_MINING = MiningConfig(min_support=3, max_pattern_nodes=6,
                           time_budget_s=20, max_patterns_per_level=40)


@pytest.fixture(scope="module")
def gaussian_dse():
    g = image.build_graph("gaussian")
    return g, specialize_per_app({"gaussian": g}, FAST_MINING,
                                 max_merge=3)["gaussian"]


def test_specialization_reduces_energy_and_area(gaussian_dse):
    """Paper Fig. 8 direction: specialized PEs beat PE1 on energy/op and
    total area."""
    g, res = gaussian_dse
    costs = [v.costs["gaussian"] for v in res.variants]
    assert costs[-1].energy_per_op_pj < costs[0].energy_per_op_pj
    assert costs[-1].total_area_um2 < costs[0].total_area_um2
    assert costs[-1].ops_per_pe > 1.2


def test_baseline_pe_is_worst(gaussian_dse):
    g, res = gaussian_dse
    base = baseline_datapath()
    c0 = evaluate_mapping(base, map_application(base, g, "gaussian"),
                          "baseline")
    best = res.best_variant("gaussian").costs["gaussian"]
    assert best.energy_per_op_pj < c0.energy_per_op_pj
    assert best.total_area_um2 < c0.total_area_um2


def test_every_variant_maps_fully(gaussian_dse):
    g, res = gaussian_dse
    for v in res.variants:
        assert v.costs["gaussian"].unmapped == 0


def test_domain_pe_supports_all_apps():
    """Paper Fig. 10/11: one domain PE runs every app in the domain and
    still beats the baseline on each."""
    apps = ml_graphs()
    res = domain_pe(apps, FAST_MINING, per_app_subgraphs=1,
                    domain_name="PE_ML")
    variant = res.variants[0]
    base = baseline_datapath()
    for name, g in apps.items():
        c = variant.costs[name]
        assert c.unmapped == 0
        c0 = evaluate_mapping(base, map_application(base, g, name), "base")
        assert c.energy_per_op_pj < c0.energy_per_op_pj, name


def test_image_reference_executes():
    img = np.arange(64, dtype=np.float64).reshape(8, 8)
    out = image.run_reference("gaussian", img)
    assert out.shape == (6, 6)
    assert np.all(np.isfinite(out))
