"""Time-domain subsystem tests: modulo scheduler + cycle-accurate simulator.

Golden rule of this file: simulated outputs must equal
``graphir.interp`` BIT FOR BIT — the suite apps use only IEEE-exact ops,
so any tolerance would hide real mapping/scheduling bugs.
"""

import numpy as np
import pytest

from repro.apps import image_graphs, ml_graphs
from repro.core import baseline_datapath, map_application
from repro.core.dse import PEVariant, app_ops, evaluate_variants
from repro.fabric import (FabricOptions, FabricSpec, extract_netlist, place,
                          place_and_route)
from repro.sim import (build_sim, check_against_interp, min_ii,
                       modulo_schedule, random_inputs, simulate,
                       verify_mapping)
from repro.sim.schedule import L_LATCH, L_OUT, route_timing

SPEC = FabricSpec(rows=8, cols=8)
FAST = dict(place_backend="python", chains=1, sweeps=8)


def _flow(name, app):
    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, name)
    return dp, mapping


@pytest.fixture(scope="module")
def gaussian_sim():
    app = image_graphs()["gaussian"]
    dp, mapping = _flow("gaussian", app)
    prog, pnr = build_sim(dp, mapping, app, SPEC, **FAST)
    return app, dp, mapping, prog, pnr


# ---------------------------------------------------------------------------
# modulo schedule legality
# ---------------------------------------------------------------------------
def test_schedule_reports_and_respects_windows(gaussian_sim):
    app, dp, mapping, prog, pnr = gaussian_sim
    s = prog.schedule
    assert s.ii >= s.min_ii >= 1
    assert s.rec_mii == 1                      # app graphs are acyclic
    assert s.latency > 0 and s.attempts >= 1
    # every op scheduled exactly once, at a non-negative cycle
    kinds = {k for k, _ in s.start}
    assert kinds <= {"pe", "in"}
    assert all(t >= 0 for t in s.start.values())
    assert len([k for k in s.start if k[0] == "pe"]) == mapping.n_pes
    # hop slots: every routed hop holds data exactly depth+1 cycles after
    # its producer fires (the (cycle, II) slot assignment of the issue)
    routed = {n.name: n for n in pnr.routes.nets}
    cells = pnr.netlist.cells
    src_of = {}
    for net in pnr.netlist.nets:
        drv = cells[net.driver]
        src_of[net.name] = (("pe", drv.instance) if drv.kind == "pe"
                            else ("in", net.signal))
    assert s.hop_time
    for (net_name, tile), t in s.hop_time.items():
        nt = route_timing(routed[net_name])
        assert t == s.start[src_of[net_name]] + L_OUT + nt.depth[tile]


def test_min_ii_lower_bound_deterministic():
    """An I/O tile streaming k signals bounds II from below by k."""
    app = image_graphs()["gaussian"]          # 9 inputs
    dp, mapping = _flow("gaussian", app)
    for io_cap, want in [(4, 3), (2, 2), (1, 1)]:
        spec = FabricSpec(rows=8, cols=8, io_capacity=io_cap)
        pnr = place_and_route(dp, mapping, app, spec, backend="python",
                              chains=1, sweeps=8)
        rec, res = min_ii(pnr.netlist, pnr.routes, pnr.spec, pnr.placement)
        assert rec == 1
        assert res >= want                    # k signals share one io tile
        sched = modulo_schedule(pnr.netlist, pnr.placement, pnr.routes,
                                pnr.spec)
        assert sched.ii >= res                # achieved II >= resource bound
    # with io_capacity=4, gaussian's 9 inputs pack 4+4+1 -> ResMII == 4
    pnr = place_and_route(dp, mapping, app, FabricSpec(8, 8),
                          backend="python", chains=1, sweeps=8)
    _, res = min_ii(pnr.netlist, pnr.routes, pnr.spec, pnr.placement)
    assert res == 4


def test_schedule_dependence_windows_hold(gaussian_sim):
    """Re-derive every producer->consumer arrival and check the modulo
    hold window independently of the scheduler's own _check."""
    app, dp, mapping, prog, pnr = gaussian_sim
    s = prog.schedule
    coords = pnr.placement.coords
    cells = pnr.netlist.cells
    routed = {n.name: n for n in pnr.routes.nets}
    hold = s.latch_depth * s.ii
    for net in pnr.netlist.nets:
        nt = route_timing(routed[net.name])
        drv = cells[net.driver]
        src = (("pe", drv.instance) if drv.kind == "pe"
               else ("in", net.signal))
        for sink in net.sinks:
            if cells[sink].kind != "pe":
                continue
            arr = s.start[src] + L_OUT + nt.depth[coords[sink]]
            t = s.start[("pe", cells[sink].instance)]
            assert arr + L_LATCH <= t <= arr + hold, (net.name, sink)


# ---------------------------------------------------------------------------
# golden verification: sim == interp, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["gaussian", "harris", "ds"])
def test_sim_bit_matches_interp(name):
    apps = {**image_graphs(), **ml_graphs()}
    app = apps[name]
    dp, mapping = _flow(name, app)
    report = verify_mapping(dp, mapping, app, SPEC, iterations=3, batch=2,
                            **FAST)
    assert report.bit_exact and report.max_abs_err == 0.0, report.row()
    assert report.ii >= report.min_ii


@pytest.mark.slow
@pytest.mark.parametrize("name", ["camera", "laplacian", "conv", "block",
                                  "strc"])
def test_sim_bit_matches_interp_full_suite(name):
    apps = {**image_graphs(), **ml_graphs()}
    app = apps[name]
    dp, mapping = _flow(name, app)
    report = verify_mapping(dp, mapping, app, SPEC, iterations=3, batch=2,
                            place_backend="jax", chains=4, sweeps=16)
    assert report.bit_exact and report.max_abs_err == 0.0, report.row()


def test_sim_multiop_merged_variant_bit_matches():
    """Merged PE variants produce multi-op instances (intra-tile temps)."""
    from repro.core import MiningConfig
    from repro.core.dse import build_variants, mine_and_rank

    app = image_graphs()["gaussian"]
    cfg = MiningConfig(min_support=3, max_pattern_nodes=5, time_budget_s=10,
                       max_patterns_per_level=30)
    variants = build_variants("gaussian", app, mine_and_rank(app, cfg),
                              max_merge=2)
    assert len(variants) >= 2
    v = variants[-1]
    mapping = map_application(v.datapath, app, "gaussian")
    assert max(i.n_ops for i in mapping.instances) >= 2
    prog, _ = build_sim(v.datapath, mapping, app, SPEC, **FAST)
    inputs = random_inputs(prog, 2, 2, seed=7)
    _, err, exact = check_against_interp(prog, app, inputs)
    assert exact and err == 0.0


def test_sim_pallas_backend_matches_jax(gaussian_sim):
    app, dp, mapping, prog, pnr = gaussian_sim
    inputs = random_inputs(prog, 2, 1, seed=3)
    res_jax, err_jax, exact_jax = check_against_interp(prog, app, inputs,
                                                       backend="jax")
    res_pl, err_pl, exact_pl = check_against_interp(prog, app, inputs,
                                                    backend="pallas")
    assert exact_jax and exact_pl and err_jax == err_pl == 0.0
    assert np.array_equal(res_jax.outputs, res_pl.outputs)


def test_simulate_accepts_dict_and_array_inputs(gaussian_sim):
    app, dp, mapping, prog, pnr = gaussian_sim
    arr = random_inputs(prog, 2, 2, seed=5)
    by_name = {name: arr[:, :, j]
               for j, name in enumerate(prog.input_names)}
    a = simulate(prog, arr)
    b = simulate(prog, by_name)
    assert np.array_equal(a.outputs, b.outputs)
    assert a.outputs.shape == (2, 2, len(app.outputs))
    assert a.cycles == prog.total_cycles(2)
    assert 0 < a.active_frac <= 1.0


# The hypothesis property test (random graphs -> sim == interp) lives in
# tests/test_property.py with the other importorskip-guarded properties.


# ---------------------------------------------------------------------------
# kernels: tile-step dispatch backends agree
# ---------------------------------------------------------------------------
def test_alu_step_backends_agree():
    from repro.kernels.sim_step import (alu_step_jnp, alu_step_pallas,
                                        alu_step_reference, op_table)

    ops = op_table(["add", "sub", "mul", "min", "max", "sel", "ashr",
                    "gt", "abs"])
    rng = np.random.default_rng(11)
    n, b = 37, 5
    codes = rng.integers(0, len(ops), n).astype(np.int32)
    a = rng.standard_normal((b, n)).astype(np.float32)
    # integral second operands: shift amounts are 2**b, and libm vs XLA
    # pow only agree bit-exactly on integral exponents (as in the apps,
    # where shifts come from constant registers)
    bb = rng.integers(-3, 4, (b, n)).astype(np.float32)
    c = rng.standard_normal((b, n)).astype(np.float32)
    want = alu_step_reference(codes, a, bb, c, ops)
    got_jnp = np.asarray(alu_step_jnp(codes, a, bb, c, ops))
    got_pl = np.asarray(alu_step_pallas(codes, a, bb, c, ops,
                                        interpret=True))
    assert np.array_equal(got_jnp, want)
    assert np.array_equal(got_pl, want)


def test_alu_step_rejects_unknown_ops():
    from repro.kernels.sim_step import op_table

    with pytest.raises(NotImplementedError):
        op_table(["add", "matmul"])


# ---------------------------------------------------------------------------
# placer: pallas HPWL backend behind the switch
# ---------------------------------------------------------------------------
def test_place_hpwl_pallas_backend_matches_jnp():
    app = image_graphs()["gaussian"]
    dp, mapping = _flow("gaussian", app)
    nl = extract_netlist(mapping, app, SPEC)
    a = place(nl, SPEC, backend="jax", chains=2, sweeps=4, seed=5,
              hpwl_backend="jnp")
    b = place(nl, SPEC, backend="jax", chains=2, sweeps=4, seed=5,
              hpwl_backend="pallas")
    # identical cost kernel values -> identical accepted move sequences
    assert a.coords == b.coords and a.cost == b.cost
    with pytest.raises(ValueError):
        place(nl, SPEC, backend="jax", chains=1, sweeps=2,
              hpwl_backend="nope")


# ---------------------------------------------------------------------------
# DSE integration: FabricOptions + simulate=True
# ---------------------------------------------------------------------------
def test_fabric_options_coerce_legacy_kwargs():
    opts = FabricOptions.coerce(SPEC, backend="python", chains=3, sweeps=9,
                                seed=2, simulate=True)
    assert opts.spec == SPEC and opts.backend == "python"
    assert opts.chains == 3 and opts.sweeps == 9 and opts.simulate
    assert FabricOptions.coerce(None) is None
    with pytest.raises(ValueError):
        FabricOptions.coerce(None, simulate=True)
    with pytest.raises(TypeError):
        FabricOptions.coerce("8x8")
    # passing an options object through is idempotent
    again = FabricOptions.coerce(opts)
    assert again == opts
    # mixing an options object with non-default legacy kwargs is an error,
    # not a silent discard
    with pytest.raises(ValueError, match="legacy kwargs"):
        FabricOptions.coerce(opts, chains=64)


def test_dse_simulate_records_measured_throughput():
    app = image_graphs()["gaussian"]
    dp = baseline_datapath(app_ops(app))
    v = PEVariant("PE1", dp)
    evaluate_variants([v], {"gaussian": app},
                      fabric=FabricOptions(spec=SPEC, backend="python",
                                           chains=1, sweeps=8,
                                           simulate=True))
    c = v.costs["gaussian"]
    assert c.sim_ii >= c.sim_min_ii >= 1
    assert c.sim_verified == 1                 # bit-exact golden check ran
    assert c.sim_latency_cycles > 0
    assert c.sim_active_frac == pytest.approx(1.0 / c.sim_ii)
    assert c.sim_throughput_gops > 0
    # idle cycles make measured energy/op dominate the static array number
    assert c.sim_energy_per_op_pj > c.fabric_energy_per_op_pj


def test_dse_legacy_fabric_kwargs_still_work():
    app = image_graphs()["gaussian"]
    dp = baseline_datapath(app_ops(app))
    v = PEVariant("PE1", dp)
    evaluate_variants([v], {"gaussian": app}, fabric=SPEC,
                      fabric_backend="python", fabric_chains=1,
                      fabric_sweeps=8)
    c = v.costs["gaussian"]
    assert c.fabric_energy_per_op_pj > 0
    assert c.sim_ii == 0                       # simulate not requested
