"""Fault isolation, stage budgets, the injection harness, CLI exit codes."""

import json

import pytest

from faults import armed, run_explorer, tiny_case
from repro import faultinject
from repro.errors import BudgetExceeded, InjectedFault
from repro.explore import (ConfigFormatError, ExploreConfig, Explorer,
                           RecordFormatError, StageFailure,
                           failures_from_jsonl, from_jsonl,
                           summarize_failures, to_jsonl)
from repro.explore.records import ExploreRecord


# ---------------------------------------------------------------------------
# the injection harness itself
# ---------------------------------------------------------------------------
def test_fault_spec_parse():
    fs = faultinject.FaultSpec.parse("pnr:exc:2")
    assert (fs.site, fs.kind, fs.nth, fs.persistent) == ("pnr", "exc", 2,
                                                         False)
    fs = faultinject.FaultSpec.parse("schedule:budget:1+")
    assert (fs.site, fs.kind, fs.nth, fs.persistent) == ("schedule",
                                                         "budget", 1, True)
    for bad in ("pnr:exc", "pnr:boom:0", "pnr:exc:x", "a:b:c:d"):
        with pytest.raises(ValueError):
            faultinject.FaultSpec.parse(bad)


def test_fire_counts_occurrences():
    with armed("s:exc:1"):
        faultinject.fire("s")             # occurrence 0: silent
        with pytest.raises(InjectedFault):
            faultinject.fire("s")         # occurrence 1: fires
        faultinject.fire("s")             # occurrence 2: spent
    faultinject.fire("s")                 # disarmed: free


def test_persistent_spec_keeps_firing():
    with armed("s:exc:1+"):
        faultinject.fire("s")
        for _ in range(3):
            with pytest.raises(InjectedFault):
                faultinject.fire("s")


def test_budget_kind_carries_state():
    with armed("s:budget:0"):
        with pytest.raises(BudgetExceeded) as ei:
            faultinject.fire("s", pe="PE1")
    assert ei.value.budget.get("injected") is True


def test_truncate_kind_sets_flag_not_exception():
    with armed("s:truncate:0"):
        faultinject.fire("s")             # no raise
        assert faultinject.consume_flag("s") is True
        assert faultinject.consume_flag("s") is False


# ---------------------------------------------------------------------------
# per-pair isolation in the pipeline
# ---------------------------------------------------------------------------
def test_transient_fault_absorbed_by_serial_retry():
    apps, cfg = tiny_case()
    ex, res = run_explorer(apps, cfg, "pnr:exc:0")
    assert res.clean and not res.failures
    assert ex.metrics.counter("isolate.retry.pnr") == 1
    assert res.records(), "retry produced no records"


def test_persistent_fault_degrades_pair_groupmates_bit_identical():
    apps, cfg = tiny_case()
    clean = Explorer(apps, cfg)
    want = clean.pnr()

    ex = Explorer(apps, cfg)
    with armed("pnr:exc:0", "pnr.retry:exc:0"):
        got = ex.pnr()
    assert len(ex.failures) == 1
    f = ex.failures[0]
    assert f.stage == "pnr" and f.retried
    assert f.error_type == "InjectedFault"
    victim = (f.pe_name, f.app)
    assert victim not in got
    assert set(got) == set(want) - {victim}
    for pair in got:                      # pow2-bucket independence
        assert got[pair].placement.coords == want[pair].placement.coords
        assert got[pair].cost == want[pair].cost


def test_on_error_raise_fails_fast():
    apps, cfg = tiny_case()
    ex = Explorer(apps, cfg.replace(on_error="raise"))
    with armed("pnr:exc:0"):
        with pytest.raises(InjectedFault):
            ex.pnr()
    assert not ex.failures                # fail-fast records nothing


def test_failures_never_memoized(tmp_path):
    """A degraded pair recomputes on the next run — including against a
    persistent store — instead of replaying the failure."""
    from repro.explore import DiskStore
    apps, cfg = tiny_case()
    d = str(tmp_path / "store")
    ex1 = Explorer(apps, cfg, store=DiskStore(d))
    with armed("pnr:exc:0", "pnr.retry:exc:0"):
        res1 = ex1.run()
    assert res1.failures
    ex2 = Explorer(apps, cfg, store=DiskStore(d))
    res2 = ex2.run()                      # no faults armed: heals
    assert res2.clean
    assert {(r.pe_name, r.app) for r in res2.records()} \
        > {(r.pe_name, r.app) for r in res1.records()
           if r.fabric_area_um2 > 0}


# ---------------------------------------------------------------------------
# stage budgets: exhausted means degraded, never a hang
# ---------------------------------------------------------------------------
def test_anneal_budget_check():
    from repro.fabric import FabricSpec, lower, synthetic_netlist
    from repro.fabric.place import check_anneal_budget
    spec = FabricSpec(rows=4, cols=4)
    p = lower(synthetic_netlist(spec, seed=0), spec)
    check_anneal_budget(p, 2, 4, None)    # no budget: no-op
    check_anneal_budget(p, 2, 4, 10**9)   # generous budget: fine
    with pytest.raises(BudgetExceeded) as ei:
        check_anneal_budget(p, 2, 4, 1)
    assert ei.value.budget["max_states"] == 1
    assert ei.value.budget["states"] > 1


def test_cycle_budget_check():
    from repro.sim.cycle import check_cycle_budget

    class Prog:
        ii, latency, app_name = 4, 26, "conv"

        def total_cycles(self, iterations):
            return self.latency + self.ii * (iterations - 1)

    check_cycle_budget(Prog(), 3, None)
    check_cycle_budget(Prog(), 3, 10**6)
    with pytest.raises(BudgetExceeded) as ei:
        check_cycle_budget(Prog(), 3, 10)
    assert ei.value.budget["total_cycles"] == 34
    assert ei.value.budget["max_cycles"] == 10


def test_exhausted_budget_becomes_stage_failure():
    apps, cfg = tiny_case(anneal_max_states=1)
    ex, res = run_explorer(apps, cfg)
    assert res.failures
    assert all(f.stage == "pnr" for f in res.failures)
    assert all(f.error_type == "BudgetExceeded" for f in res.failures)
    assert all(f.budget["max_states"] == 1 for f in res.failures)
    assert ex.metrics.counter("budget_exhausted.pnr") == len(res.failures)
    # degraded, not dead: records still exist with mapping-level columns
    assert res.records()


# ---------------------------------------------------------------------------
# structured failure rows: round trips and summaries
# ---------------------------------------------------------------------------
def test_stage_failure_round_trip(tmp_path):
    e = BudgetExceeded("no schedule up to II=4", max_ii=4, mii=2)
    f = StageFailure.from_exception("schedule", e, pe_name="PE1",
                                    app="conv", retried=True)
    assert f.error_type == "BudgetExceeded"
    assert f.budget == {"max_ii": 4, "mii": 2}
    back = StageFailure.from_dict(f.to_dict())
    assert back == f

    path = str(tmp_path / "records.jsonl")
    to_jsonl([], path, failures=[f])
    assert failures_from_jsonl(path) == [f]
    assert from_jsonl(path) == []         # records reader skips failures

    assert summarize_failures([f, f]) == "schedule=2 (2 failures)"
    assert summarize_failures([]) == "no failures"


def test_stage_failure_rejects_malformed():
    with pytest.raises(RecordFormatError):
        StageFailure.from_dict({"kind": "stage_failure", "schema": 99,
                                "stage": "pnr"})
    with pytest.raises(RecordFormatError):
        StageFailure.from_dict({"kind": "nope"})


# ---------------------------------------------------------------------------
# hardened loaders: one-line actionable errors, no stack-trace spelunking
# ---------------------------------------------------------------------------
def test_config_from_dict_unknown_field():
    d = ExploreConfig(mode="per_app").to_dict()
    d["max_merg"] = 3                     # typo
    with pytest.raises(ConfigFormatError, match="unknown ExploreConfig"):
        ExploreConfig.from_dict(d)


def test_config_from_dict_wrong_type():
    d = ExploreConfig(mode="per_app").to_dict()
    d["max_merge"] = "three"
    with pytest.raises(ConfigFormatError, match="must be int"):
        ExploreConfig.from_dict(d)


def test_config_from_dict_future_schema():
    d = ExploreConfig(mode="per_app").to_dict()
    d["schema"] = 99
    with pytest.raises(ConfigFormatError, match="not supported"):
        ExploreConfig.from_dict(d)


def test_config_on_error_round_trip():
    cfg = ExploreConfig(mode="per_app", on_error="raise")
    assert ExploreConfig.from_dict(cfg.to_dict()).on_error == "raise"
    with pytest.raises(ValueError):
        ExploreConfig(mode="per_app", on_error="explode")


def test_record_from_dict_errors():
    row = {"kind_of": "wrong"}
    with pytest.raises(RecordFormatError, match="unknown"):
        ExploreRecord.from_dict({**_good_row(), "bogus_column": 1})
    with pytest.raises(RecordFormatError, match="schema"):
        ExploreRecord.from_dict({**_good_row(), "schema": 99})
    with pytest.raises(RecordFormatError, match="missing"):
        d = _good_row()
        d.pop("app")
        ExploreRecord.from_dict(d)
    with pytest.raises(RecordFormatError):
        ExploreRecord.from_dict(row)


def _good_row():
    from repro.explore.records import RECORD_SCHEMA
    return dict(schema=RECORD_SCHEMA, mode="per_app", config_key="k",
                n_merged=1, sim_bucket="", app="conv", pe_name="PE1",
                n_pes=4, total_ops=9, pe_area_um2=1.0, total_area_um2=4.0,
                energy_pj=1.0, energy_per_op_pj=0.1, fmax_ghz=1.0,
                ops_per_pe=2.0, unmapped=0)


def test_from_jsonl_names_bad_line(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps(_good_row()) + "\n")
        f.write("{truncated...\n")
    with pytest.raises(RecordFormatError, match=r"bad\.jsonl:2"):
        from_jsonl(path)


def test_history_skips_corrupt_lines(tmp_path, capsys):
    from repro.obs import history
    row = history.make_row("b", "smoke", {"m": 1.0},
                           manifest={"git_sha": "abc"}, ts=0.0)
    d = str(tmp_path)
    assert history.append(row, directory=d)
    with open(history.history_path(d, "b"), "a") as f:
        f.write("{torn write...\n")
    rows = history.load(d, "b")
    assert len(rows) == 1                 # good row survives
    assert "skipping corrupted history row" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# CLI: structured failure summaries and exit codes
# ---------------------------------------------------------------------------
def _cli(*argv):
    from repro.explore.__main__ import main
    return main(list(argv))


def test_cli_exit_codes_on_degraded_run(tmp_path, capsys):
    args = ("per-app", "--suite", "camera", "--min-support", "2",
            "--max-pattern-nodes", "4",
            "--inject-fault", "map:exc:0",
            "--inject-fault", "map.retry:exc:0")
    assert _cli(*args) == 1               # degraded: nonzero
    err = capsys.readouterr().err
    assert "# DEGRADED: map=1 (1 failure)" in err
    assert "Traceback" not in err
    assert _cli(*args, "--allow-partial") == 0


def test_cli_clean_run_exits_zero(capsys):
    assert _cli("per-app", "--suite", "camera", "--min-support", "2",
                "--max-pattern-nodes", "4") == 0
    assert "DEGRADED" not in capsys.readouterr().err


def test_cli_malformed_config_is_one_line_error(tmp_path, capsys):
    cfg = str(tmp_path / "cfg.json")
    with open(cfg, "w") as f:
        json.dump({"schema": 99, "mode": "per_app"}, f)
    assert _cli("per-app", "--config", cfg) == 2
    err = capsys.readouterr().err
    assert err.startswith("error: ")
    assert "Traceback" not in err


def test_cli_bad_fault_spec_is_one_line_error(capsys):
    assert _cli("per-app", "--suite", "camera",
                "--inject-fault", "pnr:frobnicate:0") == 1
    err = capsys.readouterr().err
    assert err.startswith("error: ValueError")
