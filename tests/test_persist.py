"""DiskStore: checksums, quarantine, atomicity, locking, crash-resume."""

import glob
import os
import pickle
import subprocess
import sys
import threading
import time

import pytest

from faults import armed, tiny_case
from repro.explore import DiskStore, Explorer, FileLock, ThreadSafeStore
from repro.explore.persist import MAGIC, STORE_SCHEMA, _key_filename
from repro.obs.metrics import MetricsRegistry


KEYS = [("mine", "abc", (2, 5)), ("pnr", ("k", 1), (4, 4)),
        ("sim", "z", (0,))]


def test_roundtrip_across_instances(tmp_path):
    d = str(tmp_path / "store")
    s = DiskStore(d)
    s[KEYS[0]] = [1, 2.5, "x"]
    s[KEYS[1]] = {"nested": (1, 2)}
    s[KEYS[2]] = None
    reg = MetricsRegistry()
    s2 = DiskStore(d, metrics=reg)
    assert s2[KEYS[0]] == [1, 2.5, "x"]
    assert s2[KEYS[1]] == {"nested": (1, 2)}
    assert s2[KEYS[2]] is None
    assert len(s2) == 3
    assert reg.counter("store.load") == 3
    assert reg.counter("store.quarantined") == 0


def test_atomic_write_leaves_no_tmp(tmp_path):
    d = str(tmp_path / "store")
    s = DiskStore(d)
    for i, k in enumerate(KEYS):
        s[k] = i
    assert not glob.glob(os.path.join(d, "*.tmp"))
    assert len(glob.glob(os.path.join(d, "*.entry"))) == len(KEYS)


def test_checksum_corruption_quarantined(tmp_path):
    d = str(tmp_path / "store")
    s = DiskStore(d)
    s[KEYS[0]] = "good"
    s[KEYS[1]] = "also good"
    victim = os.path.join(d, _key_filename(KEYS[0]))
    blob = bytearray(open(victim, "rb").read())
    blob[-1] ^= 0xFF                      # flip one payload byte
    open(victim, "wb").write(bytes(blob))

    reg = MetricsRegistry()
    s2 = DiskStore(d, metrics=reg)
    assert KEYS[0] not in s2              # recomputes instead of trusting
    assert s2[KEYS[1]] == "also good"     # neighbors unaffected
    assert reg.counter("store.quarantined") == 1
    qfile = os.path.join(s2.quarantine_dir, _key_filename(KEYS[0]))
    assert os.path.exists(qfile)
    reason = open(qfile + ".reason").read()
    assert "checksum mismatch" in reason


def test_torn_write_injection_quarantined(tmp_path):
    d = str(tmp_path / "store")
    s = DiskStore(d)
    with armed("store.write:truncate:0"):
        s[KEYS[0]] = list(range(100))     # committed, then torn
    assert s[KEYS[0]] == list(range(100))  # memory view still serves it
    reg = MetricsRegistry()
    s2 = DiskStore(d, metrics=reg)
    assert KEYS[0] not in s2
    assert reg.counter("store.quarantined") == 1
    reasons = glob.glob(os.path.join(s2.quarantine_dir, "*.reason"))
    assert reasons and "truncated payload" in open(reasons[0]).read()


def test_bad_magic_and_foreign_schema_quarantined(tmp_path):
    d = str(tmp_path / "store")
    DiskStore(d)                          # creates the directory
    with open(os.path.join(d, "garbage.entry"), "wb") as f:
        f.write(b"not a header at all\n\x00\x01")
    payload = pickle.dumps((("k",), 1))
    import hashlib
    import json
    with open(os.path.join(d, "future.entry"), "wb") as f:
        f.write(json.dumps({
            "magic": MAGIC, "schema": STORE_SCHEMA + 1,
            "sha256": hashlib.sha256(payload).hexdigest(),
            "size": len(payload)}).encode() + b"\n" + payload)
    reg = MetricsRegistry()
    s = DiskStore(d, metrics=reg)
    assert len(s) == 0
    assert reg.counter("store.quarantined") == 2
    assert not glob.glob(os.path.join(d, "*.entry"))


def test_unpicklable_value_stays_memory_only(tmp_path):
    d = str(tmp_path / "store")
    reg = MetricsRegistry()
    s = DiskStore(d, metrics=reg)
    s[KEYS[0]] = lambda: 1                # jit-handle stand-in
    assert KEYS[0] in s
    assert reg.counter("store.unpicklable") == 1
    assert DiskStore(d) is not None
    assert KEYS[0] not in DiskStore(d)    # memory-only: gone on reopen


def test_delete_removes_entry_file(tmp_path):
    d = str(tmp_path / "store")
    s = DiskStore(d)
    s[KEYS[0]] = 1
    fpath = os.path.join(d, _key_filename(KEYS[0]))
    assert os.path.exists(fpath)
    del s[KEYS[0]]
    assert KEYS[0] not in s
    assert not os.path.exists(fpath)


def test_crash_resume_bit_identical(tmp_path):
    """Kill after stage k (simulated by abandoning the Explorer), re-run
    against the same store: completed stages replay from disk and the
    final records are bit-identical to an uninterrupted run."""
    apps, cfg = tiny_case()
    want = [r.to_dict() for r in Explorer(apps, cfg).run().records()]

    d = str(tmp_path / "store")
    ex1 = Explorer(apps, cfg, store=DiskStore(d))
    ex1.pnr()                             # mine..pnr complete, then "crash"
    del ex1

    reg = MetricsRegistry()
    ex2 = Explorer(apps, cfg, store=DiskStore(d, metrics=reg),
                   metrics=reg)
    got = [r.to_dict() for r in ex2.run().records()]
    assert got == want
    # the resumed run replayed the persisted stages instead of redoing
    # them: zero mine/pnr misses, and the store served real entries
    assert ex2.metrics.counter("memo.miss.mine") == 0
    assert ex2.metrics.counter("memo.miss.pnr") == 0
    assert ex2.metrics.counter("memo.hit.pnr") > 0
    assert reg.counter("store.load") > 0
    assert reg.counter("store.quarantined") == 0
    # SimPrograms round-tripped through pickle (schedule stage was NOT
    # memoized before the crash, so sched entries were written by ex2;
    # a third explorer must replay those too)
    ex3 = Explorer(apps, cfg, store=DiskStore(d))
    assert [r.to_dict() for r in ex3.run().records()] == want
    assert ex3.metrics.counter("memo.miss.sched") == 0
    assert ex3.metrics.counter("memo.miss.sim") == 0


def test_filelock_mutual_exclusion(tmp_path):
    lock_path = str(tmp_path / "x.lock")
    order = []

    def worker(tag):
        with FileLock(lock_path):
            order.append((tag, "in"))
            time.sleep(0.05)
            order.append((tag, "out"))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # critical sections never interleave: every "in" is followed by the
    # same worker's "out"
    for i in range(0, len(order), 2):
        assert order[i][0] == order[i + 1][0]
        assert order[i][1] == "in" and order[i + 1][1] == "out"


def test_filelock_not_reentrant(tmp_path):
    lk = FileLock(str(tmp_path / "x.lock"))
    with lk:
        with pytest.raises(RuntimeError):
            lk.acquire()


def test_concurrent_writers_no_corruption(tmp_path):
    """N writers hammering one store directory (each its own DiskStore,
    like N server processes): every committed entry must verify clean
    on reopen — zero quarantines, and overlapping keys hold one of the
    values actually written."""
    d = str(tmp_path / "store")
    n_writers, n_keys = 4, 12
    errs = []

    def writer(wid):
        try:
            s = DiskStore(d)
            for i in range(n_keys):
                s[("k", i)] = {"writer": wid, "i": i,
                               "blob": list(range(200))}
        except Exception as e:       # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    reg = MetricsRegistry()
    s = DiskStore(d, metrics=reg)
    assert reg.counter("store.quarantined") == 0
    assert len(s) == n_keys
    for i in range(n_keys):
        v = s[("k", i)]
        assert v["i"] == i and v["writer"] in range(n_writers)
        assert v["blob"] == list(range(200))
    assert not glob.glob(os.path.join(d, "*.tmp"))


def test_concurrent_writers_corrupted_entry_quarantined(tmp_path):
    """A torn write into a store that concurrent writers filled degrades
    to exactly one quarantined entry; every writer's entries stay
    trusted."""
    d = str(tmp_path / "store")

    def writer(wid):
        s = DiskStore(d)
        for i in range(6):
            s[("ok", wid, i)] = wid * 100 + i

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    s0 = DiskStore(d)
    with armed("store.write:truncate:0"):
        s0[("torn", 0)] = list(range(50))    # committed, then torn

    reg = MetricsRegistry()
    s = DiskStore(d, metrics=reg)
    assert reg.counter("store.quarantined") == 1
    assert ("torn", 0) not in s              # recomputes, never trusted
    for w in range(3):
        for i in range(6):
            assert s[("ok", w, i)] == w * 100 + i
    reasons = glob.glob(os.path.join(s.quarantine_dir, "*.reason"))
    assert reasons and "truncated payload" in open(reasons[0]).read()


def test_read_through_adopts_foreign_writes(tmp_path):
    """A miss checks the directory before recomputing: an entry another
    process committed after our open is verified and adopted."""
    d = str(tmp_path / "store")
    rega, regb = MetricsRegistry(), MetricsRegistry()
    a = DiskStore(d, metrics=rega)
    b = DiskStore(d, metrics=regb)           # the "other process"
    b[KEYS[0]] = {"from": "b"}
    assert KEYS[0] in a                      # read-through, not a miss
    assert a[KEYS[0]] == {"from": "b"}
    assert rega.counter("store.readthrough") == 1

    # a corrupt foreign entry is quarantined on read-through, not trusted
    b[KEYS[1]] = "soon corrupt"
    victim = os.path.join(d, _key_filename(KEYS[1]))
    blob = bytearray(open(victim, "rb").read())
    blob[-1] ^= 0xFF
    open(victim, "wb").write(bytes(blob))
    with pytest.raises(KeyError):
        a[KEYS[1]]
    assert rega.counter("store.quarantined") == 1


def test_thread_safe_store_facade(tmp_path):
    """ThreadSafeStore serializes mapping ops from many threads over one
    shared inner store (the serving batcher's executor-thread shape)."""
    inner = DiskStore(str(tmp_path / "store"))
    s = ThreadSafeStore(inner)
    errs = []

    def worker(wid):
        try:
            for i in range(25):
                s[("t", wid, i)] = wid
                assert s[("t", wid, i)] == wid
                assert ("t", wid, i) in s
        except Exception as e:       # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(s) == 100
    del s[("t", 0, 0)]
    assert ("t", 0, 0) not in s
    assert len(list(iter(s))) == 99


@pytest.mark.slow
def test_kill9_resume_cli():
    """The real thing: SIGKILL mid-store-write via the CLI harness."""
    p = subprocess.run(
        [sys.executable, "-m", "repro.explore", "--resume-smoke"],
        capture_output=True, text=True, timeout=580,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "resume-smoke OK" in p.stdout
