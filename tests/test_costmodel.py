"""Cost model: the quantities behind Figs. 8/10/11 and Table I."""

import numpy as np
import pytest

from repro.core import baseline_datapath, evaluate_mapping, map_application
from repro.core.costmodel import vector_mac_asic_energy_per_op_pj
from repro.core.merge import add_pattern
from repro.core.pe import Datapath
from repro.graphir import pattern_from_spec, trace_scalar


def test_baseline_pe_area_plausible():
    """A 16-bit Garnet-class PE core is ~1e3 um^2 at 16 nm."""
    dp = baseline_datapath()
    assert 500 < dp.area_um2() < 3000
    assert 1.0 < dp.fmax_ghz() < 3.0


def test_energy_grows_with_active_units():
    dp = Datapath()
    cfg1 = add_pattern(dp, pattern_from_spec([("add", (-1, -1))]), "a")
    cfg2 = add_pattern(dp, pattern_from_spec(
        [("mul", (-1, -1)), ("add", (0, -1))]), "ma")
    assert dp.config_energy_pj(cfg2) > dp.config_energy_pj(cfg1)


def test_idle_units_cost_energy():
    dp = baseline_datapath()
    cfg = dp.configs["op:add"]
    e_full = dp.config_energy_pj(cfg, idle_fraction=0.55)
    e_isolated = dp.config_energy_pj(cfg, idle_fraction=0.0)
    assert e_full > e_isolated * 1.2     # glitching matters (Sec. V harris)


def test_asic_bound_beats_cgra():
    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c
    g = trace_scalar(conv4, ["i0", "i1", "i2", "i3",
                             "w0", "w1", "w2", "w3", "c"])
    base = baseline_datapath()
    c0 = evaluate_mapping(base, map_application(base, g, "conv"), "base")
    asic = vector_mac_asic_energy_per_op_pj()
    assert asic < c0.cgra_energy_per_op_pj / 3   # Table I ordering


def test_io_overhead_scales_with_inputs():
    dp2 = Datapath()
    add_pattern(dp2, pattern_from_spec([("add", (-1, -1))]), "a")
    dp3 = Datapath()
    add_pattern(dp3, pattern_from_spec(
        [("mul", (-1, -1)), ("add", (0, -1)), ("add", (1, -1))]), "b")
    # Sec. II-C: more PE inputs -> more CB area
    assert dp3.area_um2(include_io=True) - dp3.area_um2() > \
        dp2.area_um2(include_io=True) - dp2.area_um2()


def test_total_area_is_pe_times_count():
    def f(a, b, c):
        return a * b + c
    g = trace_scalar(f, ["a", "b", "c"])
    base = baseline_datapath()
    cost = evaluate_mapping(base, map_application(base, g, "f"), "base")
    assert cost.total_area_um2 == pytest.approx(
        cost.pe_area_um2 * cost.n_pes)
