"""Optimizer, data pipeline, checkpointing, trainer fault tolerance."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticLM, make_source
from repro.train import (AdamWConfig, adamw_update, build_train_step,
                         init_opt_state, lr_schedule)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, moment_dtype=jnp.float32)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    opt = init_opt_state(params, cfg)
    target = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=1e-2)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[-1] < lrs[50] < lrs[11]
    assert lrs[-1] >= cfg.lr_peak * cfg.lr_min_ratio - 1e-9


def test_grad_clip_applies():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params, cfg)
    _, _, metrics = adamw_update(params, {"w": jnp.asarray([100., 0., 0.])},
                                 opt, cfg)
    assert float(metrics["grad_norm"]) > 99.0


@pytest.mark.slow
def test_grad_accumulation_equivalence():
    """microbatches=2 must match microbatches=1 on the same global batch."""
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("llama3.2-1b").reduced(n_layers=1, d_model=32,
                                            d_ff=64, vocab=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10,
                          moment_dtype=jnp.float32)
    batch = {"inputs": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, cfg.vocab)}
    batch["targets"] = batch["inputs"]
    p1, _, m1 = build_train_step(cfg, opt_cfg)(
        params, init_opt_state(params, opt_cfg), batch)
    p2, _, m2 = build_train_step(cfg, opt_cfg, microbatches=2)(
        params, init_opt_state(params, opt_cfg), batch)
    # bf16 compute: microbatch reduction order shifts the loss at ~1e-3
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=8, n_hosts=2,
                     host_id=0, seed=3)
    a = SyntheticLM(cfg).batch_at(7)
    b = SyntheticLM(cfg).batch_at(7)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    assert a["inputs"].shape == (4, 32)
    other = SyntheticLM(DataConfig(vocab=100, seq_len=32, global_batch=8,
                                   n_hosts=2, host_id=1, seed=3)).batch_at(7)
    assert not np.array_equal(a["inputs"], other["inputs"])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    d = str(tmp_path)
    save_checkpoint(d, 42, tree)
    assert latest_step(d) == 42
    got = restore_checkpoint(d, 42, tree)
    for k in ("a", "step"):
        np.testing.assert_array_equal(np.asarray(got[k], np.float32),
                                      np.asarray(tree[k], np.float32))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_tmp_ignored(tmp_path):
    d = str(tmp_path)
    tree = {"x": jnp.zeros(2)}
    for s in (10, 20, 30, 40):
        save_checkpoint(d, s, tree, keep=2)
    assert latest_step(d) == 40
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 2
    # a crashed partial write must be ignored
    os.makedirs(os.path.join(d, "step_00000099.tmp0"))
    assert latest_step(d) == 40


@pytest.mark.slow
def test_trainer_fault_injection_resumes(tmp_path):
    """A step that raises resumes from the last checkpoint and completes."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train import Trainer, TrainerConfig, init_opt_state
    cfg = get_config("llama3.2-1b").reduced(n_layers=1, d_model=32,
                                            d_ff=64, vocab=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=20)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_cfg))
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    tr = Trainer(TrainerConfig(total_steps=20, ckpt_every=5,
                               ckpt_dir=str(tmp_path), log_every=5),
                 step, params, opt, data_cfg)
    state = tr.run(fail_at=12)
    assert state.restarts == 1
    assert state.step == 20
    assert latest_step(str(tmp_path)) == 20
