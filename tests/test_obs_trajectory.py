"""Performance-trajectory layer: manifests, noise-aware diffing, the
history store, and the regression detector (PR 7).

Covers the ISSUE acceptance points directly: manifest capture is
deterministic, repeats summaries carry median/IQR, a golden trace pair
with a known stage delta diffs correctly (exact series at zero
tolerance), history append is idempotent per (sha, bench, mode), and
``repro.obs.regress`` flags an injected synthetic regression while
passing on the committed artifacts.
"""

import importlib.util
import json
import os
import subprocess
import sys

import pytest

from repro import obs
from repro.obs import history as history_mod
from repro.obs import regress as regress_mod
from repro.obs.diff import (NoiseModel, diff_metrics, diff_stage_rows,
                            summarize_repeats)
from repro.obs.manifest import (MANIFEST_SCHEMA, RunManifest, capture,
                                validate_manifest)
from repro.obs.report import TraceFormatError, aggregate_stages, \
    load_trace_rows
from repro.obs.report import main as report_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# manifests
# ---------------------------------------------------------------------------
def test_manifest_capture_is_deterministic_and_valid():
    a = capture()
    b = capture()
    assert a == b                        # cached: literally the same record
    d = a.to_dict()
    assert validate_manifest(d) == []
    assert d["schema"] == MANIFEST_SCHEMA
    assert d["xla_cache"] in ("off", "cold", "warm")
    assert isinstance(d["cpu_count"], int) and d["cpu_count"] >= 1
    # round-trip through the validating constructor
    assert RunManifest.from_dict(d) == a


def test_manifest_refresh_keeps_stable_fields():
    a = capture().to_dict()
    b = capture(refresh=True).to_dict()
    for k in ("schema", "git_sha", "python", "jax", "jaxlib", "platform",
              "device_kind", "backend", "cpu_count"):
        assert a[k] == b[k]


def test_validate_manifest_rejects_bad_shapes():
    good = capture().to_dict()
    assert validate_manifest("nope") == \
        ["manifest is str, expected a dict"]
    missing = dict(good)
    del missing["git_sha"]
    assert any("missing field 'git_sha'" in e
               for e in validate_manifest(missing))
    unknown = dict(good, extra=1)
    assert any("unknown field 'extra'" in e
               for e in validate_manifest(unknown))
    assert any("schema" in e
               for e in validate_manifest(dict(good, schema=99)))
    assert any("cpu_count" in e
               for e in validate_manifest(dict(good, cpu_count=0)))
    assert any("xla_cache" in e
               for e in validate_manifest(dict(good, xla_cache="tepid")))
    with pytest.raises(ValueError, match="invalid manifest"):
        RunManifest.from_dict(dict(good, xla_cache="tepid"))


def test_written_artifacts_embed_the_manifest(tmp_path):
    # chrome trace
    tracer = obs.Tracer()
    with tracer.span("root"):
        pass
    trace_path = str(tmp_path / "t.trace.json")
    tracer.write_chrome(trace_path)
    doc = json.load(open(trace_path))
    assert validate_manifest(doc["metadata"]["manifest"]) == []

    # records jsonl header
    from repro.explore import read_manifest, to_jsonl
    rec_path = str(tmp_path / "records.jsonl")
    to_jsonl([], rec_path)
    man = read_manifest(rec_path)
    assert validate_manifest(man) == []
    # and from_jsonl skips the header transparently
    from repro.explore import from_jsonl
    assert from_jsonl(rec_path) == []


# ---------------------------------------------------------------------------
# repeats + noise model
# ---------------------------------------------------------------------------
def test_summarize_repeats_known_values():
    s = summarize_repeats([1.0, 2.0, 3.0, 4.0])
    assert s == {"n": 4, "median": 2.5, "iqr": 1.5, "min": 1.0, "max": 4.0}
    single = summarize_repeats([0.7])
    assert single["n"] == 1 and single["iqr"] == 0.0
    assert single["median"] == single["min"] == single["max"] == 0.7
    with pytest.raises(ValueError):
        summarize_repeats([])


def test_noise_model_threshold_takes_the_max_bound():
    nm = NoiseModel(abs_floor_s=0.005, rel_floor=0.10, iqr_k=3.0)
    assert nm.threshold(0.001) == 0.005            # abs floor dominates
    assert nm.threshold(10.0) == pytest.approx(1.0)  # rel floor dominates
    assert nm.threshold(1.0, iqr=0.5) == pytest.approx(1.5)  # iqr dominates


# ---------------------------------------------------------------------------
# diffing: golden trace pair with a known stage delta
# ---------------------------------------------------------------------------
def _rows(pnr_s, sim_s, sim_count=2):
    rows = [{"name": "pnr", "path": "pnr", "dur_s": pnr_s}]
    rows += [{"name": "simulate", "path": "simulate",
              "dur_s": sim_s / sim_count}] * sim_count
    return rows


def test_diff_stage_rows_golden_pair():
    # golden delta: pnr slowed 1.0s -> 1.5s (significant), simulate moved
    # within noise, and b gained an extra simulate span (exact count delta)
    a = _rows(pnr_s=1.0, sim_s=0.40, sim_count=2)
    b = _rows(pnr_s=1.5, sim_s=0.41, sim_count=3)
    deltas = {d.path: d for d in diff_stage_rows(
        a, b, noise=NoiseModel(abs_floor_s=0.005, rel_floor=0.10))}
    pnr = deltas["pnr"]
    assert pnr.kind == "time" and pnr.significant
    assert pnr.delta == pytest.approx(0.5)
    sim = deltas["simulate"]
    assert not sim.significant                     # 10ms on 0.4s: noise
    cnt = deltas["simulate#count"]
    assert cnt.kind == "exact" and cnt.significant  # 2 -> 3: zero tolerance
    assert deltas["pnr#count"].significant is False


def test_diff_stage_rows_added_and_removed_paths_are_significant():
    deltas = {d.path: d for d in diff_stage_rows(
        [{"name": "old", "dur_s": 0.1}], [{"name": "new", "dur_s": 0.1}])}
    assert deltas["old"].significant and deltas["old"].b is None
    assert deltas["new"].significant and deltas["new"].a is None


def test_diff_stage_rows_iqr_widens_the_bound():
    a = [{"name": "pnr", "dur_s": 1.0}]
    b = [{"name": "pnr", "dur_s": 1.3}]
    tight = diff_stage_rows(a, b)[0]
    assert tight.significant                        # 30% > 10% rel floor
    wide = diff_stage_rows(a, b, iqr={"pnr": 0.2})[0]
    assert not wide.significant                     # 3*IQR = 0.6 bound


def test_diff_metrics_exact_vs_timelike():
    a = {"counters": {"pnr.dispatch": 3, "memo.hit": 10},
         "gauges": {"mem.host_peak_bytes.pnr": 1000},
         "histograms": {"jax.compile.secs": {"sum": 1.0, "count": 4}}}
    b = {"counters": {"pnr.dispatch": 4, "memo.hit": 10},
         "gauges": {"mem.host_peak_bytes.pnr": 1000},
         "histograms": {"jax.compile.secs": {"sum": 1.05, "count": 4}}}
    deltas = {d.path: d for d in diff_metrics(a, b)}
    assert deltas["counters/pnr.dispatch"].significant   # exact: 3 != 4
    assert not deltas["counters/memo.hit"].significant
    assert not deltas["gauges/mem.host_peak_bytes.pnr"].significant
    # second-valued histogram sum is noise-thresholded, not exact
    assert deltas["histograms/jax.compile.secs.sum"].kind == "time"
    assert not deltas["histograms/jax.compile.secs.sum"].significant
    assert not deltas["histograms/jax.compile.secs.count"].significant


def test_diff_traces_cli_flags_exact_drift(tmp_path):
    from repro.obs.diff import main as diff_main
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    for path, count in ((a, 2), (b, 3)):
        with open(path, "w") as fh:
            for _ in range(count):
                fh.write(json.dumps({"name": "pnr", "dur_s": 0.1}) + "\n")
    assert diff_main([a, a]) == 0
    assert diff_main([a, b]) == 1                  # span count grew: exact


# ---------------------------------------------------------------------------
# history store
# ---------------------------------------------------------------------------
def _mk_row(sha, metric_val, mode="full", ts=0.0):
    man = dict(capture().to_dict(), git_sha=sha)
    return history_mod.make_row("bench_x", mode,
                                {"serial_s": metric_val, "speedup": 2.0},
                                manifest=man, ts=ts)


def test_history_append_is_idempotent_per_sha_bench_mode(tmp_path):
    d = str(tmp_path / "hist")
    assert history_mod.append(_mk_row("aaa", 1.0), directory=d) is True
    assert history_mod.append(_mk_row("aaa", 99.0), directory=d) is False
    assert history_mod.append(_mk_row("aaa", 1.0, mode="smoke"),
                              directory=d) is True
    assert history_mod.append(_mk_row("bbb", 2.0), directory=d) is True
    rows = history_mod.load(d, "bench_x")
    assert len(rows) == 3
    # first measurement wins: the 99.0 re-run never landed
    assert rows[0]["metrics"]["serial_s"] == 1.0


def test_history_rolling_stats_windows_and_modes(tmp_path):
    d = str(tmp_path / "hist")
    for i in range(12):
        history_mod.append(_mk_row(f"sha{i}", float(i), ts=float(i)),
                           directory=d)
    rows = history_mod.load(d, "bench_x")
    stats = history_mod.rolling_stats(rows, "serial_s", mode="full",
                                      window=4)
    assert stats["n"] == 4 and stats["median"] == 9.5   # last 4: 8..11
    assert history_mod.rolling_stats(rows, "serial_s", mode="smoke") is None
    assert history_mod.rolling_stats(rows, "nope") is None


def test_history_load_rejects_unknown_schema(tmp_path):
    d = str(tmp_path / "hist")
    os.makedirs(d)
    with open(history_mod.history_path(d, "bench_x"), "w") as fh:
        fh.write(json.dumps({"schema": 99, "bench": "bench_x"}) + "\n")
    with pytest.raises(ValueError, match="history schema"):
        history_mod.load(d, "bench_x")


def test_history_path_is_filename_safe():
    p = history_mod.history_path("h", "pnr_bench/v2")
    assert "/v2" not in os.path.basename(p)
    assert p.endswith("pnr_bench_v2.jsonl")


# ---------------------------------------------------------------------------
# the regression detector
# ---------------------------------------------------------------------------
def _explore_doc(serial_s=10.0, grouped_s=2.0, dispatches=3):
    return {
        "bench": "explore_pnr_batch", "mode": "full",
        "manifest": capture().to_dict(),
        "serial_dispatches": 11, "grouped_dispatches": dispatches,
        "serial_s": serial_s, "grouped_s": grouped_s,
        "speedup": round(serial_s / grouped_s, 2),
        "repeats": {"n": 3,
                    "serial_s": summarize_repeats([serial_s] * 3),
                    "grouped_s": summarize_repeats([grouped_s] * 3)},
        "metrics": {"pnr_dispatch": dispatches, "memo_hit": 5},
    }


def _seed_history(tmp_path, n=4):
    d = str(tmp_path / "hist")
    for i in range(n):
        doc = _explore_doc()
        bench, mode, metrics, _ = regress_mod.flatten_bench(doc)
        man = dict(doc["manifest"], git_sha=f"seed{i}")
        history_mod.append(
            history_mod.make_row(bench, mode, metrics, manifest=man,
                                 ts=float(i)), directory=d)
    return d


def test_regress_passes_on_a_steady_trajectory(tmp_path):
    d = _seed_history(tmp_path)
    findings = regress_mod.check_artifact(_explore_doc(), "x.json",
                                          history_dir=d)
    assert all(f.status != "regress" for f in findings)


def test_regress_flags_injected_synthetic_regression(tmp_path):
    d = _seed_history(tmp_path)
    # inject: grouped wall-clock x3, dispatch count grew, speedup eroded
    bad = _explore_doc(grouped_s=6.0, dispatches=5)
    findings = regress_mod.check_artifact(bad, "x.json", history_dir=d)
    by = {f.metric: f for f in findings}
    assert by["grouped_s"].status == "regress"
    assert by["grouped_dispatches"].status == "regress"
    assert by["metrics.pnr_dispatch"].status == "regress"
    assert by["speedup"].status == "regress"
    assert by["serial_s"].status == "ok"
    # smoke downgrades wall-clock/ratio drifts but count growth still fails
    smoke = {f.metric: f for f in regress_mod.check_artifact(
        bad, "x.json", history_dir=d, smoke=True)}
    assert smoke["grouped_s"].status == "warn"
    assert smoke["speedup"].status == "warn"
    assert smoke["grouped_dispatches"].status == "regress"


def test_regress_no_baseline_bootstraps(tmp_path):
    findings = regress_mod.check_artifact(
        _explore_doc(), "x.json", history_dir=str(tmp_path / "empty"))
    assert {f.status for f in findings if f.kind in ("time", "ratio",
                                                     "count")} \
        == {"no-baseline"}


def test_regress_missing_or_invalid_manifest_is_a_regression(tmp_path):
    doc = _explore_doc()
    del doc["manifest"]
    findings = regress_mod.check_artifact(doc, "x.json",
                                          history_dir=str(tmp_path))
    assert any(f.metric == "manifest" and f.status == "regress"
               for f in findings)
    doc = _explore_doc()
    doc["manifest"]["xla_cache"] = "tepid"
    findings = regress_mod.check_artifact(doc, "x.json",
                                          history_dir=str(tmp_path))
    assert any(f.metric == "manifest" and f.status == "regress"
               for f in findings)


def test_regress_flag_metrics_fail_hard_even_in_smoke(tmp_path):
    doc = {
        "schema": "pnr_bench/v2", "smoke": True,
        "manifest": capture().to_dict(),
        "repeats": {"n": 1},
        "sizes": [{"rows": 8, "cols": 8, "delta_wall_s": 0.1,
                   "full_wall_s": 0.2, "speedup": 2.0,
                   "repeats": {"n": 1},
                   "bit_identical": False}],
    }
    findings = regress_mod.check_artifact(doc, "x.json",
                                          history_dir=str(tmp_path),
                                          smoke=True)
    by = {f.metric: f for f in findings}
    assert by["8x8.bit_identical"].status == "regress"


def test_regress_uses_fresh_repeats_iqr(tmp_path):
    d = _seed_history(tmp_path)
    # a noisy fresh measurement: median drifted +30% but the artifact's own
    # IQR documents that spread, so 3*IQR absorbs it
    doc = _explore_doc()
    doc["grouped_s"] = 2.6
    doc["repeats"]["grouped_s"] = summarize_repeats([1.8, 2.6, 3.4])
    findings = {f.metric: f for f in regress_mod.check_artifact(
        doc, "x.json", history_dir=d)}
    assert findings["grouped_s"].status == "ok"


def test_regress_cli_append_and_detect(tmp_path):
    d = str(tmp_path / "hist")
    art = str(tmp_path / "BENCH_x.json")
    with open(art, "w") as fh:
        json.dump(_explore_doc(), fh)
    assert regress_mod.main([art, "--history", d, "--append"]) == 0
    assert len(history_mod.load(d, "explore_pnr_batch")) == 1
    # same sha: idempotent
    assert regress_mod.main([art, "--history", d, "--append"]) == 0
    assert len(history_mod.load(d, "explore_pnr_batch")) == 1
    bad = str(tmp_path / "BENCH_bad.json")
    with open(bad, "w") as fh:
        json.dump(_explore_doc(dispatches=7), fh)
    assert regress_mod.main([bad, "--history", d]) == 1


def test_regress_passes_on_committed_artifacts():
    """The committed BENCH_*.json + committed history must stay green —
    this is the tier-1 CI step run as a test."""
    arts = sorted(
        p for p in (os.path.join(REPO, "results", f)
                    for f in os.listdir(os.path.join(REPO, "results")))
        if os.path.basename(p).startswith("BENCH_")
        and p.endswith(".json"))
    assert arts, "no committed BENCH_*.json artifacts"
    hist = os.path.join(REPO, "results", "history")
    for path in arts:
        with open(path) as fh:
            doc = json.load(fh)
        findings = regress_mod.check_artifact(doc, path, history_dir=hist,
                                              smoke=True)
        bad = [f for f in findings if f.status == "regress"]
        assert not bad, "\n".join(f.line() for f in bad)


def test_flatten_bench_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown benchmark kind"):
        regress_mod.flatten_bench({"bench": "mystery"})


# ---------------------------------------------------------------------------
# report CLI hardening
# ---------------------------------------------------------------------------
def test_report_empty_trace_is_a_one_line_error(tmp_path, capsys):
    path = str(tmp_path / "empty.trace.json")
    open(path, "w").close()
    with pytest.raises(TraceFormatError, match="empty trace file"):
        load_trace_rows(path)
    assert report_main([path]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error: ") and "Traceback" not in err


def test_report_truncated_trace_is_a_one_line_error(tmp_path, capsys):
    path = str(tmp_path / "trunc.jsonl")
    with open(path, "w") as fh:
        fh.write(json.dumps({"name": "pnr", "dur_s": 0.1}) + "\n")
        fh.write('{"name": "simulate", "dur_')       # torn write
    with pytest.raises(TraceFormatError, match="line 2"):
        load_trace_rows(path)
    assert report_main([path]) == 2
    assert "truncated" in capsys.readouterr().err


def test_report_missing_file_is_a_one_line_error(capsys):
    assert report_main(["/definitely/not/here.json"]) == 2
    assert capsys.readouterr().err.startswith("error: ")


def test_aggregate_stages_orders_ties_deterministically():
    rows = [{"name": n, "dur_s": 0.25} for n in ("zeta", "alpha", "mid")]
    rows += [{"name": "big", "dur_s": 1.0}]
    names = [a["name"] for a in aggregate_stages(rows)]
    assert names == ["big", "alpha", "mid", "zeta"]
    # same rows, shuffled input order -> same table
    names2 = [a["name"] for a in aggregate_stages(list(reversed(rows)))]
    assert names2 == names


# ---------------------------------------------------------------------------
# memory observability
# ---------------------------------------------------------------------------
def test_stage_memory_sets_gauges_under_telemetry():
    from repro.obs.memprof import stage_memory
    reg = obs.MetricsRegistry()
    obs.enable_telemetry()
    try:
        with stage_memory(reg, "stage_a"):
            blob = bytearray(2_000_000)
            assert blob is not None
    finally:
        obs.enable_telemetry(False)
    gauges = reg.to_dict()["gauges"]
    assert gauges["mem.host_peak_bytes.stage_a"] >= 2_000_000
    assert gauges["mem.device_bytes.stage_a"] >= 0


def test_stage_memory_is_a_noop_when_telemetry_off():
    from repro.obs.memprof import stage_memory
    reg = obs.MetricsRegistry()
    with stage_memory(reg, "stage_a"):
        pass
    assert reg.to_dict()["gauges"] == {}
    with stage_memory(None, "stage_a"):       # registry-less: also a no-op
        pass


# ---------------------------------------------------------------------------
# the stdlib gate + trend tables
# ---------------------------------------------------------------------------
def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO, "results", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_requires_manifest_and_repeats():
    cb = _load_script("check_bench")
    doc = _explore_doc()
    doc["bit_identical"] = doc["ii_identical"] = doc["verified"] = True
    errors = []
    cb._manifest(doc, "x.json", errors)
    cb._repeats(doc, "x.json", errors)
    assert errors == []
    errors = []
    cb._manifest({}, "x.json", errors)
    assert any("missing manifest" in e for e in errors)
    errors = []
    cb._manifest(dict(doc, manifest=dict(doc["manifest"], rogue=1)),
                 "x.json", errors)
    assert any("unknown manifest key 'rogue'" in e for e in errors)
    errors = []
    cb._repeats({"repeats": {"n": 0}}, "x.json", errors)
    assert any("positive int" in e for e in errors)
    errors = []
    cb._repeats({}, "x.json", errors)
    assert any("missing repeats" in e for e in errors)
    # the contract mirrors must not drift
    from repro.obs import manifest as manifest_mod
    import dataclasses
    assert cb.MANIFEST_KEYS == {
        f.name for f in dataclasses.fields(manifest_mod.RunManifest)}
    assert cb.MANIFEST_SCHEMA == manifest_mod.MANIFEST_SCHEMA
    assert tuple(cb.XLA_CACHE_STATES) == manifest_mod.XLA_CACHE_STATES


def test_check_bench_passes_on_committed_artifacts():
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "results", "check_bench.py")],
        capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr


def test_make_tables_trend_and_manifest_skip(tmp_path):
    mt = _load_script("make_tables")
    # load() skips manifest header lines (records jsonl)
    p = str(tmp_path / "rows.jsonl")
    with open(p, "w") as fh:
        fh.write(json.dumps({"schema": 2,
                             "manifest": capture().to_dict()}) + "\n")
        fh.write(json.dumps({"app": "conv", "x": 1}) + "\n")
    rows = mt.load(p)
    assert rows == [{"app": "conv", "x": 1}]
    # trend table renders committed history when present, or the synthetic
    d = str(tmp_path / "hist")
    for i in range(3):
        history_mod.append(_mk_row(f"s{i}", 1.0 + i, ts=float(i)),
                           directory=d)
    table = mt.trend_table(d)
    assert "### bench_x" in table and "| s0" in table
    assert "speedup" in table and "serial_s" in table
    assert mt.trend_table(str(tmp_path / "none")) == "(no history rows yet)"


def test_explorer_forget_purges_only_named_stages():
    from repro.apps import ml_graphs
    from repro.explore import ExploreConfig, Explorer
    from repro.core import MiningConfig
    apps = dict(list(ml_graphs().items())[:2])
    ex = Explorer(apps, ExploreConfig(
        mode="per_app",
        mining=MiningConfig(min_support=3, max_pattern_nodes=4,
                            time_budget_s=5, max_patterns_per_level=10)))
    mapped = ex.map()
    assert ex.forget("pnr") == 0            # nothing pnr'd yet
    assert ex.forget("map") >= 1            # map entries purged
    assert ex.forget("map") == 0            # ... and purged only once
    remapped = ex.map()                     # recomputes cleanly after forget
    assert sorted(remapped) == sorted(mapped)
