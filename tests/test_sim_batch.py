"""Batch-first schedule/simulate: the batched paths must be bit-identical
to the per-pair paths, regardless of how pairs are grouped.

The contract mirrors the one the batched placer met in the pnr stage:
padding is per-program (bucket shapes), seeding is content-derived, and
grouping is purely a throughput decision — never visible in the results.
"""

import zlib
from collections import defaultdict

import numpy as np
import pytest

from repro.apps import image_graphs
from repro.core import baseline_datapath, map_application
from repro.core.dse import app_ops
from repro.fabric import FabricSpec, place_and_route
from repro.sim import (build_sim, build_sim_batch, fabric_signature,
                       modulo_schedule, modulo_schedule_batch, random_inputs,
                       sim_signature, simulate, simulate_batch)

SPEC = FabricSpec(rows=8, cols=8)
FAST = dict(backend="python", chains=1, sweeps=8)


def _pnr(name, app):
    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, name)
    return dp, mapping, place_and_route(dp, mapping, app, SPEC, **FAST)


@pytest.fixture(scope="module")
def fig8_pnrs():
    """The paper's Fig. 8 image apps, placed and routed (camera and
    laplacian auto-fit beyond 8x8, so the batch spans several fabric
    signatures — singleton and multi-pair lockstep groups both run)."""
    apps = image_graphs()
    return {name: (_pnr(name, app), app) for name, app in apps.items()}


# ---------------------------------------------------------------------------
# schedule batching: lockstep == solo, per pair
# ---------------------------------------------------------------------------
def test_schedule_batch_ii_equivalence_fig8(fig8_pnrs):
    items, solo = [], []
    for name, ((dp, mapping, pnr), app) in sorted(fig8_pnrs.items()):
        items.append((pnr.netlist, pnr.placement, pnr.routes, pnr.spec))
        solo.append(modulo_schedule(pnr.netlist, pnr.placement, pnr.routes,
                                    pnr.spec))
    batch = modulo_schedule_batch(items)
    assert len(batch) == len(solo)
    for s, b in zip(solo, batch):
        assert b.ii == s.ii and b.min_ii == s.min_ii
        assert b.start == s.start                  # full schedule, not just II
        assert b.latency == s.latency and b.attempts == s.attempts
        assert b.hop_time == s.hop_time and b.capture == s.capture


def test_schedule_batch_groups_by_fabric_signature(fig8_pnrs):
    sigs = {fabric_signature(pnr.spec)
            for (_, _, pnr), _ in fig8_pnrs.values()}
    assert len(sigs) > 1                # camera/laplacian auto-fit past 8x8
    from collections import Counter
    stats = Counter()
    items = [(pnr.netlist, pnr.placement, pnr.routes, pnr.spec)
             for (_, _, pnr), _ in (fig8_pnrs[k] for k in sorted(fig8_pnrs))]
    modulo_schedule_batch(items, stats=stats)
    assert stats["sched_group"] == len(sigs)


def test_build_sim_batch_matches_build_sim(fig8_pnrs):
    (dp, mapping, pnr), app = fig8_pnrs["gaussian"]
    solo, _ = build_sim(dp, mapping, app, pnr=pnr)
    batch = build_sim_batch([(dp, mapping, app, pnr)])
    assert len(batch) == 1
    assert batch[0].ii == solo.ii
    assert np.array_equal(batch[0].opcodes, solo.opcodes)
    assert np.array_equal(batch[0].fire_time, solo.fire_time)


# ---------------------------------------------------------------------------
# simulate batching: one vmapped scan == per-program scans, bit for bit
# ---------------------------------------------------------------------------
def test_simulate_batch_bit_identical_and_grouping_independent(fig8_pnrs):
    progs, inputs, serial = {}, {}, {}
    for name in ("gaussian", "harris"):
        (dp, mapping, pnr), app = fig8_pnrs[name]
        prog, _ = build_sim(dp, mapping, app, pnr=pnr)
        progs[name] = prog
        inputs[name] = random_inputs(prog, 3, 2,
                                     seed=zlib.crc32(name.encode()) & 0xFFFF)
        serial[name] = simulate(prog, inputs[name])

    # singleton batches: padding alone must not change a single bit
    for name, prog in progs.items():
        res = simulate_batch([prog], [inputs[name]])[0]
        assert np.array_equal(res.outputs, serial[name].outputs)
        assert res.ii == serial[name].ii
        assert res.cycles == serial[name].cycles

    # grouped batches: members read the same outputs they read alone
    by_sig = defaultdict(list)
    for name, prog in progs.items():
        by_sig[sim_signature(prog, 3, 2)].append(name)
    for members in by_sig.values():
        batch = simulate_batch([progs[n] for n in members],
                               [inputs[n] for n in members])
        for n, res in zip(members, batch):
            assert np.array_equal(res.outputs, serial[n].outputs), n


def test_simulate_batch_rejects_bad_groups(fig8_pnrs):
    (dp, mapping, pnr), app = fig8_pnrs["gaussian"]
    prog, _ = build_sim(dp, mapping, app, pnr=pnr)
    x = random_inputs(prog, 2, 1, seed=0)
    with pytest.raises(ValueError, match="backend"):
        simulate_batch([prog], [x], backend="pallas")
    with pytest.raises(ValueError, match="1:1"):
        simulate_batch([prog], [x, x])
    # mixed (B, K) shapes cannot share a dispatch
    with pytest.raises(ValueError):
        simulate_batch([prog, prog], [x, random_inputs(prog, 3, 2, seed=0)])


def test_sim_signature_floors_are_static():
    """Bucket floors must stay constants: a program's bucket (and padded
    lowering) may depend only on the program itself."""
    from repro.sim.cycle import _SIG_FLOORS
    from repro.kernels.tiling import pow2_bucket
    assert all(f == pow2_bucket(f) for f in _SIG_FLOORS)


# ---------------------------------------------------------------------------
# kernels: masked dispatch == plain dispatch on active lanes, 0 elsewhere
# ---------------------------------------------------------------------------
def test_alu_step_masked_matches_jnp_on_active_lanes():
    from repro.kernels.sim_step import (alu_step_jnp, alu_step_masked,
                                        op_table)

    ops = op_table(["add", "mul", "sub", "max"])
    rng = np.random.default_rng(3)
    n, b = 24, 4
    codes = rng.integers(0, len(ops), n).astype(np.int32)
    a = rng.standard_normal((b, n)).astype(np.float32)
    bb = rng.standard_normal((b, n)).astype(np.float32)
    c = rng.standard_normal((b, n)).astype(np.float32)
    active = rng.integers(0, 2, n).astype(bool)
    want = np.asarray(alu_step_jnp(codes, a, bb, c, ops))
    got = np.asarray(alu_step_masked(codes, a, bb, c, ops, active))
    assert np.array_equal(got[:, active], want[:, active])
    assert np.all(got[:, ~active] == 0.0)
