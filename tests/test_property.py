"""Hypothesis property tests on the system's core invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Datapath, add_pattern, maximal_independent_set,
                        validate_config)
from repro.graphir import Graph
from repro.graphir.ops import OPS

# ops safe for random-pattern property testing (total functions)
_SAFE_OPS = ["add", "sub", "mul", "min", "max", "abs", "neg"]


@st.composite
def random_pattern(draw):
    """Connected random DAG of 2..5 safe ops (+ optional const leaf)."""
    n = draw(st.integers(2, 5))
    g = Graph()
    ids = []
    for i in range(n):
        op = draw(st.sampled_from(_SAFE_OPS))
        nid = g.add_node(op)
        # connect to a previous node on port 0 to stay connected
        if ids:
            src = draw(st.sampled_from(ids))
            arity = OPS[op].arity
            port = draw(st.integers(0, arity - 1)) if arity else 0
            g.add_edge(src, nid, port)
        ids.append(nid)
    if draw(st.booleans()):
        c = g.add_node("const", value=draw(st.floats(-2, 2, allow_nan=False)))
        # feed const into a free port if one exists
        from repro.graphir.graph import free_in_ports
        free = free_in_ports(g)
        free = [fp for fp in free if g.nodes[fp[0]] != "const"]
        if free:
            node, port = free[draw(st.integers(0, len(free) - 1))]
            g.add_edge(c, node, port)
    return g


@settings(max_examples=40, deadline=None)
@given(pats=st.lists(random_pattern(), min_size=1, max_size=3))
def test_merged_datapath_implements_every_pattern(pats):
    """THE merging invariant: after merging any sequence of patterns, every
    config still computes exactly its source subgraph through the muxes."""
    dp = Datapath()
    for i, p in enumerate(pats):
        add_pattern(dp, p, f"cfg{i}", validate=False)
    for name, cfg in dp.configs.items():
        ok, msg = validate_config(dp, cfg, trials=3)
        assert ok, f"{name}: {msg}"


@settings(max_examples=40, deadline=None)
@given(pats=st.lists(random_pattern(), min_size=2, max_size=3))
def test_merging_never_exceeds_disjoint_area(pats):
    merged = Datapath()
    total_disjoint = 0.0
    for i, p in enumerate(pats):
        add_pattern(merged, p, f"cfg{i}", validate=False)
        solo = Datapath()
        add_pattern(solo, p, "only", validate=False)
        total_disjoint += solo.area_um2()
    # merging may add muxes/config bits but must beat fully disjoint
    # datapaths on unit area; allow small bookkeeping slack
    assert merged.area_um2() <= total_disjoint * 1.05 + 50.0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.frozensets(st.integers(0, 12), min_size=1, max_size=4),
                min_size=1, max_size=12))
def test_mis_independent_and_maximal(sets):
    picked = maximal_independent_set(sets)
    chosen = [sets[i] for i in picked]
    # independent
    for i in range(len(chosen)):
        for j in range(i + 1, len(chosen)):
            assert not (chosen[i] & chosen[j])
    # maximal: every unpicked set conflicts with some picked set
    picked_union = set()
    for s in chosen:
        picked_union |= s
    for i, s in enumerate(sets):
        if i not in picked:
            assert s & picked_union


@settings(max_examples=30, deadline=None)
@given(random_pattern())
def test_canonical_label_invariant_under_relabeling(g):
    assert g.canonical_label() == g.relabeled().canonical_label()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=300))
def test_int8_compression_error_bound(vals):
    """Quantization error <= half an LSB of the block scale."""
    import jax.numpy as jnp
    from repro.sharding.compression import _quantize, BLOCK
    x = jnp.asarray(np.asarray(vals, np.float32))
    q, scale = _quantize(x)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:x.shape[0]]
    err = np.abs(np.asarray(deq) - np.asarray(x))
    n = x.shape[0]
    pad = (-n) % BLOCK
    scales = np.repeat(np.asarray(scale)[:, 0], BLOCK)[:n]
    assert np.all(err <= scales * 0.5 + 1e-6)


# ---------------------------------------------------------------------------
# placer: delta move scoring is bit-identical to full recompute
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(net_seed=st.integers(0, 10_000),
       fill=st.floats(0.25, 0.95),
       fanout=st.integers(1, 5),
       anneal_seed=st.integers(0, 1_000))
def test_property_delta_equals_full_recompute(net_seed, fill, fanout,
                                              anneal_seed):
    """Random netlists, random seeds: the delta-scored annealer accepts
    exactly the moves the full-recompute annealer accepts, so placements
    and costs come back bit-identical."""
    from repro.fabric import FabricSpec, synthetic_netlist
    from repro.fabric.place import anneal_jax, lower

    spec = FabricSpec(rows=4, cols=4)
    nl = synthetic_netlist(spec, fill=fill, seed=net_seed,
                           max_fanout=fanout)
    p = lower(nl, spec)
    s_d, c_d = anneal_jax(p, chains=2, seed=anneal_seed, sweeps=3,
                          score_mode="delta")
    s_f, c_f = anneal_jax(p, chains=2, seed=anneal_seed, sweeps=3,
                          score_mode="full")
    assert np.array_equal(s_d, s_f)
    assert np.array_equal(c_d, c_f)


# ---------------------------------------------------------------------------
# time-domain subsystem: random graphs simulate bit-exactly
# ---------------------------------------------------------------------------
_SIM_OPS = ["add", "sub", "mul", "min", "max"]


@st.composite
def random_app_graph(draw):
    """Random small application DAG over IEEE-exact binary ops, with named
    inputs, optional integral consts, and 1-2 marked outputs."""
    n_in = draw(st.integers(2, 4))
    n_ops = draw(st.integers(3, 8))
    g = Graph()
    pool = [g.add_node("input", name=f"i{k}") for k in range(n_in)]
    for _ in range(draw(st.integers(0, 2))):
        pool.append(g.add_node("const",
                               value=float(draw(st.integers(-4, 4)))))
    input_used = False
    for _ in range(n_ops):
        op = draw(st.sampled_from(_SIM_OPS))
        nid = g.add_node(op)
        for port in range(2):
            if not input_used and port == 0:
                src = pool[0]                  # guarantee an array input
                input_used = True
            else:
                src = draw(st.sampled_from(pool))
            g.add_edge(src, nid, port)
        pool.append(nid)
    compute = [n for n, op in g.nodes.items()
               if op not in ("input", "const")]
    g.mark_output(compute[-1])
    extra = draw(st.sampled_from(compute))
    if extra != compute[-1]:
        g.mark_output(extra)
    return g


@settings(max_examples=12, deadline=None)
@given(random_app_graph())
def test_property_simulated_array_equals_interp(app):
    """Full time-domain flow on a random graph bit-matches the interpreter
    (map -> place -> route -> modulo-schedule -> cycle-accurate sim)."""
    from repro.core import baseline_datapath, map_application
    from repro.core.dse import app_ops
    from repro.fabric import FabricSpec
    from repro.sim import verify_mapping

    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, "prop")
    assert not mapping.unmapped
    report = verify_mapping(dp, mapping, app, FabricSpec(4, 4),
                            iterations=2, batch=2, place_backend="python",
                            chains=1, sweeps=8)
    assert report.bit_exact and report.max_abs_err == 0.0, report.row()


# ---------------------------------------------------------------------------
# batch-first schedule/simulate: grouping never changes a bit
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(apps=st.lists(random_app_graph(), min_size=2, max_size=3),
       seed=st.integers(0, 1000))
def test_property_sim_batch_independent_of_grouping(apps, seed):
    """Random graphs, random seeds: batched modulo schedules equal the
    per-pair schedules exactly, and batched simulation returns the same
    bits whether a program runs alone, with its bucket-mates, or in any
    other bucket composition — the serial per-pair result is the
    grouping-independent reference both must hit."""
    from repro.core import baseline_datapath, map_application
    from repro.core.dse import app_ops
    from repro.fabric import FabricSpec, place_and_route
    from repro.sim import (build_sim, build_sim_batch, random_inputs,
                           sim_signature, simulate, simulate_batch)

    items, solo_progs = [], []
    for i, app in enumerate(apps):
        dp = baseline_datapath(app_ops(app))
        mapping = map_application(dp, app, f"prop{i}")
        assert not mapping.unmapped
        pnr = place_and_route(dp, mapping, app, FabricSpec(4, 4),
                              backend="python", chains=1, sweeps=4,
                              seed=seed)
        items.append((dp, mapping, app, pnr))
        solo_progs.append(build_sim(dp, mapping, app, pnr=pnr)[0])

    batch_progs = build_sim_batch(items)
    for s, b in zip(solo_progs, batch_progs):
        assert b.ii == s.ii and b.latency == s.latency
        assert b.schedule.start == s.schedule.start

    inputs = [random_inputs(p, 2, 2, seed=seed + i)
              for i, p in enumerate(solo_progs)]
    serial = [simulate(p, x) for p, x in zip(solo_progs, inputs)]
    # one grouping: singletons
    for i, p in enumerate(batch_progs):
        res = simulate_batch([p], [inputs[i]])[0]
        assert np.array_equal(res.outputs, serial[i].outputs)
    # another grouping: full buckets
    by_sig = {}
    for i, p in enumerate(batch_progs):
        by_sig.setdefault(sim_signature(p, 2, 2), []).append(i)
    for idxs in by_sig.values():
        batch = simulate_batch([batch_progs[i] for i in idxs],
                               [inputs[i] for i in idxs])
        for i, res in zip(idxs, batch):
            assert np.array_equal(res.outputs, serial[i].outputs)
