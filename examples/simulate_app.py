"""Time-domain quickstart: schedule + cycle-accurately simulate an app.

Maps Harris corner detection onto the baseline PE, places and routes it on
an 8x8 fabric, modulo-schedules the array, simulates pipelined iterations
over a batch of random pixel windows, and checks the outputs bit-match the
dataflow interpreter.

Run:  PYTHONPATH=src python examples/simulate_app.py
"""

from repro.apps import image_graphs
from repro.core import baseline_datapath, map_application
from repro.core.dse import app_ops
from repro.fabric import FabricSpec
from repro.sim import build_sim, check_against_interp, random_inputs


def main() -> None:
    app = image_graphs()["harris"]
    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, "harris")
    print(f"mapped: {mapping.n_pes} PE instances, "
          f"{mapping.total_ops} ops")

    prog, pnr = build_sim(dp, mapping, app, FabricSpec(rows=8, cols=8))
    print(pnr.cost.row())
    print(prog.schedule.summary())
    print(prog.summary())

    inputs = random_inputs(prog, iterations=4, batch=8, seed=0)
    res, err, exact = check_against_interp(prog, app, inputs)
    print(f"simulated {res.iterations} pipelined iterations x "
          f"{inputs.shape[0]} samples in {res.cycles} cycles "
          f"(II={res.ii}, min {res.min_ii}, latency {res.latency})")
    print(f"golden check vs graphir.interp: max |err| = {err} "
          f"({'bit-exact' if exact else 'MISMATCH'})")


if __name__ == "__main__":
    main()
