"""End-to-end training driver: train a ~20M-param llama-family model for a
few hundred steps on CPU with checkpointing, then demonstrate crash
recovery (a fault is injected and training resumes from the checkpoint).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--big]
(--big uses a ~100M-param config; expect minutes/step-scale wall time on
one CPU core.)
"""

import argparse
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_example_ckpt"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M params instead of ~20M")
    args = ap.parse_args()
    shutil.rmtree(CKPT, ignore_errors=True)

    dims = (["--d-model", "768", "--n-layers", "12", "--d-ff", "2048",
             "--vocab", "32000"] if args.big else
            ["--d-model", "384", "--n-layers", "6", "--d-ff", "1024",
             "--vocab", "4096"])
    common = [sys.executable, "-m", "repro.launch.train",
              "--arch", "llama3.2-1b", "--reduced",
              "--batch", "4", "--seq", "128", "--lr", "3e-3",
              "--ckpt-dir", CKPT, "--ckpt-every", "50",
              "--steps", str(args.steps), *dims]

    print("== phase 1: train with an injected fault at step",
          args.steps // 2, "==")
    subprocess.run(common + ["--fail-at", str(args.steps // 2)], check=True,
                   env={"PYTHONPATH": "src"})
    print("\n== phase 2: resume from latest checkpoint and finish ==")
    subprocess.run(common, check=True, env={"PYTHONPATH": "src"})


if __name__ == "__main__":
    main()
