"""Full image-domain DSE (paper Sec. V-A): camera / harris / gaussian /
laplacian, per-app specialized PEs vs a cross-application PE IP.

Run:  PYTHONPATH=src python examples/dse_image_pipeline.py [--deep]
"""

import argparse

from repro.apps import image_graphs
from repro.core import (MiningConfig, baseline_datapath, evaluate_mapping,
                        map_application)
from repro.explore import ExploreConfig, Explorer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--deep", action="store_true",
                    help="bigger mining budget (several minutes)")
    args = ap.parse_args()
    mining = MiningConfig(min_support=3, max_pattern_nodes=10,
                          time_budget_s=90, max_patterns_per_level=80) \
        if args.deep else \
        MiningConfig(min_support=4, max_pattern_nodes=8,
                     time_budget_s=30, max_patterns_per_level=50)

    apps = image_graphs()
    base = baseline_datapath()
    print("application graphs:")
    for n, g in sorted(apps.items()):
        print(f"  {n:<10} {g.num_compute_nodes()} ops")

    print("\n== per-app specialization (PE Spec) ==")
    # one Explorer memo store for the whole example: the domain run below
    # reuses this run's mining/ranking instead of re-mining all four apps
    ex = Explorer(apps, ExploreConfig(mode="per_app", mining=mining,
                                      max_merge=4))
    per_app = ex.run().results
    for name in sorted(apps):
        res = per_app[name]
        c0 = evaluate_mapping(base, map_application(base, apps[name], name),
                              "baseline")
        best = res.best_variant(name).costs[name]
        print(f"  {name:<10} baseline e/op={c0.energy_per_op_pj:.3f}pJ -> "
              f"spec {best.energy_per_op_pj:.3f}pJ "
              f"({c0.energy_per_op_pj/best.energy_per_op_pj:.2f}x), "
              f"area {c0.total_area_um2/best.total_area_um2:.2f}x, "
              f"ops/pe {best.ops_per_pe:.2f}")

    print("\n== cross-application PE IP (paper Fig. 10) ==")
    ip = ex.with_config(mode="domain", per_app_subgraphs=2,
                        domain_name="PE_IP").run().results["PE_IP"]
    v = ip.variants[0]
    print(f"  PE IP: {v.datapath.summary()}")
    for name in sorted(apps):
        c0 = evaluate_mapping(base, map_application(base, apps[name], name),
                              "baseline")
        c = v.costs[name]
        print(f"  {name:<10} e={c.energy_per_op_pj/c0.energy_per_op_pj:.3f} "
              f"a={c.total_area_um2/c0.total_area_um2:.3f} (vs baseline=1.0)")


if __name__ == "__main__":
    main()
