"""Fabric-level DSE: mine an image app, build PE variants, place + route
each on an N x M CGRA array, and compare per-PE vs array-accurate numbers.

The per-tile cost model (paper Figs. 8/10/11) rewards specialized PEs for
executing more ops per invocation; the fabric view adds the second-order
win: fewer instances means fewer tiles, shorter routes, and less channel
pressure.

Run:  PYTHONPATH=src python examples/place_and_route.py [--app harris]
      [--rows 8] [--cols 8] [--backend jax|python] [--chains 32]
"""

import argparse

from repro.apps import image_graphs
from repro.core import MiningConfig, specialize_per_app
from repro.fabric import FabricSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="harris",
                    help="image app to specialize (harris/gaussian/...)")
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--cols", type=int, default=8)
    ap.add_argument("--backend", default="jax", choices=["jax", "python"])
    ap.add_argument("--chains", type=int, default=32,
                    help="parallel annealing chains (jax backend)")
    ap.add_argument("--max-merge", type=int, default=3)
    args = ap.parse_args()

    apps = image_graphs()
    if args.app not in apps:
        raise SystemExit(f"unknown app {args.app!r}; have {sorted(apps)}")
    app = {args.app: apps[args.app]}
    spec = FabricSpec(rows=args.rows, cols=args.cols)
    mining = MiningConfig(min_support=3, max_pattern_nodes=8,
                          time_budget_s=30, max_patterns_per_level=50)

    print(f"app {args.app}: {apps[args.app].num_compute_nodes()} compute ops")
    print(f"fabric: {spec.summary()}, placer backend={args.backend} "
          f"chains={args.chains}\n")

    res = specialize_per_app(app, mining, max_merge=args.max_merge,
                             fabric=spec, fabric_backend=args.backend,
                             fabric_chains=args.chains)[args.app]

    hdr = (f"{'variant':<8} {'pes':>4} {'ops/pe':>7} "
           f"{'pe e/op':>9} {'pe area':>10} | "
           f"{'grid':>6} {'util':>5} {'wl':>5} {'crit':>5} "
           f"{'arr e/op':>9} {'arr area':>10} {'arr fmax':>9}")
    print(hdr)
    print("-" * len(hdr))
    for v in res.variants:
        c = v.costs[args.app]
        f = v.fabric_costs[args.app]
        print(f"{v.name:<8} {c.n_pes:>4d} {c.ops_per_pe:>7.2f} "
              f"{c.energy_per_op_pj:>8.4f}p {c.total_area_um2/1e3:>8.1f}k | "
              f"{f.cols}x{f.rows:<3} {f.utilization:>5.2f} "
              f"{f.wirelength_hops:>5d} {f.crit_path_hops:>5d} "
              f"{f.energy_per_op_pj:>8.4f}p {f.fabric_area_um2/1e3:>8.1f}k "
              f"{f.fmax_ghz:>7.2f}GHz")

    base = res.variants[0]
    best = min(res.variants,
               key=lambda v: v.fabric_costs[args.app].energy_per_op_pj)
    b0, bf = base.fabric_costs[args.app], best.fabric_costs[args.app]
    print(f"\nbest at array level: {best.name} — "
          f"e/op {b0.energy_per_op_pj/bf.energy_per_op_pj:.2f}x, "
          f"wirelength {b0.wirelength_hops}->{bf.wirelength_hops} hops, "
          f"tiles {b0.n_pe_cells}->{bf.n_pe_cells} "
          f"(vs {base.name})")


if __name__ == "__main__":
    main()
