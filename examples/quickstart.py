"""Quickstart: the paper's whole pipeline on its own Fig. 3 example.

Trace a convolution to a dataflow graph, mine frequent subgraphs, rank by
maximal independent set, merge into a specialized PE, map the app onto it,
compare against the baseline PE, and run the mined pattern as a generated
fused TPU kernel (interpret mode on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.graphir import trace_scalar
from repro.core import (MiningConfig, baseline_datapath, evaluate_mapping,
                        map_application, mine_and_rank)
from repro.explore import ExploreConfig, Explorer
from repro.kernels import fused_pe_apply
from repro.kernels.ref import ref_pe
from repro.graphir.graph import free_in_ports


def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
    """Paper Fig. 3a: ((((i0*w0)+(i1*w1))+(i2*w2))+(i3*w3))+c"""
    return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c


def main() -> None:
    names = ["i0", "i1", "i2", "i3", "w0", "w1", "w2", "w3", "c"]
    app = trace_scalar(conv4, names)
    print(f"application graph: {app.num_compute_nodes()} compute ops")

    # 1-2. mine + MIS-rank (Sec. III-A/B)
    ranked = mine_and_rank(app, MiningConfig(min_support=2,
                                             max_pattern_nodes=5))
    print("\ntop mined subgraphs (paper Fig. 3b-d):")
    for m in ranked[:4]:
        print("  ", m)

    # 3-5. merge into PE variants + map + evaluate (Sec. III-C, IV, V) —
    # the staged pipeline behind `python -m repro.explore`
    cfg = ExploreConfig(mode="per_app",
                        mining=MiningConfig(min_support=2,
                                            max_pattern_nodes=5))
    res = Explorer({"conv": app}, cfg).run().results["conv"]
    base = baseline_datapath()
    c0 = evaluate_mapping(base, map_application(base, app, "conv"),
                          "baseline")
    print("\nPE specialization sweep (paper Fig. 8 shape):")
    print("  " + c0.row())
    for v in res.variants:
        print("  " + v.costs["conv"].row())

    # 6. the TPU adaptation: generate a fused Pallas kernel from the top
    # mined subgraph and validate it against the graph oracle
    pat = ranked[0].pattern
    n_in = len(free_in_ports(pat))
    xs = [jnp.asarray(np.random.default_rng(i).uniform(0, 1, (64, 128)),
                      jnp.float32) for i in range(n_in)]
    out = fused_pe_apply(pat, *xs, interpret=True)
    exp = ref_pe(pat, *[np.asarray(x) for x in xs])
    outs = out if isinstance(out, tuple) else (out,)
    exps = exp if isinstance(exp, tuple) else (exp,)
    err = max(float(jnp.max(jnp.abs(o - jnp.asarray(e, jnp.float32))))
              for o, e in zip(outs, exps))
    print(f"\ngenerated fused PE kernel matches oracle: max err {err:.2e}")


if __name__ == "__main__":
    main()
