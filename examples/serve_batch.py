"""Batched serving example: continuous prefill+decode over a request queue
(the serving-side end-to-end driver; decode_step is the same function the
multi-pod dry-run lowers for 512 chips).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import Request, ServeEngine


def main() -> None:
    cfg = get_config("llama3.2-1b").reduced(n_layers=4, d_model=256,
                                            d_ff=512, vocab=2048)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    print(f"serving {cfg.name} (reduced, {n_params/1e6:.1f}M params)")

    eng = ServeEngine(cfg, params, slots=4, smax=256)
    rng = np.random.default_rng(0)
    n_req, max_new = 12, 24
    for rid in range(n_req):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, 32,
                                             dtype=np.int32),
                           max_new=max_new))
    t0 = time.time()
    outs = eng.run(max_steps=n_req * max_new + 32)
    dt = time.time() - t0
    tokens = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests / {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s on 1 CPU core)")
    for rid in sorted(outs)[:4]:
        print(f"  req {rid}: {outs[rid][:10]}...")


if __name__ == "__main__":
    main()
