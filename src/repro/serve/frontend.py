"""Async front-end for explorer-as-a-service.

:class:`ExploreService` wraps a :class:`~repro.serve.batcher.
ContinuousBatcher` with the two client surfaces:

* **in-process** — ``await service.explore(apps, config)`` (or
  ``submit_request`` with a pre-built :class:`ServeRequest`) from any
  number of concurrent asyncio clients;
* **wire** — newline-delimited JSON over a TCP socket
  (``serve_tcp``) or stdio (``serve_stdio``): one request object per
  line in, one response object per line out, connections multiplexed
  onto the same batcher so strangers on different sockets still share
  dispatches.

Admission normalizes every request's config to ``on_error="isolate"``
(PR 8's fault-containment machinery): one client's poisoned graph
degrades to StageFailure rows in *that client's* response, never an
exception in a batchmate's.  Persistent stores (``store=`` a directory
path) ride :class:`~repro.explore.ThreadSafeStore` over
:class:`~repro.explore.DiskStore`, so cache warmth survives restarts
and the store file locking keeps concurrent server processes safe.
"""

from __future__ import annotations

import asyncio
import json
import sys
import time
from typing import Any, Dict, Optional, Union

from ..explore import ExploreConfig
from ..graphir.graph import Graph
from ..obs import event as obs_event
from ..obs.metrics import MetricsRegistry
from .batcher import ContinuousBatcher, QueueFull
from .protocol import (ProtocolError, ServeRequest, ServeResponse,
                       parse_request_line)

__all__ = ["ExploreService"]


def _open_store(store: Union[None, str, Dict]) -> Optional[Dict]:
    if store is None or isinstance(store, dict):
        return store
    from ..explore import DiskStore, ThreadSafeStore
    return ThreadSafeStore(DiskStore(store))


class ExploreService:
    """The serving subsystem's front door.

    ::

        async with ExploreService(store="memo/") as svc:
            resp = await svc.explore("r1", apps, config)

    or as a server: ``await svc.serve_tcp("127.0.0.1", 7341)``.
    """

    def __init__(self, store: Union[None, str, Dict] = None, *,
                 max_batch_apps: int = 8, max_wait_ms: float = 50.0,
                 queue_limit: int = 32,
                 metrics: Optional[MetricsRegistry] = None) -> None:
        self.metrics = metrics or MetricsRegistry()
        self.batcher = ContinuousBatcher(
            _open_store(store), max_batch_apps=max_batch_apps,
            max_wait_s=max_wait_ms / 1e3, queue_limit=queue_limit,
            metrics=self.metrics)

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> "ExploreService":
        await self.batcher.start()
        return self

    async def aclose(self) -> None:
        await self.batcher.aclose()

    async def __aenter__(self) -> "ExploreService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # -- in-process API ----------------------------------------------------
    async def explore(self, rid: str, apps: Dict[str, Graph],
                      config: ExploreConfig, *,
                      block: bool = True) -> ServeResponse:
        return await self.submit_request(
            ServeRequest(rid=rid, apps=dict(apps), config=config),
            block=block)

    async def submit_request(self, request: ServeRequest, *,
                             block: bool = True) -> ServeResponse:
        """One request through admission -> batcher -> response.

        Everything that can go wrong becomes an ``ok: false`` response
        (except :class:`QueueFull` with ``block=False``, which raises so
        callers can shed load explicitly).
        """
        t0 = time.perf_counter()
        if request.config.on_error != "isolate":
            # a batched stranger must never fail-fast its batchmates;
            # note this changes the config (and record config_key) the
            # request is served under — serving always runs isolated
            request = ServeRequest(
                rid=request.rid, apps=request.apps,
                config=request.config.replace(on_error="isolate"))
        try:
            records, failures, cached = await self.batcher.submit(
                request, block=block)
        except QueueFull:
            raise
        except Exception as e:
            self.metrics.observe("serve.request_ms",
                                 (time.perf_counter() - t0) * 1e3)
            obs_event("serve.request_failed", rid=request.rid,
                      error=type(e).__name__)
            return ServeResponse(rid=request.rid, ok=False,
                                 error=f"{type(e).__name__}: {e}")
        elapsed_ms = (time.perf_counter() - t0) * 1e3
        self.metrics.observe("serve.request_ms", elapsed_ms)
        if cached:
            self.metrics.observe("serve.cache_hit_ms", elapsed_ms)
        obs_event("serve.request_done", rid=request.rid, cached=cached,
                  records=len(records), failures=len(failures))
        return ServeResponse(rid=request.rid, ok=True, records=records,
                             failures=failures, cached=cached,
                             elapsed_ms=elapsed_ms)

    # -- wire protocol -----------------------------------------------------
    async def handle_line(self, line: Union[str, bytes]) -> Dict[str, Any]:
        """One NDJSON request line -> one response object (a dict)."""
        try:
            obj = json.loads(line)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            self.metrics.inc("serve.protocol_errors")
            return ServeResponse(rid="", ok=False,
                                 error=f"bad JSON: {e}").to_dict()
        try:
            request = parse_request_line(obj)
        except ProtocolError as e:
            self.metrics.inc("serve.protocol_errors")
            rid = obj.get("id", "") if isinstance(obj, dict) else ""
            return ServeResponse(rid=str(rid), ok=False,
                                 error=str(e)).to_dict()
        resp = await self.submit_request(request)
        return resp.to_dict()

    async def _serve_stream(self, reader: asyncio.StreamReader,
                            write_line) -> None:
        """Shared connection loop: requests on a connection run
        concurrently (that's the point of batching), responses are
        serialized through ``write_lock`` in completion order."""
        write_lock = asyncio.Lock()
        tasks = set()

        async def one(line: bytes) -> None:
            d = await self.handle_line(line)
            async with write_lock:
                await write_line(json.dumps(d) + "\n")

        self.metrics.inc("serve.connections")
        while True:
            line = await reader.readline()
            if not line:
                break
            if not line.strip():
                continue
            t = asyncio.ensure_future(one(line))
            tasks.add(t)
            t.add_done_callback(tasks.discard)
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)

    async def serve_connection(self, reader: asyncio.StreamReader,
                               writer: asyncio.StreamWriter) -> None:
        async def write_line(s: str) -> None:
            writer.write(s.encode())
            await writer.drain()

        try:
            await self._serve_stream(reader, write_line)
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def serve_tcp(self, host: str = "127.0.0.1",
                        port: int = 7341) -> asyncio.AbstractServer:
        """Start (and return) the TCP server; callers own its lifetime:
        ``server.close(); await server.wait_closed()``."""
        server = await asyncio.start_server(self.serve_connection,
                                            host, port)
        return server

    async def serve_stdio(self) -> None:
        """NDJSON over stdin/stdout until EOF (one-shot pipelines)."""
        loop = asyncio.get_event_loop()
        reader = asyncio.StreamReader()
        await loop.connect_read_pipe(
            lambda: asyncio.StreamReaderProtocol(reader), sys.stdin)

        async def write_line(s: str) -> None:
            sys.stdout.write(s)
            sys.stdout.flush()

        await self._serve_stream(reader, write_line)
