"""CLI for explorer-as-a-service.

::

    python -m repro.serve --smoke                  # CI self-check
    python -m repro.serve --port 7341 --store memo/
    python -m repro.serve --stdio < requests.jsonl

The smoke is the serving layer's load-bearing CI assertion: it serves
the same requests solo, batched with strangers, from cache, and over
the wire protocol, and requires the records to be **byte-identical**
in all four paths — while the batched path performs strictly fewer JAX
dispatches than the solo runs summed.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
from typing import Dict, List

from ..graphir.graph import Graph
from .frontend import ExploreService
from .protocol import encode_request


def _smoke_requests():
    """Four overlapping client requests over three small apps (the
    explore smoke's Fig. 3 convolution plus two MAC/add kernels)."""
    from ..explore.__main__ import _smoke_case
    from ..graphir import trace_scalar

    apps, cfg = _smoke_case()
    conv = apps["conv"]

    def fir4(i0, i1, i2, i3, w0, w1, w2, w3):
        return ((i0 * w0) + (i1 * w1)) + ((i2 * w2) + (i3 * w3))

    def blur4(a, b, c, d, w):
        return (((a + b) + (c + d)) * w)

    fir = trace_scalar(fir4, ["i0", "i1", "i2", "i3",
                              "w0", "w1", "w2", "w3"])
    blur = trace_scalar(blur4, ["a", "b", "c", "d", "w"])
    cfg = cfg.replace(on_error="isolate")   # what the service runs under
    clients = [
        ("r1", {"conv": conv}),
        ("r2", {"conv": conv, "fir": fir}),
        ("r3", {"fir": fir, "blur": blur}),
        ("r4", {"conv": conv, "blur": blur}),
    ]
    return clients, cfg


def _solo_lines(apps: Dict[str, Graph], cfg) -> tuple:
    """Ground truth: one fresh solo Explorer run -> (record line bytes,
    dispatch count)."""
    from ..explore import Explorer
    ex = Explorer(apps, cfg)
    res = ex.run()
    assert not res.failures, f"solo run degraded: {res.failures}"
    lines = [json.dumps(r.to_dict()) for r in res.records()]
    return lines, ex.stats["pnr_dispatch"] + ex.stats["sim_dispatch"]


async def _smoke_async() -> int:
    clients, cfg = _smoke_requests()

    solo: Dict[str, List[str]] = {}
    solo_dispatches = 0
    for rid, apps in clients:
        solo[rid], n = _solo_lines(apps, cfg)
        solo_dispatches += n
        assert solo[rid], f"solo {rid} produced no records"

    async with ExploreService(max_batch_apps=4, max_wait_ms=100,
                              queue_limit=16) as svc:
        # -- N concurrent clients, batched across requests ---------------
        resps = await asyncio.gather(*[
            svc.explore(rid, apps, cfg) for rid, apps in clients])
        for (rid, _apps), resp in zip(clients, resps):
            assert resp.ok, f"{rid} failed: {resp.error}"
            assert not resp.cached, f"{rid} unexpectedly cached"
            assert resp.record_lines() == solo[rid], \
                f"bit-identity violated for {rid}: batched != solo"
            assert not resp.failures, f"{rid} degraded: {resp.failures}"
        stats = svc.metrics.view()
        served_dispatches = (stats["pnr_dispatch"] + stats["sim_dispatch"])
        assert served_dispatches < solo_dispatches, (
            f"no cross-request amortization: served {served_dispatches} "
            f"dispatches vs {solo_dispatches} solo")
        n_apps = len({n for _rid, apps in clients for n in apps})
        assert stats["mine"] == n_apps, (
            f"expected {n_apps} unique mines across all requests, "
            f"got {stats['mine']}")

        # -- cache hit: same content, new rid, zero new dispatches --------
        rid2, apps2 = clients[1]
        resp = await svc.explore("r2-again", apps2, cfg)
        assert resp.ok and resp.cached, "repeat request missed the cache"
        assert resp.record_lines() == solo[rid2], \
            "bit-identity violated: cached != solo"
        after = stats["pnr_dispatch"] + stats["sim_dispatch"]
        assert after == served_dispatches, "cache hit dispatched JAX work"
        assert resp.elapsed_ms < 1000, \
            f"cache hit took {resp.elapsed_ms:.1f} ms"

        # -- wire protocol round trip -------------------------------------
        server = await svc.serve_tcp("127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write((json.dumps(
            encode_request("wire-1", apps2, cfg)) + "\n").encode())
        writer.write(b'{"this is": "not a request"}\n')
        await writer.drain()
        writer.write_eof()
        line1 = json.loads(await reader.readline())
        line2 = json.loads(await reader.readline())
        by_ok = {d["ok"]: d for d in (line1, line2)}
        assert set(by_ok) == {True, False}, f"unexpected replies: {by_ok}"
        assert by_ok[True]["id"] == "wire-1" and by_ok[True]["cached"]
        assert [json.dumps(r) for r in by_ok[True]["records"]] \
            == solo[rid2], "bit-identity violated: wire != solo"
        writer.close()
        await writer.wait_closed()
        server.close()
        await server.wait_closed()

        cache_ms = svc.metrics.histogram("serve.cache_hit_ms")
        print(f"# serve smoke OK: {len(clients)} clients bit-identical "
              f"(solo == batched == cached == wire), "
              f"{served_dispatches} batched dispatches vs "
              f"{solo_dispatches} solo, {stats['mine']}/"
              f"{sum(len(a) for _r, a in clients)} apps mined, "
              f"cache hits {cache_ms.count} "
              f"(mean {cache_ms.mean:.2f} ms)")
    return 0


async def _serve_async(args) -> int:
    svc = ExploreService(store=args.store,
                         max_batch_apps=args.max_batch_apps,
                         max_wait_ms=args.max_wait_ms,
                         queue_limit=args.queue_limit)
    async with svc:
        if args.stdio:
            await svc.serve_stdio()
            return 0
        server = await svc.serve_tcp(args.host, args.port)
        port = server.sockets[0].getsockname()[1]
        print(f"# repro.serve listening on {args.host}:{port} "
              f"(max_batch_apps={args.max_batch_apps}, "
              f"max_wait_ms={args.max_wait_ms}, "
              f"queue_limit={args.queue_limit}, "
              f"store={args.store or 'in-memory'})", flush=True)
        async with server:
            await server.serve_forever()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Exploration serving: NDJSON front-end with "
                    "cross-request continuous batching")
    ap.add_argument("--smoke", action="store_true",
                    help="fast end-to-end self check (CI): bit-identity "
                         "solo == batched == cached == wire")
    ap.add_argument("--stdio", action="store_true",
                    help="serve NDJSON on stdin/stdout until EOF")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7341,
                    help="TCP port (0 picks a free one)")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent DiskStore directory (default: "
                         "in-memory, cache dies with the process)")
    ap.add_argument("--max-batch-apps", type=int, default=8,
                    help="flush a batch once this many distinct apps "
                         "are pending (default 8)")
    ap.add_argument("--max-wait-ms", type=float, default=50.0,
                    help="flush the oldest ticket after this long even "
                         "if the batch is not full (default 50)")
    ap.add_argument("--queue-limit", type=int, default=32,
                    help="bounded admission queue: tickets beyond this "
                         "wait at the door (default 32)")
    args = ap.parse_args(argv)
    try:
        if args.smoke:
            return asyncio.run(_smoke_async())
        return asyncio.run(_serve_async(args))
    except KeyboardInterrupt:
        return 130
    except AssertionError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
