"""Wire types for the exploration serving front-end.

One request = one client's exploration: a set of application graphs plus
an :class:`~repro.explore.ExploreConfig`.  Requests and responses travel
as newline-delimited JSON (one object per line) over a socket or stdio —
see :mod:`repro.serve.frontend` — or as in-process
:class:`ServeRequest` / :class:`ServeResponse` objects.

Request line::

    {"id": "r1",
     "config": {... ExploreConfig.to_dict() blob ...},
     "apps": {"conv": {... Graph.to_dict() blob ...}}}

``apps`` may be replaced (or extended) by a built-in suite reference:
``{"suite": "ml"}`` or ``{"suite": "image", "select": ["conv2d"]}`` —
the graphs are built server-side, so two clients naming the same suite
app share one content key (and therefore one computation).

Response line::

    {"id": "r1", "ok": true, "cached": false, "schema": <RECORD_SCHEMA>,
     "records": [...], "failures": [...], "elapsed_ms": 12.3}

``records`` rows are schema-versioned :class:`repro.explore.
ExploreRecord` dicts in exactly the order (and with exactly the bytes)
a solo ``Explorer(request.apps, request.config).run()`` would produce —
the serving layer's bit-identity guarantee.  A malformed request gets
``{"ok": false, "error": "..."}`` and never kills the connection.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

from ..explore import ExploreConfig
from ..explore.records import RECORD_SCHEMA
from ..graphir.graph import Graph

__all__ = ["PROTOCOL_SCHEMA", "ProtocolError", "ServeRequest",
           "ServeResponse", "parse_request_line", "request_key"]

#: bump when the request/response line shape changes incompatibly
PROTOCOL_SCHEMA = 1


class ProtocolError(ValueError):
    """A request line that can't be parsed — reported as a one-line
    ``{"ok": false}`` response, never a dropped connection."""


def _suite_graphs(suite: str, select=None) -> Dict[str, Graph]:
    from ..apps import image, image_graphs, ml_graphs
    if suite == "ml":
        apps = ml_graphs()
    elif suite == "image":
        apps = image_graphs()
    elif suite == "camera":
        apps = {"camera": image.build_graph("camera")}
    else:
        raise ProtocolError(f"unknown suite {suite!r} (ml | image | camera)")
    if select is not None:
        missing = [n for n in select if n not in apps]
        if missing:
            raise ProtocolError(f"suite {suite!r} has no apps {missing} "
                                f"(has {sorted(apps)})")
        apps = {n: apps[n] for n in select}
    return apps


@dataclass
class ServeRequest:
    """One client exploration: id + app graphs + config.

    The service normalizes ``config.on_error`` to ``"isolate"`` at
    admission (see :class:`repro.serve.frontend.ExploreService`): a
    batched stranger must never be able to fail-fast its batchmates.
    """

    rid: str
    apps: Dict[str, Graph]
    config: ExploreConfig

    def key(self) -> Tuple:
        return request_key(self.apps, self.config)


def request_key(apps: Dict[str, Graph], config: ExploreConfig) -> Tuple:
    """Content identity of one exploration: the config digest plus every
    app's name + structural fingerprint.  Two requests with equal keys
    are the same computation — the batcher coalesces them."""
    from ..explore.pipeline import _digest, graph_key
    return (_digest(config.to_dict()),
            tuple(sorted((name, graph_key(g)) for name, g in apps.items())))


@dataclass
class ServeResponse:
    """What one request gets back (in-process object = wire line)."""

    rid: str
    ok: bool
    records: List[Dict[str, Any]] = field(default_factory=list)
    failures: List[Dict[str, Any]] = field(default_factory=list)
    cached: bool = False
    elapsed_ms: float = 0.0
    error: str = ""

    def record_lines(self) -> List[str]:
        """The response's records as jsonl lines — the byte-level view
        the bit-identity guarantee (solo == batched == cached) is
        asserted on."""
        return [json.dumps(r) for r in self.records]

    def to_dict(self) -> Dict[str, Any]:
        d = {"id": self.rid, "ok": self.ok, "schema": RECORD_SCHEMA,
             "protocol": PROTOCOL_SCHEMA}
        if self.ok:
            d.update(cached=self.cached, records=self.records,
                     failures=self.failures,
                     elapsed_ms=round(self.elapsed_ms, 3))
        else:
            d["error"] = self.error
        return d


def parse_request_line(d: Any) -> ServeRequest:
    """One decoded NDJSON request object -> :class:`ServeRequest`.

    Raises :class:`ProtocolError` (with the offending field named) on
    anything malformed; the caller turns that into an ``ok: false``
    response line.
    """
    from ..explore.config import ConfigFormatError
    if not isinstance(d, dict):
        raise ProtocolError(f"request must be an object, "
                            f"got {type(d).__name__}")
    rid = d.get("id")
    if not isinstance(rid, str) or not rid:
        raise ProtocolError("request needs a non-empty string 'id'")
    op = d.get("op", "explore")
    if op != "explore":
        raise ProtocolError(f"unknown op {op!r} (only 'explore')")

    cfg_blob = d.get("config")
    if not isinstance(cfg_blob, dict):
        raise ProtocolError("request needs a 'config' object "
                            "(ExploreConfig.to_dict() blob)")
    try:
        config = ExploreConfig.from_dict(cfg_blob)
    except ConfigFormatError as e:
        raise ProtocolError(f"bad config: {e}")

    apps: Dict[str, Graph] = {}
    if d.get("suite") is not None:
        apps.update(_suite_graphs(d["suite"], d.get("select")))
    inline = d.get("apps")
    if inline is not None:
        if not isinstance(inline, dict):
            raise ProtocolError("'apps' must map app names to graph blobs")
        for name, blob in inline.items():
            try:
                apps[str(name)] = Graph.from_dict(blob)
            except ValueError as e:
                raise ProtocolError(f"bad graph for app {name!r}: {e}")
    if not apps:
        raise ProtocolError("request has no apps (inline 'apps' and/or "
                            "a 'suite' reference)")
    return ServeRequest(rid=rid, apps=apps, config=config)


def encode_request(rid: str, apps: Dict[str, Graph],
                   config: ExploreConfig) -> Dict[str, Any]:
    """The NDJSON request object for (apps, config) — what a client
    sends; inverse of :func:`parse_request_line`."""
    return {"id": rid, "op": "explore", "config": config.to_dict(),
            "apps": {name: g.to_dict() for name, g in apps.items()}}
