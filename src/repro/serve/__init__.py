"""Explorer-as-a-service: async front-end + cross-request batching.

Many concurrent clients submit app-graph exploration requests (in-
process coroutines, or newline-delimited JSON over a socket / stdio);
the service deduplicates them against the Explorer's content-keyed memo
store — repeat requests answer from cache in milliseconds without
touching JAX — and continuously batches the rest: pending (variant,
app) pairs from *different* requests are grouped by pow2 bucket
signature and flushed through the batch-first pnr/schedule/simulate
stages together when a batch fills or the max-wait deadline expires.

Bit-identity guarantee: a request's records are byte-identical whether
it is served solo, batched with strangers, or answered from cache —
the pipeline's content-key memoization and content-nonce seeding make
results independent of dispatch grouping (``python -m repro.serve
--smoke`` asserts this end to end).

Entry points::

    from repro.serve import ExploreService
    async with ExploreService(store="memo/") as svc:
        resp = await svc.explore("r1", apps, config)

    python -m repro.serve --port 7341 --store memo/     # NDJSON server
    python -m repro.serve --smoke                       # CI smoke

The token-decode LM demo that used to live here moved to
:mod:`repro.serve.lm_engine`; the package-level ``ServeEngine`` /
``Request`` names (and ``repro.serve.engine``) still resolve but warn
``DeprecationWarning``.
"""

from .batcher import ContinuousBatcher, QueueFull
from .frontend import ExploreService
from .protocol import (PROTOCOL_SCHEMA, ProtocolError, ServeRequest,
                       ServeResponse, encode_request, parse_request_line,
                       request_key)

__all__ = [
    "ContinuousBatcher", "QueueFull",
    "ExploreService",
    "PROTOCOL_SCHEMA", "ProtocolError", "ServeRequest", "ServeResponse",
    "encode_request", "parse_request_line", "request_key",
    # deprecated LM-demo names, resolved lazily with a warning:
    "Request", "ServeEngine",
]


def __getattr__(name):
    if name in ("Request", "ServeEngine"):
        import warnings
        warnings.warn(
            f"repro.serve.{name} is deprecated: the LM demo moved to "
            f"repro.serve.lm_engine (repro.serve now names the "
            f"exploration serving subsystem)",
            DeprecationWarning, stacklevel=2)
        from . import lm_engine
        return getattr(lm_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
