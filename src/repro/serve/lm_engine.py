"""LM demo engine: continuous prefill+decode over a token-request queue.

Static-shape serving in the vLLM spirit adapted to XLA: a fixed decode batch
of ``slots``; finished/empty slots are refilled by prefilling queued
requests into the slot's cache region.  All steps are jitted with static
shapes (slot count, smax), so serving never recompiles.

Single-host CPU demo scale here; the decode_step itself is exactly what the
dry-run lowers for 512 chips (launch/dryrun.py decode cells).

.. deprecated::
    ``repro.serve`` now names the exploration serving subsystem
    (:mod:`repro.serve.frontend` / :mod:`repro.serve.batcher`).  This LM
    demo lives on here for the sharding tests; importing it through
    ``repro.serve.engine`` (or the package-level ``ServeEngine`` /
    ``Request`` names) warns ``DeprecationWarning``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig
from ..models.model import decode_step, init_cache, prefill


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any, *, slots: int = 4,
                 smax: int = 512, compute_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.smax = smax
        self.compute_dtype = compute_dtype
        self.queue: List[Request] = []
        self.all_requests: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.remaining = np.zeros(slots, np.int32)
        self.cache = init_cache(cfg, slots, smax, compute_dtype)
        self.last_tok = jnp.zeros((slots,), jnp.int32)

        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c,
                                        compute_dtype=compute_dtype))
        # per-slot prefill is batched over a single sequence
        self._prefill = jax.jit(
            lambda p, toks: prefill(p, cfg, toks, smax=smax,
                                    compute_dtype=compute_dtype),
            static_argnums=())

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.all_requests.append(req)

    def _refill(self) -> None:
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, c1 = self._prefill(self.params, toks)
            # splice the slot's cache rows
            def put(dst, src):
                if dst.ndim >= 2 and dst.shape[1] == self.slots:
                    return dst.at[:, s].set(src[:, 0])
                return dst
            for key in self.cache:
                if key == "len":
                    continue
                self.cache[key] = put(self.cache[key], c1[key])
            # slot-local length bookkeeping: engine uses a uniform len; for
            # the demo all prompts share a length (padded upstream)
            self.cache["len"] = jnp.asarray(len(req.prompt), jnp.int32)
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            self.last_tok = self.last_tok.at[s].set(tok)
            req.out.append(int(tok))
            self.active[s] = req
            self.remaining[s] = req.max_new - 1

    def step(self) -> int:
        """One decode step for the whole batch; returns #active slots."""
        self._refill()
        if all(a is None for a in self.active):
            return 0
        logits, self.cache = self._decode(
            self.params, self.last_tok, self.cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.last_tok = next_tok
        n_active = 0
        toks = np.asarray(next_tok)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(toks[s]))
            self.remaining[s] -= 1
            if self.remaining[s] <= 0:
                req.done = True
                self.active[s] = None
            else:
                n_active += 1
        return n_active

    def run(self, max_steps: int = 256) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            self.step()
        return {r.rid: r.out for r in self.all_requests}
