"""Cross-request continuous batching over the exploration pipeline.

Many concurrent clients submit :class:`~repro.serve.protocol.
ServeRequest` objects; the :class:`ContinuousBatcher` coalesces them so
the expensive JAX stages run as few dispatches as the union of their
work allows:

* **response cache** — a request whose content key (config digest +
  app fingerprints) was already answered returns in microseconds,
  without touching the queue or JAX;
* **in-flight coalescing** — identical requests arriving while the
  first is queued/executing await the same future and share one
  computation;
* **admission queue** — bounded (``queue_limit`` tickets); a full
  queue makes ``submit`` wait (backpressure) or raise
  :class:`QueueFull` when ``block=False``;
* **continuous batching** — pending tickets with the same config are
  merged into one :class:`~repro.explore.Explorer` run over the union
  of their apps when enough work accumulates (``max_batch_apps``) or
  the oldest ticket's ``max_wait_s`` deadline expires.  The Explorer's
  batch-first pnr/schedule/simulate stages then group the merged
  (variant, app) pairs by pow2 bucket signature, so strangers' pairs
  share JAX dispatches.

The whole scheme is sound because of the pipeline's content-key +
content-nonce discipline: in ``per_app`` mode every stage artifact of an
app depends only on that app's graph and the config, and every pair's
anneal chains / golden inputs are seeded from its own content nonce —
so a request's records are **byte-identical** whether it runs solo,
batched with strangers, or is answered from cache.  ``domain`` mode
merges *across* apps, so domain tickets never share a batch: each
flushes as its own solo Explorer run.

Failure containment: batches run with ``on_error="isolate"`` (the
service normalizes configs at admission), so a poisoned pair degrades to
its own :class:`~repro.explore.records.StageFailure` rows without
touching batchmates.  A catastrophic batch error (the Explorer itself
raising) re-runs each ticket solo before giving up on any of them.
"""

from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..explore import ExploreResult, Explorer
from ..explore.pipeline import graph_key
from ..explore.records import ExploreRecord, StageFailure
from ..obs import event as obs_event, span
from ..obs.metrics import MetricsRegistry
from .protocol import ServeRequest

__all__ = ["ContinuousBatcher", "QueueFull", "ticket_records",
           "ticket_failures"]


class QueueFull(RuntimeError):
    """Admission queue at ``queue_limit`` and ``block=False``."""


def ticket_records(result: ExploreResult,
                   request: ServeRequest) -> List[ExploreRecord]:
    """One ticket's record rows out of a (possibly merged) run — in
    exactly the order ``Explorer(request.apps, request.config).run().
    records()`` would produce them, which is what the bit-identity
    guarantee is asserted on.

    ``per_app`` mode: a solo run's results dict iterates the request's
    apps in insertion order with one single-app DSEResult each, so we
    walk ``request.apps`` and pick each app's result out of the merged
    run.  ``domain`` tickets always run solo (their merge is cross-app),
    so the run's own view already matches.
    """
    if result.config.mode != "per_app":
        return result.records()
    buckets = result.sim_buckets or {}
    rows: List[ExploreRecord] = []
    for app_name in request.apps:
        res = result.results.get(app_name)
        if res is None:                      # app degraded upstream
            continue
        for v in res.variants:
            if app_name not in v.costs:
                continue
            rows.append(ExploreRecord.from_cost(
                v.costs[app_name], mode=result.config.mode,
                config_key=result.config_key,
                n_merged=len(v.merged_subgraphs),
                sim_bucket=buckets.get((v.name, app_name), "")))
    return rows


def ticket_failures(result: ExploreResult,
                    request: ServeRequest) -> List[StageFailure]:
    """The merged run's StageFailure rows that belong to one ticket."""
    if result.config.mode != "per_app":
        return list(result.failures or ())
    return [f for f in (result.failures or ())
            if f.app in request.apps]


@dataclass
class _Ticket:
    """One admitted request waiting for (or riding) a batch."""

    request: ServeRequest
    key: Tuple
    group: str                       # batch group: the config digest
    solo: bool                       # domain mode: never share a batch
    future: "asyncio.Future[Tuple[list, list]]"
    enqueued: float                  # loop.time() at admission
    app_keys: Dict[str, str] = field(default_factory=dict)


class ContinuousBatcher:
    """Admission queue + flush loop + batch executor.

    ``await submit(request)`` is the whole client API; ``start()`` /
    ``aclose()`` bracket the flush loop (or use ``async with``).  The
    batch itself runs in a worker thread (``run_in_executor``) so the
    event loop keeps admitting clients while JAX works; batches are
    serialized — one Explorer run at a time — which is the right shape
    for a single accelerator and keeps the shared memo store single-
    writer within this process.
    """

    def __init__(self, store: Optional[Dict] = None, *,
                 max_batch_apps: int = 8, max_wait_s: float = 0.05,
                 queue_limit: int = 32, cache_limit: int = 256,
                 metrics: Optional[MetricsRegistry] = None) -> None:
        if max_batch_apps < 1:
            raise ValueError("max_batch_apps must be >= 1")
        if queue_limit < 1:
            raise ValueError("queue_limit must be >= 1")
        self._store: Dict = {} if store is None else store
        self.metrics = metrics or MetricsRegistry()
        self.max_batch_apps = max_batch_apps
        self.max_wait_s = max_wait_s
        self.queue_limit = queue_limit
        self.cache_limit = cache_limit
        self._pending: List[_Ticket] = []
        self._inflight: Dict[Tuple, asyncio.Future] = {}
        self._cache: Dict[Tuple, Tuple[list, list]] = {}
        self._depth = 0                       # admitted, not yet flushed
        self._slots: Optional[asyncio.Semaphore] = None
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._stopping = False

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> "ContinuousBatcher":
        if self._task is not None:
            return self
        self._stopping = False
        self._slots = asyncio.Semaphore(self.queue_limit)
        self._wake = asyncio.Event()
        self._task = asyncio.ensure_future(self._run())
        return self

    async def aclose(self) -> None:
        """Flush everything still queued, then stop the loop."""
        if self._task is None:
            return
        self._stopping = True
        self._wake.set()
        try:
            await self._task
        finally:
            self._task = None

    async def __aenter__(self) -> "ContinuousBatcher":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    @property
    def queue_depth(self) -> int:
        return self._depth

    # -- client API --------------------------------------------------------
    async def submit(self, request: ServeRequest, *,
                     block: bool = True) -> Tuple[list, list, bool]:
        """One exploration: returns ``(records, failures, cached)`` where
        records/failures are plain row dicts.  Raises :class:`QueueFull`
        when the admission queue is full and ``block=False``; otherwise a
        full queue just delays admission (backpressure).
        """
        if self._task is None:
            raise RuntimeError("batcher is not started")
        self.metrics.inc("serve.requests")
        key = request.key()

        hit = self._cache.get(key)
        if hit is not None:
            self.metrics.inc("serve.cache_hit")
            obs_event("serve.cache_hit", rid=request.rid)
            return hit[0], hit[1], True

        fut = self._inflight.get(key)
        if fut is not None:                   # identical request in flight
            self.metrics.inc("serve.coalesced")
            records, failures = await asyncio.shield(fut)
            return records, failures, False

        if not block and self._depth >= self.queue_limit:
            self.metrics.inc("serve.rejected")
            raise QueueFull(
                f"admission queue full ({self.queue_limit} tickets)")
        await self._slots.acquire()
        self._depth += 1
        self.metrics.set_gauge("serve.queue_depth", self._depth)

        loop = asyncio.get_event_loop()
        fut = loop.create_future()
        cfg = request.config
        ticket = _Ticket(
            request=request, key=key,
            group=key[0], solo=(cfg.mode != "per_app"),
            future=fut, enqueued=loop.time(),
            app_keys={n: graph_key(g) for n, g in request.apps.items()})
        self._inflight[key] = fut
        self._pending.append(ticket)
        self._wake.set()
        records, failures = await asyncio.shield(fut)
        return records, failures, False

    # -- flush loop --------------------------------------------------------
    async def _run(self) -> None:
        loop = asyncio.get_event_loop()
        while True:
            if not self._pending:
                if self._stopping:
                    return
                self._wake.clear()
                if self._pending:             # raced with a submit
                    continue
                await self._wake.wait()
                continue
            now = loop.time()
            batch = self._select_batch(now)
            if batch is None:
                oldest = min(t.enqueued for t in self._pending)
                delay = max(0.0, oldest + self.max_wait_s - now)
                self._wake.clear()
                try:
                    await asyncio.wait_for(self._wake.wait(), delay)
                except asyncio.TimeoutError:
                    pass
                continue
            await self._flush(batch, loop)

    def _select_batch(self, now: float) -> Optional[List[_Ticket]]:
        """The next batch to flush, or None if nothing is ready yet.

        A config group is ready when its pending apps reach
        ``max_batch_apps``, when its oldest ticket has waited
        ``max_wait_s``, or when the batcher is draining.  Tickets whose
        app *names* collide with a different graph already in the batch
        are deferred to a later flush (same name + same graph is fine —
        that's sharing, the point of batching).
        """
        ready: Dict[str, List[_Ticket]] = {}
        napps: Dict[str, int] = {}
        for t in self._pending:
            g = t.key if t.solo else t.group  # solo tickets: own group
            ready.setdefault(g, []).append(t)
            napps[g] = napps.get(g, 0) + len(t.request.apps)
        pick = None
        for g, tickets in ready.items():
            if (self._stopping or napps[g] >= self.max_batch_apps
                    or now - tickets[0].enqueued >= self.max_wait_s):
                if pick is None or tickets[0].enqueued < pick[0].enqueued:
                    pick = tickets
        if pick is None:
            return None

        batch: List[_Ticket] = []
        apps: Dict[str, str] = {}             # name -> graph fingerprint
        for t in pick:
            if batch and len(apps) >= self.max_batch_apps:
                break
            if any(apps.get(n, k) != k for n, k in t.app_keys.items()):
                self.metrics.inc("serve.deferred_conflict")
                continue                      # same name, different graph
            batch.append(t)
            apps.update(t.app_keys)
        return batch or None

    async def _flush(self, batch: List[_Ticket], loop) -> None:
        now = loop.time()
        for t in batch:
            self._pending.remove(t)
            self._depth -= 1
            self._slots.release()
            self.metrics.observe("serve.time_in_queue_ms",
                                 (now - t.enqueued) * 1e3)
        self.metrics.set_gauge("serve.queue_depth", self._depth)
        self.metrics.inc("serve.batches")
        self.metrics.observe("serve.batch_tickets", len(batch))
        napps = len({(n, k) for t in batch for n, k in t.app_keys.items()})
        self.metrics.observe("serve.batch_apps", napps)

        try:
            outs = await loop.run_in_executor(
                None, self._run_batch, batch)
        except Exception as e:
            if len(batch) == 1:
                self._resolve_error(batch[0], e)
                return
            # catastrophic merged-run failure: contain by re-running each
            # ticket alone so one poisoned request can't take down the rest
            self.metrics.inc("serve.batch_degraded")
            obs_event("serve.batch_degraded", tickets=len(batch),
                      error=type(e).__name__)
            for t in batch:
                try:
                    out = await loop.run_in_executor(
                        None, self._run_batch, [t])
                except Exception as solo_e:
                    self._resolve_error(t, solo_e)
                else:
                    self._resolve(t, out[0])
            return
        for t, out in zip(batch, outs):
            self._resolve(t, out)

    def _resolve(self, t: _Ticket, out: Tuple[list, list]) -> None:
        self._inflight.pop(t.key, None)
        self._cache[t.key] = out
        while len(self._cache) > self.cache_limit:   # FIFO eviction
            self._cache.pop(next(iter(self._cache)))
        if not t.future.done():
            t.future.set_result(out)

    def _resolve_error(self, t: _Ticket, exc: BaseException) -> None:
        self._inflight.pop(t.key, None)
        self.metrics.inc("serve.request_errors")
        if not t.future.done():
            t.future.set_exception(exc)

    # -- the batch itself (worker thread) ----------------------------------
    def _run_batch(self, batch: List[_Ticket]) -> List[Tuple[list, list]]:
        merged: Dict[str, Any] = {}
        for t in batch:
            merged.update(t.request.apps)
        cfg = batch[0].request.config         # group key = config digest
        ex = Explorer(merged, cfg, store=self._store, metrics=self.metrics)
        with span("serve.batch", tickets=len(batch), apps=len(merged)):
            result = ex.run()
        return [([r.to_dict() for r in ticket_records(result, t.request)],
                 [f.to_dict() for f in ticket_failures(result, t.request)])
                for t in batch]
