"""Deprecated import path for the LM demo engine.

``repro.serve`` now names the exploration serving subsystem (async
front-end + cross-request continuous batching over the Explorer); the
token-decode demo this module used to hold moved to
:mod:`repro.serve.lm_engine`.  Importing through this path keeps working
but warns ``DeprecationWarning``.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.serve.engine is deprecated: the LM demo moved to "
    "repro.serve.lm_engine; repro.serve now names the exploration "
    "serving subsystem (ExploreService / ContinuousBatcher)",
    DeprecationWarning, stacklevel=2)

from .lm_engine import Request, ServeEngine  # noqa: E402,F401

__all__ = ["Request", "ServeEngine"]
