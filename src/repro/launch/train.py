"""Training launcher.

CPU-scale end-to-end run (examples/train_lm.py wraps this) and the entry
point a real deployment would invoke per host with jax.distributed.  For
the 512-chip production mesh the same build_train_step is lowered by
launch/dryrun.py — this driver is about actually *stepping*.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..data.pipeline import DataConfig
from ..models.transformer import init_params
from ..train.optimizer import AdamWConfig, init_opt_state
from ..train.steps import build_train_step
from ..train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke config (CPU scale)")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a fault at this step (tests restart)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.n_layers, d_model=args.d_model,
                          d_ff=args.d_ff, vocab=args.vocab, seq=args.seq)

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(10, args.steps // 20),
                          total_steps=args.steps)
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(build_train_step(cfg, opt_cfg,
                                       microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)
    trainer = Trainer(TrainerConfig(total_steps=args.steps,
                                    ckpt_every=args.ckpt_every,
                                    ckpt_dir=args.ckpt_dir),
                      step_fn, params, opt_state, data_cfg)
    t0 = time.time()
    state = trainer.run(fail_at=args.fail_at)
    dt = time.time() - t0
    print(json.dumps({"history": trainer.history,
                      "steps": state.step,
                      "restarts": state.restarts,
                      "stragglers": state.stragglers,
                      "wall_s": round(dt, 1)}, indent=2))


if __name__ == "__main__":
    main()
