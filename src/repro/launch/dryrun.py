import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
must compile for the 256-chip single-pod mesh and the 512-chip double-pod
mesh, for every assigned architecture and shape.  Sharding mismatches,
unsupported collectives and compile-time OOMs all surface here.

Outputs per cell: memory_analysis (fits?), cost_analysis (FLOPs/bytes for
the roofline), and the collective schedule parsed from the partitioned HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh both --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs import get_config, list_archs
from ..models.config import ArchConfig
from ..models.model import init_cache
from ..models.transformer import param_shapes
from ..sharding.specs import (activation_shard_fn, batch_axes, batch_pspecs,
                              cache_pspecs, param_pspecs, to_named)
from ..train.optimizer import AdamWConfig, opt_state_shapes
from ..train.steps import (build_decode_step, build_prefill_step,
                           build_train_step)
from .mesh import make_production_mesh
from .roofline import (Roofline, collective_bytes_from_hlo, model_flops)
from jax.sharding import PartitionSpec as P

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

#: per-(arch, shape) overrides applied on top of the baseline (perf levers
#: recorded in EXPERIMENTS.md §Perf; baseline runs use an empty dict)
OVERRIDES: Dict[Tuple[str, str], Dict[str, Any]] = {}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    if info["kind"] in ("train", "prefill"):
        if cfg.input_mode == "embeddings":
            batch = {"inputs": _sds((b, s, cfg.d_model), jnp.bfloat16)}
        else:
            batch = {"inputs": _sds((b, s), jnp.int32)}
        batch["targets"] = _sds((b, s), jnp.int32)
        if cfg.n_cross_layers:
            batch["enc"] = _sds((b, cfg.encoder_len, cfg.d_model),
                                jnp.bfloat16)
        return batch
    # decode: one new token + caches of length seq
    if cfg.input_mode == "embeddings":
        token = _sds((b, cfg.d_model), jnp.bfloat16)
    else:
        token = _sds((b,), jnp.int32)
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s, jnp.bfloat16))
    return {"token": token, "cache": cache}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               microbatches: int = 1, verbose: bool = True
               ) -> Dict[str, Any]:
    t0 = time.monotonic()
    cfg = get_config(arch)
    info = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    chips = 512 if multi_pod else 256

    if shape_name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "pure full-attention arch; 500k dense KV decode "
                          "needs sub-quadratic attention (DESIGN.md §4)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    from ..models.perf_flags import set_mesh
    set_mesh(mesh, batch_axes(multi_pod))
    shard = activation_shard_fn(mesh, cfg, multi_pod=multi_pod)
    p_specs = to_named(mesh, param_pspecs(cfg))
    p_sds = jax.tree.map(lambda s: _sds(s.shape, s.dtype), param_shapes(cfg))

    b = info["batch"]
    bp = batch_axes(multi_pod)
    dp_size = 16 * (2 if multi_pod else 1)
    if b % dp_size == 0:
        baxis: Any = bp
    elif b % 16 == 0:
        baxis = bp[-1]
    else:
        baxis = None

    if info["kind"] == "train":
        opt_cfg = AdamWConfig()
        opt_sds = opt_state_shapes(p_sds, opt_cfg)
        # moments share the param sharding; step is replicated
        opt_specs = type(opt_sds)(
            step=NamedSharding(mesh, P()),
            m=p_specs, v=p_specs)
        batch_specs = to_named(mesh, batch_pspecs(cfg, multi_pod=multi_pod,
                                                  batch=b))
        batch_sds = input_specs(cfg, shape_name)
        step = build_train_step(cfg, opt_cfg, microbatches=microbatches,
                                shard=shard)
        metric_specs = {"grad_norm": NamedSharding(mesh, P()),
                        "lr": NamedSharding(mesh, P()),
                        "loss": NamedSharding(mesh, P())}
        jitted = jax.jit(step,
                         in_shardings=(p_specs, opt_specs, batch_specs),
                         out_shardings=(p_specs, opt_specs, metric_specs),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_sds, opt_sds, batch_sds)
    elif info["kind"] == "prefill":
        batch_specs = to_named(mesh, batch_pspecs(cfg, multi_pod=multi_pod,
                                                  batch=b))
        batch_sds = input_specs(cfg, shape_name)
        c_specs = to_named(mesh, cache_pspecs(cfg, multi_pod=multi_pod,
                                              batch=b))
        vocab_ax = "model" if cfg.vocab % 16 == 0 else None
        logits_spec = NamedSharding(mesh, P(baxis, vocab_ax))
        step = build_prefill_step(cfg, smax=info["seq"], shard=shard)
        jitted = jax.jit(step, in_shardings=(p_specs, batch_specs),
                         out_shardings=(logits_spec, c_specs))
        lowered = jitted.lower(p_sds, batch_sds)
    else:  # decode
        ins = input_specs(cfg, shape_name)
        c_specs = to_named(mesh, cache_pspecs(cfg, multi_pod=multi_pod,
                                              batch=b))
        vocab_ax = "model" if cfg.vocab % 16 == 0 else None
        tok_spec = NamedSharding(
            mesh, P(baxis, None) if cfg.input_mode == "embeddings"
            else P(baxis))
        out_tok_spec = NamedSharding(mesh, P(baxis))
        logits_spec = NamedSharding(mesh, P(baxis, vocab_ax))
        step = build_decode_step(cfg, shard=shard)
        jitted = jax.jit(step, in_shardings=(p_specs, tok_spec, c_specs),
                         out_shardings=(out_tok_spec, logits_spec, c_specs),
                         donate_argnums=(2,))
        lowered = jitted.lower(p_sds, ins["token"], ins["cache"])

    t_lower = time.monotonic() - t0
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()

    # trip-count-weighted per-device costs from the partitioned HLO
    # (cost_analysis counts while bodies once — see launch/hlo_cost.py)
    from .hlo_cost import analyze as hlo_analyze
    hc = hlo_analyze(hlo)
    coll_bytes, coll_kinds = hc.collective_bytes, hc.collective_breakdown
    n_coll = int(hc.collective_count)

    flops_dev = float(hc.flops)
    bytes_dev = float(hc.bytes)
    xla_flops_dev = float(cost.get("flops", 0.0))   # loop-once, for reference
    peak_mem = 0.0
    mem_str = str(mem)
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes"):
        peak_mem += float(getattr(mem, attr, 0.0) or 0.0)

    rl = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_bytes,
        collective_breakdown=coll_kinds,
        model_flops=model_flops(cfg, shape_name, info["batch"], info["seq"]),
        peak_memory_bytes=peak_mem,
        collective_count=n_coll,
    )
    result = {"status": "ok", "t_lower_s": round(t_lower, 1),
              "t_compile_s": round(t_compile, 1),
              "memory_analysis": mem_str,
              "microbatches": microbatches,
              "xla_flops_per_device_loop_once": xla_flops_dev,
              **rl.to_dict()}
    if verbose:
        print(rl.row())
        print(f"    mem: {mem_str}")
        print(f"    collectives: n={n_coll} {coll_kinds}")
        print(f"    lower {t_lower:.1f}s compile {t_compile:.1f}s")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="perf flag override, e.g. --set attention_impl="
                         "q_outer (see models/perf_flags.py)")
    args = ap.parse_args()

    if args.set:
        from ..models.perf_flags import set_flags
        overrides = {}
        for kv in args.set:
            key, val = kv.split("=", 1)
            if val in ("true", "True"):
                val = True
            elif val in ("false", "False"):
                val = False
            elif val.isdigit():
                val = int(val)
            overrides[key] = val
        print(f"perf flags: {set_flags(**overrides)}")

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    r = lower_cell(arch, shape, multi_pod=multi,
                                   microbatches=args.microbatches)
                except Exception as e:  # a failing cell is a bug — surface it
                    r = {"arch": arch, "shape": shape,
                         "mesh": "multi" if multi else "single",
                         "status": "error", "error": f"{type(e).__name__}: {e}",
                         "traceback": traceback.format_exc()[-2000:]}
                    print(f"ERROR {arch} {shape} "
                          f"{'multi' if multi else 'single'}: "
                          f"{r['error'][:200]}")
                results.append(r)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(r) + "\n")
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"\ndry-run cells: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
