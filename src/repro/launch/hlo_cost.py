"""HLO-text cost model with while-loop trip-count weighting.

``compiled.cost_analysis()`` counts each while-loop body ONCE, so for a
scan-over-layers model it undercounts FLOPs/bytes/collectives by ~n_layers.
This module parses ``compiled.as_text()`` (post-SPMD-partitioning HLO) into
its computation graph and aggregates:

* **flops** — dot ops (2 x |result| x |contracted dims|) + elementwise ops
  (1 flop/element; transcendentals weighted higher), with while bodies
  multiplied by their trip count (parsed from the loop-condition constant);
* **bytes** — per-instruction operand+result buffer traffic at fusion
  boundaries (inside-fusion values never touch HBM), trip-weighted;
* **collective_bytes** — all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute result bytes x ring multiplier,
  trip-weighted.

All numbers are per-device (the SPMD module is per-device).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

#: flops-per-element for elementwise opcodes (everything else: 0)
_ELEMENTWISE = {
    "add": 1, "subtract": 1, "multiply": 1, "divide": 3, "negate": 1,
    "abs": 1, "maximum": 1, "minimum": 1, "compare": 1, "select": 1,
    "and": 1, "or": 1, "xor": 1, "not": 1, "exponential": 6, "log": 6,
    "tanh": 8, "logistic": 6, "rsqrt": 4, "sqrt": 4, "power": 8,
    "cosine": 6, "sine": 6, "floor": 1, "round-nearest-afz": 1,
    "exponential-minus-one": 6, "clamp": 2, "sign": 1,
    "multiply-add": 2, "erf": 8,
}

_COLLECTIVES = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

#: instructions that move no HBM bytes themselves
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str
    raw: str = ""


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)   # value -> type str
    params: List[str] = field(default_factory=list)       # in header order
    root: Optional[str] = None


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|\S+?))\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\)|[^,)]+(?:\[[^\]]*\])?(?:\{[^}]*\})?))")


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        # tuple types embed /*index=N*/ comments whose '=' breaks parsing
        raw = _COMMENT_RE.sub("", raw)
        line = raw.strip()
        if not line:
            continue
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                if raw.lstrip().startswith("ENTRY"):
                    entry = cur.name
                # header params carry types (order matters for fusion I/O)
                for pm in _PARAM_RE.finditer(m.group(2)):
                    cur.types[pm.group(1)] = pm.group(2)
                    cur.params.append(pm.group(1))
            continue
        if line == "}" or line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode = m.group(1), m.group(2), m.group(3)
        # operand names: inside the first balanced paren group after opcode
        start = line.find(opcode + "(") + len(opcode)
        depth = 0
        end = start
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = line[start + 1:end]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        attrs = line[end + 1:]
        cur.types[name] = rtype
        cur.instrs.append(Instr(name, rtype, opcode, operands, attrs, line))
        if line.startswith("ROOT"):
            cur.root = name
    return comps, entry


_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                        r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _called(attrs: str) -> Dict[str, List[str]]:
    out: Dict[str, List[str]] = {}
    for key in ("calls", "to_apply", "body", "condition"):
        m = re.search(key + r"=%?([\w.\-]+)", attrs)
        if m:
            out[key] = [m.group(1)]
    m = re.search(r"branch_computations=\{([^}]*)\}", attrs)
    if m:
        out["branches"] = re.findall(r"%?([\w.\-]+)", m.group(1))
    return out


def _trip_count(cond: Computation,
                comps: Dict[str, "Computation"]) -> int:
    """Trip count heuristic: largest integer constant in the loop condition
    (scan lowers to  induction_var < constant ), recursing one level into
    computations the condition calls (fused compares)."""
    best = 1
    stack = [cond]
    for ins in cond.instrs:
        for subs in _called(ins.attrs).values():
            for sub in subs:
                if sub in comps:
                    stack.append(comps[sub])
    for comp in stack:
        for ins in comp.instrs:
            if ins.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", ins.raw)
                if m:
                    best = max(best, int(m.group(1)))
    return best


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_count: float = 0.0
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    bytes_by_opcode: Dict[str, float] = field(default_factory=dict)
    flops_by_opcode: Dict[str, float] = field(default_factory=dict)


def analyze(text: str, *, debug_opcodes: bool = False) -> HloCost:
    comps, entry = parse_hlo(text)
    if entry is None:
        return HloCost()
    memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(name: str, count_bytes: bool) -> HloCost:
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        total = HloCost()
        memo[key] = total                      # cycle guard
        if comp is None:
            return total
        def merge(c: HloCost, mult: float) -> None:
            total.flops += c.flops * mult
            total.bytes += c.bytes * mult
            total.collective_bytes += c.collective_bytes * mult
            total.collective_count += c.collective_count * mult
            for k, v in c.collective_breakdown.items():
                total.collective_breakdown[k] = \
                    total.collective_breakdown.get(k, 0) + v * mult
            for k, v in c.bytes_by_opcode.items():
                total.bytes_by_opcode[k] = \
                    total.bytes_by_opcode.get(k, 0) + v * mult
            for k, v in c.flops_by_opcode.items():
                total.flops_by_opcode[k] = \
                    total.flops_by_opcode.get(k, 0) + v * mult

        def add_bytes(opcode: str, b: float) -> None:
            total.bytes += b
            total.bytes_by_opcode[opcode] = \
                total.bytes_by_opcode.get(opcode, 0) + b

        def add_flops(opcode: str, f: float) -> None:
            total.flops += f
            total.flops_by_opcode[opcode] = \
                total.flops_by_opcode.get(opcode, 0) + f

        for ins in comp.instrs:
            called = _called(ins.attrs)
            if ins.opcode == "while":
                body = called.get("body", [None])[0]
                cond = called.get("condition", [None])[0]
                trips = _trip_count(comps[cond], comps) if cond in comps else 1
                for sub in (body, cond):
                    if sub in comps:
                        merge(comp_cost(sub, count_bytes), trips)
                continue
            if ins.opcode == "conditional":
                branches = called.get("branches", [])
                subs = [comp_cost(s, count_bytes) for s in branches
                        if s in comps]
                if subs:                        # worst-case branch
                    worst = max(subs, key=lambda c: c.flops + c.bytes)
                    merge(worst, 1.0)
                continue
            if ins.opcode == "fusion":
                sub = called.get("calls", [None])[0]
                if sub in comps:
                    c = comp_cost(sub, False)   # fusion interior: flops only
                    for k, v in c.flops_by_opcode.items():
                        total.flops_by_opcode[k] = \
                            total.flops_by_opcode.get(k, 0) + v
                    total.flops += c.flops
                    total.collective_bytes += c.collective_bytes
                    total.collective_count += c.collective_count
                    for k, v in c.collective_breakdown.items():
                        total.collective_breakdown[k] = \
                            total.collective_breakdown.get(k, 0) + v
                    add_bytes("fusion:" + (sub.split(".")[0] if sub else "?"),
                              _fusion_io_bytes(comp, ins, comps[sub]))
                else:
                    add_bytes("fusion", _io_bytes(comp, ins))
                continue
            if ins.opcode in ("call", "custom-call", "map", "reduce",
                              "reduce-window", "sort", "scatter",
                              "select-and-scatter"):
                per_elem = ins.opcode in ("map", "reduce", "reduce-window",
                                          "scatter", "select-and-scatter")
                if per_elem:
                    in_t = comp.types.get(ins.operands[0], "") \
                        if ins.operands else ""
                    scale = max(1, _type_elems(in_t))
                else:
                    scale = 1
                for subs in called.values():
                    for sub in subs:
                        if sub in comps:
                            c = comp_cost(sub, False)
                            add_flops(ins.opcode, c.flops * scale)
                if count_bytes and ins.opcode not in _FREE:
                    add_bytes(ins.opcode, _io_bytes(comp, ins))
                continue

            if ins.opcode in _COLLECTIVES:
                b = _type_bytes(ins.result_type) * _COLLECTIVES[ins.opcode]
                total.collective_bytes += b
                total.collective_count += 1
                total.collective_breakdown[ins.opcode] = \
                    total.collective_breakdown.get(ins.opcode, 0) + b
            elif ins.opcode.endswith("-start") and \
                    ins.opcode[:-6] in _COLLECTIVES:
                kind = ins.opcode[:-6]
                b = _type_bytes(ins.result_type) * _COLLECTIVES[kind]
                total.collective_bytes += b
                total.collective_count += 1
                total.collective_breakdown[kind] = \
                    total.collective_breakdown.get(kind, 0) + b

            if ins.opcode in ("dot", "dot_general"):
                add_flops("dot", _dot_flops(comp, ins))
            elif ins.opcode == "convolution":
                add_flops("convolution", _conv_flops(comp, ins))
            elif ins.opcode in _ELEMENTWISE:
                add_flops(ins.opcode, _ELEMENTWISE[ins.opcode] *
                          _type_elems(ins.result_type))

            if count_bytes and ins.opcode not in _FREE:
                add_bytes(ins.opcode, _io_bytes(comp, ins))
        memo[key] = total
        return total

    _SLICY = {"dynamic-slice", "slice", "gather", "get-tuple-element",
              "bitcast", "reshape", "transpose"}

    def _io_bytes(comp: Computation, ins: Instr) -> float:
        # in-place update ops touch only the updated window, not the buffer
        if ins.opcode == "dynamic-update-slice" and len(ins.operands) >= 2:
            upd = comp.types.get(ins.operands[1], "")
            return 2.0 * _type_bytes(upd)
        if ins.opcode in ("dynamic-slice", "slice"):
            return 2.0 * _type_bytes(ins.result_type)
        b = _type_bytes(ins.result_type)
        for op in ins.operands:
            t = comp.types.get(op)
            if t:
                b += _type_bytes(t)
        return b

    def _fusion_io_bytes(comp: Computation, ins: Instr,
                         sub: Computation) -> float:
        """Fusion boundary traffic with slice-aware operand accounting: a
        fused dynamic-slice of a stacked (L, ...) buffer reads one slice per
        call, not the whole stack; a fused dynamic-update-slice root writes
        one window."""
        # writes
        b = 0.0
        root_ins = next((i for i in sub.instrs if i.name == sub.root), None)
        if root_ins is not None and root_ins.opcode == "dynamic-update-slice" \
                and len(root_ins.operands) >= 2:
            b += 2.0 * _type_bytes(sub.types.get(root_ins.operands[1], ""))
        else:
            b += _type_bytes(ins.result_type)
        # reads
        uses_by_param: Dict[str, List[Instr]] = {}
        for i2 in sub.instrs:
            for op in i2.operands:
                if op in sub.types and op in sub.params:
                    uses_by_param.setdefault(op, []).append(i2)
        for site_op, pname in zip(ins.operands, sub.params):
            uses = uses_by_param.get(pname, [])
            if uses and all(u.opcode in _SLICY for u in uses):
                b += sum(_type_bytes(u.result_type) for u in uses)
            else:
                t = comp.types.get(site_op)
                if t:
                    b += _type_bytes(t)
        return b

    def _dot_flops(comp: Computation, ins: Instr) -> float:
        out_elems = _type_elems(ins.result_type)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        contract = 1
        if m and ins.operands:
            lhs_t = comp.types.get(ins.operands[0], "")
            dims = _shape_dims(lhs_t)
            for d in m.group(1).split(","):
                if d and int(d) < len(dims):
                    contract *= dims[int(d)]
        return 2.0 * out_elems * contract

    def _conv_flops(comp: Computation, ins: Instr) -> float:
        out_elems = _type_elems(ins.result_type)
        rhs_t = comp.types.get(ins.operands[1], "") if len(ins.operands) > 1 \
            else ""
        kernel = 1
        for d in _shape_dims(rhs_t)[:-1]:
            kernel *= d
        return 2.0 * out_elems * kernel

    return comp_cost(entry, True)
