"""Production mesh construction.

Single pod = 16 x 16 = 256 chips (axes ``data x model``); two pods = 512
chips (``pod x data x model``).  Defined as a function so importing this
module never touches jax device state (the dry-run must set XLA_FLAGS before
the first jax call).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py which sets "
            "--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:n])


# TPU v5e hardware constants (roofline targets)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per-direction)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip
