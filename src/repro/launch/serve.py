"""Serving launcher: batched continuous prefill+decode (CPU demo scale).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, list_archs
from ..models.transformer import init_params
from ..serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--smax", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(n_layers=2, d_model=128, d_ff=256,
                                        vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, slots=args.slots, smax=args.smax)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, args.prompt_len,
                                             dtype=np.int32),
                           max_new=args.max_new))
    t0 = time.time()
    outs = eng.run(max_steps=args.requests * args.max_new + 16)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)")
    for rid, toks in sorted(outs.items()):
        print(f"  req {rid}: {toks[:12]}{'...' if len(toks) > 12 else ''}")


if __name__ == "__main__":
    main()
