"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_global   / (chips x 197e12 FLOP/s)
  memory     = HLO_bytes_global   / (chips x 819e9 B/s)
  collective = collective_bytes   / (chips x 50e9 B/s per link)

``compiled.cost_analysis()`` reports the per-device (SPMD) program, so the
global numbers are per-device x chips and the chips cancel; we keep the
brief's formula by computing global = per_device * chips.

collective_bytes is parsed from ``compiled.as_text()`` (post-partitioning
HLO): every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute contributes its result-buffer bytes, with the standard
ring multipliers (all-reduce moves ~2x its payload).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

# result shapes like  bf16[128,32768,8,128]{3,2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

#: traffic multiplier per collective kind (ring algorithms, payload-relative)
_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Per-device collective traffic (bytes) summed over the module."""
    per_kind: Dict[str, float] = {}
    seen_done = set()
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        result_type, kind = m.group(1), m.group(2)
        b = _shape_bytes(result_type) * _MULT[kind]
        per_kind[kind] = per_kind.get(kind, 0.0) + b
    return sum(per_kind.values()), per_kind


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    model_flops: float = 0.0            # 6*N*D (dense) / 6*N_active*D (MoE)
    peak_memory_bytes: float = 0.0      # from memory_analysis
    collective_count: int = 0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_global — catches remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the step is to the compute roofline if it ran at the
        max(term) bound: compute_s / bound_s."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def row(self) -> str:
        return (f"{self.arch:<22} {self.shape:<12} {self.mesh:<7} "
                f"cmp={self.compute_s*1e3:9.3f}ms "
                f"mem={self.memory_s*1e3:9.3f}ms "
                f"col={self.collective_s*1e3:9.3f}ms "
                f"dom={self.dominant:<10} "
                f"useful={self.useful_ratio:5.2f} "
                f"roof={self.roofline_fraction:5.2f}")

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_breakdown": self.collective_breakdown,
            "collective_count": self.collective_count,
            "model_flops": self.model_flops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def count_params(cfg) -> int:
    from ..models.transformer import param_shapes
    import numpy as np
    import jax
    shapes = param_shapes(cfg)
    return int(sum(int(np.prod(s.shape))
                   for s in jax.tree.leaves(shapes)))


def count_active_params(cfg) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    n = count_params(cfg)
    if cfg.moe is None:
        return n
    moe = cfg.moe
    per_expert = 3 * cfg.d_model * moe.d_expert
    n_self = cfg.n_self_layers if cfg.mixer != "mamba" else cfg.n_layers
    routed_total = n_self * moe.n_experts_padded * per_expert
    routed_active = n_self * moe.top_k * per_expert
    return n - routed_total + routed_active


def model_flops(cfg, shape_name: str, batch: int, seq: int) -> float:
    """6*N_active*D for training, 2*N_active*D for inference steps."""
    n_active = count_active_params(cfg)
    if shape_name.startswith("train"):
        return 6.0 * n_active * batch * seq
    if shape_name.startswith("prefill"):
        return 2.0 * n_active * batch * seq
    # decode shapes: one token per sequence
    return 2.0 * n_active * batch
