"""The paper's contribution: frequent-subgraph-analysis PE design-space
exploration (mine -> MIS-rank -> merge -> map -> evaluate)."""

from .costmodel import AppCost, evaluate_mapping
from .dse import DSEResult, PEVariant, domain_pe, mine_and_rank, specialize_per_app
from .isomorphism import Embedding, count_occurrences, find_embeddings, mni_support
from .mapper import Mapping, map_application
from .merge import add_pattern, baseline_datapath, is_pe_pattern, merge_subgraphs, validate_config
from .mining import MinedSubgraph, MiningConfig, mine_frequent_subgraphs
from .mis import maximal_independent_set, mis_of_occurrences, rank_by_mis
from .pe import Config, Datapath, single_op_pattern

__all__ = [
    "AppCost", "evaluate_mapping", "DSEResult", "PEVariant", "domain_pe",
    "mine_and_rank", "specialize_per_app", "Embedding", "count_occurrences",
    "find_embeddings", "mni_support", "Mapping", "map_application",
    "add_pattern", "baseline_datapath", "is_pe_pattern", "merge_subgraphs",
    "validate_config", "MinedSubgraph", "MiningConfig",
    "mine_frequent_subgraphs", "maximal_independent_set",
    "mis_of_occurrences", "rank_by_mis", "Config", "Datapath",
    "single_op_pattern",
]
