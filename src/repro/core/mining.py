"""Frequent subgraph mining on a single large graph (paper Sec. III-A).

The paper uses GRAMI.  We implement the same functionality natively: grow
connected candidate patterns edge-by-edge from frequent seeds, deduplicate by
canonical label, and count support against the application graph.  Two
support measures are tracked:

* ``occurrences`` — distinct embedded node-sets (what Fig. 3 reports, e.g.
  "frequency four" for the overlapping add-add pattern in Fig. 3d);
* ``mni`` — GRAMI's minimum-node-image support, which is anti-monotone and is
  what we prune the growth lattice with.

Patterns are restricted to compute(+const) nodes; ``input``/``output`` and
tensor-macro structural nodes never appear inside a mined pattern's interior
op set unless ``allow_macros`` is set (LM tensor-level graphs mine elementwise
idioms around matmul macro nodes; the PE generator later filters).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from ..graphir.graph import Graph
from ..graphir.ops import NON_COMPUTE, OPS, unit_of, U_IO, U_REDUCE, U_MATMUL
from .isomorphism import Embedding, find_embeddings, mni_support

#: ops that may seed/extend patterns by default (real PE compute + const)
def _default_minable(op: str) -> bool:
    if op in NON_COMPUTE:
        return False
    u = unit_of(op)
    return u not in (U_IO, U_REDUCE, U_MATMUL)


@dataclass
class MinedSubgraph:
    """A frequent subgraph with its occurrence statistics."""

    pattern: Graph
    label: str
    embeddings: List[Embedding]
    occurrences: int          # distinct node sets
    mni: int                  # GRAMI MNI support
    mis_size: int = -1        # filled in by core.mis.rank_by_mis

    @property
    def size(self) -> int:
        return self.pattern.num_compute_nodes()

    def __repr__(self) -> str:  # pragma: no cover
        hist = self.pattern.op_histogram()
        ops = ",".join(f"{k}x{v}" for k, v in sorted(hist.items()))
        return (f"MinedSubgraph({ops}; occ={self.occurrences}, mni={self.mni},"
                f" mis={self.mis_size})")


@dataclass
class MiningConfig:
    min_support: int = 2          # MNI threshold (GRAMI semantics)
    max_pattern_nodes: int = 6    # pattern size cap
    max_patterns_per_level: int = 400
    max_embeddings: int = 100_000
    max_ext_embeddings: int = 300  # embeddings examined when extending
    time_budget_s: float = 60.0
    allow_macros: bool = False    # let matmul/reduce macro nodes into patterns


def _minable(op: str, cfg: MiningConfig) -> bool:
    if cfg.allow_macros:
        return op not in NON_COMPUTE and unit_of(op) != U_IO
    return _default_minable(op)


def _seed_patterns(target: Graph, cfg: MiningConfig) -> Dict[str, Graph]:
    """All 1-edge patterns present in the target, keyed by canonical label."""
    seeds: Dict[str, Graph] = {}
    for (s, d, p) in target.edges:
        so, do = target.nodes[s], target.nodes[d]
        if not (_minable(so, cfg) and _minable(do, cfg)):
            continue
        g = Graph()
        a = g.add_node(so)
        b = g.add_node(do)
        g.add_edge(a, b, p)
        seeds.setdefault(g.canonical_label(), g)
    return seeds


def _attach_port(pattern: Graph, dst: int, want: int) -> Optional[int]:
    """Port at which a new in-edge may attach to `dst` inside the pattern.

    Non-commutative ops need exactly `want`; commutative ops take any free
    port (PE input muxes make operand order configurable)."""
    driven = set(pattern.in_edges(dst))
    op = pattern.nodes[dst]
    if not OPS[op].commutative:
        return None if want in driven else want
    for port in range(OPS[op].arity):
        if port not in driven:
            return port
    return None


def _extensions(pattern: Graph, embeddings: List[Embedding],
                target: Graph, cfg: MiningConfig) -> Dict[str, Graph]:
    """Candidate (pattern + 1 edge) extensions, keyed by canonical label."""
    out: Dict[str, Graph] = {}
    pat_nodes = sorted(pattern.nodes)
    n_nodes = len(pat_nodes)
    # one embedding per distinct node-set is enough to enumerate extensions
    uniq: Dict[FrozenSet[int], Embedding] = {}
    for e in embeddings:
        uniq.setdefault(e.nodes, e)
    for emb in list(uniq.values())[: cfg.max_ext_embeddings]:
        inv = {tn: pn for pn, tn in emb.mapping.items()}
        image = emb.nodes
        for (ts, td, tp) in target.edges:
            s_in = ts in image
            d_in = td in image
            if not (s_in or d_in):
                continue
            if s_in and d_in:
                # close an edge between two mapped nodes
                ps, pd = inv[ts], inv[td]
                if any(src == ps for src in pattern.in_edges(pd).values()):
                    continue
                port = _attach_port(pattern, pd, tp)
                if port is None:
                    continue  # port already driven inside pattern
                g = pattern.copy()
                g.add_edge(ps, pd, port)
            else:
                if n_nodes >= cfg.max_pattern_nodes:
                    continue
                new_op = target.nodes[td if s_in else ts]
                if not _minable(new_op, cfg):
                    continue
                g = pattern.copy()
                nid = g.add_node(new_op)
                if s_in:
                    g.add_edge(inv[ts], nid, tp)
                else:
                    port = _attach_port(pattern, inv[td], tp)
                    if port is None:
                        continue
                    g.add_edge(nid, inv[td], port)
            try:
                label = g.canonical_label()
            except ValueError:
                continue
            out.setdefault(label, g)
            if len(out) >= cfg.max_patterns_per_level * 4:
                return out
    return out


def mine_frequent_subgraphs(target: Graph,
                            config: Optional[MiningConfig] = None,
                            ) -> List[MinedSubgraph]:
    """Mine frequent connected subgraphs of `target`.

    Returns patterns with MNI support >= min_support and >= 2 compute nodes,
    sorted by (size desc, occurrences desc).  Single-op "patterns" are the
    baseline PE's territory (paper PE 1) and are not returned here.
    """
    cfg = config or MiningConfig()
    t0 = time.monotonic()
    results: List[MinedSubgraph] = []
    seen: Set[str] = set()

    frontier: Dict[str, Graph] = _seed_patterns(target, cfg)
    while frontier:
        if time.monotonic() - t0 > cfg.time_budget_s:
            break
        scored: List[Tuple[str, Graph, List[Embedding], int, int]] = []
        for label, pat in frontier.items():
            if label in seen:
                continue
            seen.add(label)
            embs = find_embeddings(pat, target,
                                   max_embeddings=cfg.max_embeddings)
            if not embs:
                continue
            occ = len({e.nodes for e in embs})
            mni = mni_support(pat, embs)
            if mni >= cfg.min_support:
                scored.append((label, pat, embs, occ, mni))
        # record + grow the most promising patterns of this level
        scored.sort(key=lambda t: (-t[3], t[0]))
        scored = scored[: cfg.max_patterns_per_level]
        next_frontier: Dict[str, Graph] = {}
        for label, pat, embs, occ, mni in scored:
            results.append(MinedSubgraph(
                pattern=pat, label=label, embeddings=embs,
                occurrences=occ, mni=mni))
            if time.monotonic() - t0 > cfg.time_budget_s:
                break
            for xlabel, xpat in _extensions(pat, embs, target, cfg).items():
                if xlabel not in seen:
                    next_frontier.setdefault(xlabel, xpat)
        frontier = next_frontier

    results.sort(key=lambda m: (-m.size, -m.occurrences, m.label))
    return results
