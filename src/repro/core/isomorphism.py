"""Subgraph-isomorphism (embedding) enumeration — VF2-style backtracking.

This underlies both frequent-subgraph mining (occurrence counting, paper
Sec. III-A) and the application mapper (covering, Sec. IV step 6).

Pattern semantics
-----------------
* Pattern nodes carry ops; an embedding maps them injectively onto target
  nodes with the *same op* (``const`` matches any ``const`` — constant
  registers are configured per application, Fig. 2c).
* Every pattern edge ``(ps, pd, port)`` must map onto a target edge
  ``(f(ps), f(pd), port')``.  For non-commutative destination ops the port
  must match exactly (operand order is significant, Sec. II-B); for
  commutative ops the PE's input muxes make operand order configurable, so
  the pattern's internal in-edges of a node must map onto *distinct* target
  in-edges at any ports.
* Pattern free in-ports are unconstrained (fed from outside the PE).
* Optionally (mapper mode) interior pattern nodes — those whose value is
  consumed inside the pattern and which are not pattern sinks — must have no
  *other* consumers in the target graph: a PE only exposes its outputs, so a
  covered interior value cannot feed anything outside the PE instance.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, Iterator, List, Optional, Set, Tuple

from ..graphir.graph import Graph, sink_nodes
from ..graphir.ops import NON_COMPUTE, OPS


class Embedding:
    """An occurrence of a pattern in a target graph."""

    __slots__ = ("mapping", "nodes")

    def __init__(self, mapping: Dict[int, int]):
        self.mapping = mapping                       # pattern node -> target node
        self.nodes: FrozenSet[int] = frozenset(mapping.values())

    def __repr__(self) -> str:  # pragma: no cover
        return f"Embedding({self.mapping})"


def _search_order(pattern: Graph) -> List[int]:
    """Connected visit order over pattern nodes (undirected BFS)."""
    nodes = sorted(pattern.nodes)
    if not nodes:
        return []
    adj: Dict[int, Set[int]] = {n: set() for n in nodes}
    for (s, d, _) in pattern.edges:
        adj[s].add(d)
        adj[d].add(s)
    order: List[int] = []
    seen: Set[int] = set()
    for root in nodes:
        if root in seen:
            continue
        queue = [root]
        seen.add(root)
        while queue:
            n = queue.pop(0)
            order.append(n)
            for m in sorted(adj[n]):
                if m not in seen:
                    seen.add(m)
                    queue.append(m)
    return order


def _commutative(op: str) -> bool:
    return OPS[op].commutative


def find_embeddings(pattern: Graph, target: Graph, *,
                    max_embeddings: int = 200_000,
                    interior_private: bool = False,
                    max_exposed: Optional[int] = None,
                    allowed_nodes: Optional[Set[int]] = None,
                    ) -> List[Embedding]:
    """Enumerate embeddings of `pattern` in `target` (see module docstring).

    interior_private=True with max_exposed=k allows up to k interior values
    to escape the instance — the PE exposes them on spare output lines
    (multi-output PEs, paper Fig. 5e / Garnet's res+res_p)."""
    order = _search_order(pattern)
    if not order:
        return []

    # target indexes ------------------------------------------------------
    t_in: Dict[int, Dict[int, int]] = {}      # dst -> {port: src}
    t_out: Dict[int, List[Tuple[int, int]]] = {}  # src -> [(dst, port)]
    for (s, d, p) in target.edges:
        t_in.setdefault(d, {})[p] = s
        t_out.setdefault(s, []).append((d, p))
    by_op: Dict[str, List[int]] = {}
    for n, op in target.nodes.items():
        if allowed_nodes is not None and n not in allowed_nodes:
            continue
        by_op.setdefault(op, []).append(n)

    p_in: Dict[int, Dict[int, int]] = {}
    p_out: Dict[int, List[Tuple[int, int]]] = {}
    for (s, d, p) in pattern.edges:
        p_in.setdefault(d, {})[p] = s
        p_out.setdefault(s, []).append((d, p))

    sinks = set(sink_nodes(pattern))
    results: List[Embedding] = []
    mapping: Dict[int, int] = {}
    used: Set[int] = set()

    def edge_ok(tn_src: int, tn_dst: int, port: int, dst_op: str) -> bool:
        """Does target have edge tn_src -> tn_dst honoring port semantics?"""
        ins = t_in.get(tn_dst, {})
        if not _commutative(dst_op):
            return ins.get(port) == tn_src
        return tn_src in ins.values()

    def node_edges_ok(pn: int, tn: int) -> bool:
        """All pattern edges between pn and already-mapped nodes hold."""
        # in-edges of pn
        internal_srcs: List[int] = []
        for port, ps in p_in.get(pn, {}).items():
            if ps in mapping:
                if not edge_ok(mapping[ps], tn, port, pattern.nodes[pn]):
                    return False
                internal_srcs.append(mapping[ps])
        # commutative: distinct pattern in-edges need distinct target in-edges
        if _commutative(pattern.nodes[pn]) and internal_srcs:
            tgt_srcs = list(t_in.get(tn, {}).values())
            for s in set(internal_srcs):
                if internal_srcs.count(s) > tgt_srcs.count(s):
                    return False
        # out-edges of pn
        for (pd, port) in p_out.get(pn, ()):
            if pd in mapping:
                if not edge_ok(tn, mapping[pd], port, pattern.nodes[pd]):
                    return False
        return True

    def candidates(pn: int) -> Iterator[int]:
        op = pattern.nodes[pn]
        for port, ps in p_in.get(pn, {}).items():
            if ps in mapping:
                for (td, tp) in t_out.get(mapping[ps], ()):
                    if target.nodes.get(td) != op:
                        continue
                    if _commutative(op) or tp == port:
                        yield td
                return
        for (pd, port) in p_out.get(pn, ()):
            if pd in mapping:
                td = mapping[pd]
                if _commutative(pattern.nodes[pd]):
                    for src in t_in.get(td, {}).values():
                        if target.nodes.get(src) == op:
                            yield src
                else:
                    src = t_in.get(td, {}).get(port)
                    if src is not None and target.nodes.get(src) == op:
                        yield src
                return
        yield from by_op.get(op, ())

    def feasible(pn: int, tn: int) -> bool:
        if tn in used:
            return False
        if allowed_nodes is not None and tn not in allowed_nodes:
            return False
        if target.nodes[tn] != pattern.nodes[pn]:
            return False
        return node_edges_ok(pn, tn)

    def interior_ok(emb: Dict[int, int]) -> bool:
        if not interior_private:
            return True
        budget = max_exposed or 0
        image = set(emb.values())
        exposed = 0
        for pn, tn in emb.items():
            if pn in sinks:
                continue
            # const registers are duplicated per PE instance (Fig. 2c), so a
            # shared constant never blocks covering
            if pattern.nodes[pn] in NON_COMPUTE or pattern.nodes[pn] == "const":
                continue
            if any(td not in image for (td, _) in t_out.get(tn, ())):
                exposed += 1
                if exposed > budget:
                    return False
        return True

    def backtrack(i: int) -> bool:
        if i == len(order):
            emb = dict(mapping)
            if interior_ok(emb):
                results.append(Embedding(emb))
            return len(results) < max_embeddings
        pn = order[i]
        seen_c: Set[int] = set()
        for tn in candidates(pn):
            if tn in seen_c:
                continue
            seen_c.add(tn)
            if not feasible(pn, tn):
                continue
            mapping[pn] = tn
            used.add(tn)
            ok = backtrack(i + 1)
            del mapping[pn]
            used.discard(tn)
            if not ok:
                return False
        return True

    backtrack(0)
    return results


def count_occurrences(pattern: Graph, target: Graph, **kw) -> int:
    """Occurrences = distinct embedded node-sets (automorphism-collapsed)."""
    embs = find_embeddings(pattern, target, **kw)
    return len({e.nodes for e in embs})


def mni_support(pattern: Graph, embeddings: List[Embedding]) -> int:
    """GRAMI's minimum-node-image support (anti-monotone)."""
    if not embeddings:
        return 0
    images: Dict[int, Set[int]] = {}
    for e in embeddings:
        for pn, tn in e.mapping.items():
            images.setdefault(pn, set()).add(tn)
    return min(len(v) for v in images.values())
