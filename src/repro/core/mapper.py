"""Application mapper (paper Sec. IV step 6).

Covers the application dataflow graph with PE configurations, minimizing the
number of PEs used: multi-op configs are matched first (largest pattern
first, non-overlapping greedy — the same maximal-independent-set machinery
that ranks subgraphs), remaining compute nodes fall back to single-op
configs.  CGRAs are spatial, so every covered instance occupies one PE tile.

Constants are absorbed into the instance that consumes them (configured
constant registers, Fig. 2c) and may be freely duplicated across instances.
Tensor-macro nodes (matmul / reductions in LM-layer graphs) are not PE ops —
they are counted separately as "offloaded" (they map to the MXU / XLA in the
TPU adaptation).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..graphir.graph import Graph
from ..graphir.ops import NON_COMPUTE, OPS, unit_of
from .isomorphism import Embedding, find_embeddings
from .merge import _PE_UNITS
from .mis import maximal_independent_set
from .pe import Config, Datapath


@dataclass
class MappedInstance:
    config: str
    mapping: Dict[int, int]          # pattern node -> app node
    covered: Set[int]                # app compute nodes covered (consts excl.)
    n_ops: int


@dataclass
class Mapping:
    app_name: str
    instances: List[MappedInstance] = field(default_factory=list)
    offloaded: List[int] = field(default_factory=list)   # macro nodes
    unmapped: List[int] = field(default_factory=list)    # should be empty

    @property
    def n_pes(self) -> int:
        return len(self.instances)

    @property
    def total_ops(self) -> int:
        return sum(i.n_ops for i in self.instances)

    @property
    def ops_per_pe(self) -> float:
        return self.total_ops / max(1, self.n_pes)


def _coverable(op: str) -> bool:
    return (op not in NON_COMPUTE and op != "const"
            and unit_of(op) in _PE_UNITS and op != "cmux")


def map_application(dp: Datapath, app: Graph, app_name: str = "app",
                    *, max_embeddings: int = 50_000,
                    max_exposed: int = 1) -> Mapping:
    """max_exposed: spare PE output lines usable to expose interior values
    (Garnet-class PEs have a second output; see Fig. 5e)."""
    m = Mapping(app_name)
    covered: Set[int] = set()
    compute = [n for n, op in sorted(app.nodes.items()) if _coverable(op)]
    m.offloaded = [n for n, op in sorted(app.nodes.items())
                   if op not in NON_COMPUTE and op != "const"
                   and not _coverable(op)]

    # ---- multi-op configs, largest first ---------------------------------
    multi = [c for c in dp.configs.values() if c.n_ops >= 2]
    multi.sort(key=lambda c: (-c.n_ops, c.name))
    for cfg in multi:
        embs = find_embeddings(cfg.pattern, app, interior_private=True,
                               max_exposed=max_exposed,
                               max_embeddings=max_embeddings)
        # drop embeddings conflicting with already-covered nodes, dedupe by
        # node set, then take a maximal independent set of the remainder —
        # the same machinery that ranks subgraphs (Sec. III-B) maximizes the
        # number of non-overlapping instances here.
        cand: Dict[frozenset, Embedding] = {}
        for e in embs:
            hard = frozenset(t for p, t in e.mapping.items()
                             if cfg.pattern.nodes[p] != "const")
            if hard & covered:
                continue
            cand.setdefault(hard, e)
        sets = sorted(cand.keys(), key=sorted)
        keep = maximal_independent_set(sets)
        for i in keep:
            hard = sets[i]
            e = cand[hard]
            covered |= hard
            m.instances.append(MappedInstance(
                cfg.name, dict(e.mapping), set(hard), cfg.n_ops))

    # ---- single-op fallback ------------------------------------------------
    for n in compute:
        if n in covered:
            continue
        op = app.nodes[n]
        ins = app.in_edges(n)
        # prefer a const-register variant when an operand is a constant
        cand: List[str] = []
        for p in sorted(ins):
            if app.nodes.get(ins[p]) == "const":
                cand.append(f"op:{op}_c{p}")
        cand.append(f"op:{op}")
        chosen: Optional[str] = None
        for name in cand:
            if name in dp.configs:
                chosen = name
                break
        if chosen is None:
            m.unmapped.append(n)
            continue
        cfg = dp.configs[chosen]
        pat_nodes = {pn for pn, o in cfg.pattern.nodes.items() if o == op}
        pn = sorted(pat_nodes)[0]
        covered.add(n)
        m.instances.append(MappedInstance(chosen, {pn: n}, {n}, cfg.n_ops))
    return m
