"""Maximal-independent-set analysis of subgraph occurrences (paper Sec. III-B).

Occurrences of a mined subgraph may overlap in the application graph; only
non-overlapping occurrences can be accelerated by fully-utilized PEs.  Each
occurrence (distinct node set) becomes a vertex of an *overlap graph*; two
vertices are adjacent iff their node sets intersect.  The size of a maximal
independent set of that graph is the subgraph's utility (paper Fig. 4) and is
the ranking key for which subgraphs get merged into the PE first.

The paper computes a *maximal* (not maximum) independent set; we use the
classic greedy minimum-degree heuristic, which returns a maximal set and
matches the paper's illustration (MIS size 2 for Fig. 3d's four overlapping
occurrences).
"""

from __future__ import annotations

from typing import Dict, FrozenSet, List, Sequence, Set

from .mining import MinedSubgraph


def maximal_independent_set(node_sets: Sequence[FrozenSet[int]]) -> List[int]:
    """Greedy MIS over occurrence node-sets; returns selected indices."""
    n = len(node_sets)
    # adjacency by shared application-graph nodes
    by_node: Dict[int, List[int]] = {}
    for i, s in enumerate(node_sets):
        for v in s:
            by_node.setdefault(v, []).append(i)
    adj: List[Set[int]] = [set() for _ in range(n)]
    for members in by_node.values():
        if len(members) > 1:
            for i in members:
                adj[i].update(members)
    for i in range(n):
        adj[i].discard(i)

    alive = set(range(n))
    chosen: List[int] = []
    while alive:
        # min-degree greedy (ties by index for determinism)
        i = min(alive, key=lambda k: (len(adj[k] & alive), k))
        chosen.append(i)
        dead = {i} | (adj[i] & alive)
        alive -= dead
    return sorted(chosen)


def mis_of_occurrences(embeddings_nodes: Sequence[FrozenSet[int]]) -> int:
    return len(maximal_independent_set(list(embeddings_nodes)))


def rank_by_mis(mined: Sequence[MinedSubgraph]) -> List[MinedSubgraph]:
    """Fill mis_size and return subgraphs sorted by the paper's ranking.

    "The mined subgraphs are ranked by MIS size so that subgraphs that have
    many overlapping occurrences are considered last" (Sec. III-C).  We rank
    by MIS size, breaking ties toward larger subgraphs (more ops fused per PE
    invocation) and then by label for determinism.
    """
    for m in mined:
        occ_sets = sorted({e.nodes for e in m.embeddings}, key=sorted)
        m.mis_size = mis_of_occurrences(occ_sets)
    return sorted(mined, key=lambda m: (-m.mis_size, -m.size, m.label))
