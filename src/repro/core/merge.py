"""Subgraph merging into a reconfigurable PE datapath (paper Sec. III-C).

Following Moreano et al. (the paper's reference [7]):

1. Enumerate *merge opportunities* between the incoming subgraph B and the
   accumulated datapath A: node-node (same hardware block, Fig. 5c) and
   edge-edge (both endpoint merges possible and destination ports match).
2. Build the *compatibility graph*: opportunities as vertices, weight = area
   saved, edge = the two opportunities induce a consistent injective mapping.
3. Solve **maximum-weight clique** (Fig. 5d) -> the lowest-area merge.
4. Reconstruct: merged nodes share one unit; a port receiving different
   sources across configs grows a config mux (Fig. 5e); external inputs and
   output lines are shared greedily across configs.

The datapath accumulates configs (one per merged subgraph), so "merge many
subgraphs" = fold :func:`add_pattern`.  Single ops are 1-node patterns, which
makes the paper's PE 1 (baseline ops only) the same code path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..graphir.graph import Graph, free_in_ports, sink_nodes
from ..graphir.interp import interpret_pattern
from ..graphir.ops import (OPS, UNIT_AREA, U_ADD, U_CONST, U_IO, U_MAC,
                           U_MATMUL, U_MUL, U_MUX, U_REDUCE, unit_of)
from .clique import max_weight_clique
from .pe import Config, Datapath, single_op_pattern

MUX_AREA = UNIT_AREA[U_MUX]

#: units a PE datapath can instantiate
_PE_UNITS = {"adder", "multiplier", "mac", "shifter", "comparator", "lut",
             "mux", "divider", "special", "const_reg"}


def is_pe_pattern(pattern: Graph) -> bool:
    """True iff every node can live inside a PE datapath (no tensor macros)."""
    for n, op in pattern.nodes.items():
        if op in ("input", "output"):
            return False
        if unit_of(op) not in _PE_UNITS:
            return False
        if op == "cmux":
            return False
    return True


def _unit_mergeable(unit_a: str, op_b: str) -> bool:
    ub = unit_of(op_b)
    if unit_a == ub:
        return True
    pair = {unit_a, ub}
    return pair <= {U_MAC, U_MUL} or pair <= {U_MAC, U_ADD}


def _merged_unit(unit_a: str, op_b: str) -> str:
    ub = unit_of(op_b)
    return unit_a if unit_a == ub else U_MAC


def _merge_weight(unit_a: str, op_b: str) -> float:
    ub = unit_of(op_b)
    return UNIT_AREA[unit_a] + UNIT_AREA[ub] - UNIT_AREA[_merged_unit(unit_a, op_b)]


@dataclass
class _Opportunity:
    pairs: Dict[int, int]     # pattern node -> unit id (1 for node, 2 for edge)
    weight: float
    kind: str                 # "node" | "edge"


def _opportunities(dp: Datapath, pattern: Graph) -> List[_Opportunity]:
    opps: List[_Opportunity] = []
    for b, op_b in sorted(pattern.nodes.items()):
        for uid, u in sorted(dp.units.items()):
            if _unit_mergeable(u.unit, op_b):
                opps.append(_Opportunity({b: uid}, _merge_weight(u.unit, op_b),
                                         "node"))
    # edge-edge: pattern edge (sb -> db @ p) onto existing source alternative
    for (sb, db, p) in sorted(pattern.edges):
        for (uid_d, port), lst in sorted(dp.alts.items()):
            if port != p:
                continue
            if not _unit_mergeable(dp.units[uid_d].unit, pattern.nodes[db]):
                continue
            for src in lst:
                if src[0] != "n":
                    continue
                uid_s = src[1]
                if not _unit_mergeable(dp.units[uid_s].unit, pattern.nodes[sb]):
                    continue
                if uid_s == uid_d:
                    continue
                opps.append(_Opportunity({sb: uid_s, db: uid_d},
                                         MUX_AREA, "edge"))
    return opps


def _compatible(a: _Opportunity, b: _Opportunity) -> bool:
    for k, v in a.pairs.items():
        if k in b.pairs and b.pairs[k] != v:
            return False
    inv_a = {v: k for k, v in a.pairs.items()}
    for k, v in b.pairs.items():
        if v in inv_a and inv_a[v] != k:
            return False
    return True


def add_pattern(dp: Datapath, pattern: Graph, name: str,
                *, validate: bool = True, rng_seed: int = 0) -> Config:
    """Merge `pattern` into `dp` (mutating) and register it as a config."""
    if not is_pe_pattern(pattern):
        raise ValueError(f"pattern {name!r} contains non-PE ops: "
                         f"{sorted(set(pattern.nodes.values()))}")
    if name in dp.configs:
        raise ValueError(f"config {name!r} already exists")

    opps = _opportunities(dp, pattern)
    adj: List[Set[int]] = [set() for _ in opps]
    for i in range(len(opps)):
        for j in range(i + 1, len(opps)):
            if _compatible(opps[i], opps[j]):
                adj[i].add(j)
                adj[j].add(i)
    chosen = max_weight_clique([o.weight for o in opps], adj,
                               rng_seed=rng_seed)

    mapping: Dict[int, int] = {}
    for i in chosen:
        mapping.update(opps[i].pairs)

    # new units for unmapped pattern nodes; upgrade units for merged ones
    for b, op_b in sorted(pattern.nodes.items()):
        if b in mapping:
            uid = mapping[b]
            u = dp.units[uid]
            u.unit = _merged_unit(u.unit, op_b)
            u.ops.add(op_b)
        else:
            unit = unit_of(op_b)
            uid = dp.new_unit(unit, {op_b})
            mapping[b] = uid

    # wiring + config ------------------------------------------------------
    sel: Dict[Tuple[int, int], int] = {}
    op_assign: Dict[int, str] = {}
    const_vals: Dict[int, object] = {}
    for b, op_b in pattern.nodes.items():
        uid = mapping[b]
        if op_b == "const":
            const_vals[uid] = pattern.attr(b, "value", 0.0)
        else:
            op_assign[uid] = op_b

    for (sb, db, p) in sorted(pattern.edges):
        idx = dp.add_alt(mapping[db], p, ("n", mapping[sb]))
        sel[(mapping[db], p)] = idx

    ext_bind: Dict[Tuple[int, int], int] = {}
    used_ext: Set[int] = set()
    for (b, p) in free_in_ports(pattern):
        uid = mapping[b]
        lst = dp.alts.get((uid, p), [])
        k = None
        for src in lst:                       # reuse an existing ext line
            if src[0] == "ext" and src[1] not in used_ext:
                k = src[1]
                break
        if k is None:                          # lowest unused line (may be new)
            k = 0
            while k in used_ext:
                k += 1
        idx = dp.add_alt(uid, p, ("ext", k))
        sel[(uid, p)] = idx
        ext_bind[(b, p)] = k
        used_ext.add(k)

    out_sel: List[Tuple[int, int]] = []
    used_lines: Set[int] = set()
    for s in sink_nodes(pattern):
        uid = mapping[s]
        line = None
        for li, lst in enumerate(dp.out_alts):  # reuse a line already wired
            if li not in used_lines and ("n", uid) in lst:
                line = li
                break
        if line is None:
            line = 0
            while line in used_lines:
                line += 1
        idx = dp.add_out_alt(line, ("n", uid))
        out_sel.append((line, idx))
        used_lines.add(line)

    cfg = Config(
        name=name, pattern=pattern.copy(), node_map=dict(mapping),
        op_assign=op_assign, sel=sel, ext_bind=ext_bind,
        const_vals=const_vals, out_sel=out_sel,
        active_units=set(mapping.values()),
    )
    dp.configs[name] = cfg
    if validate:
        ok, msg = validate_config(dp, cfg, rng_seed=rng_seed)
        if not ok:
            raise AssertionError(f"merged config {name!r} mis-executes: {msg}")
    return cfg


def validate_config(dp: Datapath, cfg: Config, *, rng_seed: int = 0,
                    trials: int = 4) -> Tuple[bool, str]:
    """Drive the datapath through its muxes and compare with the pattern."""
    rng = np.random.default_rng(rng_seed)
    pattern = cfg.pattern
    sinks = sink_nodes(pattern)
    for _ in range(trials):
        port_values = {(n, p): float(rng.uniform(0.5, 2.0))
                       for (n, p) in free_in_ports(pattern)}
        const_over = {n: float(rng.uniform(0.5, 2.0))
                      for n, op in pattern.nodes.items() if op == "const"}
        vals = interpret_pattern(pattern, port_values, const_over)
        expected = [vals[s] for s in sinks]
        ext_values = {cfg.ext_bind[fp]: v for fp, v in port_values.items()}
        const_unit_over = {cfg.node_map[n]: v for n, v in const_over.items()}
        got = dp.execute(cfg, ext_values, const_override=const_unit_over)
        if not np.allclose(np.array(expected, dtype=np.float64),
                           np.array(got, dtype=np.float64),
                           rtol=1e-6, atol=1e-9):
            return False, f"expected {expected}, datapath produced {got}"
    return True, "ok"


# ---------------------------------------------------------------------------
# Baseline PE (paper Fig. 7): ALU + multiplier + LUT + constant register.
# ---------------------------------------------------------------------------

#: which ops each baseline hardware block provides
BASELINE_OPS = [
    "add", "sub", "neg", "abs",                     # adder/ALU
    "mul",                                          # multiplier
    "shl", "shr", "ashr",                           # shifter
    "min", "max", "lt", "lte", "gt", "gte", "eq", "neq",   # comparator
    "and", "or", "xor", "not", "sign",              # LUT
    "sel",                                          # data mux
]

_NONCOMM = {"sub", "shl", "shr", "ashr", "div", "lt", "lte", "gt", "gte"}


def baseline_datapath(ops_used: Optional[Set[str]] = None,
                      *, with_const_variants: bool = True) -> Datapath:
    """The general-purpose baseline PE, optionally restricted to `ops_used`
    (that restriction is the paper's PE 1)."""
    ops = [o for o in BASELINE_OPS if ops_used is None or o in ops_used]
    if ops_used is not None:
        # PE 1 must still run every op the app needs (special units etc.)
        for o in sorted(ops_used):
            if o not in ops and unit_of(o) in _PE_UNITS:
                ops.append(o)
    dp = Datapath()
    for op in ops:
        add_pattern(dp, single_op_pattern(op), f"op:{op}", validate=False)
        if with_const_variants and OPS[op].arity >= 2:
            add_pattern(dp, single_op_pattern(op, const_port=1),
                        f"op:{op}_c1", validate=False)
            if op in _NONCOMM:
                add_pattern(dp, single_op_pattern(op, const_port=0),
                            f"op:{op}_c0", validate=False)
    return dp


def merge_subgraphs(subgraphs: Sequence[Tuple[str, Graph]],
                    base: Optional[Datapath] = None,
                    *, validate: bool = True) -> Datapath:
    """Fold a list of (name, pattern) into one PE datapath."""
    dp = base.copy() if base is not None else Datapath()
    for name, g in subgraphs:
        add_pattern(dp, g, name, validate=validate)
    return dp
