"""PE specification — the PEak-DSL analogue (paper Sec. IV step 4/5).

A :class:`Datapath` is a merged, configurable PE architecture:

* **units** — hardware blocks (adder, multiplier, shifter, comparator, LUT,
  special, const register), each able to execute a set of ops;
* **alts** — per (unit, port) the list of alternative sources (another unit,
  or an external PE input line); >1 alternative implies a config mux
  (paper Fig. 5e);
* **out_alts** — PE output lines, each with its own output mux;
* **configs** — one per supported operation pattern ("rewrite rules" in the
  paper): which units are active, which op each performs, mux selections,
  external-input bindings and constant-register values.

Every config's source pattern is stored, so the application mapper can match
patterns in the app graph and the validator can check that the datapath,
*driven purely through its muxes*, computes exactly what the source subgraph
computes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..graphir.graph import Graph, free_in_ports, sink_nodes
from ..graphir.interp import SEMANTICS
from ..graphir.ops import (OPS, UNIT_AREA, UNIT_ENERGY, U_CONST, U_MUX,
                           unit_of)

# source alternatives
Src = Tuple[str, int]          # ("n", unit_id) or ("ext", input_line)


@dataclass
class Unit:
    uid: int
    unit: str                  # hardware block type
    ops: Set[str] = field(default_factory=set)

    @property
    def is_const(self) -> bool:
        return self.unit == U_CONST

    @property
    def arity(self) -> int:
        return max((OPS[o].arity for o in self.ops), default=0)


@dataclass
class Config:
    """One supported operation pattern of the PE."""

    name: str
    pattern: Graph                                  # source subgraph
    node_map: Dict[int, int]                        # pattern node -> unit id
    op_assign: Dict[int, str]                       # unit id -> op it performs
    sel: Dict[Tuple[int, int], int]                 # (unit, port) -> alt index
    ext_bind: Dict[Tuple[int, int], int]            # pattern free port -> ext line
    const_vals: Dict[int, Any]                      # const unit -> value
    out_sel: List[Tuple[int, int]]                  # [(line, alt index)] per sink
    active_units: Set[int] = field(default_factory=set)

    @property
    def n_ops(self) -> int:
        """Compute ops executed per invocation (consts excluded)."""
        return sum(1 for n, op in self.pattern.nodes.items()
                   if op not in ("const", "input", "output"))

    @property
    def n_inputs(self) -> int:
        return len(set(self.ext_bind.values()))


@dataclass
class Datapath:
    """A configurable PE architecture."""

    units: Dict[int, Unit] = field(default_factory=dict)
    alts: Dict[Tuple[int, int], List[Src]] = field(default_factory=dict)
    out_alts: List[List[Src]] = field(default_factory=list)
    configs: Dict[str, Config] = field(default_factory=dict)
    n_ext: int = 0
    _next_uid: int = 0

    # -- construction -------------------------------------------------------
    def new_unit(self, unit: str, ops: Set[str]) -> int:
        uid = self._next_uid
        self._next_uid += 1
        self.units[uid] = Unit(uid, unit, set(ops))
        return uid

    def add_alt(self, uid: int, port: int, src: Src) -> int:
        lst = self.alts.setdefault((uid, port), [])
        if src in lst:
            return lst.index(src)
        lst.append(src)
        if src[0] == "ext":
            self.n_ext = max(self.n_ext, src[1] + 1)
        return len(lst) - 1

    def add_out_alt(self, line: int, src: Src) -> int:
        while len(self.out_alts) <= line:
            self.out_alts.append([])
        lst = self.out_alts[line]
        if src in lst:
            return lst.index(src)
        lst.append(src)
        return len(lst) - 1

    def copy(self) -> "Datapath":
        dp = Datapath()
        dp.units = {u.uid: Unit(u.uid, u.unit, set(u.ops))
                    for u in self.units.values()}
        dp.alts = {k: list(v) for k, v in self.alts.items()}
        dp.out_alts = [list(v) for v in self.out_alts]
        dp.configs = dict(self.configs)   # configs are immutable once built
        dp.n_ext = self.n_ext
        dp._next_uid = self._next_uid
        return dp

    # -- structure metrics ------------------------------------------------------
    def mux_ways(self) -> List[int]:
        """Fan-in of every mux (input and output muxes with >=2 alternatives)."""
        ways = [len(v) for v in self.alts.values() if len(v) >= 2]
        ways += [len(v) for v in self.out_alts if len(v) >= 2]
        return ways

    @property
    def n_out(self) -> int:
        return max(1, len(self.out_alts))

    def area_um2(self, *, include_io: bool = False,
                 cb_area: float = 520.0, sb_area: float = 960.0) -> float:
        """PE core area; optionally add connection/switch-box overhead."""
        a = sum(UNIT_AREA[u.unit] for u in self.units.values())
        a += sum((w - 1) * UNIT_AREA[U_MUX] for w in self.mux_ways())
        # config storage: ~1 flop-equivalent per mux selection bit
        sel_bits = sum(max(1, int(np.ceil(np.log2(max(w, 2)))))
                       for w in self.mux_ways())
        a += 2.1 * sel_bits
        if include_io:
            a += cb_area * max(2, self.n_ext) + sb_area * self.n_out
        return a

    def config_energy_pj(self, cfg: Config, *, idle_fraction: float = 0.55,
                         reg_pj: float = 0.09, clock_pj: float = 0.18
                         ) -> float:
        """Energy of one PE invocation under `cfg`.

        Active units dissipate their full op energy.  Inactive units are NOT
        operand-isolated in a Garnet-class baseline PE, so input toggles
        glitch through them every cycle: they burn `idle_fraction` of their
        op energy (the paper's own Harris observation — "an architecture
        that reduces activity on an input to a multiplier" — is this effect).
        Every mux costs mux energy; each invocation additionally clocks its
        input/output registers and the clock/config tree (`reg_pj` per
        active 16-bit register, `clock_pj` fixed).  Fusing more ops per
        invocation amortizes all of this — the mechanism behind Fig. 8.
        """
        e = 0.0
        for uid, u in self.units.items():
            if uid in cfg.active_units:
                op = cfg.op_assign.get(uid)
                e += OPS[op].energy_pj if op else UNIT_ENERGY[u.unit]
            else:
                e += idle_fraction * UNIT_ENERGY[u.unit]
        n_mux = len(self.mux_ways())
        e += n_mux * UNIT_ENERGY[U_MUX]
        e += reg_pj * (cfg.n_inputs + len(cfg.out_sel)) + clock_pj
        return e

    def idle_cycle_energy_pj(self, *, fraction: float = 0.15,
                             clock_pj: float = 0.18) -> float:
        """Energy a tile burns per cycle it does NOT fire.

        Between invocations the input latches hold, so datapath glitching
        is far below the active-invocation idle_fraction — what remains is
        the clock/config tree plus residual toggling (`fraction` of each
        unit's op energy).  Used by the time-domain cost feedback: a design
        running at II charges every tile II-1 of these per iteration.
        """
        return (fraction * sum(UNIT_ENERGY[u.unit]
                               for u in self.units.values()) + clock_pj)

    def critical_path_ns(self) -> float:
        """Longest combinational path through the datapath (any config)."""
        delay = {
            "adder": 0.15, "multiplier": 0.45, "mac": 0.55, "shifter": 0.12,
            "comparator": 0.10, "lut": 0.05, "mux": 0.02, "const_reg": 0.0,
            "divider": 1.10, "special": 0.85, "reduce": 0.0, "matmul": 0.0,
            "io": 0.0,
        }
        memo: Dict[int, float] = {}

        def arrival(uid: int, stack: Set[int]) -> float:
            if uid in memo:
                return memo[uid]
            if uid in stack:          # structural cycle across configs: cut
                return 0.0
            stack = stack | {uid}
            u = self.units[uid]
            t_in = 0.0
            for port in range(u.arity):
                lst = self.alts.get((uid, port), [])
                mux_d = delay["mux"] * max(0, int(np.ceil(
                    np.log2(max(len(lst), 2)))) if len(lst) >= 2 else 0)
                for src in lst:
                    if src[0] == "n":
                        t_in = max(t_in, arrival(src[1], stack) + mux_d)
                    else:
                        t_in = max(t_in, mux_d)
            memo[uid] = t_in + delay[u.unit]
            return memo[uid]

        t = 0.0
        for line in (self.out_alts or [[]]):
            mux_d = delay["mux"] * (1 if len(line) >= 2 else 0)
            for src in line:
                if src[0] == "n":
                    t = max(t, arrival(src[1], set()) + mux_d)
        for uid in self.units:
            t = max(t, arrival(uid, set()))
        return t + 0.08   # input/output register + clk overhead

    def stage_delay_ns(self) -> float:
        """Pipelined-PE cycle time: slowest unit + its input-mux tree + reg.

        CGRA PEs register unit outputs; the paper's specialized PEs reach
        *higher* fmax than the baseline (Sec. V-A) because each pipeline
        stage is a lean single unit, while the baseline pays a multi-function
        ALU decode.  Baseline decode overhead is modeled via config count.
        """
        delay = {
            "adder": 0.15, "multiplier": 0.45, "mac": 0.55, "shifter": 0.12,
            "comparator": 0.10, "lut": 0.05, "mux": 0.02, "const_reg": 0.0,
            "divider": 1.10, "special": 0.85, "reduce": 0.0, "matmul": 0.0,
            "io": 0.0,
        }
        worst = 0.0
        for uid, u in self.units.items():
            mux_depth = 0.0
            for port in range(u.arity):
                lst = self.alts.get((uid, port), [])
                if len(lst) >= 2:
                    mux_depth = max(mux_depth, float(np.ceil(
                        np.log2(len(lst)))))
            # multi-op units pay an opcode-decode stage proportional to the
            # number of ops they can perform
            decode = 0.015 * max(0, len(u.ops) - 1)
            worst = max(worst, delay[u.unit] + 0.02 * mux_depth + decode)
        return worst + 0.08

    def fmax_ghz(self, *, pipelined: bool = True) -> float:
        t = self.stage_delay_ns() if pipelined else self.critical_path_ns()
        return 1.0 / max(t, 1e-3)

    # -- execution (validation oracle for merged wiring) -----------------------
    def execute(self, cfg: Config, ext_values: Dict[int, Any],
                const_override: Optional[Dict[int, Any]] = None) -> List[Any]:
        """Run one invocation through the datapath muxes.

        ext_values: ext line -> value.  Returns per-sink outputs in
        cfg.out_sel order.  This deliberately does NOT consult cfg.pattern
        for structure — only mux selections — so it validates the wiring.
        """
        memo: Dict[int, Any] = {}

        def value(uid: int) -> Any:
            if uid in memo:
                return memo[uid]
            u = self.units[uid]
            if u.is_const:
                if const_override and uid in const_override:
                    memo[uid] = const_override[uid]
                else:
                    memo[uid] = cfg.const_vals[uid]
                return memo[uid]
            op = cfg.op_assign[uid]
            args = []
            for port in range(OPS[op].arity):
                lst = self.alts[(uid, port)]
                src = lst[cfg.sel[(uid, port)]]
                if src[0] == "n":
                    args.append(value(src[1]))
                else:
                    args.append(ext_values[src[1]])
            memo[uid] = SEMANTICS[op](*args)
            return memo[uid]

        outs = []
        for (line, alt) in cfg.out_sel:
            src = self.out_alts[line][alt]
            assert src[0] == "n"
            outs.append(value(src[1]))
        return outs

    def render_graph(self) -> Graph:
        """Visualization-only Graph with explicit cmux nodes."""
        g = Graph()
        ids: Dict[int, int] = {}
        for uid, u in sorted(self.units.items()):
            rep = sorted(u.ops)[0] if u.ops else "const"
            ids[uid] = g.add_node(rep if rep in OPS else "opaque",
                                  ops=sorted(u.ops), unit=u.unit)
        ext_ids = {k: g.add_node("input", name=f"ext{k}")
                   for k in range(self.n_ext)}

        def src_node(src: Src) -> int:
            return ids[src[1]] if src[0] == "n" else ext_ids[src[1]]

        for (uid, port), lst in sorted(self.alts.items()):
            if len(lst) == 1:
                g.add_edge(src_node(lst[0]), ids[uid], port)
            else:
                m = g.add_node("cmux", ways=len(lst))
                for i, src in enumerate(lst):
                    g.add_edge(src_node(src), m, i)
                g.add_edge(m, ids[uid], port)
        for line, lst in enumerate(self.out_alts):
            out = g.add_node("output", name=f"out{line}")
            if len(lst) == 1:
                g.add_edge(src_node(lst[0]), out, 0)
            elif lst:
                m = g.add_node("cmux", ways=len(lst))
                for i, src in enumerate(lst):
                    g.add_edge(src_node(src), m, i)
                g.add_edge(m, out, 0)
        return g

    def summary(self) -> str:
        unit_str = ", ".join(
            f"{u.unit}{{{'/'.join(sorted(u.ops))}}}" for u in
            sorted(self.units.values(), key=lambda x: x.uid))
        return (f"Datapath[{len(self.units)} units | {len(self.configs)} cfgs"
                f" | in={self.n_ext} out={self.n_out}"
                f" | area={self.area_um2():.0f}um2"
                f" | fmax={self.fmax_ghz():.2f}GHz] {unit_str}")


def single_op_pattern(op: str, const_port: Optional[int] = None) -> Graph:
    """1-op pattern; optionally with port `const_port` fed by a const reg."""
    g = Graph()
    n = g.add_node(op)
    if const_port is not None:
        c = g.add_node("const", value=0.0)
        g.add_edge(c, n, const_port)
    return g
