"""Area / energy / performance evaluation (paper Sec. IV steps 7-8, Sec. V).

The paper synthesizes in TSMC 16 nm and reports PE-core energy per op and
total active-PE-core area (Fig. 8/10/11) plus a CGRA-level comparison with a
Simba-class ASIC (Table I).  We evaluate the same quantities analytically
from the unit tables in graphir.ops:

* PE core area — sum of unit areas + mux trees + config bits.
* Energy per invocation — active units at full op energy, idle units at an
  idle fraction (clock/glitch toggling), plus mux energy.
* Energy per op — total mapped energy / total application compute ops; a
  specialized PE executes more ops per invocation, amortizing overheads.
* Total area — PE core area x number of PEs used (CGRAs are spatial; each
  invocation occupies a tile), exactly Fig. 8's metric.
* fmax — longest combinational unit+mux path (critical path model).
* CGRA level — adds connection-box/switch-box interconnect overhead per PE
  I/O (Sec. II-C) and memory-tile cost for Table I.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from ..graphir.graph import Graph
from .mapper import Mapping
from .pe import Datapath

# CGRA-level constants (16 nm-class, per tile)
CB_AREA_UM2 = 520.0        # connection box per PE input (10-track, 16-bit)
SB_AREA_UM2 = 960.0        # switch box per PE output
CB_ENERGY_PJ = 0.045       # per word routed through a CB
SB_ENERGY_PJ = 0.060       # per word routed through an SB
MEM_TILE_AREA_UM2 = 9800.0
MEM_TILE_ENERGY_PJ = 1.9   # per access (512 x 16b SRAM bank + control)
PE_PER_MEM = 4.0           # tile ratio on the array (paper Fig. 7 layout)


@dataclass
class AppCost:
    app: str
    pe_name: str
    n_pes: int
    total_ops: int
    pe_area_um2: float
    total_area_um2: float          # PE core area x n_pes (paper Fig. 8)
    energy_pj: float               # PE cores only
    energy_per_op_pj: float
    fmax_ghz: float
    ops_per_pe: float
    unmapped: int
    # CGRA level (Table I)
    cgra_area_um2: float = 0.0
    cgra_energy_pj: float = 0.0
    cgra_energy_per_op_pj: float = 0.0
    # array level, filled by repro.fabric after place-and-route (0 = not run)
    fabric_area_um2: float = 0.0
    fabric_energy_per_op_pj: float = 0.0
    fabric_fmax_ghz: float = 0.0
    fabric_wirelength: int = 0
    fabric_utilization: float = 0.0
    # time domain, filled by repro.sim after modulo scheduling + simulation
    # (0 = not run).  These are *measured* on the scheduled array, not
    # estimated: achieved initiation interval, the schedule's lower bound,
    # pipeline fill latency, per-tile activity, sustained throughput at the
    # fabric clock, and energy/op including the idle cycles each tile burns
    # between fires — the number the static model cannot see.
    sim_ii: int = 0
    sim_min_ii: int = 0
    sim_latency_cycles: int = 0
    sim_active_frac: float = 0.0
    sim_throughput_gops: float = 0.0
    sim_energy_per_op_pj: float = 0.0
    sim_verified: int = -1         # 1 bit-exact vs interp, 0 mismatch, -1 n/a

    def row(self) -> str:
        return (f"{self.app:<16} {self.pe_name:<10} pes={self.n_pes:<5d} "
                f"ops={self.total_ops:<6d} e/op={self.energy_per_op_pj:7.4f}pJ "
                f"area={self.total_area_um2/1e3:8.1f}kum2 "
                f"fmax={self.fmax_ghz:4.2f}GHz opspe={self.ops_per_pe:4.2f}")


def evaluate_mapping(dp: Datapath, mapping: Mapping, pe_name: str = "PE",
                     *, idle_fraction: float = 0.55) -> AppCost:
    pe_area = dp.area_um2()
    energy = 0.0
    for inst in mapping.instances:
        cfg = dp.configs[inst.config]
        energy += dp.config_energy_pj(cfg, idle_fraction=idle_fraction)
    total_ops = mapping.total_ops
    n_pes = mapping.n_pes

    # CGRA level: every PE instance carries its CB/SB share; words routed =
    # one per PE input + output; memory tiles amortized over the array.
    cgra_pe_area = dp.area_um2(include_io=True,
                               cb_area=CB_AREA_UM2, sb_area=SB_AREA_UM2)
    n_mem = max(1.0, n_pes / PE_PER_MEM)
    cgra_area = cgra_pe_area * n_pes + MEM_TILE_AREA_UM2 * n_mem
    route_energy = 0.0
    for inst in mapping.instances:
        cfg = dp.configs[inst.config]
        route_energy += CB_ENERGY_PJ * max(1, cfg.n_inputs) + SB_ENERGY_PJ
    mem_energy = MEM_TILE_ENERGY_PJ * 2.0 * n_mem   # rd + wr per output
    cgra_energy = energy + route_energy + mem_energy

    return AppCost(
        app=mapping.app_name,
        pe_name=pe_name,
        n_pes=n_pes,
        total_ops=total_ops,
        pe_area_um2=pe_area,
        total_area_um2=pe_area * n_pes,
        energy_pj=energy,
        energy_per_op_pj=energy / max(1, total_ops),
        fmax_ghz=dp.fmax_ghz(),
        ops_per_pe=mapping.ops_per_pe,
        unmapped=len(mapping.unmapped),
        cgra_area_um2=cgra_area,
        cgra_energy_pj=cgra_energy,
        cgra_energy_per_op_pj=cgra_energy / max(1, total_ops),
    )


def attach_sim(cost: AppCost, dp: Datapath, schedule,
               *, fabric_cost=None, verified: int = -1) -> AppCost:
    """Write measured time-domain numbers onto an AppCost record.

    schedule: a :class:`repro.sim.schedule.ModuloSchedule`.  Throughput is
    the steady state — ``total_ops`` useful ops retire every II cycles at
    the fabric clock.  Energy/op re-prices the array per *iteration*: every
    invocation at its config energy (as before) plus ``II - 1`` idle cycles
    per tile at the idle-cycle energy, all divided by the ops of one
    iteration.  A schedule with slack (II above the resource bound) now
    shows up as worse energy/op, which the cycle-free model never could.
    """
    cost.sim_ii = schedule.ii
    cost.sim_min_ii = schedule.min_ii
    cost.sim_latency_cycles = schedule.latency
    cost.sim_active_frac = 1.0 / schedule.ii
    fmax = (fabric_cost.fmax_ghz if fabric_cost is not None
            else cost.fabric_fmax_ghz) or cost.fmax_ghz
    total_ops = max(1, cost.total_ops)
    cost.sim_throughput_gops = total_ops * fmax / schedule.ii
    base = (fabric_cost.total_energy_pj if fabric_cost is not None
            else cost.cgra_energy_pj)
    idle = (schedule.ii - 1) * cost.n_pes * dp.idle_cycle_energy_pj()
    cost.sim_energy_per_op_pj = (base + idle) / total_ops
    cost.sim_verified = verified
    return cost


def vector_mac_asic_energy_per_op_pj(n_lanes: int = 8) -> float:
    """Simba-class bound: n_lanes 8-bit vector MACs sharing one control path.

    Per-MAC energy at 8-bit is ~1/4 of the 16-bit MAC (quadratic multiplier
    scaling); control/SRAM overhead is amortized over the vector width.
    A MAC is 2 ops (mul + add).
    """
    from ..graphir.ops import UNIT_ENERGY, U_MAC
    mac8 = UNIT_ENERGY[U_MAC] / 4.0
    control = 0.18 / n_lanes          # sequencer + operand fetch, amortized
    sram = MEM_TILE_ENERGY_PJ / (4.0 * n_lanes)
    return (mac8 + control + sram) / 2.0
