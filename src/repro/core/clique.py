"""Maximum-weight clique (paper Sec. III-C, Fig. 5d).

Subgraph merging reduces to a maximum-weight clique over the compatibility
graph of merge opportunities.  Compatibility graphs here are small (tens to a
few hundred vertices), so an exact branch-and-bound with a sorted-residual
upper bound is run first; beyond a vertex budget we fall back to randomized
greedy with restarts (documented approximation).
"""

from __future__ import annotations

import random
from typing import Dict, List, Sequence, Set, Tuple


def max_weight_clique(weights: Sequence[float],
                      adj: Sequence[Set[int]],
                      *,
                      exact_limit: int = 160,
                      node_budget: int = 2_000_000,
                      rng_seed: int = 0) -> List[int]:
    """Return vertex indices of a (near-)maximum-weight clique.

    weights[i] > 0; adj[i] = neighbors of i (compatibility).  Exact BnB when
    len(weights) <= exact_limit and the search stays within node_budget;
    otherwise greedy with restarts.
    """
    n = len(weights)
    if n == 0:
        return []
    order = sorted(range(n), key=lambda i: -weights[i])

    if n <= exact_limit:
        result = _bnb(order, weights, adj, node_budget)
        if result is not None:
            return sorted(result)
    return sorted(_greedy_restarts(order, weights, adj, rng_seed))


def _bnb(order: List[int], weights: Sequence[float],
         adj: Sequence[Set[int]], node_budget: int):
    best: List[int] = []
    best_w = 0.0
    visited = 0
    aborted = False

    # prefix weights for the upper bound
    def ub(cands: List[int]) -> float:
        return sum(weights[c] for c in cands)

    def expand(clique: List[int], cw: float, cands: List[int]) -> None:
        nonlocal best, best_w, visited, aborted
        if aborted:
            return
        visited += 1
        if visited > node_budget:
            aborted = True
            return
        if cw > best_w:
            best, best_w = list(clique), cw
        if not cands:
            return
        if cw + ub(cands) <= best_w:
            return
        for idx, v in enumerate(cands):
            rest = cands[idx + 1:]
            if cw + weights[v] + ub(rest) <= best_w:
                break  # sorted by weight: no later start can beat best
            clique.append(v)
            new_cands = [u for u in rest if u in adj[v]]
            expand(clique, cw + weights[v], new_cands)
            clique.pop()
            if aborted:
                return

    expand([], 0.0, list(order))
    if aborted:
        return None
    return best


def _greedy_restarts(order: List[int], weights: Sequence[float],
                     adj: Sequence[Set[int]], rng_seed: int,
                     restarts: int = 32) -> List[int]:
    rng = random.Random(rng_seed)
    best: List[int] = []
    best_w = -1.0
    n = len(order)
    for r in range(restarts):
        if r == 0:
            seq = list(order)
        else:
            seq = list(order)
            # weight-biased shuffle
            rng.shuffle(seq)
            seq.sort(key=lambda i: -weights[i] * rng.uniform(0.5, 1.0))
        clique: List[int] = []
        cset: Set[int] = set()
        for v in seq:
            if all(v in adj[c] for c in clique):
                clique.append(v)
                cset.add(v)
        w = sum(weights[c] for c in clique)
        if w > best_w:
            best, best_w = clique, w
    return best
