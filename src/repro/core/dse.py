"""End-to-end design-space-exploration driver (paper Sec. IV, Fig. 6).

Given one or more application dataflow graphs:

1. mine frequent subgraphs per app (Sec. III-A),
2. rank by maximal-independent-set size (Sec. III-B),
3. build PE variants (Sec. V):
   * ``PE 1``  — baseline PE restricted to the ops the app uses,
   * ``PE k``  — PE 1 + the top (k-1) subgraphs merged in MIS order,
   * domain PE (``PE IP`` / ``PE ML``) — top subgraphs of *all* apps merged,
4. map every app onto every variant and evaluate area/energy/fmax.

The returned records are exactly what the paper's Figs. 8/10/11 plot.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..graphir.graph import Graph
from ..graphir.ops import NON_COMPUTE, unit_of
from .costmodel import AppCost, evaluate_mapping
from .mapper import map_application
from .merge import add_pattern, baseline_datapath, is_pe_pattern, _PE_UNITS
from .mining import MinedSubgraph, MiningConfig, mine_frequent_subgraphs
from .mis import rank_by_mis
from .pe import Datapath


@dataclass
class PEVariant:
    name: str
    datapath: Datapath
    merged_subgraphs: List[str] = field(default_factory=list)
    costs: Dict[str, AppCost] = field(default_factory=dict)   # per app
    fabric_costs: Dict[str, "object"] = field(default_factory=dict)
    # per app FabricCost when fabric-level evaluation is enabled


@dataclass
class DSEResult:
    apps: Dict[str, Graph]
    mined: Dict[str, List[MinedSubgraph]]
    variants: List[PEVariant]
    elapsed_s: float = 0.0

    def best_variant(self, app: str) -> PEVariant:
        cands = [v for v in self.variants if app in v.costs]
        return min(cands, key=lambda v: v.costs[app].energy_per_op_pj)

    def table(self) -> str:
        lines = []
        for v in self.variants:
            for app, c in sorted(v.costs.items()):
                lines.append(c.row())
        return "\n".join(lines)


def app_ops(app: Graph) -> Set[str]:
    """PE-implementable ops used by an application graph."""
    return {op for op in app.nodes.values()
            if op not in NON_COMPUTE and op != "const"
            and unit_of(op) in _PE_UNITS and op != "cmux"}


def mine_and_rank(app: Graph, cfg: Optional[MiningConfig] = None
                  ) -> List[MinedSubgraph]:
    mined = mine_frequent_subgraphs(app, cfg)
    mined = [m for m in mined if is_pe_pattern(m.pattern)]
    return rank_by_mis(mined)


def _dedup_keep_maximal(ranked: List[MinedSubgraph]) -> List[MinedSubgraph]:
    """Drop subgraphs fully contained in an earlier-ranked, larger subgraph
    with at-least-equal MIS utility (merging the bigger one subsumes them)."""
    from .isomorphism import find_embeddings
    kept: List[MinedSubgraph] = []
    for m in ranked:
        subsumed = False
        for k in kept:
            if (k.size >= m.size and k.mis_size >= m.mis_size
                    and find_embeddings(m.pattern, k.pattern,
                                        max_embeddings=4)):
                subsumed = True
                break
        if not subsumed:
            kept.append(m)
    return kept


def build_variants(app_name: str, app: Graph,
                   ranked: List[MinedSubgraph],
                   *, max_merge: int = 4,
                   rank_mode: str = "mis",
                   validate: bool = True) -> List[PEVariant]:
    """PE 1 .. PE (1+max_merge) for a single application.

    rank_mode:
      * ``"mis"`` — the paper's ordering: subgraphs merged in MIS-size order
        (Sec. III-C / Sec. V bullet list).
      * ``"utility"`` — beyond-paper: order by MIS x (ops fused - 1), i.e.
        the number of PE invocations each subgraph eliminates, and skip
        candidates whose marginal coverage is zero.  Recorded separately in
        EXPERIMENTS.md as an improvement over the reproduction baseline.
    """
    variants: List[PEVariant] = []
    ops = app_ops(app)
    dp = baseline_datapath(ops)
    variants.append(PEVariant(f"PE1", dp.copy()))
    usable = _dedup_keep_maximal(ranked)
    if rank_mode == "utility":
        usable = sorted(usable,
                        key=lambda m: (-m.mis_size * max(1, m.size - 1),
                                       -m.size, m.label))
    merged_names: List[str] = []
    cur = dp
    k = 0
    for m in usable:
        if k >= max_merge:
            break
        name = f"sg:{app_name}:{k}"
        nxt = cur.copy()
        add_pattern(nxt, m.pattern, name, validate=validate)
        if rank_mode == "utility":
            # marginal-gain check: does the new config actually get used?
            from .mapper import map_application
            trial = map_application(nxt, app, app_name)
            used = sum(1 for i in trial.instances if i.config == name)
            if used == 0:
                continue
        cur = nxt
        merged_names.append(name)
        variants.append(PEVariant(f"PE{k + 2}", cur.copy(),
                                  list(merged_names)))
        k += 1
    return variants


def evaluate_variants(variants: Sequence[PEVariant],
                      apps: Dict[str, Graph],
                      *, fabric: Optional[object] = None,
                      fabric_backend: Optional[str] = None,
                      fabric_chains: Optional[int] = None,
                      fabric_sweeps: Optional[int] = None,
                      fabric_seed: Optional[int] = None,
                      simulate: bool = False) -> None:
    """Map + cost every (variant, app) pair; optionally also at array level.

    fabric: a :class:`repro.fabric.FabricOptions` (or a bare ``FabricSpec``
    plus the legacy ``fabric_*`` kwargs, folded in automatically) — when
    given, each mapping is placed and routed on the fabric (auto-grown when
    the variant needs more tiles) and the array-accurate numbers are
    attached to the AppCost records (``fabric_*`` fields) and kept in
    ``variant.fabric_costs``.  A specialized PE covers the same app with
    fewer instances, so it earns both the per-tile win *and* shorter
    routes — the tradeoff only visible at this level.

    simulate: with a fabric, additionally modulo-schedule and cycle-
    accurately simulate every mapping, attaching *measured* throughput
    (``sim_*`` fields: achieved II, latency, activity, energy/op including
    idle cycles) and — when ``options.sim_verify`` — the bit-exact golden
    check against ``graphir.interp``.
    """
    from ..fabric.options import FabricOptions

    options = FabricOptions.coerce(fabric, backend=fabric_backend,
                                   chains=fabric_chains,
                                   sweeps=fabric_sweeps, seed=fabric_seed,
                                   simulate=simulate)
    if options is not None:
        from ..fabric import place_and_route
        from ..fabric.cost import attach_fabric
        from .costmodel import attach_sim
    for v in variants:
        for app_name, app in apps.items():
            mapping = map_application(v.datapath, app, app_name)
            cost = evaluate_mapping(v.datapath, mapping, v.name)
            v.costs[app_name] = cost
            if options is None:
                continue
            pnr = place_and_route(v.datapath, mapping, app, options.spec,
                                  backend=options.backend,
                                  chains=options.chains,
                                  sweeps=options.sweeps,
                                  seed=options.seed, pe_name=v.name,
                                  hpwl_backend=options.hpwl_backend,
                                  score_mode=options.score_mode)
            v.fabric_costs[app_name] = pnr.cost
            attach_fabric(cost, pnr.cost)
            if options.simulate:
                from ..sim import (build_sim, check_against_interp,
                                   random_inputs)
                prog, _ = build_sim(v.datapath, mapping, app, pnr=pnr)
                verified = -1
                if options.sim_verify:
                    inputs = random_inputs(prog, options.sim_iterations,
                                           options.sim_batch,
                                           seed=options.seed)
                    _, err, exact = check_against_interp(
                        prog, app, inputs, backend=options.sim_backend)
                    verified = int(exact and err == 0.0)
                    if not verified:
                        raise AssertionError(
                            f"simulated {app_name} on {v.name} diverges "
                            f"from graphir.interp (max |err|={err:.3e})")
                attach_sim(cost, v.datapath, prog.schedule,
                           fabric_cost=pnr.cost, verified=verified)


def specialize_per_app(apps: Dict[str, Graph],
                       mining: Optional[MiningConfig] = None,
                       *, max_merge: int = 4,
                       rank_mode: str = "mis",
                       validate: bool = True,
                       fabric: Optional[object] = None,
                       fabric_backend: Optional[str] = None,
                       fabric_chains: Optional[int] = None,
                       fabric_sweeps: Optional[int] = None,
                       fabric_seed: Optional[int] = None,
                       simulate: bool = False) -> Dict[str, DSEResult]:
    """Per-application DSE: PE1..PE5 per app (paper Sec. V-A camera sweep).

    Pass ``fabric=FabricOptions(...)`` (or a bare ``FabricSpec``) to
    additionally place-and-route every variant on the array, and
    ``simulate=True`` to modulo-schedule + cycle-accurately simulate each
    mapping so the records carry measured throughput
    (see :func:`evaluate_variants`).
    """
    out: Dict[str, DSEResult] = {}
    for name, app in apps.items():
        t0 = time.monotonic()
        ranked = mine_and_rank(app, mining)
        variants = build_variants(name, app, ranked, max_merge=max_merge,
                                  rank_mode=rank_mode, validate=validate)
        evaluate_variants(variants, {name: app}, fabric=fabric,
                          fabric_backend=fabric_backend,
                          fabric_chains=fabric_chains,
                          fabric_sweeps=fabric_sweeps,
                          fabric_seed=fabric_seed, simulate=simulate)
        out[name] = DSEResult({name: app}, {name: ranked}, variants,
                              time.monotonic() - t0)
    return out


def domain_pe(apps: Dict[str, Graph],
              mining: Optional[MiningConfig] = None,
              *, per_app_subgraphs: int = 2,
              domain_name: str = "PE_DOM",
              validate: bool = True,
              fabric: Optional[object] = None,
              fabric_backend: Optional[str] = None,
              fabric_chains: Optional[int] = None,
              fabric_sweeps: Optional[int] = None,
              fabric_seed: Optional[int] = None,
              simulate: bool = False) -> DSEResult:
    """Cross-application PE (paper's PE IP / PE ML)."""
    t0 = time.monotonic()
    mined: Dict[str, List[MinedSubgraph]] = {}
    all_ops: Set[str] = set()
    for name, app in apps.items():
        mined[name] = mine_and_rank(app, mining)
        all_ops |= app_ops(app)
    dp = baseline_datapath(all_ops)
    merged: List[str] = []
    seen_labels: Set[str] = set()
    for name, ranked in sorted(mined.items()):
        usable = _dedup_keep_maximal(ranked)
        count = 0
        for m in usable:
            if count >= per_app_subgraphs:
                break
            if m.label in seen_labels:
                count += 1           # another app already contributed it
                continue
            seen_labels.add(m.label)
            cfg_name = f"sg:{name}:{count}"
            add_pattern(dp, m.pattern, cfg_name, validate=validate)
            merged.append(cfg_name)
            count += 1
    variant = PEVariant(domain_name, dp, merged)
    evaluate_variants([variant], apps, fabric=fabric,
                      fabric_backend=fabric_backend,
                      fabric_chains=fabric_chains,
                      fabric_sweeps=fabric_sweeps,
                      fabric_seed=fabric_seed, simulate=simulate)
    return DSEResult(apps, mined, [variant], time.monotonic() - t0)
