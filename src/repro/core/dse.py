"""End-to-end design-space-exploration primitives (paper Sec. IV, Fig. 6).

Given one or more application dataflow graphs:

1. mine frequent subgraphs per app (Sec. III-A),
2. rank by maximal-independent-set size (Sec. III-B),
3. build PE variants (Sec. V):
   * ``PE 1``  — baseline PE restricted to the ops the app uses,
   * ``PE k``  — PE 1 + the top (k-1) subgraphs merged in MIS order,
   * domain PE (``PE IP`` / ``PE ML``) — top subgraphs of *all* apps merged,
4. map every app onto every variant and evaluate area/energy/fmax.

The returned records are exactly what the paper's Figs. 8/10/11 plot.

The end-to-end drivers (``specialize_per_app`` / ``domain_pe`` /
``evaluate_variants``) are retained as thin, bit-identical shims over the
staged pipeline in :mod:`repro.explore` — new code should build an
:class:`repro.explore.ExploreConfig` and run an
:class:`repro.explore.Explorer` instead of threading loose kwargs here.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, TYPE_CHECKING, Union

from ..graphir.graph import Graph
from ..graphir.ops import NON_COMPUTE, unit_of
from .costmodel import AppCost
from .merge import add_pattern, baseline_datapath, is_pe_pattern, _PE_UNITS
from .mining import MinedSubgraph, MiningConfig, mine_frequent_subgraphs
from .mis import rank_by_mis
from .pe import Datapath

if TYPE_CHECKING:
    from ..fabric.arch import FabricSpec
    from ..fabric.cost import FabricCost
    from ..fabric.options import FabricOptions


@dataclass
class PEVariant:
    name: str
    datapath: Datapath
    merged_subgraphs: List[str] = field(default_factory=list)
    costs: Dict[str, AppCost] = field(default_factory=dict)   # per app
    fabric_costs: Dict[str, "FabricCost"] = field(default_factory=dict)
    # per app, filled when fabric-level evaluation is enabled


@dataclass
class DSEResult:
    apps: Dict[str, Graph]
    mined: Dict[str, List[MinedSubgraph]]
    variants: List[PEVariant]
    elapsed_s: float = 0.0

    def best_variant(self, app: str) -> PEVariant:
        """Lowest-energy variant for an app.

        Ranks by the *measured* ``sim_energy_per_op_pj`` when the time-
        domain simulation ran for a variant (``sim_ii > 0``) — so a skew-
        bound schedule's idle cycles penalize it — falling back to the
        static ``energy_per_op_pj`` estimate for variants the simulator
        never saw.
        """
        cands = [v for v in self.variants if app in v.costs]

        def energy(v: PEVariant) -> float:
            c = v.costs[app]
            return (c.sim_energy_per_op_pj if c.sim_ii > 0
                    else c.energy_per_op_pj)

        return min(cands, key=energy)

    def table(self) -> str:
        lines = []
        for v in self.variants:
            for app, c in sorted(v.costs.items()):
                lines.append(c.row())
        return "\n".join(lines)


def app_ops(app: Graph) -> Set[str]:
    """PE-implementable ops used by an application graph."""
    return {op for op in app.nodes.values()
            if op not in NON_COMPUTE and op != "const"
            and unit_of(op) in _PE_UNITS and op != "cmux"}


def mine_and_rank(app: Graph, cfg: Optional[MiningConfig] = None
                  ) -> List[MinedSubgraph]:
    mined = mine_frequent_subgraphs(app, cfg)
    mined = [m for m in mined if is_pe_pattern(m.pattern)]
    return rank_by_mis(mined)


def _dedup_keep_maximal(ranked: List[MinedSubgraph]) -> List[MinedSubgraph]:
    """Drop subgraphs fully contained in an earlier-ranked, larger subgraph
    with at-least-equal MIS utility (merging the bigger one subsumes them)."""
    from .isomorphism import find_embeddings
    kept: List[MinedSubgraph] = []
    for m in ranked:
        subsumed = False
        for k in kept:
            if (k.size >= m.size and k.mis_size >= m.mis_size
                    and find_embeddings(m.pattern, k.pattern,
                                        max_embeddings=4)):
                subsumed = True
                break
        if not subsumed:
            kept.append(m)
    return kept


def build_variants(app_name: str, app: Graph,
                   ranked: List[MinedSubgraph],
                   *, max_merge: int = 4,
                   rank_mode: str = "mis",
                   validate: bool = True) -> List[PEVariant]:
    """PE 1 .. PE (1+max_merge) for a single application.

    rank_mode:
      * ``"mis"`` — the paper's ordering: subgraphs merged in MIS-size order
        (Sec. III-C / Sec. V bullet list).
      * ``"utility"`` — beyond-paper: order by MIS x (ops fused - 1), i.e.
        the number of PE invocations each subgraph eliminates, and skip
        candidates whose marginal coverage is zero.  Recorded separately in
        EXPERIMENTS.md as an improvement over the reproduction baseline.
    """
    variants: List[PEVariant] = []
    ops = app_ops(app)
    dp = baseline_datapath(ops)
    variants.append(PEVariant(f"PE1", dp.copy()))
    usable = _dedup_keep_maximal(ranked)
    if rank_mode == "utility":
        usable = sorted(usable,
                        key=lambda m: (-m.mis_size * max(1, m.size - 1),
                                       -m.size, m.label))
    merged_names: List[str] = []
    cur = dp
    k = 0
    for m in usable:
        if k >= max_merge:
            break
        name = f"sg:{app_name}:{k}"
        nxt = cur.copy()
        add_pattern(nxt, m.pattern, name, validate=validate)
        if rank_mode == "utility":
            # marginal-gain check: does the new config actually get used?
            from .mapper import map_application
            trial = map_application(nxt, app, app_name)
            used = sum(1 for i in trial.instances if i.config == name)
            if used == 0:
                continue
        cur = nxt
        merged_names.append(name)
        variants.append(PEVariant(f"PE{k + 2}", cur.copy(),
                                  list(merged_names)))
        k += 1
    return variants


def _explorer_config(mode: str, mining: Optional[MiningConfig],
                     options: Optional["FabricOptions"], **kw):
    """Build the ExploreConfig a legacy driver call corresponds to.

    ``pnr_batch="serial"`` pins the one-dispatch-per-pair annealing loop,
    which is what makes the shims reproduce the pre-``repro.explore``
    records bit-identically at equal seeds.
    """
    from ..explore.config import ExploreConfig
    return ExploreConfig(mode=mode, mining=mining or MiningConfig(),
                         fabric=options, pnr_batch="serial", **kw)


def evaluate_variants(variants: Sequence[PEVariant],
                      apps: Dict[str, Graph],
                      *, fabric: Optional[Union["FabricSpec",
                                                "FabricOptions"]] = None,
                      fabric_backend: Optional[str] = None,
                      fabric_chains: Optional[int] = None,
                      fabric_sweeps: Optional[int] = None,
                      fabric_seed: Optional[int] = None,
                      simulate: bool = False) -> None:
    """Deprecated shim: map + cost every (variant, app) pair in place.

    Delegates to :func:`repro.explore.evaluate_pairs` (serial mode — the
    legacy loop, bit-identical at equal seeds).  The loose ``fabric_*``
    kwargs emit :class:`DeprecationWarning`; new code should run an
    :class:`repro.explore.Explorer` (which also batches the annealing
    across pairs) or pass a full :class:`repro.fabric.FabricOptions`.

    fabric: a :class:`repro.fabric.FabricOptions` (or a bare ``FabricSpec``
    plus the legacy ``fabric_*`` kwargs, folded in automatically) — when
    given, each mapping is placed and routed on the fabric (auto-grown when
    the variant needs more tiles) and the array-accurate numbers are
    attached to the AppCost records (``fabric_*`` fields) and kept in
    ``variant.fabric_costs``.

    simulate: with a fabric, additionally modulo-schedule and cycle-
    accurately simulate every mapping, attaching *measured* throughput
    (``sim_*`` fields) and — when ``options.sim_verify`` — the bit-exact
    golden check against ``graphir.interp``.
    """
    from ..explore.pipeline import evaluate_pairs
    from ..fabric.options import FabricOptions

    options = FabricOptions.coerce(fabric, backend=fabric_backend,
                                   chains=fabric_chains,
                                   sweeps=fabric_sweeps, seed=fabric_seed,
                                   simulate=simulate)
    evaluate_pairs(variants, apps, options, pnr_batch="serial")


def specialize_per_app(apps: Dict[str, Graph],
                       mining: Optional[MiningConfig] = None,
                       *, max_merge: int = 4,
                       rank_mode: str = "mis",
                       validate: bool = True,
                       fabric: Optional[Union["FabricSpec",
                                              "FabricOptions"]] = None,
                       fabric_backend: Optional[str] = None,
                       fabric_chains: Optional[int] = None,
                       fabric_sweeps: Optional[int] = None,
                       fabric_seed: Optional[int] = None,
                       simulate: bool = False) -> Dict[str, DSEResult]:
    """Deprecated shim: per-application DSE (paper Sec. V-A camera sweep).

    Runs an :class:`repro.explore.Explorer` in ``per_app`` mode with
    ``pnr_batch="serial"``, reproducing the pre-redesign records
    bit-identically at equal seeds.  New code should build an
    :class:`repro.explore.ExploreConfig` directly — it memoizes every
    stage and batches the annealing across (variant, app) pairs.
    """
    from ..explore.pipeline import Explorer
    from ..fabric.options import FabricOptions

    options = FabricOptions.coerce(fabric, backend=fabric_backend,
                                   chains=fabric_chains,
                                   sweeps=fabric_sweeps, seed=fabric_seed,
                                   simulate=simulate)
    cfg = _explorer_config("per_app", mining, options, max_merge=max_merge,
                           rank_mode=rank_mode, validate=validate)
    return Explorer(apps, cfg).run().results


def domain_pe(apps: Dict[str, Graph],
              mining: Optional[MiningConfig] = None,
              *, per_app_subgraphs: int = 2,
              domain_name: str = "PE_DOM",
              validate: bool = True,
              fabric: Optional[Union["FabricSpec",
                                     "FabricOptions"]] = None,
              fabric_backend: Optional[str] = None,
              fabric_chains: Optional[int] = None,
              fabric_sweeps: Optional[int] = None,
              fabric_seed: Optional[int] = None,
              simulate: bool = False) -> DSEResult:
    """Deprecated shim: cross-application PE (paper's PE IP / PE ML).

    Runs an :class:`repro.explore.Explorer` in ``domain`` mode with
    ``pnr_batch="serial"`` — bit-identical to the pre-redesign driver at
    equal seeds.  New code should use :class:`repro.explore.ExploreConfig`.
    """
    from ..explore.pipeline import Explorer
    from ..fabric.options import FabricOptions

    options = FabricOptions.coerce(fabric, backend=fabric_backend,
                                   chains=fabric_chains,
                                   sweeps=fabric_sweeps, seed=fabric_seed,
                                   simulate=simulate)
    cfg = _explorer_config("domain", mining, options,
                           per_app_subgraphs=per_app_subgraphs,
                           domain_name=domain_name, validate=validate)
    return Explorer(apps, cfg).run().results[domain_name]
