"""Generated fused-PE Pallas kernel — the paper's technique on TPU.

A CGRA PE specialized for a mined subgraph executes the whole multi-op
dataflow graph in one configured datapath pass.  The TPU analogue
(DESIGN.md §2): given the same subgraph (an elementwise/mac op-DAG from
repro.core mining+merging), *generate* a Pallas kernel whose body evaluates
the DAG on VPU registers over one VMEM tile — each application of the PE
touches HBM once per operand tile instead of once per primitive op.  Mux
configuration happens at trace time (each config compiles its own body), so
the datapath specialization is free on TPU.

``make_pe_kernel(pattern)`` returns a jitted function
``f(*inputs) -> tuple(outputs)`` with one input per free in-port of the
pattern (tile-blocked, any 2D shape padded to the block) and one output per
pattern sink.  Constants are baked into the kernel body.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..graphir.graph import Graph, free_in_ports, sink_nodes
from ..graphir.ops import OPS

# jnp semantics for kernel bodies (VPU ops on tiles)
_JNP_SEMANTICS: Dict[str, Callable] = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "neg": lambda a: -a,
    "abs": lambda a: jnp.abs(a),
    "mul": lambda a, b: a * b,
    "mac": lambda a, b, c: a * b + c,
    "div": lambda a, b: a / b,
    "recip": lambda a: 1.0 / a,
    "shl": lambda a, b: a * jnp.exp2(b),
    "shr": lambda a, b: a * jnp.exp2(-b),
    "ashr": lambda a, b: a * jnp.exp2(-b),
    "eq": lambda a, b: (a == b),
    "neq": lambda a, b: (a != b),
    "lt": lambda a, b: (a < b),
    "lte": lambda a, b: (a <= b),
    "gt": lambda a, b: (a > b),
    "gte": lambda a, b: (a >= b),
    "min": lambda a, b: jnp.minimum(a, b),
    "max": lambda a, b: jnp.maximum(a, b),
    "and": lambda a, b: jnp.logical_and(a, b),
    "or": lambda a, b: jnp.logical_or(a, b),
    "xor": lambda a, b: jnp.logical_xor(a, b),
    "not": lambda a: jnp.logical_not(a),
    "sign": lambda a: jnp.sign(a),
    "sel": lambda c, f, t: jnp.where(c, t, f),
    "exp": lambda a: jnp.exp(a),
    "log": lambda a: jnp.log(a),
    "tanh": lambda a: jnp.tanh(a),
    "sigmoid": lambda a: jax.nn.sigmoid(a),
    "rsqrt": lambda a: jax.lax.rsqrt(a),
    "sqrt": lambda a: jnp.sqrt(a),
    "erf": lambda a: jax.lax.erf(a),
    "pow": lambda a, b: jnp.power(a, b),
    "floor": lambda a: jnp.floor(a),
    "round": lambda a: jnp.round(a),
}


def pe_kernel_body(pattern: Graph, n_in: int, sinks: List[int],
                   free: List[Tuple[int, int]]):
    """Build the Pallas kernel body evaluating the pattern DAG on one tile."""
    topo = pattern.topo_order()

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in:]
        port_vals = {fp: in_refs[i][...] for i, fp in enumerate(free)}
        vals: Dict[int, jax.Array] = {}
        for n in topo:
            op = pattern.nodes[n]
            if op == "const":
                vals[n] = jnp.float32(pattern.attr(n, "value", 0.0))
                continue
            ins = pattern.in_edges(n)
            args = []
            for p in range(OPS[op].arity):
                if p in ins:
                    args.append(vals[ins[p]])
                else:
                    args.append(port_vals[(n, p)])
            vals[n] = _JNP_SEMANTICS[op](*args)
        for i, s in enumerate(sinks):
            v = vals[s]
            out_refs[i][...] = v.astype(out_refs[i].dtype)

    return kernel


def make_pe_kernel(pattern: Graph, *,
                   block: Tuple[int, int] = (256, 256),
                   interpret: bool = False) -> Callable:
    """Compile a mined/merged PE pattern into a fused elementwise kernel.

    Returns f(*inputs) -> output (or tuple of outputs for multi-sink PEs).
    Inputs must share one 2D shape (callers reshape); non-multiple shapes
    are padded to the (8k, 128k)-aligned block and cropped back.
    """
    free = free_in_ports(pattern)
    sinks = sink_nodes(pattern)
    if not free:
        raise ValueError("pattern has no free in-ports")
    for n, op in pattern.nodes.items():
        if op not in _JNP_SEMANTICS and op != "const":
            raise ValueError(f"op {op!r} not supported in PE kernels")
    n_in = len(free)
    body = pe_kernel_body(pattern, n_in, sinks, free)

    @jax.jit
    def run(*inputs: jax.Array):
        if len(inputs) != n_in:
            raise TypeError(f"expected {n_in} inputs, got {len(inputs)}")
        x0 = inputs[0]
        shape = x0.shape
        flat = [i.reshape(-1) for i in inputs]
        n = flat[0].shape[0]
        bm, bn = block
        cols = bn
        rows = max(1, math.ceil(n / cols))
        rows_pad = math.ceil(rows / bm) * bm
        padded = rows_pad * cols

        def pad2d(v):
            v = jnp.pad(v, (0, padded - n))
            return v.reshape(rows_pad, cols)

        tiles = [pad2d(v) for v in flat]
        grid = (rows_pad // bm, 1)
        in_specs = [pl.BlockSpec((bm, bn), lambda i, j: (i, j))
                    for _ in range(n_in)]
        out_specs = [pl.BlockSpec((bm, bn), lambda i, j: (i, j))
                     for _ in sinks]
        out_shapes = [jax.ShapeDtypeStruct((rows_pad, cols), x0.dtype)
                      for _ in sinks]
        outs = pl.pallas_call(
            body,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs if len(sinks) > 1 else out_specs[0],
            out_shape=out_shapes if len(sinks) > 1 else out_shapes[0],
            interpret=interpret,
        )(*tiles)
        if len(sinks) == 1:
            outs = (outs,)
        res = tuple(o.reshape(-1)[:n].reshape(shape) for o in outs)
        return res if len(sinks) > 1 else res[0]

    return run


def kernel_from_config(dp, config_name: str, **kw) -> Callable:
    """Fused kernel for one configuration of a merged PE datapath."""
    cfg = dp.configs[config_name]
    return make_pe_kernel(cfg.pattern, **kw)
