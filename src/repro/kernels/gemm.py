"""MXU-tiled matmul with a fused PE-graph epilogue.

The ML-domain PEs the paper derives (Fig. 12) are MAC datapaths followed by
small op chains (bias add, ReLU, requantize, residual add).  On TPU the MAC
array is the MXU; the mined epilogue graph fuses into the matmul's output
tile while the accumulator is still in VMEM — this kernel is the bridge
between the DSE output and the MXU.

Grid (M/bm, N/bn, K/bk) with K innermost; accumulation in an f32 VMEM
scratch; on the last K step the epilogue DAG (a repro.graphir pattern whose
first free port is the accumulator) is evaluated on the tile and written
out.  Extra epilogue operands are (N,)-vectors (bias-like, tiled by bn) or
(M, N) matrices (residual-like, tiled by (bm, bn)).
"""

from __future__ import annotations

import functools
import math
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..graphir.graph import Graph, free_in_ports, sink_nodes
from ..graphir.ops import OPS
from .pe_fused import _JNP_SEMANTICS


def _eval_epilogue(pattern: Graph, acc, extras: Sequence[jax.Array]):
    free = free_in_ports(pattern)
    port_vals = {free[0]: acc}
    for fp, x in zip(free[1:], extras):
        port_vals[fp] = x
    vals = {}
    for node in pattern.topo_order():
        op = pattern.nodes[node]
        if op == "const":
            vals[node] = jnp.float32(pattern.attr(node, "value", 0.0))
            continue
        ins = pattern.in_edges(node)
        args = []
        for p in range(OPS[op].arity):
            args.append(vals[ins[p]] if p in ins else port_vals[(node, p)])
        vals[node] = _JNP_SEMANTICS[op](*args)
    return vals[sink_nodes(pattern)[0]]


def _gemm_kernel(*refs, pattern: Optional[Graph], n_extra: int,
                 extra_kinds: Tuple[str, ...], nsteps: int):
    x_ref, w_ref = refs[0], refs[1]
    extra_refs = refs[2:2 + n_extra]
    o_ref = refs[2 + n_extra]
    acc_scr = refs[3 + n_extra]
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ki == nsteps - 1)
    def _emit():
        acc = acc_scr[...]
        if pattern is not None:
            extras = []
            for ref, kind in zip(extra_refs, extra_kinds):
                v = ref[...].astype(jnp.float32)
                if kind == "vec":
                    v = v[None, :]                     # broadcast over rows
                extras.append(v)
            acc = _eval_epilogue(pattern, acc, extras)
        o_ref[...] = acc.astype(o_ref.dtype)


def gemm_pe(x: jax.Array, w: jax.Array,
            *extras: jax.Array,
            epilogue: Optional[Graph] = None,
            extra_kinds: Tuple[str, ...] = (),
            bm: int = 128, bn: int = 128, bk: int = 128,
            out_dtype=None,
            interpret: bool = False) -> jax.Array:
    """x (M, K) @ w (K, N) with fused epilogue.  Shapes must be multiples of
    the block sizes (ops.py pads)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    if epilogue is not None:
        need = len(free_in_ports(epilogue)) - 1
        assert len(extras) == need, (len(extras), need)
        assert len(extra_kinds) == need
    nsteps = k // bk
    grid = (m // bm, n // bn, nsteps)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
    ]
    for kind in extra_kinds:
        if kind == "vec":
            in_specs.append(pl.BlockSpec((bn,), lambda i, j, s: (j,)))
        else:
            in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)))

    kernel = functools.partial(
        _gemm_kernel, pattern=epilogue, n_extra=len(extras),
        extra_kinds=extra_kinds, nsteps=nsteps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype or x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, *extras)
