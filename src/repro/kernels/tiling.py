"""Shared TPU tile-shape helpers for the Pallas kernels in this package."""

from __future__ import annotations

#: float32 VMEM tile shape (sublane x lane)
SUBLANE = 8
LANE = 128


def round_up(n: int, k: int) -> int:
    """Smallest multiple of k that is >= max(n, k)."""
    return max(k, (n + k - 1) // k * k)
