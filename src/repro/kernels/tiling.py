"""Shared TPU tile-shape helpers for the Pallas kernels in this package."""

from __future__ import annotations

#: float32 VMEM tile shape (sublane x lane)
SUBLANE = 8
LANE = 128


def round_up(n: int, k: int) -> int:
    """Smallest multiple of k that is >= max(n, k)."""
    return max(k, (n + k - 1) // k * k)


def pow2_bucket(n: int) -> int:
    """Next power of two >= max(n, 1) — the padding granule shared by every
    cross-problem batching scheme in this repo (batched annealing, batched
    cycle simulation).

    Padding each problem to bucket sizes (instead of group-max) makes a
    problem's batched result independent of which other problems share its
    dispatch, so batched artifacts are reproducible and cacheable per
    problem, and the compiled program is reused across explorations."""
    return 1 << max(0, (n - 1)).bit_length()


def pad2d(x, fill=0):
    """Zero-copy-where-possible pad of a 2-D array to the float32 VMEM tile
    grid (rows to a SUBLANE multiple, cols to a LANE multiple).

    Returns the padded array; ``fill`` seeds the padding region (0 for data
    whose pad rows must reduce to the masked identity).
    """
    import jax.numpy as jnp

    r, c = x.shape
    rp, cp = round_up(r, SUBLANE), round_up(c, LANE)
    if (rp, cp) == (r, c):
        return x
    out = jnp.full((rp, cp), fill, dtype=x.dtype)
    return out.at[:r, :c].set(x)
