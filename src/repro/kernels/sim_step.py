"""Tile-step ALU dispatch kernels for the cycle-accurate fabric simulator.

Every simulated cycle, all PE tiles execute one micro-op of their configured
datapath in lockstep: gather operands, apply the tile's opcode, write the
result.  That inner step — a batched, opcode-indexed elementwise dispatch —
is the hot loop of :mod:`repro.sim.cycle`, and it is exactly VPU-shaped:
same instruction stream across lanes, divergence resolved by select.

Three implementations behind the same backend-switch pattern as
:mod:`repro.kernels.pnr_cost`:

* :func:`alu_step_reference` — pure NumPy loop, the oracle;
* :func:`alu_step_jnp` — ``jax.vmap`` of ``lax.switch`` over the flattened
  (batch x tile) lanes, jitted per static op table;
* :func:`alu_step_pallas` — Pallas kernel computing every op of the static
  table and masking by opcode (compute-all-select, the way a SIMD machine
  actually retires divergent lanes).  Interpret mode on CPU hosts;
  compiles to VMEM tiles on TPU.

Opcode 0 is always ``nop`` (padding lanes).  Semantics mirror
:data:`repro.graphir.interp.SEMANTICS` in float32: predicates are encoded
as 1.0/0.0 and consumed as ``x != 0``, so a schedule simulated here
bit-matches the NumPy interpreter on IEEE-exact op sets (the whole paper
suite: add/sub/mul/min/max/shift/compare/select).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

#: (a, b, c) -> result, all float32; must mirror graphir.interp.SEMANTICS
ALU_IMPLS: Dict[str, Callable] = {
    "nop": lambda a, b, c: jnp.zeros_like(a),
    "add": lambda a, b, c: a + b,
    "sub": lambda a, b, c: a - b,
    "neg": lambda a, b, c: -a,
    "abs": lambda a, b, c: jnp.abs(a),
    "mul": lambda a, b, c: a * b,
    "mac": lambda a, b, c: a * b + c,
    "div": lambda a, b, c: a / b,
    "recip": lambda a, b, c: 1.0 / a,
    "shl": lambda a, b, c: a * (2.0 ** b),
    "shr": lambda a, b, c: a / (2.0 ** b),
    "ashr": lambda a, b, c: a / (2.0 ** b),
    "eq": lambda a, b, c: (a == b).astype(a.dtype),
    "neq": lambda a, b, c: (a != b).astype(a.dtype),
    "lt": lambda a, b, c: (a < b).astype(a.dtype),
    "lte": lambda a, b, c: (a <= b).astype(a.dtype),
    "gt": lambda a, b, c: (a > b).astype(a.dtype),
    "gte": lambda a, b, c: (a >= b).astype(a.dtype),
    "min": lambda a, b, c: jnp.minimum(a, b),
    "max": lambda a, b, c: jnp.maximum(a, b),
    "and": lambda a, b, c: ((a != 0) & (b != 0)).astype(a.dtype),
    "or": lambda a, b, c: ((a != 0) | (b != 0)).astype(a.dtype),
    "xor": lambda a, b, c: ((a != 0) ^ (b != 0)).astype(a.dtype),
    "not": lambda a, b, c: (a == 0).astype(a.dtype),
    "sign": lambda a, b, c: jnp.sign(a),
    "sel": lambda a, b, c: jnp.where(a != 0, c, b),   # ports: cond,false,true
    "floor": lambda a, b, c: jnp.floor(a),
    "round": lambda a, b, c: jnp.round(a),
    "exp": lambda a, b, c: jnp.exp(a),
    "log": lambda a, b, c: jnp.log(a),
    "tanh": lambda a, b, c: jnp.tanh(a),
    "sigmoid": lambda a, b, c: 1.0 / (1.0 + jnp.exp(-a)),
    "rsqrt": lambda a, b, c: jax.lax.rsqrt(a),
    "sqrt": lambda a, b, c: jnp.sqrt(a),
    "pow": lambda a, b, c: a ** b,
}


def op_table(used_ops: Sequence[str]) -> Tuple[str, ...]:
    """Static opcode table for a design: nop first, then sorted used ops."""
    missing = sorted(set(used_ops) - set(ALU_IMPLS))
    if missing:
        raise NotImplementedError(f"no ALU dispatch for ops {missing}")
    return ("nop",) + tuple(sorted(set(used_ops) - {"nop"}))


def alu_step_reference(codes: np.ndarray, a: np.ndarray, b: np.ndarray,
                       c: np.ndarray, ops: Tuple[str, ...]) -> np.ndarray:
    """NumPy oracle built on the interpreter's SEMANTICS table (independent
    of the jnp implementations above); codes (N,), operands (..., N)."""
    from ..graphir.interp import SEMANTICS
    from ..graphir.ops import OPS

    out = np.zeros_like(a, dtype=np.float32)
    for k, name in enumerate(ops):
        m = codes == k
        if not m.any() or name == "nop":
            continue
        args = [x[..., m].astype(np.float32) for x in (a, b, c)]
        if name == "sel":
            r = SEMANTICS[name](args[0] != 0, args[1], args[2])
        else:
            r = SEMANTICS[name](*args[:OPS[name].arity])
        out[..., m] = np.asarray(r, dtype=np.float32)
    return out


@functools.partial(jax.jit, static_argnames=("ops",))
def alu_step_jnp(codes: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, ops: Tuple[str, ...]) -> jax.Array:
    """Batched dispatch: ``lax.switch`` vmapped over every (batch, tile)
    lane.  codes (N,), operands (N,) or (B, N)."""
    branches = [ALU_IMPLS[name] for name in ops]
    flat_codes = jnp.broadcast_to(codes, a.shape).reshape(-1)
    fa, fb, fc = (x.reshape(-1) for x in (a, b, c))
    out = jax.vmap(
        lambda k, x, y, z: jax.lax.switch(k, branches, x, y, z)
    )(flat_codes, fa, fb, fc)
    return out.reshape(a.shape)


@functools.partial(jax.jit, static_argnames=("ops",))
def alu_step_masked(codes: jax.Array, a: jax.Array, b: jax.Array,
                    c: jax.Array, ops: Tuple[str, ...],
                    active: jax.Array) -> jax.Array:
    """:func:`alu_step_jnp` with a dynamic activity mask.

    ``active`` (broadcastable to ``a``'s shape) carries dynamic program
    structure as *data*: the batched cycle simulator pads every tile to the
    bucket's micro-op count and instance count, then masks the padding with
    ``(step < n_steps) & (lane < n_inst)`` instead of baking each program's
    real lengths into the compiled code.  Inactive lanes retire 0.0 — the
    same value the nop padding computes — so one jitted program serves
    every program in a bucket and results are bit-identical to the
    per-program dispatch on the real lanes.
    """
    out = alu_step_jnp(codes, a, b, c, ops)
    return jnp.where(jnp.broadcast_to(active, out.shape), out,
                     jnp.zeros_like(out))


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _build_step_kernel(ops: Tuple[str, ...]):
    def kernel(codes_ref, a_ref, b_ref, c_ref, o_ref):
        codes = codes_ref[...]
        a, b, c = a_ref[...], b_ref[...], c_ref[...]
        out = jnp.zeros_like(a)
        for k, name in enumerate(ops):
            if name == "nop":
                continue
            out = jnp.where(codes == k, ALU_IMPLS[name](a, b, c), out)
        o_ref[...] = out
    return kernel


@functools.partial(jax.jit, static_argnames=("ops", "interpret"))
def alu_step_pallas(codes: jax.Array, a: jax.Array, b: jax.Array,
                    c: jax.Array, ops: Tuple[str, ...],
                    *, interpret: bool = True) -> jax.Array:
    """Compute-all-select dispatch as a Pallas VPU kernel.

    Operands are padded to float32 tile multiples (8 x 128); the batch axis
    maps onto sublanes, tiles onto lanes.  Division/transcendental branches
    run on every lane and are masked out by the opcode select — standard
    SIMD divergence handling, no flow control in the kernel.
    """
    from .tiling import LANE, SUBLANE, round_up

    shape = a.shape
    a2 = a.reshape(-1, shape[-1]).astype(jnp.float32)
    b2 = b.reshape(-1, shape[-1]).astype(jnp.float32)
    c2 = c.reshape(-1, shape[-1]).astype(jnp.float32)
    rows, cols = a2.shape
    rp, cp = round_up(rows, SUBLANE), round_up(cols, LANE)
    pad = lambda x: jnp.zeros((rp, cp), jnp.float32).at[:rows, :cols].set(x)
    codes2 = jnp.zeros((rp, cp), jnp.int32).at[:rows, :cols].set(
        jnp.broadcast_to(codes.astype(jnp.int32), (rows, cols)))
    out = pl.pallas_call(
        _build_step_kernel(ops),
        out_shape=jax.ShapeDtypeStruct((rp, cp), jnp.float32),
        interpret=interpret,
    )(codes2, pad(a2), pad(b2), pad(c2))
    return out[:rows, :cols].reshape(shape)
