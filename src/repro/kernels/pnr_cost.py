"""Half-perimeter wirelength (HPWL) cost kernels for the fabric placer.

The annealing placer in :mod:`repro.fabric.place` scores candidate
placements by total HPWL over all nets.  Nets are lowered once to a padded
pin matrix (``net_pins``: net x pin -> entity index, ``net_mask`` marking
real pins); a placement is then just a gather + masked min/max reduction —
the hot numeric loop of PnR, and embarrassingly parallel across annealing
chains.

Three implementations:

* :func:`hpwl` — jax.numpy, ``jax.jit``-compiled, differentiable-free hot
  path used inside the annealing loop;
* :func:`hpwl_batched` — vmapped over a leading chain axis;
* :func:`hpwl_pallas` — Pallas kernel over the padded per-net coordinate
  matrices (interpret mode on CPU hosts; compiles for TPU VMEM tiles).

A pure-NumPy oracle (:func:`hpwl_reference`) anchors the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_BIG = 1e9


def hpwl_reference(pos: np.ndarray, net_pins: np.ndarray,
                   net_mask: np.ndarray) -> float:
    """Pure-Python/NumPy oracle.  pos: (E, 2); net_pins/net_mask: (N, D)."""
    total = 0.0
    for i in range(net_pins.shape[0]):
        xs, ys = [], []
        for j in range(net_pins.shape[1]):
            if net_mask[i, j]:
                e = int(net_pins[i, j])
                xs.append(float(pos[e, 0]))
                ys.append(float(pos[e, 1]))
        if xs:
            total += (max(xs) - min(xs)) + (max(ys) - min(ys))
    return total


def net_hpwl(pos: jax.Array, net_pins: jax.Array,
             net_mask: jax.Array) -> jax.Array:
    """Per-net HPWL.  pos: (E, 2) float; net_pins: (N, D) int (pad entries
    may hold any valid index); net_mask: (N, D) bool.  Returns (N,)."""
    xy = pos[net_pins]                       # (N, D, 2)
    x, y = xy[..., 0], xy[..., 1]
    xmin = jnp.min(jnp.where(net_mask, x, _BIG), axis=-1)
    xmax = jnp.max(jnp.where(net_mask, x, -_BIG), axis=-1)
    ymin = jnp.min(jnp.where(net_mask, y, _BIG), axis=-1)
    ymax = jnp.max(jnp.where(net_mask, y, -_BIG), axis=-1)
    valid = jnp.any(net_mask, axis=-1)
    return jnp.where(valid, (xmax - xmin) + (ymax - ymin), 0.0)


@jax.jit
def hpwl(pos: jax.Array, net_pins: jax.Array,
         net_mask: jax.Array) -> jax.Array:
    """Total HPWL of one placement (scalar)."""
    return jnp.sum(net_hpwl(pos, net_pins, net_mask))


#: (C, E, 2) x (N, D) x (N, D) -> (C,): one HPWL per annealing chain.
hpwl_batched = jax.jit(jax.vmap(hpwl, in_axes=(0, None, None)))


# ---------------------------------------------------------------------------
# Pallas kernel: per-net masked min/max reduction over the pin axis.
# ---------------------------------------------------------------------------
def _hpwl_kernel(x_ref, y_ref, m_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    m = m_ref[...] != 0
    xmin = jnp.min(jnp.where(m, x, _BIG), axis=1, keepdims=True)
    xmax = jnp.max(jnp.where(m, x, -_BIG), axis=1, keepdims=True)
    ymin = jnp.min(jnp.where(m, y, _BIG), axis=1, keepdims=True)
    ymax = jnp.max(jnp.where(m, y, -_BIG), axis=1, keepdims=True)
    valid = jnp.any(m, axis=1, keepdims=True)
    o_ref[...] = jnp.where(valid, (xmax - xmin) + (ymax - ymin), 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hpwl_pallas(pos: jax.Array, net_pins: jax.Array, net_mask: jax.Array,
                *, interpret: bool = True) -> jax.Array:
    """Total HPWL via a Pallas reduction kernel.

    Gathers pin coordinates outside the kernel (gathers are host-side
    cheap; the reduction is the VPU-shaped part), pads the pin matrices to
    TPU tile multiples (8 x 128 for float32), and reduces per net.
    """
    from .tiling import LANE, SUBLANE, round_up

    n, d = net_pins.shape
    xy = pos[net_pins].astype(jnp.float32)           # (N, D, 2)
    n_pad, d_pad = round_up(n, SUBLANE), round_up(d, LANE)
    x = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(xy[..., 0])
    y = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(xy[..., 1])
    m = jnp.zeros((n_pad, d_pad), jnp.int32).at[:n, :d].set(
        net_mask.astype(jnp.int32))
    per_net = pl.pallas_call(
        _hpwl_kernel,
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        interpret=interpret,
    )(x, y, m)
    return jnp.sum(per_net)
