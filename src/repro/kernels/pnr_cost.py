"""Half-perimeter wirelength (HPWL) cost kernels for the fabric placer.

The annealing placer in :mod:`repro.fabric.place` scores candidate
placements by total HPWL over all nets.  Nets are lowered once to a padded
pin matrix (``net_pins``: net x pin -> entity index, ``net_mask`` marking
real pins); a placement is then just a gather + masked min/max reduction —
the hot numeric loop of PnR, and embarrassingly parallel across annealing
chains.

Full-recompute implementations:

* :func:`hpwl` — jax.numpy, ``jax.jit``-compiled, differentiable-free hot
  path used inside the annealing loop;
* :func:`hpwl_batched` — vmapped over a leading chain axis;
* :func:`hpwl_pallas` — Pallas kernel over the padded per-net coordinate
  matrices (interpret mode on CPU hosts; compiles for TPU VMEM tiles).

Delta (incremental) implementations — a swap move touches only the nets
incident to the two swapped entities, so the annealer's hot loop rescopes
those ≤2K nets instead of all N:

* :func:`hpwl_delta` — jnp path: gather only the touched nets' pins under
  the candidate permutation and rescore them;
* :func:`hpwl_delta_pallas` — fused Pallas variant: pre-swap pin
  coordinates go to VMEM and the kernel *applies the swap in-kernel*
  (select on the two swapped entity ids) before reducing the per-net
  bounding boxes, emitting new per-net costs plus the move delta.

Fixed-terminal ("mixed") variants — the hierarchical placer's detailed
level anneals each cluster in its own local coordinate frame, with pins
outside the cluster frozen at their estimated positions.  Rather than
materializing those terminals as entities, each net carries a precomputed
*fixed bounding box* (``net_fix``: xmin/xmax/ymin/ymax over its external
pins, rebased into the cluster frame) that is folded into the per-net
reduction:

* :func:`net_hpwl_fixed` / :func:`hpwl_fixed` — full recompute with the
  fixed boxes folded in;
* :func:`hpwl_delta_fixed` — the incremental counterpart of
  :func:`hpwl_delta`;
* :data:`EMPTY_BOX` — the "no external pins" sentinel (min > max, so the
  box never widens a bound and a box-only net scores 0).

A pure-NumPy oracle (:func:`hpwl_reference`) anchors the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_BIG = 1e9


def hpwl_reference(pos: np.ndarray, net_pins: np.ndarray,
                   net_mask: np.ndarray) -> float:
    """Pure-Python/NumPy oracle.  pos: (E, 2); net_pins/net_mask: (N, D)."""
    total = 0.0
    for i in range(net_pins.shape[0]):
        xs, ys = [], []
        for j in range(net_pins.shape[1]):
            if net_mask[i, j]:
                e = int(net_pins[i, j])
                xs.append(float(pos[e, 0]))
                ys.append(float(pos[e, 1]))
        if xs:
            total += (max(xs) - min(xs)) + (max(ys) - min(ys))
    return total


def net_hpwl_from_xy(xy: jax.Array, net_mask: jax.Array) -> jax.Array:
    """Per-net HPWL from already-gathered pin coordinates.
    xy: (N, D, 2) float; net_mask: (N, D) bool.  Returns (N,)."""
    x, y = xy[..., 0], xy[..., 1]
    xmin = jnp.min(jnp.where(net_mask, x, _BIG), axis=-1)
    xmax = jnp.max(jnp.where(net_mask, x, -_BIG), axis=-1)
    ymin = jnp.min(jnp.where(net_mask, y, _BIG), axis=-1)
    ymax = jnp.max(jnp.where(net_mask, y, -_BIG), axis=-1)
    valid = jnp.any(net_mask, axis=-1)
    return jnp.where(valid, (xmax - xmin) + (ymax - ymin), 0.0)


def net_hpwl(pos: jax.Array, net_pins: jax.Array,
             net_mask: jax.Array) -> jax.Array:
    """Per-net HPWL.  pos: (E, 2) float; net_pins: (N, D) int (pad entries
    may hold any valid index); net_mask: (N, D) bool.  Returns (N,)."""
    return net_hpwl_from_xy(pos[net_pins], net_mask)


@jax.jit
def hpwl(pos: jax.Array, net_pins: jax.Array,
         net_mask: jax.Array) -> jax.Array:
    """Total HPWL of one placement (scalar)."""
    return jnp.sum(net_hpwl(pos, net_pins, net_mask))


#: (C, E, 2) x (N, D) x (N, D) -> (C,): one HPWL per annealing chain.
hpwl_batched = jax.jit(jax.vmap(hpwl, in_axes=(0, None, None)))


# ---------------------------------------------------------------------------
# Pallas kernel: per-net masked min/max reduction over the pin axis.
# ---------------------------------------------------------------------------
def _hpwl_kernel(x_ref, y_ref, m_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    m = m_ref[...] != 0
    xmin = jnp.min(jnp.where(m, x, _BIG), axis=1, keepdims=True)
    xmax = jnp.max(jnp.where(m, x, -_BIG), axis=1, keepdims=True)
    ymin = jnp.min(jnp.where(m, y, _BIG), axis=1, keepdims=True)
    ymax = jnp.max(jnp.where(m, y, -_BIG), axis=1, keepdims=True)
    valid = jnp.any(m, axis=1, keepdims=True)
    o_ref[...] = jnp.where(valid, (xmax - xmin) + (ymax - ymin), 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hpwl_pallas(pos: jax.Array, net_pins: jax.Array, net_mask: jax.Array,
                *, interpret: bool = True) -> jax.Array:
    """Total HPWL via a Pallas reduction kernel.

    Gathers pin coordinates outside the kernel (gathers are host-side
    cheap; the reduction is the VPU-shaped part), pads the pin matrices to
    TPU tile multiples (8 x 128 for float32), and reduces per net.
    """
    from .tiling import pad2d, round_up, SUBLANE

    n, d = net_pins.shape
    xy = pos[net_pins].astype(jnp.float32)           # (N, D, 2)
    x = pad2d(xy[..., 0])
    y = pad2d(xy[..., 1])
    m = pad2d(net_mask.astype(jnp.int32))
    per_net = pl.pallas_call(
        _hpwl_kernel,
        out_shape=jax.ShapeDtypeStruct((round_up(n, SUBLANE), 1),
                                       jnp.float32),
        interpret=interpret,
    )(x, y, m)
    return jnp.sum(per_net)


# ---------------------------------------------------------------------------
# Delta rescoring: only the nets touched by a swap move.
# ---------------------------------------------------------------------------
def _touched_view(net_pins: jax.Array, net_mask: jax.Array,
                  per_net_cost: jax.Array, touched: jax.Array):
    """(pins, mask, old) restricted to the touched nets.

    ``touched`` holds net indices padded with ``N`` (out of range) for
    unused / duplicate entries; those rows come back fully masked with an
    old cost of 0, so they drop out of every reduction.
    """
    n = net_pins.shape[0]
    valid = touched < n
    tc = jnp.minimum(touched, n - 1)
    pins = net_pins[tc]                               # (T, D)
    mask = net_mask[tc] & valid[:, None]
    old = jnp.where(valid, per_net_cost[tc], 0.0)
    return pins, mask, old


def hpwl_delta(slot_xy: jax.Array, cand_slot_of: jax.Array,
               net_pins: jax.Array, net_mask: jax.Array,
               per_net_cost: jax.Array, touched: jax.Array):
    """Rescore only the ``touched`` nets under a candidate permutation.

    slot_xy: (E, 2) slot coordinates; cand_slot_of: (E,) candidate
    entity -> slot permutation; per_net_cost: (N,) current per-net HPWL;
    touched: (T,) int32 net indices (pad/duplicate entries hold N).

    Returns ``(new_vals, delta)``: ``new_vals[t]`` is the candidate HPWL
    of net ``touched[t]`` (0 for padding) and ``delta`` the scalar move
    cost change.  O(T * D) instead of O(N * D).
    """
    pins, mask, old = _touched_view(net_pins, net_mask, per_net_cost,
                                    touched)
    xy = slot_xy[cand_slot_of[pins]]                  # (T, D, 2)
    new_vals = net_hpwl_from_xy(xy, mask)
    return new_vals, jnp.sum(new_vals - old)


def _hpwl_delta_kernel(x_ref, y_ref, p_ref, m_ref, old_ref, ab_ref, sw_ref,
                       new_ref, delta_ref):
    """Fused swap + bounding-box reduction.

    x/y hold the *pre-swap* pin coordinates; ab the two swapped entity
    ids; sw their *post-swap* (x, y) positions.  The swap is applied
    in-kernel (two selects on the resident coordinate tiles), then the
    per-net boxes reduce as in :func:`_hpwl_kernel`.
    """
    p = p_ref[...]
    a, b = ab_ref[0, 0], ab_ref[0, 1]
    x = x_ref[...]
    y = y_ref[...]
    x = jnp.where(p == a, sw_ref[0, 0], jnp.where(p == b, sw_ref[1, 0], x))
    y = jnp.where(p == a, sw_ref[0, 1], jnp.where(p == b, sw_ref[1, 1], y))
    m = m_ref[...] != 0
    xmin = jnp.min(jnp.where(m, x, _BIG), axis=1, keepdims=True)
    xmax = jnp.max(jnp.where(m, x, -_BIG), axis=1, keepdims=True)
    ymin = jnp.min(jnp.where(m, y, _BIG), axis=1, keepdims=True)
    ymax = jnp.max(jnp.where(m, y, -_BIG), axis=1, keepdims=True)
    valid = jnp.any(m, axis=1, keepdims=True)
    new = jnp.where(valid, (xmax - xmin) + (ymax - ymin), 0.0)
    new_ref[...] = new
    delta_ref[...] = jnp.sum(new - old_ref[...], keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hpwl_delta_pallas(slot_xy: jax.Array, slot_of: jax.Array,
                      net_pins: jax.Array, net_mask: jax.Array,
                      per_net_cost: jax.Array, touched: jax.Array,
                      ent_a: jax.Array, ent_b: jax.Array,
                      *, interpret: bool = True):
    """Same contract as :func:`hpwl_delta`, but scores *the swap of
    ent_a/ent_b applied to slot_of* without materializing the candidate
    permutation: the touched nets' pre-swap coordinates stay resident in
    VMEM and the kernel applies the swap before reducing.
    """
    from .tiling import pad2d, round_up, SUBLANE

    pins, mask, old = _touched_view(net_pins, net_mask, per_net_cost,
                                    touched)
    t = pins.shape[0]
    t_pad = round_up(t, SUBLANE)
    xy = slot_xy[slot_of[pins]].astype(jnp.float32)   # pre-swap coords
    x = pad2d(xy[..., 0])
    y = pad2d(xy[..., 1])
    p = pad2d(pins.astype(jnp.int32), fill=-1)        # -1 never matches
    m = pad2d(mask.astype(jnp.int32))
    old_p = jnp.zeros((t_pad, 1), jnp.float32).at[:t, 0].set(old)
    ab = jnp.stack([ent_a, ent_b]).astype(jnp.int32)[None]        # (1, 2)
    # post-swap positions: each entity lands on the other's slot
    sw = jnp.stack([slot_xy[slot_of[ent_b]],
                    slot_xy[slot_of[ent_a]]]).astype(jnp.float32)  # (2, 2)
    new_p, delta = pl.pallas_call(
        _hpwl_delta_kernel,
        out_shape=(jax.ShapeDtypeStruct((t_pad, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)),
        interpret=interpret,
    )(x, y, p, m, old_p, ab, sw)
    return new_p[:t, 0], delta[0, 0]


# ---------------------------------------------------------------------------
# Fixed-terminal variants: per-net fixed bounding boxes folded into the
# reduction (cluster-local frames for the hierarchical placer).
# ---------------------------------------------------------------------------

#: per-net "no external pins" box: [xmin, xmax, ymin, ymax] with min > max,
#: the identity of the fold below — jnp.minimum(x, _BIG) == x and
#: jnp.maximum(x, -_BIG) == x exactly, so a sentinel box is a bit-exact
#: no-op and fixed-box programs agree with the plain ones on box-free nets
EMPTY_BOX = (_BIG, -_BIG, _BIG, -_BIG)


def fixed_box(points) -> np.ndarray:
    """[xmin, xmax, ymin, ymax] float32 over (x, y) pairs; EMPTY_BOX when
    there are none.  Host-side helper for lowering cluster-local nets."""
    pts = np.asarray(list(points), np.float32)
    if pts.size == 0:
        return np.asarray(EMPTY_BOX, np.float32)
    return np.asarray([pts[:, 0].min(), pts[:, 0].max(),
                       pts[:, 1].min(), pts[:, 1].max()], np.float32)


def net_hpwl_fixed_from_xy(xy: jax.Array, net_mask: jax.Array,
                           net_fix: jax.Array) -> jax.Array:
    """Per-net HPWL with per-net fixed boxes folded in.
    xy: (N, D, 2); net_mask: (N, D) bool; net_fix: (N, 4).  Returns (N,).
    A net is scored when it has movable pins or a non-empty box."""
    x, y = xy[..., 0], xy[..., 1]
    xmin = jnp.minimum(jnp.min(jnp.where(net_mask, x, _BIG), axis=-1),
                       net_fix[..., 0])
    xmax = jnp.maximum(jnp.max(jnp.where(net_mask, x, -_BIG), axis=-1),
                       net_fix[..., 1])
    ymin = jnp.minimum(jnp.min(jnp.where(net_mask, y, _BIG), axis=-1),
                       net_fix[..., 2])
    ymax = jnp.maximum(jnp.max(jnp.where(net_mask, y, -_BIG), axis=-1),
                       net_fix[..., 3])
    valid = (jnp.any(net_mask, axis=-1)
             | (net_fix[..., 0] <= net_fix[..., 1]))
    return jnp.where(valid, (xmax - xmin) + (ymax - ymin), 0.0)


def net_hpwl_fixed(pos: jax.Array, net_pins: jax.Array, net_mask: jax.Array,
                   net_fix: jax.Array) -> jax.Array:
    """Per-net HPWL under fixed boxes.  Same contract as :func:`net_hpwl`
    plus ``net_fix`` (N, 4)."""
    return net_hpwl_fixed_from_xy(pos[net_pins], net_mask, net_fix)


@jax.jit
def hpwl_fixed(pos: jax.Array, net_pins: jax.Array, net_mask: jax.Array,
               net_fix: jax.Array) -> jax.Array:
    """Total HPWL of one placement with fixed terminals (scalar)."""
    return jnp.sum(net_hpwl_fixed(pos, net_pins, net_mask, net_fix))


def hpwl_delta_fixed(slot_xy: jax.Array, cand_slot_of: jax.Array,
                     net_pins: jax.Array, net_mask: jax.Array,
                     per_net_cost: jax.Array, touched: jax.Array,
                     net_fix: jax.Array):
    """Rescore the ``touched`` nets under fixed boxes — the incremental
    counterpart of :func:`hpwl_delta`, same contract plus ``net_fix``."""
    pins, mask, old = _touched_view(net_pins, net_mask, per_net_cost,
                                    touched)
    n = net_pins.shape[0]
    tc = jnp.minimum(touched, n - 1)
    # pad/duplicate rows are fully masked with old=0; their clamped gather
    # would still pull net n-1's real box, so force those boxes empty too
    fix = jnp.where((touched < n)[:, None], net_fix[tc],
                    jnp.asarray(EMPTY_BOX, net_fix.dtype))
    xy = slot_xy[cand_slot_of[pins]]                  # (T, D, 2)
    new_vals = net_hpwl_fixed_from_xy(xy, mask, fix)
    return new_vals, jnp.sum(new_vals - old)
