"""Pallas TPU kernels: generated fused PEs (the paper technique), flash
attention, selective scan, MXU matmul with PE epilogues.  Validated against
ref.py oracles in interpret mode (this host is CPU-only)."""

from .ops import attention, fused_pe_apply, matmul_fused, selective_scan
from .pe_fused import kernel_from_config, make_pe_kernel

__all__ = ["attention", "fused_pe_apply", "matmul_fused", "selective_scan",
           "kernel_from_config", "make_pe_kernel"]
