"""Jitted public wrappers around the Pallas kernels (padding + dispatch).

``interpret`` defaults to True on CPU hosts (the kernels TARGET TPU; the
interpreter executes the kernel bodies in Python for validation) and False
when a TPU backend is present.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..graphir.graph import Graph
from .flash_attention import flash_attention
from .gemm import gemm_pe
from .mamba_scan import mamba_scan
from .pe_fused import kernel_from_config, make_pe_kernel


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_pe_apply(pattern: Graph, *inputs, block=(256, 256),
                   interpret: Optional[bool] = None):
    """Apply a mined/merged PE pattern elementwise over the inputs."""
    interp = _default_interpret() if interpret is None else interpret
    fn = make_pe_kernel(pattern, block=block, interpret=interp)
    return fn(*inputs)


def attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=0.0,
              bq=128, bk=128, interpret: Optional[bool] = None):
    """Padded flash attention; q (B,Hq,S,D), k/v (B,Hkv,S,D)."""
    interp = _default_interpret() if interpret is None else interpret
    b, hq, s, d = q.shape
    blk = max(min(bq, s), min(bk, s))
    pad = (-s) % blk
    if pad:
        zq = jnp.zeros((b, hq, pad, d), q.dtype)
        zk = jnp.zeros((b, k.shape[1], pad, d), k.dtype)
        q = jnp.concatenate([q, zq], axis=2)
        k = jnp.concatenate([k, zk], axis=2)
        v = jnp.concatenate([v, zk.astype(v.dtype)], axis=2)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, scale=scale, bq=bq, bk=bk,
                          interpret=interp)
    return out[:, :, :s] if pad else out


def selective_scan(a, bx, c, *, bs=128, bd=128,
                   interpret: Optional[bool] = None):
    """Padded chunked mamba scan; a/bx (B,S,D,N), c (B,S,N) -> y (B,S,D)."""
    interp = _default_interpret() if interpret is None else interpret
    b, s, d, n = a.shape
    pad_s = (-s) % min(bs, max(s, 1))
    pad_d = (-d) % min(bd, max(d, 1))
    if pad_s or pad_d:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_d), (0, 0)),
                    constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad_s), (0, pad_d), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_s), (0, 0)))
    y = mamba_scan(a, bx, c, bs=bs, bd=bd, interpret=interp)
    return y[:, :s, :d]


def matmul_fused(x, w, *extras, epilogue=None, extra_kinds=(),
                 bm=128, bn=128, bk=128, out_dtype=None,
                 interpret: Optional[bool] = None):
    """Padded MXU matmul with fused PE epilogue."""
    interp = _default_interpret() if interpret is None else interpret
    m, k = x.shape
    _, n = w.shape
    pm, pk, pn = (-m) % min(bm, m), (-k) % min(bk, k), (-n) % min(bn, n)
    if pm or pk or pn:
        x = jnp.pad(x, ((0, pm), (0, pk)))
        w = jnp.pad(w, ((0, pk), (0, pn)))
        extras = tuple(
            jnp.pad(e, ((0, pn),)) if e.ndim == 1
            else jnp.pad(e, ((0, pm), (0, pn))) for e in extras)
    out = gemm_pe(x, w, *extras, epilogue=epilogue, extra_kinds=extra_kinds,
                  bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                  interpret=interp)
    return out[:m, :n] if (pm or pn) else out
