"""Chunked selective-scan Pallas kernel (Mamba-1 inner recurrence).

Computes  h_t = a_t * h_{t-1} + bx_t ;  y_t = <h_t, c_t> + skip_t
with diagonal a (the discretized state matrix).  The grid is
(B, D/bd, S/bs) with the *sequence axis innermost* — TPU grids execute
sequentially on a core, so the running state h lives in a VMEM scratch that
persists across sequence blocks (initialized at block 0).  HBM traffic is
exactly one read of a/bx/c and one write of y; the (S, D, N) state tensor
that a naive associative scan materializes never exists.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, bx_ref, c_ref, y_ref, h_scr, *, bs: int, bd: int,
                 n: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)       # (bs, bd, N)
    bx = bx_ref[0].astype(jnp.float32)     # (bs, bd, N)
    c = c_ref[0].astype(jnp.float32)       # (bs, N)

    def step(t, carry):
        h, y = carry
        h = a[t] * h + bx[t]               # (bd, N)
        yt = jnp.sum(h * c[t][None, :], axis=-1)   # (bd,)
        y = y.at[t].set(yt)
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros((bs, bd), jnp.float32)
    h, y = lax.fori_loop(0, bs, step, (h0, y0))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)


def mamba_scan(a: jax.Array, bx: jax.Array, c: jax.Array, *,
               bs: int = 128, bd: int = 128,
               interpret: bool = False) -> jax.Array:
    """a, bx: (B, S, D, N); c: (B, S, N).  Returns y (B, S, D) f32 where
    y[b,t,d] = sum_n h[b,t,d,n] * c[b,t,n] under the recurrence above."""
    b, s, d, n = a.shape
    bs = min(bs, s)
    bd = min(bd, d)
    assert s % bs == 0 and d % bd == 0, (s, d, bs, bd)
    grid = (b, d // bd, s // bs)
    kernel = functools.partial(_scan_kernel, bs=bs, bd=bd, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd, n), lambda b_, di, si: (b_, si, di, 0)),
            pl.BlockSpec((1, bs, bd, n), lambda b_, di, si: (b_, si, di, 0)),
            pl.BlockSpec((1, bs, n), lambda b_, di, si: (b_, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd), lambda b_, di, si: (b_, si, di)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(a, bx, c)
