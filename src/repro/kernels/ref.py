"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graphir.graph import Graph, free_in_ports, sink_nodes
from ..graphir.interp import interpret_pattern


def ref_pe(pattern: Graph, *inputs) -> Tuple:
    """Oracle for pe_fused.make_pe_kernel: numpy graph interpretation."""
    free = free_in_ports(pattern)
    port_values = {fp: np.asarray(x, dtype=np.float64)
                   for fp, x in zip(free, inputs)}
    vals = interpret_pattern(pattern, port_values)
    outs = tuple(vals[s] for s in sink_nodes(pattern))
    return outs if len(outs) > 1 else outs[0]


def ref_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                  scale=0.0) -> jax.Array:
    """Oracle for flash_attention: direct softmax over the full score
    matrix.  q (B,Hq,S,D); k/v (B,Hkv,S,D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale or 1.0 / math.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    sarr = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale
    if softcap:
        sarr = softcap * jnp.tanh(sarr / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    sarr = jnp.where(mask[None, None], sarr, -1e30)
    p = jax.nn.softmax(sarr, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ref_mamba_scan(a, bx, c) -> jax.Array:
    """Oracle for mamba_scan: plain sequential recurrence in f64-ish f32."""
    b, s, d, n = a.shape
    h = jnp.zeros((b, d, n), jnp.float32)
    ys = []
    for t in range(s):
        h = a[:, t].astype(jnp.float32) * h + bx[:, t].astype(jnp.float32)
        ys.append(jnp.sum(h * c[:, t].astype(jnp.float32)[:, None, :],
                          axis=-1))
    return jnp.stack(ys, axis=1)              # (B, S, D)


def ref_gemm_pe(x, w, *extras, epilogue=None, extra_kinds=(),
                out_dtype=None) -> jax.Array:
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if epilogue is not None:
        free = free_in_ports(epilogue)
        port_values = {free[0]: np.asarray(acc, np.float64)}
        for fp, e, kind in zip(free[1:], extras, extra_kinds):
            v = np.asarray(e, np.float64)
            if kind == "vec":
                v = v[None, :]
            port_values[fp] = v
        vals = interpret_pattern(epilogue, port_values)
        acc = jnp.asarray(vals[sink_nodes(epilogue)[0]])
    return acc.astype(out_dtype or x.dtype)
