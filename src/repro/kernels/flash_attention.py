"""Flash attention Pallas kernel (GQA, causal, sliding window, softcap).

Blockwise online-softmax attention with q-tiles resident in VMEM — the
HBM-traffic-optimal loop order (contrast with the XLA kv-chunk scan in
models/layers.py, whose full-sequence accumulator round-trips HBM every
chunk; see EXPERIMENTS.md §Perf).

Layouts: q (B, Hq, S, D); k/v (B, Hkv, S, D); grid (B, Hq, S/bq); the kv
block index map folds the GQA group (h -> h // group).  The kv loop runs
over ``ceil(S/bk)`` blocks with causal/window masking via iota comparisons;
fully-masked trailing blocks are skipped by bounding the fori upper limit
with the causal horizon of the q-tile.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                 seq: int, scale: float, causal: bool, window: int,
                 softcap: float):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    q_pos = qi * bq + lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_kv = seq // bk
    if causal:
        # highest kv block any row of this q-tile may attend to
        hi = jnp.minimum(((qi + 1) * bq - 1) // bk + 1, n_kv)
    else:
        hi = n_kv

    def body(j, carry):
        acc, m, l = carry
        # scalar leading indices must be pl.ds slices (bare Python ints are
        # rejected by pl.load's NDIndexer on this JAX version)
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(0, 1), pl.ds(j * bk, bk),
                            slice(None)))[0, 0].astype(jnp.float32)  # (bk, D)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(0, 1), pl.ds(j * bk, bk),
                            slice(None)))[0, 0].astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bk + lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    d = q_ref.shape[-1]
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    lo = 0
    if causal and window:
        lo = jnp.maximum(0, (qi * bq - window) // bk)
    acc, m, l = lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float = 0.0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0.

    Returns (B, Hq, S, D) in q.dtype.  S must be a multiple of max(bq, bk)
    (ops.py pads).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale or (1.0 / math.sqrt(d))
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)

    grid = (b, hq, s // bq)
    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, seq=s, scale=scale, causal=causal,
        window=window, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, s, d),
                         lambda b_, h, i, group=group: (b_, h // group, 0, 0)),
            pl.BlockSpec((1, 1, s, d),
                         lambda b_, h, i, group=group: (b_, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
