"""Distribution layer: sharding rules, gradient compression, pipelining."""

from .specs import (activation_shard_fn, batch_axes, batch_pspecs,
                    cache_pspecs, param_pspecs, to_named)

__all__ = ["activation_shard_fn", "batch_axes", "batch_pspecs",
           "cache_pspecs", "param_pspecs", "to_named"]
