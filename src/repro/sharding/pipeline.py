"""GPipe-style pipeline parallelism over the ``pod`` axis (optional).

The default multi-pod config treats ``pod`` as an extra DP axis; this module
provides the alternative: layers are partitioned into ``n_stages``
contiguous stages (stage s owns layers [s*L/S, (s+1)*L/S)), microbatches
stream through the stages with ``lax.ppermute`` handing activations across
pods, in the classic GPipe schedule (bubble fraction (S-1)/(M+S-1)).

Implemented with ``shard_map`` so it composes with the in-stage TP sharding;
``jax.grad`` differentiates straight through (ppermute is differentiable),
giving 1F1B-equivalent memory behavior under remat.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn: Callable[[Any, jax.Array], jax.Array],
          mesh: Mesh, axis: str = "pod"):
    """Build pipeline_apply(stage_params, x_micro) -> y_micro.

    stage_params: pytree whose leaves have a leading ``n_stages`` dim,
    sharded over `axis` (each pod holds its stage's slice).
    x_micro: (n_micro, mb, ...) microbatched inputs (replicated over `axis`).
    Returns (n_micro, mb, ...) outputs of the LAST stage (replicated).
    """
    n_stages = mesh.shape[axis]

    def _inner(params_local, x):
        # params_local: leaves (1, ...) — this pod's stage
        params_here = jax.tree.map(lambda a: a[0], params_local)
        stage_id = lax.axis_index(axis)
        n_micro = x.shape[0]
        mb_shape = x.shape[1:]
        n_ticks = n_micro + n_stages - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf_in, outputs = carry
            # stage 0 injects microbatch t (when valid)
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            x_t = x[inject]
            my_in = jnp.where(stage_id == 0, x_t, buf_in)
            y = stage_fn(params_here, my_in)
            # last stage records its result at slot t - (n_stages - 1)
            slot = t - (n_stages - 1)
            valid = (slot >= 0) & (stage_id == n_stages - 1)
            write = jnp.where(slot >= 0, slot, 0)
            outputs = lax.cond(
                valid,
                lambda o: o.at[write].set(y),
                lambda o: o,
                outputs)
            nxt = lax.ppermute(y, axis, fwd_perm)
            return (nxt, outputs), None

        buf0 = jnp.zeros(mb_shape, x.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, x.dtype)
        (_, outputs), _ = lax.scan(tick, (buf0, outs0),
                                   jnp.arange(n_ticks))
        # broadcast the last stage's outputs to every pod (masked psum —
        # ppermute can't fan out one source to all destinations)
        outputs = jnp.where(stage_id == n_stages - 1, outputs,
                            jnp.zeros_like(outputs))
        outputs = lax.psum(outputs, axis)
        return outputs

    other_axes = [a for a in mesh.axis_names if a != axis]

    def apply(stage_params, x_micro):
        p_spec = jax.tree.map(lambda _: P(axis), stage_params)
        return shard_map(
            _inner, mesh=mesh,
            in_specs=(p_spec, P()),
            out_specs=P(),
            check_rep=False,
        )(stage_params, x_micro)

    return apply


def stage_split(tree: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    def split(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])
    return jax.tree.map(split, tree)
