"""Sharding rules: parameter / batch / cache PartitionSpecs.

Mesh axes (see launch/mesh.py): single-pod ``("data", "model")`` = (16, 16);
multi-pod ``("pod", "data", "model")`` = (2, 16, 16).  ``pod`` acts as an
extra data-parallel axis by default (PP over pod is the optional
sharding/pipeline.py strategy).

Policy (Megatron-style TP16 x DP16(x2)):
* attention qkv/out projections and MLP in/out: column/row-sharded over
  ``model`` — dims are guarded for divisibility by 16; non-divisible dims
  (e.g. hymba's 32001 vocab) stay replicated;
* MoE expert stacks: expert dim over ``model`` (expert parallelism);
* SSM: d_inner over ``model``;
* embeddings: vocab over ``model``; lm_head column-sharded;
* batch dims over ``(pod,) data``;
* decode KV caches: batch over data; kv-heads over ``model`` when divisible,
  otherwise the cache *sequence* dim goes over ``model`` (attention then
  psum-reduces over sequence shards);
* long-context (batch=1): cache sequence over data (+model if kv heads
  don't shard) — context parallelism.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.config import ArchConfig
from ..models.transformer import param_shapes

MODEL_AXIS = "model"


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def _div(n: int, by: int) -> bool:
    return n % by == 0


def _model_if(n: int, axis_size: int = 16) -> Optional[str]:
    return MODEL_AXIS if _div(n, axis_size) else None


# per-key rules applied to the trailing dims (leading stacked dims -> None)
def _rule(key: str, shape: Tuple[int, ...], cfg: ArchConfig,
          axis_size: int) -> Tuple[Optional[Any], ...]:
    nd = len(shape)
    m = lambda n: _model_if(n, axis_size)
    if key == "embed":
        return (m(shape[0]), None)
    if key == "lm_head":
        return (None, m(shape[1]))
    if key == "final_norm":
        return (None,)
    if key in ("wq", "wk", "wv"):
        return (None, m(shape[-1]))
    if key == "wo":
        return (m(shape[-2]), None)
    if key in ("wg", "wu"):
        if nd == 3:                      # (E, D, Fe): expert parallel
            return (m(shape[0]), None, None)
        return (None, m(shape[-1]))
    if key == "wd":
        if nd == 3:
            return (m(shape[0]), None, None)
        return (m(shape[-2]), None)
    if key == "wi" or key in ("sg", "su"):
        return (None, m(shape[-1]))
    if key in ("wom", "sd"):
        return (m(shape[-2]), None)
    if key == "w_router":
        return (None, None)
    if key.startswith("ssm_"):
        sub = key[len("ssm_"):]
        if sub == "in_proj":
            return (None, m(shape[-1]))
        if sub == "conv_w":
            return (None, m(shape[-1]))
        if sub in ("conv_b", "dt_bias", "D"):
            return (m(shape[-1]),)
        if sub in ("x_proj", "A_log", "out_proj"):
            return (m(shape[-2]), None)
        if sub == "dt_proj":
            return (None, m(shape[-1]))
    # norms, gates, anything else: replicated
    return tuple(None for _ in range(nd))


def param_pspecs(cfg: ArchConfig, *, axis_size: int = 16) -> Any:
    """PartitionSpec pytree mirroring param_shapes(cfg)."""
    shapes = param_shapes(cfg)

    def walk(tree, stacked: int):
        out = {}
        for key, val in tree.items():
            if isinstance(val, dict):
                out[key] = walk(val, stacked)
            else:
                shape = val.shape
                trailing = _rule(key, shape[stacked:], cfg, axis_size)
                out[key] = P(*((None,) * stacked + tuple(trailing)))
        return out

    specs: Dict[str, Any] = {}
    for key, val in shapes.items():
        if key in ("layers", "cross_layers"):
            specs[key] = walk(val, stacked=1)
        elif isinstance(val, dict):
            specs[key] = walk(val, stacked=0)
        else:
            specs[key] = P(*_rule(key, val.shape, cfg, axis_size))
    return specs


def batch_pspecs(cfg: ArchConfig, *, multi_pod: bool, batch: int) -> Any:
    bp = batch_axes(multi_pod)
    bsize = 16 * (2 if multi_pod else 1)
    baxis = bp if _div(batch, bsize) else (bp[-1] if _div(batch, 16) else None)
    specs = {"inputs": P(baxis, None, None) if cfg.input_mode == "embeddings"
             else P(baxis, None),
             "targets": P(baxis, None)}
    if cfg.n_cross_layers:
        specs["enc"] = P(baxis, None, None)
    return specs


def cache_pspecs(cfg: ArchConfig, *, multi_pod: bool, batch: int,
                 axis_size: int = 16) -> Dict[str, Any]:
    bp = batch_axes(multi_pod)
    dp_size = 16 * (2 if multi_pod else 1)
    if _div(batch, dp_size):
        baxis: Any = bp
    elif _div(batch, 16):
        baxis = bp[-1]
    else:
        baxis = None
    kv_sharded = cfg.n_kv and _div(cfg.n_kv, axis_size)
    specs: Dict[str, Any] = {"len": P()}
    if cfg.mixer in ("attn", "hymba"):
        if baxis is not None:
            seq_ax = None if kv_sharded else MODEL_AXIS
            head_ax = MODEL_AXIS if kv_sharded else None
            specs["k"] = P(None, baxis, seq_ax, head_ax, None)
        else:
            # long-context, batch 1: context parallelism over data(+pod)
            head_ax = MODEL_AXIS if kv_sharded else None
            specs["k"] = P(None, None, bp, head_ax, None)
        specs["v"] = specs["k"]
    if cfg.mixer in ("mamba", "hymba"):
        di = cfg.ssm.expand * cfg.d_model
        di_ax = _model_if(di, axis_size)
        specs["ssm_conv"] = P(None, baxis, None, di_ax)
        specs["ssm_h"] = P(None, baxis, di_ax, None)
    if cfg.n_cross_layers:
        head_ax = MODEL_AXIS if kv_sharded else None
        specs["cross_k"] = P(None, baxis, None, head_ax, None)
        specs["cross_v"] = specs["cross_k"]
    return specs


def activation_shard_fn(mesh: Mesh, cfg: ArchConfig, *, multi_pod: bool):
    """The `shard` callback threaded through the model code."""
    bp = batch_axes(multi_pod)
    vocab_ax = _model_if(cfg.vocab)
    from ..models.perf_flags import get_flags
    seq_ax = MODEL_AXIS if get_flags().seq_shard else None
    table = {
        "hidden": P(bp, seq_ax, None),
        "logits": P(bp, None, vocab_ax),
        # MoE buffers (B, E, C, d|f).  The scatter-built dispatch buffer
        # stays expert-REPLICATED across `model` (dispatch combinatorics are
        # cheap and redundant per model-rank; scattering into an E-sharded
        # buffer makes GSPMD all-reduce the whole global buffer).  Only the
        # expert-einsum intermediates are E-sharded (weights-stationary EP).
        "moe_buf": P(bp, None, None, None),
        "moe_h": P(bp, MODEL_AXIS, None, None),
    }

    def shard(x, name):
        spec = table.get(name)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return shard


def to_named(mesh: Mesh, tree: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda s: isinstance(s, P))
