"""Gradient compression: int8-quantized data-parallel all-reduce.

At 1000+ nodes the gradient all-reduce over the DP axes dominates step time
for small models.  This module provides a ``grad_transform`` hook (see
train/steps.py) that swaps the implicit f32 all-reduce for an explicit
``shard_map`` int8 ring reduction with per-block scales and an error-
feedback buffer (residual carried between steps keeps convergence).

Traffic: 4 bytes -> 1 byte + 1/256 scale overhead  (~3.9x less DP traffic).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

BLOCK = 256


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape, n: int) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale
    return blocks.reshape(-1)[:n].reshape(shape)


def compressed_psum(x: jax.Array, axis_names: Tuple[str, ...]) -> jax.Array:
    """Shared-scale int8 mean-all-reduce (runs inside shard_map).

    Phase 1: psum(local block maxima) -> shared per-block scale (tiny);
    Phase 2: quantize with the shared scale, psum in int32, dequantize.
    """
    n_dev = 1
    for a in axis_names:
        n_dev *= jax.lax.axis_size(a)
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    local_max = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    shared_max = jax.lax.pmax(local_max, axis_names)
    scale = jnp.maximum(shared_max / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
    mean = qsum.astype(jnp.float32) * scale / n_dev
    return mean.reshape(-1)[:n].reshape(x.shape)


def make_compressed_grad_transform(mesh: Mesh, dp_axes: Tuple[str, ...],
                                   param_specs: Any):
    """Returns grads->grads applying int8 all-reduce over the DP axes.

    The gradients arriving here are the *local* (per-DP-shard) averages that
    XLA would otherwise all-reduce in f32; we mark them unreduced by running
    the reduction explicitly under shard_map.  Error feedback: quantization
    residual is returned for the caller to carry (optional simple mode drops
    it; the trainer example carries it).
    """
    from jax.experimental.shard_map import shard_map

    def transform(grads):
        def leaf_allreduce(g, spec):
            in_spec = spec if isinstance(spec, P) else P()

            def body(gl):
                return compressed_psum(gl, dp_axes)

            return shard_map(
                body, mesh=mesh,
                in_specs=(in_spec,), out_specs=in_spec,
                check_rep=False)(g)

        return jax.tree.map(
            lambda g, s: leaf_allreduce(g, s), grads, param_specs,
            is_leaf=lambda x: isinstance(x, jax.Array))

    return transform
