"""Fabric architecture spec: N x M PE tile grid, mesh interconnect, IO ring.

The array model follows the paper's Fig. 7 layout and the Garnet-class CGRAs
it targets: an ``rows x cols`` grid of PE tiles connected by a bidirectional
mesh (``channel_width`` tracks per direction per channel), surrounded by a
perimeter ring of I/O tiles (one per non-corner boundary position) that
stream application inputs/outputs and host the memory interfaces.

Coordinates are ``(x, y)`` with PE tiles at ``0 <= x < cols`` and
``0 <= y < rows``.  I/O sites sit just outside the grid: ``(x, -1)`` (north),
``(x, rows)`` (south), ``(-1, y)`` (west) and ``(cols, y)`` (east).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Tuple

Coord = Tuple[int, int]
Edge = Tuple[Coord, Coord]     # directed (src tile, dst tile)


@dataclass(frozen=True)
class FabricSpec:
    rows: int = 8
    cols: int = 8
    channel_width: int = 4       # tracks per direction per mesh channel
    io_capacity: int = 4         # distinct signals one I/O tile can stream
    hop_energy_pj: float = 0.035  # per word per switch-to-switch hop (16 nm)
    hop_delay_ns: float = 0.055   # wire + switch delay per hop
    latch_depth: int = 4         # per-input iteration FIFO depth: an operand
    # word survives latch_depth initiation intervals before the stream
    # overwrites it, so consumer fire times may lag producer arrivals by up
    # to latch_depth x II (Garnet-style input FIFOs; bounds operand skew)

    def __post_init__(self) -> None:
        if self.rows < 2 or self.cols < 2:
            raise ValueError("fabric must be at least 2x2")
        if self.channel_width < 1 or self.io_capacity < 1:
            raise ValueError("channel_width and io_capacity must be >= 1")
        if self.latch_depth < 1:
            raise ValueError("latch_depth must be >= 1")

    # -- tiles -------------------------------------------------------------
    @property
    def n_pe_tiles(self) -> int:
        return self.rows * self.cols

    @property
    def n_io_sites(self) -> int:
        return 2 * self.rows + 2 * self.cols

    def pe_tiles(self) -> List[Coord]:
        return [(x, y) for y in range(self.rows) for x in range(self.cols)]

    def io_sites(self) -> List[Coord]:
        north = [(x, -1) for x in range(self.cols)]
        south = [(x, self.rows) for x in range(self.cols)]
        west = [(-1, y) for y in range(self.rows)]
        east = [(self.cols, y) for y in range(self.rows)]
        return north + south + west + east

    def is_pe(self, t: Coord) -> bool:
        return 0 <= t[0] < self.cols and 0 <= t[1] < self.rows

    def is_io(self, t: Coord) -> bool:
        x, y = t
        if y in (-1, self.rows):
            return 0 <= x < self.cols
        if x in (-1, self.cols):
            return 0 <= y < self.rows
        return False

    # -- routing graph -----------------------------------------------------
    def neighbors(self, t: Coord) -> List[Coord]:
        """Adjacent routable tiles (mesh for PEs; single port for IO)."""
        x, y = t
        if self.is_io(t):
            inward = (min(max(x, 0), self.cols - 1),
                      min(max(y, 0), self.rows - 1))
            return [inward]
        out = []
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            n = (x + dx, y + dy)
            if self.is_pe(n) or self.is_io(n):
                out.append(n)
        return out

    def edge_capacity(self, a: Coord, b: Coord) -> int:
        """Track count of directed channel a -> b."""
        if self.is_io(a) or self.is_io(b):
            return self.io_capacity
        return self.channel_width

    def routing_edges(self) -> Dict[Edge, int]:
        """All directed channels with capacities."""
        caps: Dict[Edge, int] = {}
        for t in self.pe_tiles() + self.io_sites():
            for n in self.neighbors(t):
                caps[(t, n)] = self.edge_capacity(t, n)
                caps[(n, t)] = self.edge_capacity(n, t)
        return caps

    # -- sizing ------------------------------------------------------------
    def fit(self, n_pe_cells: int, n_io_cells: int = 0) -> "FabricSpec":
        """Smallest square-ish spec (same channel/IO params) that fits the
        given cell counts; returns self when already large enough."""
        rows, cols = self.rows, self.cols
        while rows * cols < n_pe_cells or 2 * (rows + cols) < n_io_cells:
            if cols <= rows:
                cols += 1
            else:
                rows += 1
        if (rows, cols) == (self.rows, self.cols):
            return self
        return FabricSpec(rows=rows, cols=cols,
                          channel_width=self.channel_width,
                          io_capacity=self.io_capacity,
                          hop_energy_pj=self.hop_energy_pj,
                          hop_delay_ns=self.hop_delay_ns,
                          latch_depth=self.latch_depth)

    def summary(self) -> str:
        return (f"Fabric[{self.cols}x{self.rows} PEs | "
                f"{self.n_io_sites} IO | W={self.channel_width}]")


def manhattan(a: Coord, b: Coord) -> int:
    return abs(a[0] - b[0]) + abs(a[1] - b[1])
