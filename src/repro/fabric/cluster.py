"""Connectivity-aware netlist partitioning for hierarchical placement.

The two-level placer (:func:`repro.fabric.place.place_hierarchical`)
needs the PE cells of a mega-fabric netlist divided into clusters that
(a) each fit one region of the cluster grid and (b) keep tightly
connected cells together, so most nets become cluster-internal and the
cheap cluster-local anneals capture most of the wirelength.  This module
provides the cgra_pnr-style front half of that recipe: a greedy seeded
growth pass followed by a Kernighan–Lin-flavoured boundary refinement.

Algorithm (deterministic — no RNG, ties break on cell index):

1. **Clique-model weights.** Every net contributes ``1 / (pins - 1)``
   to each pair of its PE pins, the standard clique approximation of
   multi-pin nets.
2. **Seeded growth.** ``n_clusters`` seeds are spread evenly over the
   cell index range; clusters then take turns (round-robin, so sizes
   stay balanced) absorbing the unassigned cell with the highest total
   weight into the cluster (a lazy max-heap per cluster).  A cluster at
   its ``cap`` stops; a cluster with an empty frontier takes the
   lowest-index unassigned cell so every cell lands somewhere.
3. **Boundary refinement.** A few passes over all cells in index order:
   a cell moves to the neighbouring cluster it is more strongly
   connected to, if that cluster has room — the KL move step without
   the paired swap (caps make pairing unnecessary).

Every cell lands in exactly one cluster and no cluster exceeds ``cap``,
by construction — property-tested in ``tests/test_hier_place.py``.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .netlist import Netlist

__all__ = ["Clustering", "partition"]


@dataclass
class Clustering:
    """A partition of a netlist's PE cells into capacity-bounded clusters.

    ``clusters[k]`` lists cell names in instance order;  ``cluster_of``
    is the inverse map.  ``cut_nets`` counts nets whose PE pins span more
    than one cluster (the coarse-level objective), ``internal_nets``
    those fully inside one.
    """

    n_clusters: int
    cap: int
    cluster_of: Dict[str, int] = field(default_factory=dict)
    clusters: List[List[str]] = field(default_factory=list)
    cut_nets: int = 0
    internal_nets: int = 0

    def summary(self) -> str:
        sizes = [len(c) for c in self.clusters]
        return (f"Clustering[{self.n_clusters} clusters cap={self.cap} "
                f"sizes={min(sizes)}..{max(sizes)} "
                f"cut={self.cut_nets}/{self.cut_nets + self.internal_nets}]")


def _pe_adjacency(netlist: Netlist, index_of: Dict[str, int]
                  ) -> List[Dict[int, float]]:
    """Clique-model weighted adjacency over PE cells (IO pins dropped)."""
    adj: List[Dict[int, float]] = [{} for _ in index_of]
    for net in netlist.nets:
        pins = sorted({index_of[c] for c in [net.driver] + net.sinks
                       if c in index_of})
        if len(pins) < 2:
            continue
        w = 1.0 / (len(pins) - 1)
        for i, a in enumerate(pins):
            for b in pins[i + 1:]:
                adj[a][b] = adj[a].get(b, 0.0) + w
                adj[b][a] = adj[b].get(a, 0.0) + w
    return adj


def partition(netlist: Netlist, n_clusters: int, cap: int, *,
              refine_passes: int = 2) -> Clustering:
    """Partition the netlist's PE cells into ``n_clusters`` clusters of at
    most ``cap`` cells each.  Deterministic; raises when the cells cannot
    fit (``n_cells > n_clusters * cap``)."""
    cells = sorted(netlist.pe_cells, key=lambda c: c.instance)
    names = [c.name for c in cells]
    n = len(names)
    if n_clusters < 1:
        raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
    if n > n_clusters * cap:
        raise ValueError(f"{n} PE cells cannot fit {n_clusters} clusters "
                         f"of cap {cap}")
    index_of = {name: i for i, name in enumerate(names)}
    adj = _pe_adjacency(netlist, index_of)

    assign = [-1] * n
    sizes = [0] * n_clusters
    # per-cluster lazy max-heap of (-gain, cell); gain[] holds the current
    # connectivity of each unassigned cell to each cluster
    heaps: List[List[Tuple[float, int]]] = [[] for _ in range(n_clusters)]
    gain = [[0.0] * n_clusters for _ in range(n)] if n else []

    def absorb(k: int, cell: int) -> None:
        assign[cell] = k
        sizes[k] += 1
        for nb, w in adj[cell].items():
            if assign[nb] == -1:
                gain[nb][k] += w
                heapq.heappush(heaps[k], (-gain[nb][k], nb))

    # seeds spread evenly over the instance order (with locality-structured
    # netlists, instance order correlates with position)
    taken = set()
    for k in range(min(n_clusters, n)):
        s = (k * n) // n_clusters
        while s in taken:
            s = (s + 1) % n
        taken.add(s)
        absorb(k, s)

    unassigned = n - len(taken)
    next_free = 0                      # lowest maybe-unassigned index
    while unassigned:
        progressed = False
        for k in range(n_clusters):
            if not unassigned or sizes[k] >= cap:
                continue
            cell = -1
            while heaps[k]:
                neg, c = heapq.heappop(heaps[k])
                if assign[c] == -1 and -neg == gain[c][k]:
                    cell = c
                    break
            if cell == -1:             # empty frontier: take lowest index
                while next_free < n and assign[next_free] != -1:
                    next_free += 1
                if next_free >= n:
                    continue
                cell = next_free
            absorb(k, cell)
            unassigned -= 1
            progressed = True
        if not progressed:             # all non-full clusters starved
            raise AssertionError("partition growth stalled")  # unreachable

    # -- KL-style boundary refinement -----------------------------------
    for _ in range(max(0, refine_passes)):
        moved = 0
        for cell in range(n):
            src = assign[cell]
            if sizes[src] <= 1:
                continue
            pull: Dict[int, float] = {}
            for nb, w in adj[cell].items():
                pull[assign[nb]] = pull.get(assign[nb], 0.0) + w
            here = pull.get(src, 0.0)
            best_k, best_w = src, here
            for k in sorted(pull):
                if k != src and sizes[k] < cap and pull[k] > best_w:
                    best_k, best_w = k, pull[k]
            if best_k != src:
                sizes[src] -= 1
                sizes[best_k] += 1
                assign[cell] = best_k
                moved += 1
        if not moved:
            break

    clusters: List[List[str]] = [[] for _ in range(n_clusters)]
    cluster_of: Dict[str, int] = {}
    for i, name in enumerate(names):   # instance order within each cluster
        clusters[assign[i]].append(name)
        cluster_of[name] = assign[i]

    cut = internal = 0
    for net in netlist.nets:
        ks = {cluster_of[c] for c in [net.driver] + net.sinks
              if c in cluster_of}
        if len(ks) > 1:
            cut += 1
        elif ks:
            internal += 1
    return Clustering(n_clusters=n_clusters, cap=cap, cluster_of=cluster_of,
                      clusters=clusters, cut_nets=cut,
                      internal_nets=internal)
