"""Mapping -> fabric netlist extraction (the exemplar's "packing" step).

A :class:`~repro.core.mapper.Mapping` places every covered application node
inside some PE instance; the fabric netlist is the inter-tile view of that
cover:

* one **PE cell** per mapped instance;
* **I/O cells** for signals that enter/leave the array — application graph
  inputs, graph outputs, and values exchanged with offloaded tensor macros.
  Up to ``io_capacity`` distinct signals share one I/O cell (a streaming
  memory-interface tile serves several operands);
* one **net** per produced signal, from its driver cell to every cell that
  consumes it externally.

Constants are folded: ``const`` nodes live in configured constant registers
inside the consuming PE (paper Fig. 2c), so they generate neither cells nor
nets.  Values produced and consumed inside the same instance stay inside the
tile and also generate no nets.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from ..core.mapper import Mapping
from ..graphir.graph import Graph
from .arch import FabricSpec


@dataclass
class Cell:
    name: str
    kind: str                    # "pe" | "io_in" | "io_out"
    instance: int = -1           # index into mapping.instances for PE cells
    signals: List[int] = field(default_factory=list)   # app nodes on IO cells


@dataclass
class Net:
    name: str
    driver: str                  # cell name
    sinks: List[str]             # cell names (deduped, sorted)
    signal: int = -1             # producing app node

    @property
    def degree(self) -> int:
        return 1 + len(self.sinks)


@dataclass
class Netlist:
    app_name: str
    cells: Dict[str, Cell] = field(default_factory=dict)
    nets: List[Net] = field(default_factory=list)

    @property
    def pe_cells(self) -> List[Cell]:
        return [c for c in self.cells.values() if c.kind == "pe"]

    @property
    def io_cells(self) -> List[Cell]:
        return [c for c in self.cells.values() if c.kind != "pe"]

    def summary(self) -> str:
        return (f"Netlist[{self.app_name}: {len(self.pe_cells)} PEs, "
                f"{len(self.io_cells)} IOs, {len(self.nets)} nets]")


def synthetic_netlist(spec: FabricSpec, *, fill: float = 0.85,
                      seed: int = 0, max_fanout: int = 3,
                      io_frac: float = 0.25,
                      locality: Optional[int] = None) -> Netlist:
    """Random netlist sized to a fabric — the placer-scaling workload.

    Fills ``fill`` of the PE tiles with cells; each PE drives one net to
    1..max_fanout random PE sinks (one produced signal per cell, like the
    extractor emits), ``io_frac`` of the perimeter sites split between
    input streams (each feeding a few PEs) and output taps (extra sinks on
    existing PE nets).  Deterministic in ``seed``; no application needed,
    so it scales to any ``rows x cols``.

    ``locality`` (a window radius in tiles) biases each PE's sinks to
    cells whose *home tile* — cell ``i`` homes at ``(i % cols,
    i // cols)`` — lies within a Chebyshev window of the driver's.  Real
    mapped dataflow graphs are local (producers feed nearby consumers),
    and the hierarchical placer's clustering only pays off on such
    structure; uniformly random netlists have no clusters to find.  The
    default (``None``) keeps the original fully random draw, bit-identical
    to what this function produced before ``locality`` existed.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    n_pe = max(2, int(spec.n_pe_tiles * fill))
    n_io = min(spec.n_io_sites, max(2, int(spec.n_io_sites * io_frac)))
    n_in = max(1, n_io // 2)
    n_out = max(1, n_io - n_in)

    nl = Netlist(f"synthetic_{spec.cols}x{spec.rows}_s{seed}")
    for i in range(n_pe):
        nl.cells[f"pe{i}"] = Cell(f"pe{i}", "pe", instance=i)
    for j in range(n_in):
        nl.cells[f"in{j}"] = Cell(f"in{j}", "io_in", signals=[j])
    for j in range(n_out):
        nl.cells[f"out{j}"] = Cell(f"out{j}", "io_out", signals=[n_in + j])

    sinks_of: Dict[int, Set[str]] = {}
    for i in range(n_pe):
        k = int(rng.integers(1, max_fanout + 1))
        if locality:
            hx, hy = i % spec.cols, i // spec.cols
            ys = np.arange(max(0, hy - locality),
                           min(spec.rows, hy + locality + 1))
            xs = np.arange(max(0, hx - locality),
                           min(spec.cols, hx + locality + 1))
            window = (ys[:, None] * spec.cols + xs[None, :]).ravel()
            window = window[(window < n_pe) & (window != i)]
            cand = rng.choice(window, size=min(k, len(window)),
                              replace=False)
            sinks = [f"pe{c}" for c in cand]
        else:
            # draw one spare so dropping the driver still leaves k sinks
            cand = rng.choice(n_pe, size=min(k + 1, n_pe), replace=False)
            sinks = [f"pe{c}" for c in cand if c != i][:k]
        sinks_of[i] = set(sinks) or {f"pe{(i + 1) % n_pe}"}
    for j in range(n_out):                 # output taps on random PE nets
        sinks_of[int(rng.integers(0, n_pe))].add(f"out{j}")
    for i in range(n_pe):
        nl.nets.append(Net(f"n{i:05d}", f"pe{i}",
                           sorted(sinks_of[i]), signal=i))
    for j in range(n_in):                  # input streams into random PEs
        k = int(rng.integers(1, max_fanout + 1))
        cand = rng.choice(n_pe, size=min(k, n_pe), replace=False)
        nl.nets.append(Net(f"n_in{j:05d}", f"in{j}",
                           sorted({f"pe{c}" for c in cand}),
                           signal=n_pe + j))
    nl.nets.sort(key=lambda n: n.name)
    return nl


def extract_netlist(mapping: Mapping, app: Graph,
                    spec: Optional[FabricSpec] = None,
                    *, io_group: Optional[int] = None) -> Netlist:
    """Build the inter-tile netlist for `mapping` of `app`.

    io_group: distinct signals per I/O cell (defaults to spec.io_capacity,
    else 4).
    """
    if io_group is None:
        io_group = spec.io_capacity if spec is not None else 4
    nl = Netlist(mapping.app_name)

    # PE cells + home map (covered app node -> owning cell)
    home: Dict[int, str] = {}
    for i, inst in enumerate(mapping.instances):
        cell = Cell(f"pe{i}", "pe", instance=i)
        nl.cells[cell.name] = cell
        for n in inst.covered:
            home[n] = cell.name

    off_array = set(mapping.offloaded)

    # signal -> external consumer cells
    consumers: Dict[int, Set[str]] = {}
    for i, inst in enumerate(mapping.instances):
        cname = f"pe{i}"
        for n in inst.covered:
            for port, src in app.in_edges(n).items():
                if src in inst.covered or app.nodes.get(src) == "const":
                    continue        # intra-tile wire / folded constant
                consumers.setdefault(src, set()).add(cname)

    # signals that leave the array: graph outputs, feeds into offloaded
    # macros or explicit output nodes
    leaves: Set[int] = set()
    for n in home:
        if n in app.outputs:
            leaves.add(n)
        for dst, _ in app.out_edges(n):
            op = app.nodes[dst]
            if dst in off_array or op == "output":
                leaves.add(n)

    # off-array producers consumed by PEs: graph inputs, offloaded macros,
    # and (defensively) unmapped compute nodes
    ext_inputs = sorted(s for s in consumers if s not in home)

    def _alloc_io(signals: List[int], kind: str, prefix: str) -> Dict[int, str]:
        where: Dict[int, str] = {}
        for gi in range(0, len(signals), io_group):
            group = signals[gi:gi + io_group]
            cell = Cell(f"{prefix}{gi // io_group}", kind, signals=list(group))
            nl.cells[cell.name] = cell
            for s in group:
                where[s] = cell.name
        return where

    in_cell_of = _alloc_io(ext_inputs, "io_in", "in")
    out_cell_of = _alloc_io(sorted(leaves), "io_out", "out")

    # nets: one per produced signal with external consumers
    for sig in sorted(set(consumers) | leaves):
        driver = home.get(sig) or in_cell_of.get(sig)
        if driver is None:
            continue
        sinks = {c for c in consumers.get(sig, ()) if c != driver}
        if sig in out_cell_of:
            sinks.add(out_cell_of[sig])
        sinks.discard(driver)
        if not sinks:
            continue
        nl.nets.append(Net(f"n{sig}", driver, sorted(sinks), signal=sig))
    nl.nets.sort(key=lambda n: n.name)
    return nl
