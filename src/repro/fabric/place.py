"""Simulated-annealing placer with JAX-batched parallel chains.

Follows the cgra_pnr (thunder/SADetailedPlacer) shape: a placement is a
permutation of cells over tiles, moves swap a random cell with a random
tile (occupied -> swap, empty -> move), and candidate states are scored by
total half-perimeter wirelength.  Two engines share one lowering:

* ``backend="python"`` — the classic single-chain annealer with incremental
  per-net cost updates (the reference path);
* ``backend="jax"`` — C independent chains annealed in lockstep, one
  ``lax.fori_loop`` step proposing one move per chain and scoring it with
  the HPWL kernels (:mod:`repro.kernels.pnr_cost`).  On accelerators the
  whole sweep stays on-device.

Move scoring (``score_mode``): a swap touches only the nets incident to
the two swapped entities, so the default ``"delta"`` mode carries the
per-net cost vector through the loop state and rescores just those ≤2K
nets per move (O(K·D) instead of O(N·D)); ``"full"`` recomputes every
net's HPWL per move and is kept as the debug fallback.  Both modes see
identical move schedules and — HPWL values being exactly-representable
integers — compute bit-identical costs, so they accept/reject the same
moves and return bit-identical placements for equal seeds.

PE cells live on the rows x cols grid, I/O cells on the perimeter ring;
moves never cross the two classes, so every intermediate state is legal by
construction.
"""

from __future__ import annotations

import functools
import math
import random as _random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..kernels.tiling import pow2_bucket as _bucket
from .arch import Coord, FabricSpec
from .netlist import Netlist

__all__ = ["PlacementProblem", "Placement", "HierPlacement", "lower",
           "net_incidence", "anneal_python", "anneal_jax",
           "anneal_jax_batch", "place", "place_hierarchical",
           "batch_signature"]


@dataclass
class PlacementProblem:
    spec: FabricSpec
    cell_names: List[str]            # PE cells first, then I/O cells
    n_pe_cells: int
    n_io_cells: int
    slot_xy: np.ndarray              # (E, 2) float32; PE slots then I/O slots
    n_pe_slots: int
    n_io_slots: int
    net_pins: np.ndarray             # (N, D) int32 entity indices (0-padded)
    net_mask: np.ndarray             # (N, D) bool
    ent_nets: np.ndarray = None      # (E, K) int32 entity -> incident nets,
    # padded with N (out of range) — the incidence table delta scoring uses
    # to find the nets a swap touches
    net_fix: Optional[np.ndarray] = None   # (N, 4) float32 per-net fixed
    # bounding boxes [xmin, xmax, ymin, ymax] over pins *outside* this
    # problem (the hierarchical placer's cluster-local sub-problems);
    # None for ordinary whole-fabric problems

    @property
    def n_entities(self) -> int:
        return self.n_pe_slots + self.n_io_slots

    def entity_of(self, cell_idx: int) -> int:
        """Entity index of the cell_idx-th cell in cell_names order."""
        if cell_idx < self.n_pe_cells:
            return cell_idx
        return self.n_pe_slots + (cell_idx - self.n_pe_cells)


@dataclass
class Placement:
    coords: Dict[str, Coord]         # cell name -> tile
    cost: float                      # HPWL of the chosen chain
    backend: str
    chains: int
    sweeps: int
    chain_costs: List[float] = field(default_factory=list)


def lower(netlist: Netlist, spec: FabricSpec) -> PlacementProblem:
    """Lower a netlist to the padded arrays both annealers consume."""
    pe = sorted(netlist.pe_cells, key=lambda c: c.instance)
    io = sorted(netlist.io_cells, key=lambda c: c.name)
    if len(pe) > spec.n_pe_tiles:
        raise ValueError(f"{len(pe)} PE cells exceed {spec.n_pe_tiles} tiles "
                         f"({spec.summary()}); use spec.fit()")
    if len(io) > spec.n_io_sites:
        raise ValueError(f"{len(io)} I/O cells exceed {spec.n_io_sites} "
                         f"perimeter sites ({spec.summary()})")
    slot_xy = np.asarray(spec.pe_tiles() + spec.io_sites(), np.float32)
    ent_of: Dict[str, int] = {}
    for i, c in enumerate(pe):
        ent_of[c.name] = i
    for j, c in enumerate(io):
        ent_of[c.name] = spec.n_pe_tiles + j

    nets = netlist.nets
    deg = max((n.degree for n in nets), default=1)
    net_pins = np.zeros((max(1, len(nets)), deg), np.int32)
    net_mask = np.zeros_like(net_pins, dtype=bool)
    for i, n in enumerate(nets):
        for j, cell in enumerate([n.driver] + n.sinks):
            net_pins[i, j] = ent_of[cell]
            net_mask[i, j] = True

    return PlacementProblem(
        spec=spec,
        cell_names=[c.name for c in pe] + [c.name for c in io],
        n_pe_cells=len(pe), n_io_cells=len(io),
        slot_xy=slot_xy,
        n_pe_slots=spec.n_pe_tiles, n_io_slots=spec.n_io_sites,
        net_pins=net_pins, net_mask=net_mask,
        ent_nets=net_incidence(net_pins, net_mask,
                               spec.n_pe_tiles + spec.n_io_sites))


def net_incidence(net_pins: np.ndarray, net_mask: np.ndarray,
                  n_entities: int) -> np.ndarray:
    """Padded entity -> incident-nets table for delta move scoring.

    Returns (E, K) int32 where K is the max nets on any entity; unused
    entries hold N (one past the last net) so out-of-range gathers and
    ``mode="drop"`` scatters ignore them.
    """
    n_nets = net_pins.shape[0]
    incident: List[List[int]] = [[] for _ in range(n_entities)]
    for i in range(n_nets):
        for e in net_pins[i][net_mask[i]]:
            incident[int(e)].append(i)
    k = max(1, max((len(l) for l in incident), default=1))
    table = np.full((n_entities, k), n_nets, np.int32)
    for e, l in enumerate(incident):
        table[e, :len(l)] = l
    return table


def _init_slots(p: PlacementProblem, rng: _random.Random) -> np.ndarray:
    """Random legal permutation: entity -> slot, classes kept separate."""
    pe_slots = list(range(p.n_pe_slots))
    io_slots = list(range(p.n_pe_slots, p.n_entities))
    rng.shuffle(pe_slots)
    rng.shuffle(io_slots)
    return np.asarray(pe_slots + io_slots, np.int32)


def _default_t0(p: PlacementProblem) -> float:
    return 0.5 * (p.spec.rows + p.spec.cols)


# ---------------------------------------------------------------------------
# Python reference chain (incremental delta evaluation)
# ---------------------------------------------------------------------------
def anneal_python(p: PlacementProblem, *, seed: int = 0, sweeps: int = 48,
                  t0: Optional[float] = None, t1: float = 0.02
                  ) -> Tuple[np.ndarray, float]:
    """Single annealing chain; returns (slot_of_entity, final HPWL)."""
    rng = _random.Random(seed)
    slot_of = _init_slots(p, rng)
    # maintained inverse permutation: occupant lookup is O(1) per move
    # instead of an O(E) nonzero scan
    ent_at_slot = np.empty_like(slot_of)
    ent_at_slot[slot_of] = np.arange(slot_of.shape[0], dtype=slot_of.dtype)
    pins = p.net_pins
    mask = p.net_mask
    xy = p.slot_xy

    def net_cost(i: int) -> float:
        xs = xy[slot_of[pins[i][mask[i]]]]
        if xs.size == 0:
            return 0.0
        return float(xs[:, 0].max() - xs[:, 0].min()
                     + xs[:, 1].max() - xs[:, 1].min())

    nets_of_ent: Dict[int, List[int]] = {}
    for i in range(pins.shape[0]):
        for e in pins[i][mask[i]]:
            nets_of_ent.setdefault(int(e), []).append(i)
    net_costs = [net_cost(i) for i in range(pins.shape[0])]
    cur = sum(net_costs)
    best = cur
    best_slot = slot_of.copy()

    movable: List[Tuple[int, int, int]] = []      # (lo_ent, n_cells, n_slots)
    if p.n_pe_cells:
        movable.append((0, p.n_pe_cells, p.n_pe_slots))
    if p.n_io_cells:
        movable.append((p.n_pe_slots, p.n_io_cells, p.n_io_slots))
    if not movable:
        return slot_of, 0.0
    n_real = p.n_pe_cells + p.n_io_cells
    steps = max(1, sweeps * n_real)
    t0 = _default_t0(p) if t0 is None else t0

    for step in range(steps):
        lo, n_cells, n_slots = movable[0] if (
            len(movable) == 1 or rng.random() < p.n_pe_cells / n_real
        ) else movable[-1]
        a = lo + rng.randrange(n_cells)
        slot_lo = 0 if lo == 0 else p.n_pe_slots
        t = slot_lo + rng.randrange(n_slots)
        b = int(ent_at_slot[t])
        if a == b:
            continue
        touched = sorted(set(nets_of_ent.get(a, []) + nets_of_ent.get(b, [])))
        old = sum(net_costs[i] for i in touched)
        slot_of[a], slot_of[b] = slot_of[b], slot_of[a]
        new_costs = {i: net_cost(i) for i in touched}
        delta = sum(new_costs.values()) - old
        temp = t0 * (t1 / t0) ** (step / steps)
        if delta <= 0 or rng.random() < math.exp(-delta / max(temp, 1e-9)):
            ent_at_slot[slot_of[a]], ent_at_slot[slot_of[b]] = a, b
            for i, c in new_costs.items():
                net_costs[i] = c
            cur += delta
            if cur < best:
                best, best_slot = cur, slot_of.copy()
        else:
            slot_of[a], slot_of[b] = slot_of[b], slot_of[a]
    return best_slot, float(best)


# ---------------------------------------------------------------------------
# JAX batched chains
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _build_annealer(steps: int, n_pe_c: int, n_io_c: int,
                    n_pe_s: int, n_io_s: int, t0: float, t1: float,
                    hpwl_backend: str = "jnp", score_mode: str = "delta"):
    """Compile one batched annealer per static problem shape.

    Caching here (rather than a fresh ``jax.jit`` per call) is what makes a
    DSE sweep cheap: every variant of the same fabric reuses the program.

    hpwl_backend selects the move-scoring kernel family: ``"jnp"`` (jitted
    jax.numpy reductions) or ``"pallas"`` (the Pallas kernels from
    :mod:`repro.kernels.pnr_cost`, compiled on TPU and interpreted on CPU
    hosts).  score_mode selects full recompute (``"full"``, O(N·D) per
    move) or incremental rescoring of only the touched nets (``"delta"``,
    O(K·D) per move).  All four combinations compute identical HPWL, so
    chains accept identical move sequences and return identical placements.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.pnr_cost import (hpwl, hpwl_delta, hpwl_delta_pallas,
                                    hpwl_pallas, net_hpwl)

    interpret = jax.default_backend() != "tpu"
    if hpwl_backend == "pallas":
        score = functools.partial(hpwl_pallas, interpret=interpret)
    elif hpwl_backend == "jnp":
        score = hpwl
    else:
        raise ValueError(f"unknown hpwl_backend {hpwl_backend!r}")
    if score_mode not in ("delta", "full"):
        raise ValueError(f"unknown score_mode {score_mode!r}")

    n_real = n_pe_c + n_io_c
    p_pe = n_pe_c / n_real
    temps = t0 * (t1 / t0) ** (jnp.arange(steps, dtype=jnp.float32) / steps)

    def chain(key, slot_of0, slot_xy, net_pins, net_mask, ent_nets):
        n_nets = net_pins.shape[0]

        # draw the whole move schedule up front: one RNG call per stream
        # instead of several threefry hashes inside every loop step
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        pick_pe = jax.random.uniform(k1, (steps,)) < p_pe
        a = jnp.where(pick_pe,
                      jax.random.randint(k2, (steps,), 0, max(1, n_pe_c)),
                      n_pe_s + jax.random.randint(k3, (steps,), 0,
                                                  max(1, n_io_c)))
        t = jnp.where(pick_pe,
                      jax.random.randint(k4, (steps,), 0, n_pe_s),
                      n_pe_s + jax.random.randint(k5, (steps,), 0, n_io_s))
        log_u = jnp.log(jax.random.uniform(k6, (steps,), minval=1e-12))

        def accept_and_track(i, accept, cand, new, state_rest):
            slot_of, cur, best_slot, best = state_rest
            slot_of = jnp.where(accept, cand, slot_of)
            cur = jnp.where(accept, new, cur)
            improved = cur < best
            best_slot = jnp.where(improved, slot_of, best_slot)
            best = jnp.where(improved, cur, best)
            return slot_of, cur, best_slot, best

        if score_mode == "full":
            def cost(slot_of):
                return score(slot_xy[slot_of], net_pins, net_mask)

            def step(i, state):
                slot_of, cur, best_slot, best = state
                ai, ti = a[i], t[i]
                b = jnp.argmax(slot_of == ti)   # occupant of target slot
                cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
                new = cost(cand)
                accept = (new <= cur) | (log_u[i] * temps[i] < cur - new)
                return accept_and_track(i, accept, cand, new, state)

            c0 = cost(slot_of0)
            _, _, best_slot, best = jax.lax.fori_loop(
                0, steps, step, (slot_of0, c0, slot_of0, c0))
            return best_slot, best

        # -- delta mode: per-net cost vector rides in the loop state -------
        k2_ = ent_nets.shape[1] * 2
        dup_tri = jnp.tril(jnp.ones((k2_, k2_), bool), k=-1)

        def step(i, state):
            slot_of, pnc, cur, best_slot, best = state
            ai, ti = a[i], t[i]
            b = jnp.argmax(slot_of == ti)       # occupant of target slot
            cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
            # nets incident to either swapped entity, deduped so a net
            # touching both contributes its delta exactly once
            tn = jnp.concatenate([ent_nets[ai], ent_nets[b]])
            dup = jnp.any((tn[:, None] == tn[None, :]) & dup_tri, axis=1)
            tn = jnp.where(dup, n_nets, tn)
            if hpwl_backend == "pallas":
                new_vals, delta = hpwl_delta_pallas(
                    slot_xy, slot_of, net_pins, net_mask, pnc, tn,
                    ai, b, interpret=interpret)
            else:
                new_vals, delta = hpwl_delta(slot_xy, cand, net_pins,
                                             net_mask, pnc, tn)
            new = cur + delta
            accept = (new <= cur) | (log_u[i] * temps[i] < cur - new)
            pnc = jnp.where(accept,
                            pnc.at[tn].set(new_vals, mode="drop"), pnc)
            slot_of, cur, best_slot, best = accept_and_track(
                i, accept, cand, new, (slot_of, cur, best_slot, best))
            return slot_of, pnc, cur, best_slot, best

        pnc0 = net_hpwl(slot_xy[slot_of0], net_pins, net_mask)
        c0 = jnp.sum(pnc0)
        _, _, _, best_slot, best = jax.lax.fori_loop(
            0, steps, step, (slot_of0, pnc0, c0, slot_of0, c0))
        return best_slot, best

    return jax.jit(jax.vmap(chain, in_axes=(0, 0, None, None, None, None)))


def anneal_jax(p: PlacementProblem, *, chains: int = 32, seed: int = 0,
               sweeps: int = 48, t0: Optional[float] = None,
               t1: float = 0.02, hpwl_backend: str = "jnp",
               score_mode: str = "delta"
               ) -> Tuple[np.ndarray, np.ndarray]:
    """C independent chains; returns (slot_of (C, E), costs (C,))."""
    import jax

    n_real = p.n_pe_cells + p.n_io_cells
    if n_real == 0:
        e = np.tile(np.arange(p.n_entities, dtype=np.int32), (chains, 1))
        return e, np.zeros((chains,), np.float32)
    steps = max(1, sweeps * n_real)
    t0 = _default_t0(p) if t0 is None else t0

    run = _build_annealer(steps, p.n_pe_cells, p.n_io_cells,
                          p.n_pe_slots, p.n_io_slots, float(t0), float(t1),
                          hpwl_backend, score_mode)
    rng = _random.Random(seed)
    init = np.stack([_init_slots(p, rng) for _ in range(chains)])
    keys = jax.random.split(jax.random.PRNGKey(seed), chains)
    ent_nets = p.ent_nets if p.ent_nets is not None else net_incidence(
        p.net_pins, p.net_mask, p.n_entities)
    slots, costs = run(keys, init, p.slot_xy, p.net_pins, p.net_mask,
                       ent_nets)
    return np.asarray(slots), np.asarray(costs)


# ---------------------------------------------------------------------------
# Cross-problem batching: many (variant, app) placements in one dispatch
# ---------------------------------------------------------------------------


def batch_signature(p: PlacementProblem, sweeps: int) -> Tuple[int, ...]:
    """Static shape key two problems must share to ride one dispatch."""
    steps = max(1, sweeps * (p.n_pe_cells + p.n_io_cells))
    return (_bucket(steps), _bucket(p.net_pins.shape[0]),
            _bucket(p.net_pins.shape[1]), _bucket(p.n_entities),
            _bucket(p.ent_nets.shape[1]))


#: cost-curve snapshot points captured per chain when telemetry is on
CURVE_POINTS = 16


@functools.lru_cache(maxsize=64)
def _build_batch_annealer(s_pad: int, n_pad: int, d_pad: int, e_pad: int,
                          k_pad: int, t1: float, hpwl_backend: str,
                          score_mode: str, telemetry: bool = False,
                          fixed: bool = False):
    """One compiled chain program for every problem of one bucket signature.

    Unlike :func:`_build_annealer` (which bakes the cell/slot counts into
    the program as static Python ints), the batched chain takes them as
    *data* — so PE1 on camera and PE4 on conv can share a program as long
    as their padded shapes land in the same buckets.  Moves are sampled by
    scaling uniforms with the dynamic counts, the temperature schedule uses
    the dynamic per-problem step count, and steps beyond a problem's real
    budget are masked to rejects.

    With ``telemetry`` the chain additionally returns its accepted-move
    count and :data:`CURVE_POINTS` current-cost snapshots.  The telemetry
    state only *observes* the accept decision and running cost — the move
    schedule and cost arithmetic are untouched — so placements and costs
    are bit-identical to the untelemetered program.

    With ``fixed`` the chain additionally takes a per-net fixed-box array
    (``net_fix``, (N, 4)) and scores through the ``*_fixed`` kernels — the
    hierarchical placer's cluster-local sub-problems, whose external pins
    are frozen boxes rather than entities.  Sentinel (:data:`EMPTY_BOX`)
    rows make the fixed fold a bit-exact no-op, so box-free nets score
    identically to the plain program.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.pnr_cost import (hpwl, hpwl_delta, hpwl_delta_fixed,
                                    hpwl_fixed, net_hpwl, net_hpwl_fixed)

    if hpwl_backend != "jnp":
        raise ValueError("anneal_jax_batch supports hpwl_backend='jnp' only "
                         "(the pallas delta kernel scores one swap per call)")
    if score_mode not in ("delta", "full"):
        raise ValueError(f"unknown score_mode {score_mode!r}")

    def chain(key, slot_of0, slot_xy, net_pins, net_mask, ent_nets,
              dims, t0, net_fix=None):
        if fixed:
            def total_cost(pos):
                return hpwl_fixed(pos, net_pins, net_mask, net_fix)

            def per_net_cost(pos):
                return net_hpwl_fixed(pos, net_pins, net_mask, net_fix)

            def delta_cost(cand, pnc, tn):
                return hpwl_delta_fixed(slot_xy, cand, net_pins, net_mask,
                                        pnc, tn, net_fix)
        else:
            def total_cost(pos):
                return hpwl(pos, net_pins, net_mask)

            def per_net_cost(pos):
                return net_hpwl(pos, net_pins, net_mask)

            def delta_cost(cand, pnc, tn):
                return hpwl_delta(slot_xy, cand, net_pins, net_mask,
                                  pnc, tn)
        n_pe_c, n_io_c, n_pe_s, n_io_s, n_steps = (
            dims[0], dims[1], dims[2], dims[3], dims[4])
        n_real = jnp.maximum(n_pe_c + n_io_c, 1)

        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        pick_pe = (jax.random.uniform(k1, (s_pad,))
                   < n_pe_c.astype(jnp.float32) / n_real.astype(jnp.float32))

        def scaled(k, count, lo):
            u = jax.random.uniform(k, (s_pad,))
            idx = jnp.minimum((u * count).astype(jnp.int32),
                              jnp.maximum(count - 1, 0))
            return lo + idx

        a = jnp.where(pick_pe, scaled(k2, n_pe_c, 0),
                      scaled(k3, n_io_c, n_pe_s))
        t = jnp.where(pick_pe, scaled(k4, n_pe_s, 0),
                      scaled(k5, n_io_s, n_pe_s))
        log_u = jnp.log(jax.random.uniform(k6, (s_pad,), minval=1e-12))
        frac = (jnp.arange(s_pad, dtype=jnp.float32)
                / jnp.maximum(n_steps.astype(jnp.float32), 1.0))
        temps = t0 * (t1 / t0) ** frac
        active = jnp.arange(s_pad) < n_steps

        def tele0():
            return (jnp.int32(0), jnp.zeros((CURVE_POINTS,), jnp.float32))

        def tele_track(i, accept, cur, tele):
            n_acc, curve = tele
            n_acc = n_acc + accept.astype(jnp.int32)
            idx = jnp.minimum((i * CURVE_POINTS) // s_pad, CURVE_POINTS - 1)
            return n_acc, curve.at[idx].set(cur)

        def accept_and_track(accept, cand, new, state_rest):
            slot_of, cur, best_slot, best = state_rest
            slot_of = jnp.where(accept, cand, slot_of)
            cur = jnp.where(accept, new, cur)
            improved = cur < best
            best_slot = jnp.where(improved, slot_of, best_slot)
            best = jnp.where(improved, cur, best)
            return slot_of, cur, best_slot, best

        if score_mode == "full":
            def step(i, state):
                slot_of, cur, best_slot, best = state[:4]
                ai, ti = a[i], t[i]
                b = jnp.argmax(slot_of == ti)
                cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
                new = total_cost(slot_xy[cand])
                accept = ((new <= cur)
                          | (log_u[i] * temps[i] < cur - new)) & active[i]
                out = accept_and_track(accept, cand, new, state[:4])
                if telemetry:
                    return out + tele_track(i, accept, out[1], state[4:])
                return out

            c0 = total_cost(slot_xy[slot_of0])
            state0 = (slot_of0, c0, slot_of0, c0)
            if telemetry:
                state0 = state0 + tele0()
            out = jax.lax.fori_loop(0, s_pad, step, state0)
            if telemetry:
                return out[2], out[3], out[4], out[5]
            return out[2], out[3]

        k2_ = k_pad * 2
        dup_tri = jnp.tril(jnp.ones((k2_, k2_), bool), k=-1)

        def step(i, state):
            slot_of, pnc, cur, best_slot, best = state[:5]
            ai, ti = a[i], t[i]
            b = jnp.argmax(slot_of == ti)
            cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
            tn = jnp.concatenate([ent_nets[ai], ent_nets[b]])
            dup = jnp.any((tn[:, None] == tn[None, :]) & dup_tri, axis=1)
            tn = jnp.where(dup, n_pad, tn)
            new_vals, delta = delta_cost(cand, pnc, tn)
            new = cur + delta
            accept = ((new <= cur)
                      | (log_u[i] * temps[i] < cur - new)) & active[i]
            pnc = jnp.where(accept,
                            pnc.at[tn].set(new_vals, mode="drop"), pnc)
            slot_of, cur, best_slot, best = accept_and_track(
                accept, cand, new, (slot_of, cur, best_slot, best))
            if telemetry:
                tele = tele_track(i, accept, cur, state[5:])
                return (slot_of, pnc, cur, best_slot, best) + tele
            return slot_of, pnc, cur, best_slot, best

        pnc0 = per_net_cost(slot_xy[slot_of0])
        c0 = jnp.sum(pnc0)
        state0 = (slot_of0, pnc0, c0, slot_of0, c0)
        if telemetry:
            state0 = state0 + tele0()
        out = jax.lax.fori_loop(0, s_pad, step, state0)
        if telemetry:
            return out[3], out[4], out[5], out[6]
        return out[3], out[4]

    # one flat vmap over problems x chains, each row carrying its own
    # problem data: a nested vmap (outer problems, inner chains with the
    # problem arrays broadcast) would avoid the per-chain copies but
    # measures ~2x slower end to end on the Fig. 11 suite, so the copies
    # (a few MB at these sizes) buy the better-vectorizing flat batch
    return jax.jit(jax.vmap(chain))


def check_anneal_budget(p: PlacementProblem, chains: int, sweeps: int,
                        max_states: Optional[int], *,
                        metrics=None) -> None:
    """Refuse (pre-dispatch) an anneal whose state count exceeds budget.

    The annealing budget is deterministic and size-based — ``chains x
    sweeps x n_entities`` proposed states per problem — so exhaustion is
    a property of the problem, not of wall clock, and results stay
    bit-identical whenever the budget is *not* exhausted.  Raises
    :class:`repro.errors.BudgetExceeded` before any compilation or
    dispatch happens; no-op when ``max_states`` is None (the default).
    """
    if max_states is None:
        return
    states = chains * max(1, sweeps * (p.n_pe_cells + p.n_io_cells))
    if states > max_states:
        if metrics is not None:
            metrics.inc("pnr.budget_exhausted")
        from ..errors import BudgetExceeded
        raise BudgetExceeded(
            f"anneal needs {states} states "
            f"({chains} chains x {sweeps} sweeps x "
            f"{p.n_pe_cells + p.n_io_cells} cells > "
            f"anneal_max_states={max_states})",
            states=states, max_states=max_states, chains=chains,
            sweeps=sweeps, n_entities=p.n_entities)


def anneal_jax_batch(problems: List[PlacementProblem], *, chains: int = 16,
                     seed: int = 0, sweeps: int = 32,
                     t0: Optional[float] = None, t1: float = 0.02,
                     score_mode: str = "delta",
                     nonces: Optional[List[int]] = None,
                     telemetry: Optional[bool] = None,
                     metrics=None, max_states: Optional[int] = None
                     ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Anneal many placement problems in one JAX dispatch.

    All problems must share one :func:`batch_signature`; every problem's
    arrays are padded to the signature's bucket shapes (masked nets score
    zero, dummy entities sit on dummy slots and are never proposed as
    moves) and all ``len(problems) x chains`` chains run as one vmapped
    ``fori_loop``.  Returns per problem ``(slot_of (C, E), costs (C,))``
    with E the problem's real entity count — the same contract as
    :func:`anneal_jax`.

    Each problem's chains draw from ``fold_in(PRNGKey(seed), nonce)`` with
    ``nonces[i]`` defaulting to ``i``.  Callers wanting placements that are
    reproducible *regardless of grouping* (the explore pipeline's memo
    contract) pass a content-derived nonce per problem; with bucket-shape
    padding the result then depends only on the problem itself, never on
    its groupmates.

    ``telemetry`` (default: :func:`repro.obs.telemetry_enabled`) selects a
    compiled variant that also reports per-chain accept counts and
    cost-curve snapshots; placements stay bit-identical.  Acceptance rates
    land in ``metrics`` (histogram ``pnr.anneal.accept_rate``, cost curves
    as ``pnr.anneal.cost_curve.<nonce>`` gauges), defaulting to the global
    registry.
    """
    import jax

    from ..kernels.pnr_cost import EMPTY_BOX
    from ..obs import telemetry_enabled
    from ..obs.metrics import global_registry

    if telemetry is None:
        telemetry = telemetry_enabled()

    if nonces is None:
        nonces = list(range(len(problems)))
    if len(nonces) != len(problems):
        raise ValueError("nonces must match problems 1:1")
    for p in problems:
        check_anneal_budget(p, chains, sweeps, max_states,
                            metrics=metrics or global_registry())
    sigs = {batch_signature(p, sweeps) for p in problems}
    if len(sigs) != 1:
        raise ValueError(f"problems span {len(sigs)} batch signatures; "
                         f"group by batch_signature() first")
    s_pad, n_pad, d_pad, e_pad, k_pad = next(iter(sigs))

    n_p = len(problems)
    has_fix = any(p.net_fix is not None for p in problems)
    net_pins = np.zeros((n_p, n_pad, d_pad), np.int32)
    net_mask = np.zeros((n_p, n_pad, d_pad), bool)
    net_fix = (np.tile(np.asarray(EMPTY_BOX, np.float32), (n_p, n_pad, 1))
               if has_fix else None)
    slot_xy = np.zeros((n_p, e_pad, 2), np.float32)
    ent_nets = np.full((n_p, e_pad, k_pad), n_pad, np.int32)
    dims = np.zeros((n_p, 5), np.int32)
    t0s = np.zeros((n_p,), np.float32)
    init = np.tile(np.arange(e_pad, dtype=np.int32), (n_p, chains, 1))
    keys = np.zeros((n_p, chains, 2), np.uint32)
    base_key = jax.random.PRNGKey(seed)
    for i, p in enumerate(problems):
        n, d = p.net_pins.shape
        net_pins[i, :n, :d] = p.net_pins
        net_mask[i, :n, :d] = p.net_mask
        if p.net_fix is not None:
            net_fix[i, :n] = p.net_fix
        e = p.n_entities
        slot_xy[i, :e] = p.slot_xy
        en = np.where(p.ent_nets == n, n_pad, p.ent_nets)
        ent_nets[i, :e, :en.shape[1]] = en
        n_real = p.n_pe_cells + p.n_io_cells
        dims[i] = (p.n_pe_cells, p.n_io_cells, p.n_pe_slots, p.n_io_slots,
                   max(1, sweeps * n_real))
        t0s[i] = _default_t0(p) if t0 is None else t0
        rng = _random.Random(seed)
        for c in range(chains):
            init[i, c, :e] = _init_slots(p, rng)
        keys[i] = np.asarray(jax.random.split(
            jax.random.fold_in(base_key, nonces[i] & 0x7FFFFFFF), chains))

    run = _build_batch_annealer(s_pad, n_pad, d_pad, e_pad, k_pad,
                                float(t1), "jnp", score_mode,
                                bool(telemetry), has_fix)

    def flat(x):                     # (P, C, ...) -> (P*C, ...)
        return x.reshape((n_p * chains,) + x.shape[2:])

    def tile(x):                     # (P, ...) -> (P*C, ...) per-chain copy
        return np.repeat(x, chains, axis=0)

    args = (flat(keys), flat(init), tile(slot_xy),
            tile(net_pins), tile(net_mask), tile(ent_nets),
            tile(dims), tile(t0s))
    if has_fix:
        args = args + (tile(net_fix),)
    out = run(*args)
    slots = np.asarray(out[0]).reshape(n_p, chains, e_pad)
    costs = np.asarray(out[1]).reshape(n_p, chains)
    if telemetry:
        reg = metrics if metrics is not None else global_registry()
        accepts = np.asarray(out[2]).reshape(n_p, chains)
        curves = np.asarray(out[3]).reshape(n_p, chains, CURVE_POINTS)
        for i, p in enumerate(problems):
            steps_i = max(1, sweeps * (p.n_pe_cells + p.n_io_cells))
            reg.observe("pnr.anneal.accept_rate",
                        float(accepts[i].mean()) / steps_i)
            best_chain = int(np.argmin(costs[i]))
            reg.set_gauge(f"pnr.anneal.cost_curve.{nonces[i] & 0x7FFFFFFF}",
                          [round(float(c), 3) for c in
                           curves[i, best_chain]])
    return [(slots[i, :, :p.n_entities], costs[i])
            for i, p in enumerate(problems)]


def place(netlist: Netlist, spec: FabricSpec, *, backend: str = "jax",
          chains: int = 32, sweeps: int = 48, seed: int = 0,
          t0: Optional[float] = None, t1: float = 0.02,
          hpwl_backend: str = "jnp", score_mode: str = "delta",
          max_states: Optional[int] = None) -> Placement:
    """Anneal and return the best chain's placement.

    ``max_states`` bounds the anneal state budget (chains x sweeps x
    entities) exactly like the batched path — the serial fallback must
    not silently out-spend the budget the grouped dispatch enforces.
    """
    if hpwl_backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown hpwl_backend {hpwl_backend!r}")
    if score_mode not in ("delta", "full"):
        raise ValueError(f"unknown score_mode {score_mode!r}")
    p = lower(netlist, spec)
    check_anneal_budget(p, chains, sweeps, max_states)

    if backend == "python":
        if hpwl_backend != "jnp":
            raise ValueError(
                "hpwl_backend applies to the jax annealer only; the python "
                "reference scores moves without the HPWL kernel")
        # the python reference is inherently incremental; score_mode only
        # selects between the jax engine's two scoring programs
        chain_results = [anneal_python(p, seed=seed + c, sweeps=sweeps,
                                       t0=t0, t1=t1)
                         for c in range(chains)]
        slots = np.stack([s for s, _ in chain_results])
        costs = np.asarray([c for _, c in chain_results], np.float32)
    elif backend == "jax":
        slots, costs = anneal_jax(p, chains=chains, seed=seed, sweeps=sweeps,
                                  t0=t0, t1=t1, hpwl_backend=hpwl_backend,
                                  score_mode=score_mode)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    best = int(np.argmin(costs))
    slot_of = slots[best]
    coords: Dict[str, Coord] = {}
    for idx, name in enumerate(p.cell_names):
        ent = p.entity_of(idx)
        x, y = p.slot_xy[slot_of[ent]]
        coords[name] = (int(x), int(y))
    return Placement(coords=coords, cost=float(costs[best]), backend=backend,
                     chains=chains, sweeps=sweeps,
                     chain_costs=[float(c) for c in costs])


# ---------------------------------------------------------------------------
# Two-level hierarchical placement (cluster -> detail -> deblock)
# ---------------------------------------------------------------------------


@dataclass
class HierPlacement(Placement):
    """A :class:`Placement` plus the hierarchical flow's per-level record.

    The level arrays exist so callers (the pnr benchmark, the tests) can
    assert delta-vs-full bit-identity *per level*, not just on the final
    coordinates: ``cluster_slots`` is the winning coarse chain (cluster ->
    region slot), ``detail_slots[k]`` the winning local chain of cluster
    ``k``, ``deblock_slots`` the winning seam-refinement chain (empty
    when the pass was skipped).  ``cost`` is the *exact* whole-netlist
    HPWL of the final coordinates — the same objective :func:`place`
    reports — while ``level_costs`` holds each level's own (approximate,
    fixed-terminal) objective.
    """

    cluster_grid: int = 1
    clusters: List[List[str]] = field(default_factory=list)
    region_of: Dict[int, Coord] = field(default_factory=dict)
    cluster_slots: Optional[np.ndarray] = None
    detail_slots: Dict[int, np.ndarray] = field(default_factory=dict)
    deblock_slots: Optional[np.ndarray] = None
    level_costs: Dict[str, float] = field(default_factory=dict)
    detail_dispatches: int = 0


def _auto_cluster_grid(spec: FabricSpec) -> int:
    """Largest cluster grid whose regions stay >= 16x16 (>= 8x8 for small
    fabrics) and divide the array evenly; 1 means 'place flat'."""
    for target in (16, 8):
        for g in range(min(spec.rows, spec.cols) // target, 1, -1):
            if spec.rows % g == 0 and spec.cols % g == 0:
                return g
    return 1


def _region_spec(spec: FabricSpec, rh: int, rw: int) -> FabricSpec:
    return FabricSpec(rows=rh, cols=rw, channel_width=spec.channel_width,
                      io_capacity=spec.io_capacity,
                      hop_energy_pj=spec.hop_energy_pj,
                      hop_delay_ns=spec.hop_delay_ns,
                      latch_depth=spec.latch_depth)


def _nets_problem(spec: FabricSpec, cell_names: List[str], n_slots: int,
                  slot_xy: np.ndarray, nets: List[Tuple[List[int], list]]
                  ) -> PlacementProblem:
    """PlacementProblem over one movable PE class with fixed-box nets.

    nets: (entity pins, external fixed points) per net; the points are
    already in the problem's coordinate frame.
    """
    from ..kernels.pnr_cost import EMPTY_BOX, fixed_box

    n = max(1, len(nets))
    d = max(1, max((len(e) for e, _ in nets), default=1))
    net_pins = np.zeros((n, d), np.int32)
    net_mask = np.zeros((n, d), bool)
    net_fix = np.tile(np.asarray(EMPTY_BOX, np.float32), (n, 1))
    for i, (ents, ext) in enumerate(nets):
        net_pins[i, :len(ents)] = ents
        net_mask[i, :len(ents)] = True
        if ext:
            net_fix[i] = fixed_box(ext)
    return PlacementProblem(
        spec=spec, cell_names=list(cell_names),
        n_pe_cells=len(cell_names), n_io_cells=0,
        slot_xy=np.asarray(slot_xy, np.float32),
        n_pe_slots=n_slots, n_io_slots=0,
        net_pins=net_pins, net_mask=net_mask,
        ent_nets=net_incidence(net_pins, net_mask, n_slots),
        net_fix=net_fix)


def place_hierarchical(netlist: Netlist, spec: FabricSpec, *,
                       cluster_grid: Optional[int] = None,
                       chains: int = 16, sweeps: int = 32, seed: int = 0,
                       score_mode: str = "delta",
                       cluster_score_mode: Optional[str] = None,
                       detail_score_mode: Optional[str] = None,
                       deblock_score_mode: Optional[str] = None,
                       cluster_sweeps: Optional[int] = None,
                       deblock_sweeps: Optional[int] = None,
                       deblock_halo: int = 1, deblock_t0: float = 2.0,
                       t1: float = 0.02,
                       max_states: Optional[int] = None,
                       metrics=None) -> HierPlacement:
    """Two-level placement for mega-fabrics (cgra_pnr's cluster ->
    detail -> deblock recipe on top of :func:`anneal_jax_batch`).

    1. **Partition** (:func:`repro.fabric.cluster.partition`): PE cells
       into ``cluster_grid**2`` connectivity-tight clusters, one per
       region of the evenly divided array.
    2. **Cluster level**: the clusters anneal as one small batched
       problem on the ``cluster_grid x cluster_grid`` coarse grid
       (inter-cluster nets only), assigning each cluster a region.
    3. **I/O**: perimeter cells go greedily to the free site nearest the
       centroid of their partner clusters' regions.
    4. **Detail level**: every cluster's cells anneal over its region's
       tiles — all clusters *simultaneously*, grouped by
       :func:`batch_signature` into giant pow2-bucketed vmapped
       dispatches.  External pins enter as per-net fixed boxes in the
       cluster's local frame (:func:`repro.kernels.pnr_cost.hpwl_delta_fixed`).
    5. **Deblock**: cells within ``deblock_halo`` tiles of a region seam
       re-anneal jointly across the seams at low temperature.

    ``score_mode`` selects delta/full move scoring for every level;
    the per-level overrides (``cluster_score_mode`` etc.) pin one level
    only.  Both modes are bit-identical per level at equal seeds (gated
    by ``benchmarks/pnr_bench.py``).  ``cluster_grid=1`` (or an array
    too small for the auto grid) degenerates to the flat single-level
    path and is bit-identical to :func:`place` at equal arguments.
    ``cluster_grid`` must divide rows and cols evenly with regions at
    least 2x2.
    """
    from ..kernels.pnr_cost import hpwl
    from ..obs import span
    from ..obs.metrics import global_registry

    if score_mode not in ("delta", "full"):
        raise ValueError(f"unknown score_mode {score_mode!r}")
    reg = metrics if metrics is not None else global_registry()
    g = _auto_cluster_grid(spec) if cluster_grid is None else int(cluster_grid)
    if g < 1:
        raise ValueError(f"cluster_grid must be >= 1, got {g}")
    if g == 1:
        flat = place(netlist, spec, backend="jax", chains=chains,
                     sweeps=sweeps, seed=seed, score_mode=score_mode,
                     t1=t1, max_states=max_states)
        return HierPlacement(coords=flat.coords, cost=flat.cost,
                             backend=flat.backend, chains=chains,
                             sweeps=sweeps, chain_costs=flat.chain_costs,
                             cluster_grid=1,
                             level_costs={"final_hpwl": flat.cost})
    if spec.rows % g or spec.cols % g:
        raise ValueError(f"cluster_grid {g} must divide rows x cols "
                         f"({spec.rows}x{spec.cols}) evenly")
    rh, rw = spec.rows // g, spec.cols // g
    if rh < 2 or rw < 2:
        raise ValueError(f"cluster_grid {g} leaves {rw}x{rh} regions; "
                         f"regions must be at least 2x2")
    cluster_sweeps = sweeps if cluster_sweeps is None else cluster_sweeps
    deblock_sweeps = (max(1, sweeps // 2) if deblock_sweeps is None
                      else deblock_sweeps)

    from .cluster import partition

    k_total = g * g
    with span("pnr.hier.partition", clusters=k_total):
        clus = partition(netlist, k_total, rh * rw)
    reg.inc("pnr.hier.place")
    total_nets = max(1, clus.cut_nets + clus.internal_nets)
    reg.observe("pnr.hier.cut_frac", clus.cut_nets / total_nets)

    # -- level 1: anneal cluster centroids on the g x g coarse grid --------
    coarse_spec = _region_spec(spec, g, g)
    coarse_nets = []
    for net in netlist.nets:
        ks = sorted({clus.cluster_of[c] for c in [net.driver] + net.sinks
                     if c in clus.cluster_of})
        if len(ks) > 1:
            coarse_nets.append((ks, []))
    coarse = _nets_problem(coarse_spec, [f"c{k}" for k in range(k_total)],
                           k_total, coarse_spec.pe_tiles(), coarse_nets)
    coarse.net_fix = None            # no external pins at the top level
    with span("pnr.hier.cluster", clusters=k_total, nets=len(coarse_nets)):
        (cslots, ccosts), = anneal_jax_batch(
            [coarse], chains=chains, seed=seed, sweeps=cluster_sweeps,
            t1=t1, score_mode=cluster_score_mode or score_mode,
            nonces=[0], metrics=reg, max_states=max_states)
    cbest = int(np.argmin(ccosts))
    cluster_slots = np.asarray(cslots[cbest])
    region_of: Dict[int, Coord] = {}
    origin: Dict[int, Tuple[int, int]] = {}
    center: Dict[int, Tuple[float, float]] = {}
    for k in range(k_total):
        rx, ry = coarse.slot_xy[cluster_slots[k]]
        region_of[k] = (int(rx), int(ry))
        origin[k] = (int(rx) * rw, int(ry) * rh)
        center[k] = (origin[k][0] + (rw - 1) / 2.0,
                     origin[k][1] + (rh - 1) / 2.0)

    # -- I/O cells: nearest free perimeter site to their partners ----------
    coords: Dict[str, Coord] = {}
    io_cells = sorted(netlist.io_cells, key=lambda c: c.name)
    partners: Dict[str, List[int]] = {c.name: [] for c in io_cells}
    for net in netlist.nets:
        pins = [net.driver] + net.sinks
        ks = [clus.cluster_of[c] for c in pins if c in clus.cluster_of]
        for c in pins:
            if c in partners:
                partners[c].extend(ks)
    free = list(enumerate(spec.io_sites()))
    with span("pnr.hier.io", cells=len(io_cells)):
        for c in io_cells:
            ks = partners[c.name]
            if ks:
                ex = sum(center[k][0] for k in ks) / len(ks)
                ey = sum(center[k][1] for k in ks) / len(ks)
            else:
                ex, ey = (spec.cols - 1) / 2.0, (spec.rows - 1) / 2.0
            j = min(range(len(free)),
                    key=lambda j: (abs(free[j][1][0] - ex)
                                   + abs(free[j][1][1] - ey), free[j][0]))
            coords[c.name] = free.pop(j)[1]

    # -- level 2: all clusters' detailed placements, one batched dispatch
    # per bucket signature -------------------------------------------------
    local_ent: Dict[str, int] = {}
    for k in range(k_total):
        for j, name in enumerate(clus.clusters[k]):
            local_ent[name] = j
    cluster_net_lists: List[List[Tuple[List[int], list]]] = [
        [] for _ in range(k_total)]
    for net in netlist.nets:
        by_k: Dict[int, List[int]] = {}
        io_pts = []
        for c in [net.driver] + net.sinks:
            k = clus.cluster_of.get(c)
            if k is None:
                io_pts.append(coords[c])
            else:
                by_k.setdefault(k, []).append(local_ent[c])
        for k, ents in by_k.items():
            ext = [center[j] for j in by_k if j != k] + io_pts
            ox, oy = origin[k]
            cluster_net_lists[k].append(
                (ents, [(px - ox, py - oy) for px, py in ext]))
    region_tiles = [(x, y) for y in range(rh) for x in range(rw)]
    local_spec = _region_spec(spec, rh, rw)
    problems: Dict[int, PlacementProblem] = {}
    for k in range(k_total):
        if clus.clusters[k]:
            problems[k] = _nets_problem(local_spec, clus.clusters[k],
                                        rh * rw, region_tiles,
                                        cluster_net_lists[k])
    groups: Dict[Tuple, List[int]] = {}
    for k in sorted(problems):
        groups.setdefault(batch_signature(problems[k], sweeps), []).append(k)
    detail_slots: Dict[int, np.ndarray] = {}
    detail_cost = 0.0
    with span("pnr.hier.detail", clusters=len(problems),
              dispatches=len(groups)):
        for sig in sorted(groups):
            idxs = groups[sig]
            out = anneal_jax_batch(
                [problems[k] for k in idxs], chains=chains, seed=seed,
                sweeps=sweeps, t1=t1,
                score_mode=detail_score_mode or score_mode,
                nonces=[k + 1 for k in idxs], metrics=reg,
                max_states=max_states)
            reg.observe("pnr.hier.detail_bucket", len(idxs))
            for k, (slots, costs) in zip(idxs, out):
                best = int(np.argmin(costs))
                detail_slots[k] = np.asarray(slots[best])
                detail_cost += float(costs[best])
    for k, prob in problems.items():
        ox, oy = origin[k]
        for j, name in enumerate(prob.cell_names):
            x, y = prob.slot_xy[detail_slots[k][j]]
            coords[name] = (int(x) + ox, int(y) + oy)

    # -- level 3: deblock — re-anneal the seam halo across clusters --------
    xs = {i * rw + dx for i in range(1, g) for dx in range(-deblock_halo,
                                                           deblock_halo)}
    ys = {i * rh + dy for i in range(1, g) for dy in range(-deblock_halo,
                                                           deblock_halo)}
    halo_tiles = [(x, y) for y in range(spec.rows) for x in range(spec.cols)
                  if x in xs or y in ys]
    halo_set = set(halo_tiles)
    pe_cells = sorted(netlist.pe_cells, key=lambda c: c.instance)
    movable = [c.name for c in pe_cells if coords[c.name] in halo_set]
    deblock_slots = None
    if movable and deblock_sweeps > 0:
        ent_of = {name: j for j, name in enumerate(movable)}
        dnets = []
        for net in netlist.nets:
            ents, ext = [], []
            for c in [net.driver] + net.sinks:
                if c in ent_of:
                    ents.append(ent_of[c])
                else:
                    ext.append(coords[c])
            if ents:
                dnets.append((ents, ext))
        dprob = _nets_problem(spec, movable, len(halo_tiles), halo_tiles,
                              dnets)
        tile_slot = {t: s for s, t in enumerate(halo_tiles)}
        incumbent = np.asarray([tile_slot[coords[name]] for name in movable]
                               + list(range(len(movable), len(halo_tiles))),
                               np.int32)
        with span("pnr.hier.deblock", cells=len(movable),
                  tiles=len(halo_tiles)):
            (dslots, dcosts), = anneal_jax_batch(
                [dprob], chains=chains, seed=seed, sweeps=deblock_sweeps,
                t0=deblock_t0, t1=t1,
                score_mode=deblock_score_mode or score_mode,
                nonces=[k_total + 1], metrics=reg, max_states=max_states)
        dbest = int(np.argmin(dcosts))
        # the anneal restarts from random seam permutations; keep the
        # detail-level arrangement when no chain beats it
        from ..kernels.pnr_cost import hpwl_fixed
        incumbent_cost = float(hpwl_fixed(
            dprob.slot_xy[incumbent], dprob.net_pins, dprob.net_mask,
            dprob.net_fix))
        if float(dcosts[dbest]) < incumbent_cost:
            deblock_slots = np.asarray(dslots[dbest])
            deblock_cost = float(dcosts[dbest])
            reg.inc("pnr.hier.deblock_improved")
        else:
            deblock_slots = incumbent
            deblock_cost = incumbent_cost
        for j, name in enumerate(movable):
            x, y = dprob.slot_xy[deblock_slots[j]]
            coords[name] = (int(x), int(y))
    else:
        deblock_cost = 0.0

    # -- exact whole-netlist objective of the final coordinates ------------
    full = lower(netlist, spec)
    slot_index = {t: i for i, t in enumerate(spec.pe_tiles())}
    slot_index.update({t: spec.n_pe_tiles + i
                       for i, t in enumerate(spec.io_sites())})
    slot_of = np.arange(full.n_entities, dtype=np.int32)
    for idx, name in enumerate(full.cell_names):
        slot_of[full.entity_of(idx)] = slot_index[coords[name]]
    final = float(hpwl(full.slot_xy[slot_of], full.net_pins, full.net_mask))
    return HierPlacement(
        coords=coords, cost=final, backend="jax", chains=chains,
        sweeps=sweeps, chain_costs=[], cluster_grid=g,
        clusters=clus.clusters, region_of=region_of,
        cluster_slots=cluster_slots, detail_slots=detail_slots,
        deblock_slots=deblock_slots, detail_dispatches=len(groups),
        level_costs={"cluster": float(ccosts[cbest]),
                     "detail": detail_cost, "deblock": deblock_cost,
                     "final_hpwl": final})
