"""Simulated-annealing placer with JAX-batched parallel chains.

Follows the cgra_pnr (thunder/SADetailedPlacer) shape: a placement is a
permutation of cells over tiles, moves swap a random cell with a random
tile (occupied -> swap, empty -> move), and candidate states are scored by
total half-perimeter wirelength.  Two engines share one lowering:

* ``backend="python"`` — the classic single-chain annealer with incremental
  per-net cost updates (the reference path);
* ``backend="jax"`` — C independent chains annealed in lockstep, one
  ``lax.fori_loop`` step proposing one move per chain and scoring it with
  the HPWL kernels (:mod:`repro.kernels.pnr_cost`).  On accelerators the
  whole sweep stays on-device.

Move scoring (``score_mode``): a swap touches only the nets incident to
the two swapped entities, so the default ``"delta"`` mode carries the
per-net cost vector through the loop state and rescores just those ≤2K
nets per move (O(K·D) instead of O(N·D)); ``"full"`` recomputes every
net's HPWL per move and is kept as the debug fallback.  Both modes see
identical move schedules and — HPWL values being exactly-representable
integers — compute bit-identical costs, so they accept/reject the same
moves and return bit-identical placements for equal seeds.

PE cells live on the rows x cols grid, I/O cells on the perimeter ring;
moves never cross the two classes, so every intermediate state is legal by
construction.
"""

from __future__ import annotations

import functools
import math
import random as _random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..kernels.tiling import pow2_bucket as _bucket
from .arch import Coord, FabricSpec
from .netlist import Netlist

__all__ = ["PlacementProblem", "Placement", "lower", "net_incidence",
           "anneal_python", "anneal_jax", "anneal_jax_batch", "place",
           "batch_signature"]


@dataclass
class PlacementProblem:
    spec: FabricSpec
    cell_names: List[str]            # PE cells first, then I/O cells
    n_pe_cells: int
    n_io_cells: int
    slot_xy: np.ndarray              # (E, 2) float32; PE slots then I/O slots
    n_pe_slots: int
    n_io_slots: int
    net_pins: np.ndarray             # (N, D) int32 entity indices (0-padded)
    net_mask: np.ndarray             # (N, D) bool
    ent_nets: np.ndarray = None      # (E, K) int32 entity -> incident nets,
    # padded with N (out of range) — the incidence table delta scoring uses
    # to find the nets a swap touches

    @property
    def n_entities(self) -> int:
        return self.n_pe_slots + self.n_io_slots

    def entity_of(self, cell_idx: int) -> int:
        """Entity index of the cell_idx-th cell in cell_names order."""
        if cell_idx < self.n_pe_cells:
            return cell_idx
        return self.n_pe_slots + (cell_idx - self.n_pe_cells)


@dataclass
class Placement:
    coords: Dict[str, Coord]         # cell name -> tile
    cost: float                      # HPWL of the chosen chain
    backend: str
    chains: int
    sweeps: int
    chain_costs: List[float] = field(default_factory=list)


def lower(netlist: Netlist, spec: FabricSpec) -> PlacementProblem:
    """Lower a netlist to the padded arrays both annealers consume."""
    pe = sorted(netlist.pe_cells, key=lambda c: c.instance)
    io = sorted(netlist.io_cells, key=lambda c: c.name)
    if len(pe) > spec.n_pe_tiles:
        raise ValueError(f"{len(pe)} PE cells exceed {spec.n_pe_tiles} tiles "
                         f"({spec.summary()}); use spec.fit()")
    if len(io) > spec.n_io_sites:
        raise ValueError(f"{len(io)} I/O cells exceed {spec.n_io_sites} "
                         f"perimeter sites ({spec.summary()})")
    slot_xy = np.asarray(spec.pe_tiles() + spec.io_sites(), np.float32)
    ent_of: Dict[str, int] = {}
    for i, c in enumerate(pe):
        ent_of[c.name] = i
    for j, c in enumerate(io):
        ent_of[c.name] = spec.n_pe_tiles + j

    nets = netlist.nets
    deg = max((n.degree for n in nets), default=1)
    net_pins = np.zeros((max(1, len(nets)), deg), np.int32)
    net_mask = np.zeros_like(net_pins, dtype=bool)
    for i, n in enumerate(nets):
        for j, cell in enumerate([n.driver] + n.sinks):
            net_pins[i, j] = ent_of[cell]
            net_mask[i, j] = True

    return PlacementProblem(
        spec=spec,
        cell_names=[c.name for c in pe] + [c.name for c in io],
        n_pe_cells=len(pe), n_io_cells=len(io),
        slot_xy=slot_xy,
        n_pe_slots=spec.n_pe_tiles, n_io_slots=spec.n_io_sites,
        net_pins=net_pins, net_mask=net_mask,
        ent_nets=net_incidence(net_pins, net_mask,
                               spec.n_pe_tiles + spec.n_io_sites))


def net_incidence(net_pins: np.ndarray, net_mask: np.ndarray,
                  n_entities: int) -> np.ndarray:
    """Padded entity -> incident-nets table for delta move scoring.

    Returns (E, K) int32 where K is the max nets on any entity; unused
    entries hold N (one past the last net) so out-of-range gathers and
    ``mode="drop"`` scatters ignore them.
    """
    n_nets = net_pins.shape[0]
    incident: List[List[int]] = [[] for _ in range(n_entities)]
    for i in range(n_nets):
        for e in net_pins[i][net_mask[i]]:
            incident[int(e)].append(i)
    k = max(1, max((len(l) for l in incident), default=1))
    table = np.full((n_entities, k), n_nets, np.int32)
    for e, l in enumerate(incident):
        table[e, :len(l)] = l
    return table


def _init_slots(p: PlacementProblem, rng: _random.Random) -> np.ndarray:
    """Random legal permutation: entity -> slot, classes kept separate."""
    pe_slots = list(range(p.n_pe_slots))
    io_slots = list(range(p.n_pe_slots, p.n_entities))
    rng.shuffle(pe_slots)
    rng.shuffle(io_slots)
    return np.asarray(pe_slots + io_slots, np.int32)


def _default_t0(p: PlacementProblem) -> float:
    return 0.5 * (p.spec.rows + p.spec.cols)


# ---------------------------------------------------------------------------
# Python reference chain (incremental delta evaluation)
# ---------------------------------------------------------------------------
def anneal_python(p: PlacementProblem, *, seed: int = 0, sweeps: int = 48,
                  t0: Optional[float] = None, t1: float = 0.02
                  ) -> Tuple[np.ndarray, float]:
    """Single annealing chain; returns (slot_of_entity, final HPWL)."""
    rng = _random.Random(seed)
    slot_of = _init_slots(p, rng)
    # maintained inverse permutation: occupant lookup is O(1) per move
    # instead of an O(E) nonzero scan
    ent_at_slot = np.empty_like(slot_of)
    ent_at_slot[slot_of] = np.arange(slot_of.shape[0], dtype=slot_of.dtype)
    pins = p.net_pins
    mask = p.net_mask
    xy = p.slot_xy

    def net_cost(i: int) -> float:
        xs = xy[slot_of[pins[i][mask[i]]]]
        if xs.size == 0:
            return 0.0
        return float(xs[:, 0].max() - xs[:, 0].min()
                     + xs[:, 1].max() - xs[:, 1].min())

    nets_of_ent: Dict[int, List[int]] = {}
    for i in range(pins.shape[0]):
        for e in pins[i][mask[i]]:
            nets_of_ent.setdefault(int(e), []).append(i)
    net_costs = [net_cost(i) for i in range(pins.shape[0])]
    cur = sum(net_costs)
    best = cur
    best_slot = slot_of.copy()

    movable: List[Tuple[int, int, int]] = []      # (lo_ent, n_cells, n_slots)
    if p.n_pe_cells:
        movable.append((0, p.n_pe_cells, p.n_pe_slots))
    if p.n_io_cells:
        movable.append((p.n_pe_slots, p.n_io_cells, p.n_io_slots))
    if not movable:
        return slot_of, 0.0
    n_real = p.n_pe_cells + p.n_io_cells
    steps = max(1, sweeps * n_real)
    t0 = _default_t0(p) if t0 is None else t0

    for step in range(steps):
        lo, n_cells, n_slots = movable[0] if (
            len(movable) == 1 or rng.random() < p.n_pe_cells / n_real
        ) else movable[-1]
        a = lo + rng.randrange(n_cells)
        slot_lo = 0 if lo == 0 else p.n_pe_slots
        t = slot_lo + rng.randrange(n_slots)
        b = int(ent_at_slot[t])
        if a == b:
            continue
        touched = sorted(set(nets_of_ent.get(a, []) + nets_of_ent.get(b, [])))
        old = sum(net_costs[i] for i in touched)
        slot_of[a], slot_of[b] = slot_of[b], slot_of[a]
        new_costs = {i: net_cost(i) for i in touched}
        delta = sum(new_costs.values()) - old
        temp = t0 * (t1 / t0) ** (step / steps)
        if delta <= 0 or rng.random() < math.exp(-delta / max(temp, 1e-9)):
            ent_at_slot[slot_of[a]], ent_at_slot[slot_of[b]] = a, b
            for i, c in new_costs.items():
                net_costs[i] = c
            cur += delta
            if cur < best:
                best, best_slot = cur, slot_of.copy()
        else:
            slot_of[a], slot_of[b] = slot_of[b], slot_of[a]
    return best_slot, float(best)


# ---------------------------------------------------------------------------
# JAX batched chains
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _build_annealer(steps: int, n_pe_c: int, n_io_c: int,
                    n_pe_s: int, n_io_s: int, t0: float, t1: float,
                    hpwl_backend: str = "jnp", score_mode: str = "delta"):
    """Compile one batched annealer per static problem shape.

    Caching here (rather than a fresh ``jax.jit`` per call) is what makes a
    DSE sweep cheap: every variant of the same fabric reuses the program.

    hpwl_backend selects the move-scoring kernel family: ``"jnp"`` (jitted
    jax.numpy reductions) or ``"pallas"`` (the Pallas kernels from
    :mod:`repro.kernels.pnr_cost`, compiled on TPU and interpreted on CPU
    hosts).  score_mode selects full recompute (``"full"``, O(N·D) per
    move) or incremental rescoring of only the touched nets (``"delta"``,
    O(K·D) per move).  All four combinations compute identical HPWL, so
    chains accept identical move sequences and return identical placements.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.pnr_cost import (hpwl, hpwl_delta, hpwl_delta_pallas,
                                    hpwl_pallas, net_hpwl)

    interpret = jax.default_backend() != "tpu"
    if hpwl_backend == "pallas":
        score = functools.partial(hpwl_pallas, interpret=interpret)
    elif hpwl_backend == "jnp":
        score = hpwl
    else:
        raise ValueError(f"unknown hpwl_backend {hpwl_backend!r}")
    if score_mode not in ("delta", "full"):
        raise ValueError(f"unknown score_mode {score_mode!r}")

    n_real = n_pe_c + n_io_c
    p_pe = n_pe_c / n_real
    temps = t0 * (t1 / t0) ** (jnp.arange(steps, dtype=jnp.float32) / steps)

    def chain(key, slot_of0, slot_xy, net_pins, net_mask, ent_nets):
        n_nets = net_pins.shape[0]

        # draw the whole move schedule up front: one RNG call per stream
        # instead of several threefry hashes inside every loop step
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        pick_pe = jax.random.uniform(k1, (steps,)) < p_pe
        a = jnp.where(pick_pe,
                      jax.random.randint(k2, (steps,), 0, max(1, n_pe_c)),
                      n_pe_s + jax.random.randint(k3, (steps,), 0,
                                                  max(1, n_io_c)))
        t = jnp.where(pick_pe,
                      jax.random.randint(k4, (steps,), 0, n_pe_s),
                      n_pe_s + jax.random.randint(k5, (steps,), 0, n_io_s))
        log_u = jnp.log(jax.random.uniform(k6, (steps,), minval=1e-12))

        def accept_and_track(i, accept, cand, new, state_rest):
            slot_of, cur, best_slot, best = state_rest
            slot_of = jnp.where(accept, cand, slot_of)
            cur = jnp.where(accept, new, cur)
            improved = cur < best
            best_slot = jnp.where(improved, slot_of, best_slot)
            best = jnp.where(improved, cur, best)
            return slot_of, cur, best_slot, best

        if score_mode == "full":
            def cost(slot_of):
                return score(slot_xy[slot_of], net_pins, net_mask)

            def step(i, state):
                slot_of, cur, best_slot, best = state
                ai, ti = a[i], t[i]
                b = jnp.argmax(slot_of == ti)   # occupant of target slot
                cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
                new = cost(cand)
                accept = (new <= cur) | (log_u[i] * temps[i] < cur - new)
                return accept_and_track(i, accept, cand, new, state)

            c0 = cost(slot_of0)
            _, _, best_slot, best = jax.lax.fori_loop(
                0, steps, step, (slot_of0, c0, slot_of0, c0))
            return best_slot, best

        # -- delta mode: per-net cost vector rides in the loop state -------
        k2_ = ent_nets.shape[1] * 2
        dup_tri = jnp.tril(jnp.ones((k2_, k2_), bool), k=-1)

        def step(i, state):
            slot_of, pnc, cur, best_slot, best = state
            ai, ti = a[i], t[i]
            b = jnp.argmax(slot_of == ti)       # occupant of target slot
            cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
            # nets incident to either swapped entity, deduped so a net
            # touching both contributes its delta exactly once
            tn = jnp.concatenate([ent_nets[ai], ent_nets[b]])
            dup = jnp.any((tn[:, None] == tn[None, :]) & dup_tri, axis=1)
            tn = jnp.where(dup, n_nets, tn)
            if hpwl_backend == "pallas":
                new_vals, delta = hpwl_delta_pallas(
                    slot_xy, slot_of, net_pins, net_mask, pnc, tn,
                    ai, b, interpret=interpret)
            else:
                new_vals, delta = hpwl_delta(slot_xy, cand, net_pins,
                                             net_mask, pnc, tn)
            new = cur + delta
            accept = (new <= cur) | (log_u[i] * temps[i] < cur - new)
            pnc = jnp.where(accept,
                            pnc.at[tn].set(new_vals, mode="drop"), pnc)
            slot_of, cur, best_slot, best = accept_and_track(
                i, accept, cand, new, (slot_of, cur, best_slot, best))
            return slot_of, pnc, cur, best_slot, best

        pnc0 = net_hpwl(slot_xy[slot_of0], net_pins, net_mask)
        c0 = jnp.sum(pnc0)
        _, _, _, best_slot, best = jax.lax.fori_loop(
            0, steps, step, (slot_of0, pnc0, c0, slot_of0, c0))
        return best_slot, best

    return jax.jit(jax.vmap(chain, in_axes=(0, 0, None, None, None, None)))


def anneal_jax(p: PlacementProblem, *, chains: int = 32, seed: int = 0,
               sweeps: int = 48, t0: Optional[float] = None,
               t1: float = 0.02, hpwl_backend: str = "jnp",
               score_mode: str = "delta"
               ) -> Tuple[np.ndarray, np.ndarray]:
    """C independent chains; returns (slot_of (C, E), costs (C,))."""
    import jax

    n_real = p.n_pe_cells + p.n_io_cells
    if n_real == 0:
        e = np.tile(np.arange(p.n_entities, dtype=np.int32), (chains, 1))
        return e, np.zeros((chains,), np.float32)
    steps = max(1, sweeps * n_real)
    t0 = _default_t0(p) if t0 is None else t0

    run = _build_annealer(steps, p.n_pe_cells, p.n_io_cells,
                          p.n_pe_slots, p.n_io_slots, float(t0), float(t1),
                          hpwl_backend, score_mode)
    rng = _random.Random(seed)
    init = np.stack([_init_slots(p, rng) for _ in range(chains)])
    keys = jax.random.split(jax.random.PRNGKey(seed), chains)
    ent_nets = p.ent_nets if p.ent_nets is not None else net_incidence(
        p.net_pins, p.net_mask, p.n_entities)
    slots, costs = run(keys, init, p.slot_xy, p.net_pins, p.net_mask,
                       ent_nets)
    return np.asarray(slots), np.asarray(costs)


# ---------------------------------------------------------------------------
# Cross-problem batching: many (variant, app) placements in one dispatch
# ---------------------------------------------------------------------------


def batch_signature(p: PlacementProblem, sweeps: int) -> Tuple[int, ...]:
    """Static shape key two problems must share to ride one dispatch."""
    steps = max(1, sweeps * (p.n_pe_cells + p.n_io_cells))
    return (_bucket(steps), _bucket(p.net_pins.shape[0]),
            _bucket(p.net_pins.shape[1]), _bucket(p.n_entities),
            _bucket(p.ent_nets.shape[1]))


#: cost-curve snapshot points captured per chain when telemetry is on
CURVE_POINTS = 16


@functools.lru_cache(maxsize=64)
def _build_batch_annealer(s_pad: int, n_pad: int, d_pad: int, e_pad: int,
                          k_pad: int, t1: float, hpwl_backend: str,
                          score_mode: str, telemetry: bool = False):
    """One compiled chain program for every problem of one bucket signature.

    Unlike :func:`_build_annealer` (which bakes the cell/slot counts into
    the program as static Python ints), the batched chain takes them as
    *data* — so PE1 on camera and PE4 on conv can share a program as long
    as their padded shapes land in the same buckets.  Moves are sampled by
    scaling uniforms with the dynamic counts, the temperature schedule uses
    the dynamic per-problem step count, and steps beyond a problem's real
    budget are masked to rejects.

    With ``telemetry`` the chain additionally returns its accepted-move
    count and :data:`CURVE_POINTS` current-cost snapshots.  The telemetry
    state only *observes* the accept decision and running cost — the move
    schedule and cost arithmetic are untouched — so placements and costs
    are bit-identical to the untelemetered program.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.pnr_cost import hpwl, hpwl_delta, net_hpwl

    if hpwl_backend != "jnp":
        raise ValueError("anneal_jax_batch supports hpwl_backend='jnp' only "
                         "(the pallas delta kernel scores one swap per call)")
    if score_mode not in ("delta", "full"):
        raise ValueError(f"unknown score_mode {score_mode!r}")

    def chain(key, slot_of0, slot_xy, net_pins, net_mask, ent_nets,
              dims, t0):
        n_pe_c, n_io_c, n_pe_s, n_io_s, n_steps = (
            dims[0], dims[1], dims[2], dims[3], dims[4])
        n_real = jnp.maximum(n_pe_c + n_io_c, 1)

        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        pick_pe = (jax.random.uniform(k1, (s_pad,))
                   < n_pe_c.astype(jnp.float32) / n_real.astype(jnp.float32))

        def scaled(k, count, lo):
            u = jax.random.uniform(k, (s_pad,))
            idx = jnp.minimum((u * count).astype(jnp.int32),
                              jnp.maximum(count - 1, 0))
            return lo + idx

        a = jnp.where(pick_pe, scaled(k2, n_pe_c, 0),
                      scaled(k3, n_io_c, n_pe_s))
        t = jnp.where(pick_pe, scaled(k4, n_pe_s, 0),
                      scaled(k5, n_io_s, n_pe_s))
        log_u = jnp.log(jax.random.uniform(k6, (s_pad,), minval=1e-12))
        frac = (jnp.arange(s_pad, dtype=jnp.float32)
                / jnp.maximum(n_steps.astype(jnp.float32), 1.0))
        temps = t0 * (t1 / t0) ** frac
        active = jnp.arange(s_pad) < n_steps

        def tele0():
            return (jnp.int32(0), jnp.zeros((CURVE_POINTS,), jnp.float32))

        def tele_track(i, accept, cur, tele):
            n_acc, curve = tele
            n_acc = n_acc + accept.astype(jnp.int32)
            idx = jnp.minimum((i * CURVE_POINTS) // s_pad, CURVE_POINTS - 1)
            return n_acc, curve.at[idx].set(cur)

        def accept_and_track(accept, cand, new, state_rest):
            slot_of, cur, best_slot, best = state_rest
            slot_of = jnp.where(accept, cand, slot_of)
            cur = jnp.where(accept, new, cur)
            improved = cur < best
            best_slot = jnp.where(improved, slot_of, best_slot)
            best = jnp.where(improved, cur, best)
            return slot_of, cur, best_slot, best

        if score_mode == "full":
            def step(i, state):
                slot_of, cur, best_slot, best = state[:4]
                ai, ti = a[i], t[i]
                b = jnp.argmax(slot_of == ti)
                cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
                new = hpwl(slot_xy[cand], net_pins, net_mask)
                accept = ((new <= cur)
                          | (log_u[i] * temps[i] < cur - new)) & active[i]
                out = accept_and_track(accept, cand, new, state[:4])
                if telemetry:
                    return out + tele_track(i, accept, out[1], state[4:])
                return out

            c0 = hpwl(slot_xy[slot_of0], net_pins, net_mask)
            state0 = (slot_of0, c0, slot_of0, c0)
            if telemetry:
                state0 = state0 + tele0()
            out = jax.lax.fori_loop(0, s_pad, step, state0)
            if telemetry:
                return out[2], out[3], out[4], out[5]
            return out[2], out[3]

        k2_ = k_pad * 2
        dup_tri = jnp.tril(jnp.ones((k2_, k2_), bool), k=-1)

        def step(i, state):
            slot_of, pnc, cur, best_slot, best = state[:5]
            ai, ti = a[i], t[i]
            b = jnp.argmax(slot_of == ti)
            cand = slot_of.at[ai].set(slot_of[b]).at[b].set(slot_of[ai])
            tn = jnp.concatenate([ent_nets[ai], ent_nets[b]])
            dup = jnp.any((tn[:, None] == tn[None, :]) & dup_tri, axis=1)
            tn = jnp.where(dup, n_pad, tn)
            new_vals, delta = hpwl_delta(slot_xy, cand, net_pins, net_mask,
                                         pnc, tn)
            new = cur + delta
            accept = ((new <= cur)
                      | (log_u[i] * temps[i] < cur - new)) & active[i]
            pnc = jnp.where(accept,
                            pnc.at[tn].set(new_vals, mode="drop"), pnc)
            slot_of, cur, best_slot, best = accept_and_track(
                accept, cand, new, (slot_of, cur, best_slot, best))
            if telemetry:
                tele = tele_track(i, accept, cur, state[5:])
                return (slot_of, pnc, cur, best_slot, best) + tele
            return slot_of, pnc, cur, best_slot, best

        pnc0 = net_hpwl(slot_xy[slot_of0], net_pins, net_mask)
        c0 = jnp.sum(pnc0)
        state0 = (slot_of0, pnc0, c0, slot_of0, c0)
        if telemetry:
            state0 = state0 + tele0()
        out = jax.lax.fori_loop(0, s_pad, step, state0)
        if telemetry:
            return out[3], out[4], out[5], out[6]
        return out[3], out[4]

    # one flat vmap over problems x chains, each row carrying its own
    # problem data: a nested vmap (outer problems, inner chains with the
    # problem arrays broadcast) would avoid the per-chain copies but
    # measures ~2x slower end to end on the Fig. 11 suite, so the copies
    # (a few MB at these sizes) buy the better-vectorizing flat batch
    return jax.jit(jax.vmap(chain))


def check_anneal_budget(p: PlacementProblem, chains: int, sweeps: int,
                        max_states: Optional[int], *,
                        metrics=None) -> None:
    """Refuse (pre-dispatch) an anneal whose state count exceeds budget.

    The annealing budget is deterministic and size-based — ``chains x
    sweeps x n_entities`` proposed states per problem — so exhaustion is
    a property of the problem, not of wall clock, and results stay
    bit-identical whenever the budget is *not* exhausted.  Raises
    :class:`repro.errors.BudgetExceeded` before any compilation or
    dispatch happens; no-op when ``max_states`` is None (the default).
    """
    if max_states is None:
        return
    states = chains * max(1, sweeps * (p.n_pe_cells + p.n_io_cells))
    if states > max_states:
        if metrics is not None:
            metrics.inc("pnr.budget_exhausted")
        from ..errors import BudgetExceeded
        raise BudgetExceeded(
            f"anneal needs {states} states "
            f"({chains} chains x {sweeps} sweeps x "
            f"{p.n_pe_cells + p.n_io_cells} cells > "
            f"anneal_max_states={max_states})",
            states=states, max_states=max_states, chains=chains,
            sweeps=sweeps, n_entities=p.n_entities)


def anneal_jax_batch(problems: List[PlacementProblem], *, chains: int = 16,
                     seed: int = 0, sweeps: int = 32,
                     t0: Optional[float] = None, t1: float = 0.02,
                     score_mode: str = "delta",
                     nonces: Optional[List[int]] = None,
                     telemetry: Optional[bool] = None,
                     metrics=None, max_states: Optional[int] = None
                     ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Anneal many placement problems in one JAX dispatch.

    All problems must share one :func:`batch_signature`; every problem's
    arrays are padded to the signature's bucket shapes (masked nets score
    zero, dummy entities sit on dummy slots and are never proposed as
    moves) and all ``len(problems) x chains`` chains run as one vmapped
    ``fori_loop``.  Returns per problem ``(slot_of (C, E), costs (C,))``
    with E the problem's real entity count — the same contract as
    :func:`anneal_jax`.

    Each problem's chains draw from ``fold_in(PRNGKey(seed), nonce)`` with
    ``nonces[i]`` defaulting to ``i``.  Callers wanting placements that are
    reproducible *regardless of grouping* (the explore pipeline's memo
    contract) pass a content-derived nonce per problem; with bucket-shape
    padding the result then depends only on the problem itself, never on
    its groupmates.

    ``telemetry`` (default: :func:`repro.obs.telemetry_enabled`) selects a
    compiled variant that also reports per-chain accept counts and
    cost-curve snapshots; placements stay bit-identical.  Acceptance rates
    land in ``metrics`` (histogram ``pnr.anneal.accept_rate``, cost curves
    as ``pnr.anneal.cost_curve.<nonce>`` gauges), defaulting to the global
    registry.
    """
    import jax

    from ..obs import telemetry_enabled
    from ..obs.metrics import global_registry

    if telemetry is None:
        telemetry = telemetry_enabled()

    if nonces is None:
        nonces = list(range(len(problems)))
    if len(nonces) != len(problems):
        raise ValueError("nonces must match problems 1:1")
    for p in problems:
        check_anneal_budget(p, chains, sweeps, max_states,
                            metrics=metrics or global_registry())
    sigs = {batch_signature(p, sweeps) for p in problems}
    if len(sigs) != 1:
        raise ValueError(f"problems span {len(sigs)} batch signatures; "
                         f"group by batch_signature() first")
    s_pad, n_pad, d_pad, e_pad, k_pad = next(iter(sigs))

    n_p = len(problems)
    net_pins = np.zeros((n_p, n_pad, d_pad), np.int32)
    net_mask = np.zeros((n_p, n_pad, d_pad), bool)
    slot_xy = np.zeros((n_p, e_pad, 2), np.float32)
    ent_nets = np.full((n_p, e_pad, k_pad), n_pad, np.int32)
    dims = np.zeros((n_p, 5), np.int32)
    t0s = np.zeros((n_p,), np.float32)
    init = np.tile(np.arange(e_pad, dtype=np.int32), (n_p, chains, 1))
    keys = np.zeros((n_p, chains, 2), np.uint32)
    base_key = jax.random.PRNGKey(seed)
    for i, p in enumerate(problems):
        n, d = p.net_pins.shape
        net_pins[i, :n, :d] = p.net_pins
        net_mask[i, :n, :d] = p.net_mask
        e = p.n_entities
        slot_xy[i, :e] = p.slot_xy
        en = np.where(p.ent_nets == n, n_pad, p.ent_nets)
        ent_nets[i, :e, :en.shape[1]] = en
        n_real = p.n_pe_cells + p.n_io_cells
        dims[i] = (p.n_pe_cells, p.n_io_cells, p.n_pe_slots, p.n_io_slots,
                   max(1, sweeps * n_real))
        t0s[i] = _default_t0(p) if t0 is None else t0
        rng = _random.Random(seed)
        for c in range(chains):
            init[i, c, :e] = _init_slots(p, rng)
        keys[i] = np.asarray(jax.random.split(
            jax.random.fold_in(base_key, nonces[i] & 0x7FFFFFFF), chains))

    run = _build_batch_annealer(s_pad, n_pad, d_pad, e_pad, k_pad,
                                float(t1), "jnp", score_mode,
                                bool(telemetry))

    def flat(x):                     # (P, C, ...) -> (P*C, ...)
        return x.reshape((n_p * chains,) + x.shape[2:])

    def tile(x):                     # (P, ...) -> (P*C, ...) per-chain copy
        return np.repeat(x, chains, axis=0)

    out = run(flat(keys), flat(init), tile(slot_xy),
              tile(net_pins), tile(net_mask), tile(ent_nets),
              tile(dims), tile(t0s))
    slots = np.asarray(out[0]).reshape(n_p, chains, e_pad)
    costs = np.asarray(out[1]).reshape(n_p, chains)
    if telemetry:
        reg = metrics if metrics is not None else global_registry()
        accepts = np.asarray(out[2]).reshape(n_p, chains)
        curves = np.asarray(out[3]).reshape(n_p, chains, CURVE_POINTS)
        for i, p in enumerate(problems):
            steps_i = max(1, sweeps * (p.n_pe_cells + p.n_io_cells))
            reg.observe("pnr.anneal.accept_rate",
                        float(accepts[i].mean()) / steps_i)
            best_chain = int(np.argmin(costs[i]))
            reg.set_gauge(f"pnr.anneal.cost_curve.{nonces[i] & 0x7FFFFFFF}",
                          [round(float(c), 3) for c in
                           curves[i, best_chain]])
    return [(slots[i, :, :p.n_entities], costs[i])
            for i, p in enumerate(problems)]


def place(netlist: Netlist, spec: FabricSpec, *, backend: str = "jax",
          chains: int = 32, sweeps: int = 48, seed: int = 0,
          t0: Optional[float] = None, t1: float = 0.02,
          hpwl_backend: str = "jnp", score_mode: str = "delta",
          max_states: Optional[int] = None) -> Placement:
    """Anneal and return the best chain's placement.

    ``max_states`` bounds the anneal state budget (chains x sweeps x
    entities) exactly like the batched path — the serial fallback must
    not silently out-spend the budget the grouped dispatch enforces.
    """
    if hpwl_backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown hpwl_backend {hpwl_backend!r}")
    if score_mode not in ("delta", "full"):
        raise ValueError(f"unknown score_mode {score_mode!r}")
    p = lower(netlist, spec)
    check_anneal_budget(p, chains, sweeps, max_states)

    if backend == "python":
        if hpwl_backend != "jnp":
            raise ValueError(
                "hpwl_backend applies to the jax annealer only; the python "
                "reference scores moves without the HPWL kernel")
        # the python reference is inherently incremental; score_mode only
        # selects between the jax engine's two scoring programs
        chain_results = [anneal_python(p, seed=seed + c, sweeps=sweeps,
                                       t0=t0, t1=t1)
                         for c in range(chains)]
        slots = np.stack([s for s, _ in chain_results])
        costs = np.asarray([c for _, c in chain_results], np.float32)
    elif backend == "jax":
        slots, costs = anneal_jax(p, chains=chains, seed=seed, sweeps=sweeps,
                                  t0=t0, t1=t1, hpwl_backend=hpwl_backend,
                                  score_mode=score_mode)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    best = int(np.argmin(costs))
    slot_of = slots[best]
    coords: Dict[str, Coord] = {}
    for idx, name in enumerate(p.cell_names):
        ent = p.entity_of(idx)
        x, y = p.slot_xy[slot_of[ent]]
        coords[name] = (int(x), int(y))
    return Placement(coords=coords, cost=float(costs[best]), backend=backend,
                     chains=chains, sweeps=sweeps,
                     chain_costs=[float(c) for c in costs])
