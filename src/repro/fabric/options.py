"""Bundled fabric/simulation options for DSE sweeps.

``core.dse`` grew one ``fabric_*`` kwarg per place-and-route knob; with the
time-domain subsystem adding scheduler/simulator knobs, the loose kwargs
are folded into one :class:`FabricOptions` record.  The legacy kwargs are
still accepted by the DSE entry points and folded into an options object,
so existing call sites keep working.
"""

from __future__ import annotations

import warnings
from dataclasses import asdict, dataclass, fields, replace
from typing import Any, Dict, Optional

from .arch import FabricSpec


@dataclass(frozen=True)
class FabricOptions:
    """Everything array-level evaluation needs, in one place.

    spec           — the target array (auto-grown per variant when needed).
    backend        — annealing engine: "jax" (batched chains) | "python".
    hpwl_backend   — placement cost kernel: "jnp" | "pallas"
                     (:func:`repro.kernels.pnr_cost.hpwl_pallas`, interpret
                     mode off-TPU).
    score_mode     — move scoring: "delta" (incremental — rescore only the
                     nets the swap touches; the default and the only mode
                     that scales past ~32x32) | "full" (recompute every
                     net per move; debug fallback — bit-identical
                     placements at equal seeds).
    chains/sweeps/seed — annealing budget and determinism.
    simulate       — run the modulo scheduler + cycle-accurate simulator on
                     every (variant, app) mapping and attach measured
                     throughput (``sim_*`` fields) to the AppCost records.
    sim_iterations/sim_batch — pipelined iterations x input batches fed to
                     the simulator (also drives the golden check).
    sim_backend    — tile-step dispatch: "jax" | "pallas".  Only "jax" can
                     ride the batch-first simulate stage (the pallas
                     kernel is per-program); other values fall back to the
                     per-pair loop.
    sim_verify     — bit-compare simulated outputs against graphir.interp
                     and record the result (raises on mismatch).

    Budgets (all deterministic, all default-off / legacy-default so
    results are bit-identical unless a budget is actually exhausted; on
    exhaustion the stage raises :class:`repro.errors.BudgetExceeded`
    instead of looping or hanging — see ISSUE 8):

    sched_max_ii        — cap on the modulo scheduler's II search (None =
                          the legacy mii + n_ops + 1 bound).
    sched_budget_factor — scheduler eviction budget multiplier (budget =
                          factor * n_ops + 64 evictions per II; 8 is the
                          legacy constant).
    anneal_max_states   — cap on chains x sweeps x n_entities per anneal
                          problem, checked *before* dispatch (None = off).
    sim_max_cycles      — cap on total simulated cycles per program,
                          checked before dispatch (None = off).
    """

    spec: Optional[FabricSpec] = None
    backend: str = "jax"
    hpwl_backend: str = "jnp"
    score_mode: str = "delta"
    chains: int = 16
    sweeps: int = 32
    seed: int = 0
    simulate: bool = False
    sim_iterations: int = 3
    sim_batch: int = 2
    sim_backend: str = "jax"
    sim_verify: bool = True
    sched_max_ii: Optional[int] = None
    sched_budget_factor: int = 8
    anneal_max_states: Optional[int] = None
    sim_max_cycles: Optional[int] = None

    def with_spec(self, spec: FabricSpec) -> "FabricOptions":
        return replace(self, spec=spec)

    def input_seed(self, nonce: int) -> int:
        """RNG seed for one pair's golden-check test vectors.

        Folding a content-derived nonce (hash of the (variant, app) pair)
        into the configured seed makes every pair's vectors — and so its
        simulated outputs — a function of the pair alone: the same whether
        the pair simulates per-pair, shares a batched dispatch, or rides a
        differently-composed bucket (the same contract
        :func:`repro.fabric.place.anneal_jax_batch` keeps for placements).
        """
        return (self.seed ^ (nonce & 0x7FFFFFFF)) & 0x7FFFFFFF

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict; inverse of :meth:`from_dict`."""
        d = asdict(self)
        d["spec"] = None if self.spec is None else asdict(self.spec)
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "FabricOptions":
        d = dict(d)
        spec = d.pop("spec", None)
        known = {f.name for f in fields(FabricOptions)} - {"spec"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FabricOptions fields {sorted(unknown)}")
        return FabricOptions(
            spec=None if spec is None else FabricSpec(**spec), **d)

    @staticmethod
    def coerce(fabric, *, backend: Optional[str] = None,
               chains: Optional[int] = None, sweeps: Optional[int] = None,
               seed: Optional[int] = None,
               simulate: bool = False) -> Optional["FabricOptions"]:
        """Normalize the legacy ``fabric=FabricSpec(...)`` + ``fabric_*``
        kwarg style (and plain None) into a FabricOptions or None.

        Legacy kwargs left at None fall back to the FabricOptions field
        defaults; passing any of them alongside a FabricOptions object is
        an error rather than a silent discard.
        """
        legacy = {"fabric_backend": backend, "fabric_chains": chains,
                  "fabric_sweeps": sweeps, "fabric_seed": seed}
        if fabric is None:
            if simulate:
                raise ValueError("simulate=True requires a fabric "
                                 "(pass FabricOptions or FabricSpec)")
            return None
        if isinstance(fabric, FabricOptions):
            overridden = [k for k, v in legacy.items() if v is not None]
            if overridden:
                raise ValueError(
                    f"legacy kwargs {overridden} are ignored when passing a "
                    f"FabricOptions — set those fields on the options object")
            return replace(fabric, simulate=fabric.simulate or simulate)
        if isinstance(fabric, FabricSpec):
            passed = [k for k, v in legacy.items() if v is not None]
            if passed:
                warnings.warn(
                    f"the loose {passed} kwargs are deprecated; pass "
                    f"fabric=FabricOptions(spec=..., ...) (or use "
                    f"repro.explore.ExploreConfig) instead",
                    DeprecationWarning, stacklevel=3)
            defaults = FabricOptions()
            return FabricOptions(
                spec=fabric,
                backend=defaults.backend if backend is None else backend,
                chains=defaults.chains if chains is None else chains,
                sweeps=defaults.sweeps if sweeps is None else sweeps,
                seed=defaults.seed if seed is None else seed,
                simulate=simulate)
        raise TypeError(f"fabric must be FabricSpec or FabricOptions, "
                        f"got {type(fabric).__name__}")
