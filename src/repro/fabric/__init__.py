"""Fabric place-and-route: map DSE variants onto an N x M CGRA array.

The paper's loop (mine -> merge -> map -> cost) stops at the single-PE
level; this subsystem models the array.  Given a
:class:`~repro.core.mapper.Mapping` and a :class:`FabricSpec`, it extracts
the inter-tile netlist, places cells with JAX-batched simulated annealing,
routes every net over the mesh, and prices the result at array level —
exposing the tradeoff the per-tile model cannot see: fewer, bigger PEs mean
fewer tiles and shorter routes.

    from repro.fabric import FabricSpec, place_and_route
    pnr = place_and_route(dp, mapping, app, FabricSpec(rows=8, cols=8))
    print(pnr.cost.row())
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..core.mapper import Mapping
from ..core.pe import Datapath
from ..graphir.graph import Graph
from .arch import FabricSpec, manhattan
from .cost import FabricCost, attach_fabric, evaluate_fabric
from .cluster import Clustering, partition
from .netlist import Cell, Net, Netlist, extract_netlist, synthetic_netlist
from .options import FabricOptions
from .place import HierPlacement, Placement, PlacementProblem, anneal_jax, \
    anneal_jax_batch, anneal_python, batch_signature, lower, net_incidence, \
    place, place_hierarchical
from .route import RouteResult, RoutedNet, route_nets

__all__ = [
    "FabricSpec", "FabricOptions", "manhattan", "Cell", "Net", "Netlist",
    "extract_netlist", "synthetic_netlist", "Placement", "PlacementProblem",
    "HierPlacement", "Clustering", "partition",
    "lower", "net_incidence", "place", "place_hierarchical", "anneal_jax",
    "anneal_jax_batch", "anneal_python", "batch_signature",
    "RouteResult", "RoutedNet", "route_nets",
    "FabricCost", "evaluate_fabric", "attach_fabric", "PnRResult",
    "place_and_route",
]


@dataclass
class PnRResult:
    spec: FabricSpec
    netlist: Netlist
    placement: Placement
    routes: RouteResult
    cost: FabricCost


def place_and_route(dp: Datapath, mapping: Mapping, app: Graph,
                    spec: Optional[FabricSpec] = None, *,
                    backend: str = "jax", chains: int = 16,
                    sweeps: int = 32, seed: int = 0,
                    auto_size: bool = True, pe_name: str = "PE",
                    hpwl_backend: str = "jnp",
                    score_mode: str = "delta",
                    max_states: Optional[int] = None,
                    pnr_mode: str = "flat") -> PnRResult:
    """Full flow: netlist -> place -> route -> array-level cost.

    ``pnr_mode="hierarchical"`` runs :func:`place_hierarchical` (cluster ->
    detail -> deblock) instead of the flat single-level anneal — worth it
    for mega-fabrics, pure overhead for the small arrays single mapped
    apps produce.  The default stays the flat path, bit-identical to what
    this function returned before ``pnr_mode`` existed.
    """
    spec = spec or FabricSpec()
    netlist = extract_netlist(mapping, app, spec)
    if auto_size:
        spec = spec.fit(len(netlist.pe_cells), len(netlist.io_cells))
    if pnr_mode == "hierarchical":
        if backend != "jax" or hpwl_backend != "jnp":
            raise ValueError("pnr_mode='hierarchical' requires the jax "
                             "backend with hpwl_backend='jnp'")
        placement = place_hierarchical(netlist, spec, chains=chains,
                                       sweeps=sweeps, seed=seed,
                                       score_mode=score_mode,
                                       max_states=max_states)
    elif pnr_mode == "flat":
        placement = place(netlist, spec, backend=backend, chains=chains,
                          sweeps=sweeps, seed=seed,
                          hpwl_backend=hpwl_backend,
                          score_mode=score_mode, max_states=max_states)
    else:
        raise ValueError(f"unknown pnr_mode {pnr_mode!r}")
    routes = route_nets(netlist, placement, spec)
    fc = evaluate_fabric(dp, mapping, netlist, placement, routes, spec,
                         pe_name=pe_name)
    return PnRResult(spec, netlist, placement, routes, fc)
