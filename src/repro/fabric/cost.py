"""Array-level area / energy / timing from a placed-and-routed mapping.

The per-tile model in :mod:`repro.core.costmodel` charges every PE a flat
connection-box/switch-box share; after place-and-route we know the actual
interconnect activity, so the fabric cost prices:

* **hop energy** — every routed channel segment toggles wire + switch
  capacitance (``spec.hop_energy_pj`` per word per hop), plus the CB at each
  sink and SB at each driver (the costmodel constants);
* **I/O energy** — each signal entering/leaving the array pays a memory-tile
  access;
* **area** — the full manufactured array (all PE tiles at CGRA-level area)
  plus one memory-interface tile per used I/O cell;
* **timing** — cycle time is the PE stage delay plus the longest
  source-to-sink route (unregistered mesh hops).

:func:`attach_fabric` writes the array-accurate numbers back onto the
:class:`~repro.core.costmodel.AppCost` record so DSE tables can show both
views side by side.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..core.costmodel import (AppCost, CB_ENERGY_PJ, MEM_TILE_AREA_UM2,
                              MEM_TILE_ENERGY_PJ, SB_ENERGY_PJ)
from ..core.mapper import Mapping
from ..core.pe import Datapath
from .arch import FabricSpec
from .netlist import Netlist
from .place import Placement
from .route import RouteResult


@dataclass
class FabricCost:
    app: str
    pe_name: str
    rows: int
    cols: int
    n_pe_cells: int
    n_io_cells: int
    utilization: float              # PE cells / PE tiles
    hpwl: float                     # placement objective of the chosen chain
    wirelength_hops: int
    max_channel_util: float
    overflow: int
    crit_path_hops: int
    fmax_ghz: float
    pe_energy_pj: float
    route_energy_pj: float
    io_energy_pj: float
    total_energy_pj: float
    energy_per_op_pj: float
    fabric_area_um2: float

    def row(self) -> str:
        return (f"{self.app:<16} {self.pe_name:<10} "
                f"grid={self.cols}x{self.rows} "
                f"util={self.utilization:4.2f} wl={self.wirelength_hops:<5d} "
                f"chan={self.max_channel_util:4.2f} "
                f"crit={self.crit_path_hops:<3d} "
                f"fmax={self.fmax_ghz:4.2f}GHz "
                f"e/op={self.energy_per_op_pj:7.4f}pJ "
                f"area={self.fabric_area_um2/1e3:8.1f}kum2")


def evaluate_fabric(dp: Datapath, mapping: Mapping, netlist: Netlist,
                    placement: Placement, routes: RouteResult,
                    spec: FabricSpec, *, pe_name: str = "PE",
                    idle_fraction: float = 0.55) -> FabricCost:
    pe_energy = sum(
        dp.config_energy_pj(dp.configs[inst.config],
                            idle_fraction=idle_fraction)
        for inst in mapping.instances)

    hop_e = routes.wirelength * spec.hop_energy_pj
    endpoint_e = sum(SB_ENERGY_PJ + CB_ENERGY_PJ * len(n.sinks)
                     for n in routes.nets)
    route_energy = hop_e + endpoint_e

    io_signals = sum(len(c.signals) for c in netlist.io_cells)
    io_energy = MEM_TILE_ENERGY_PJ * io_signals

    n_io_used = len(netlist.io_cells)
    area = (dp.area_um2(include_io=True) * spec.n_pe_tiles
            + MEM_TILE_AREA_UM2 * n_io_used)

    crit = routes.crit_path_hops
    t_clk = dp.stage_delay_ns() + crit * spec.hop_delay_ns
    fmax = 1.0 / max(t_clk, 1e-3)

    total = pe_energy + route_energy + io_energy
    total_ops = max(1, mapping.total_ops)
    return FabricCost(
        app=mapping.app_name, pe_name=pe_name,
        rows=spec.rows, cols=spec.cols,
        n_pe_cells=len(netlist.pe_cells), n_io_cells=n_io_used,
        utilization=len(netlist.pe_cells) / spec.n_pe_tiles,
        hpwl=placement.cost,
        wirelength_hops=routes.wirelength,
        max_channel_util=routes.max_util,
        overflow=routes.overflow,
        crit_path_hops=crit,
        fmax_ghz=fmax,
        pe_energy_pj=pe_energy,
        route_energy_pj=route_energy,
        io_energy_pj=io_energy,
        total_energy_pj=total,
        energy_per_op_pj=total / total_ops,
        fabric_area_um2=area)


def attach_fabric(cost: AppCost, fc: FabricCost) -> AppCost:
    """Write array-accurate numbers onto the per-tile AppCost record."""
    cost.fabric_area_um2 = fc.fabric_area_um2
    cost.fabric_energy_per_op_pj = fc.energy_per_op_pj
    cost.fabric_fmax_ghz = fc.fmax_ghz
    cost.fabric_wirelength = fc.wirelength_hops
    cost.fabric_utilization = fc.utilization
    return cost
