"""Deterministic maze router over mesh channels with congestion rip-up.

PathFinder-style negotiated congestion, scoped to the small fabrics this
subsystem targets: each net is routed as a Steiner-ish tree (Dijkstra from
the growing tree to each sink, farthest sink first), channel overuse is
priced into edge costs, and overused iterations rip up only the offending
nets and reroute them with accumulated history penalties.  Everything is
ordered (sorted nets, sorted neighbor expansion, tie-broken heap) so a
given (netlist, placement, spec) always routes identically.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .arch import Coord, Edge, FabricSpec, manhattan
from .netlist import Netlist
from .place import Placement


@dataclass
class RoutedNet:
    name: str
    driver: Coord
    sinks: List[Coord]
    edges: List[Edge] = field(default_factory=list)     # tree edges, directed
    sink_hops: Dict[Coord, int] = field(default_factory=dict)

    @property
    def wirelength(self) -> int:
        return len(self.edges)

    @property
    def max_hops(self) -> int:
        return max(self.sink_hops.values(), default=0)


@dataclass
class RouteResult:
    nets: List[RoutedNet]
    wirelength: int
    overflow: int                     # sum of per-edge overuse after routing
    max_util: float                   # worst edge usage / capacity
    iterations: int
    edge_usage: Dict[Edge, int] = field(default_factory=dict)

    @property
    def success(self) -> bool:
        return self.overflow == 0

    @property
    def crit_path_hops(self) -> int:
        return max((n.max_hops for n in self.nets), default=0)


def _dijkstra_to_sink(sources: Set[Coord], sink: Coord,
                      caps: Dict[Edge, int], usage: Dict[Edge, int],
                      hist: Dict[Edge, float], spec: FabricSpec,
                      pres_fac: float) -> Optional[List[Edge]]:
    """Cheapest path from any source tile to `sink`; returns directed edges."""
    dist: Dict[Coord, float] = {s: 0.0 for s in sources}
    prev: Dict[Coord, Coord] = {}
    counter = 0
    heap: List[Tuple[float, int, Coord]] = []
    for s in sorted(sources):
        heapq.heappush(heap, (manhattan(s, sink) * 1.0, counter, s))
        counter += 1
    done: Set[Coord] = set()
    while heap:
        _, _, u = heapq.heappop(heap)
        if u in done:
            continue
        done.add(u)
        if u == sink:
            path: List[Edge] = []
            while u not in sources:
                path.append((prev[u], u))
                u = prev[u]
            path.reverse()
            return path
        du = dist[u]
        for v in sorted(spec.neighbors(u)):
            e = (u, v)
            over = usage.get(e, 0) + 1 - caps[e]
            cost = 1.0 + hist.get(e, 0.0) + (pres_fac * over if over > 0
                                             else 0.0)
            nd = du + cost
            if nd < dist.get(v, float("inf")):
                dist[v] = nd
                prev[v] = u
                heapq.heappush(heap, (nd + manhattan(v, sink), counter, v))
                counter += 1
    return None


def _route_one(name: str, driver: Coord, sinks: List[Coord],
               caps: Dict[Edge, int], usage: Dict[Edge, int],
               hist: Dict[Edge, float], spec: FabricSpec,
               pres_fac: float) -> RoutedNet:
    net = RoutedNet(name, driver, list(sinks))
    tree: Set[Coord] = {driver}
    hops: Dict[Coord, int] = {driver: 0}
    used: Set[Edge] = set()
    for sink in sorted(sinks, key=lambda s: (-manhattan(driver, s), s)):
        if sink in tree:
            net.sink_hops[sink] = hops[sink]
            continue
        path = _dijkstra_to_sink(tree, sink, caps, usage, hist, spec,
                                 pres_fac)
        if path is None:                      # grid is connected; defensive
            raise RuntimeError(f"net {name}: no route {driver} -> {sink}")
        base = path[0][0]
        h = hops.get(base, 0)
        for (a, b) in path:
            h += 1
            if (a, b) not in used:
                used.add((a, b))
                net.edges.append((a, b))
                usage[(a, b)] = usage.get((a, b), 0) + 1
            tree.add(b)
            hops[b] = min(hops.get(b, h), h)
        net.sink_hops[sink] = hops[sink]
    return net


def route_nets(netlist: Netlist, placement: Placement, spec: FabricSpec,
               *, max_iters: int = 8, pres_fac: float = 2.0,
               hist_inc: float = 1.0) -> RouteResult:
    """Route every net of `netlist` under `placement`."""
    caps = spec.routing_edges()
    usage: Dict[Edge, int] = {}
    hist: Dict[Edge, float] = {}
    coords = placement.coords

    work = []
    for n in sorted(netlist.nets, key=lambda n: n.name):
        driver = coords[n.driver]
        sinks = [coords[s] for s in n.sinks]
        work.append((n.name, driver, sinks))

    routed: Dict[str, RoutedNet] = {}
    iters = 0
    pending = list(work)
    pf = pres_fac
    for it in range(max_iters):
        iters = it + 1
        for name, driver, sinks in pending:
            routed[name] = _route_one(name, driver, sinks, caps, usage,
                                      hist, spec, pf)
        overused = {e for e, u in usage.items() if u > caps[e]}
        if not overused or it == max_iters - 1:
            break        # done, or out of iterations: keep usage honest
        # penalize, rip up offenders, retry
        for e in overused:
            hist[e] = hist.get(e, 0.0) + hist_inc
        pending = []
        for name, driver, sinks in work:
            net = routed[name]
            if any(e in overused for e in net.edges):
                for e in net.edges:
                    usage[e] -= 1
                pending.append((name, driver, sinks))
        if not pending:
            break
        pf *= 1.6

    nets = [routed[name] for name, _, _ in work]
    overflow = sum(max(0, u - caps[e]) for e, u in usage.items())
    max_util = max((u / caps[e] for e, u in usage.items()), default=0.0)
    return RouteResult(nets=nets,
                       wirelength=sum(n.wirelength for n in nets),
                       overflow=overflow, max_util=max_util,
                       iterations=iters,
                       edge_usage={e: u for e, u in usage.items() if u})
