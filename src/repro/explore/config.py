"""One frozen config object for a whole exploration.

The paper's flow (Sec. IV, Fig. 6) is a fixed pipeline — mine -> rank ->
merge -> map -> evaluate — but the original driver threaded one keyword
argument per subsystem through three layers.  :class:`ExploreConfig`
bundles every knob (mining budget, merge/rank options, fabric
place-and-route, time-domain simulation) into a single dataclass with a
JSON round trip, so an exploration is reproducible from one blob::

    cfg = ExploreConfig(mode="per_app", mining=MiningConfig(min_support=3),
                        fabric=FabricOptions(spec=FabricSpec(rows=8, cols=8),
                                             simulate=True))
    json.dump(cfg.to_dict(), open("explore.json", "w"))
    cfg2 = ExploreConfig.from_dict(json.load(open("explore.json")))
    assert cfg2 == cfg
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field, fields, replace
from typing import Any, Dict, Optional

from ..core.mining import MiningConfig
from ..fabric.options import FabricOptions

#: bump when a field is added/renamed/retyped; from_dict rejects unknown
#: versions so stale blobs fail loudly instead of silently defaulting
#: (2: added sim_batch — batch-first schedule/simulate stages)
#: (3: added on_error — per-pair fault isolation policy)
#: (4: added pnr_mode — flat vs hierarchical placement)
CONFIG_SCHEMA = 4

MODES = ("per_app", "domain")
PNR_BATCH_MODES = ("grouped", "serial")
PNR_MODES = ("flat", "hierarchical")
SIM_BATCH_MODES = ("grouped", "serial")
ON_ERROR_MODES = ("isolate", "raise")


class ConfigFormatError(ValueError):
    """An ExploreConfig blob that can't be parsed — reported as a
    one-line error by the CLI, never a stack trace."""


@dataclass(frozen=True)
class ExploreConfig:
    """Everything one DSE run needs, in one place.

    mode              — "per_app" (PE1..PE(1+max_merge) per application,
                        paper Sec. V-A) | "domain" (one cross-application
                        PE IP / PE ML, Sec. V-B).
    mining            — frequent-subgraph mining budget (Sec. III-A).
    max_merge         — subgraphs merged per app in per_app mode.
    rank_mode         — "mis" (paper order) | "utility" (beyond-paper).
    validate          — prove each merged config executes its pattern.
    per_app_subgraphs — subgraphs each app contributes in domain mode.
    domain_name       — the domain variant's PE name.
    fabric            — array-level evaluation (place-and-route and, with
                        ``fabric.simulate``, modulo scheduling + cycle-
                        accurate simulation); None = per-tile model only.
    pnr_batch         — "grouped": all (variant, app) placements of one
                        bucket signature anneal in one JAX dispatch
                        (:func:`repro.fabric.place.anneal_jax_batch`);
                        "serial": one dispatch per pair (the legacy loop —
                        bit-identical to the pre-``repro.explore`` driver).
    pnr_mode          — "flat": single-level anneal over the whole array
                        (the default; bit-identical to every build before
                        this field existed); "hierarchical": two-level
                        cluster -> detail -> deblock flow
                        (:func:`repro.fabric.place.place_hierarchical`)
                        for mega-fabrics.  Hierarchical pairs run on the
                        serial dispatch path (each placement is already
                        internally batched across its clusters), so
                        ``pnr_batch="grouped"`` is ignored for them.
    sim_batch         — "grouped": modulo scheduling runs its slot-conflict
                        scans in lockstep across pairs sharing a fabric
                        signature, and all simulations of one bucket
                        signature ride ONE vmapped ``lax.scan``
                        (:func:`repro.sim.simulate_batch`); "serial": the
                        per-pair schedule + one-compile-per-program loop.
                        Both modes produce bit-identical schedules and
                        simulated outputs.  (Distinct from
                        ``FabricOptions.sim_batch``, the *input batch
                        size* fed to each simulation.)
    on_error          — "isolate": a failing (variant, app) pair falls
                        out of its batch group, is retried once on the
                        serial path, and on second failure becomes a
                        structured StageFailure row while groupmates
                        complete (the pow2-bucket independence invariant
                        makes this safe); "raise": legacy behavior, the
                        first failure propagates and kills the run.
    """

    mode: str = "per_app"
    mining: MiningConfig = field(default_factory=MiningConfig)
    max_merge: int = 4
    rank_mode: str = "mis"
    validate: bool = True
    per_app_subgraphs: int = 2
    domain_name: str = "PE_DOM"
    fabric: Optional[FabricOptions] = None
    pnr_batch: str = "grouped"
    pnr_mode: str = "flat"
    sim_batch: str = "grouped"
    on_error: str = "isolate"

    def __post_init__(self) -> None:
        if self.pnr_mode not in PNR_MODES:
            raise ValueError(f"pnr_mode must be one of {PNR_MODES}, "
                             f"got {self.pnr_mode!r}")
        if self.on_error not in ON_ERROR_MODES:
            raise ValueError(f"on_error must be one of {ON_ERROR_MODES}, "
                             f"got {self.on_error!r}")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.pnr_batch not in PNR_BATCH_MODES:
            raise ValueError(f"pnr_batch must be one of {PNR_BATCH_MODES}, "
                             f"got {self.pnr_batch!r}")
        if self.sim_batch not in SIM_BATCH_MODES:
            raise ValueError(f"sim_batch must be one of {SIM_BATCH_MODES}, "
                             f"got {self.sim_batch!r}")
        if self.rank_mode not in ("mis", "utility"):
            raise ValueError(f"unknown rank_mode {self.rank_mode!r}")
        if self.simulate and self.fabric is None:
            raise ValueError("simulation requires a fabric")

    @property
    def simulate(self) -> bool:
        return self.fabric is not None and self.fabric.simulate

    def replace(self, **changes: Any) -> "ExploreConfig":
        return replace(self, **changes)

    # -- JSON round trip ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["mining"] = asdict(self.mining)
        d["fabric"] = None if self.fabric is None else self.fabric.to_dict()
        d["schema"] = CONFIG_SCHEMA
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ExploreConfig":
        if not isinstance(d, dict):
            raise ConfigFormatError(
                f"ExploreConfig blob must be an object, got "
                f"{type(d).__name__}")
        d = dict(d)
        schema = d.pop("schema", CONFIG_SCHEMA)
        if schema != CONFIG_SCHEMA:
            raise ConfigFormatError(
                f"ExploreConfig schema {schema!r} not supported (this build "
                f"reads schema {CONFIG_SCHEMA}) — regenerate the blob with "
                f"ExploreConfig.to_dict() from a matching build")
        known = {f.name for f in fields(ExploreConfig)}
        unknown = set(d) - known
        if unknown:
            raise ConfigFormatError(
                f"unknown ExploreConfig fields {sorted(unknown)} — "
                f"known fields are {sorted(known)}")
        for name, want in (("mode", str), ("max_merge", int),
                           ("rank_mode", str), ("validate", bool),
                           ("per_app_subgraphs", int), ("domain_name", str),
                           ("pnr_batch", str), ("pnr_mode", str),
                           ("sim_batch", str), ("on_error", str)):
            if name in d and (not isinstance(d[name], want)
                              or (want is int and isinstance(d[name], bool))):
                raise ConfigFormatError(
                    f"ExploreConfig field {name!r} must be "
                    f"{want.__name__}, got {type(d[name]).__name__} "
                    f"({d[name]!r})")
        mining = d.pop("mining", None)
        fabric = d.pop("fabric", None)
        if mining is not None and not isinstance(mining, dict):
            raise ConfigFormatError(
                f"ExploreConfig field 'mining' must be an object, got "
                f"{type(mining).__name__}")
        if fabric is not None and not isinstance(fabric, dict):
            raise ConfigFormatError(
                f"ExploreConfig field 'fabric' must be an object or null, "
                f"got {type(fabric).__name__}")
        try:
            return ExploreConfig(
                mining=MiningConfig(**mining) if mining else MiningConfig(),
                fabric=(None if fabric is None
                        else FabricOptions.from_dict(fabric)),
                **d)
        except (TypeError, ValueError) as e:
            if isinstance(e, ConfigFormatError):
                raise
            raise ConfigFormatError(f"bad ExploreConfig blob: {e}")
