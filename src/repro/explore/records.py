"""Schema-versioned flat result rows for exploration sweeps.

Every (variant, app) pair an :class:`~repro.explore.Explorer` evaluates
becomes one :class:`ExploreRecord`: the full ``AppCost`` column set
(per-tile, CGRA-level, array-accurate ``fabric_*``, measured ``sim_*``)
plus exploration identity — the pipeline mode, the variant's merged-
subgraph count, and the content key of the producing config, so a row can
always be traced back to the exact exploration that made it.

Rows round-trip through jsonl (:func:`to_jsonl` / :func:`from_jsonl`) and
stay directly consumable by ``results/make_tables.py ... fabric`` (the
record is a strict superset of the AppCost dict that table reads).
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List

from ..core.costmodel import AppCost

#: bump on any field add/rename/retype; from_jsonl rejects other versions
#: (2: added sim_bucket — the batched-simulate bucket the row rode)
RECORD_SCHEMA = 2


@dataclass
class ExploreRecord:
    """One flat row per (variant, app): identity + the AppCost columns."""

    schema: int
    mode: str                  # "per_app" | "domain"
    config_key: str            # content key of the producing ExploreConfig
    n_merged: int              # subgraphs merged into this variant
    sim_bucket: str            # batched-simulate bucket signature ("serial"
    # for the per-pair loop, "" when the pair was not simulated); outputs
    # are bucket-independent — this is provenance, not a result column
    # -- AppCost columns (names match costmodel.AppCost exactly) ----------
    app: str
    pe_name: str
    n_pes: int
    total_ops: int
    pe_area_um2: float
    total_area_um2: float
    energy_pj: float
    energy_per_op_pj: float
    fmax_ghz: float
    ops_per_pe: float
    unmapped: int
    cgra_area_um2: float = 0.0
    cgra_energy_pj: float = 0.0
    cgra_energy_per_op_pj: float = 0.0
    fabric_area_um2: float = 0.0
    fabric_energy_per_op_pj: float = 0.0
    fabric_fmax_ghz: float = 0.0
    fabric_wirelength: int = 0
    fabric_utilization: float = 0.0
    sim_ii: int = 0
    sim_min_ii: int = 0
    sim_latency_cycles: int = 0
    sim_active_frac: float = 0.0
    sim_throughput_gops: float = 0.0
    sim_energy_per_op_pj: float = 0.0
    sim_verified: int = -1

    @staticmethod
    def from_cost(cost: AppCost, *, mode: str, config_key: str,
                  n_merged: int = 0, sim_bucket: str = "") -> "ExploreRecord":
        return ExploreRecord(schema=RECORD_SCHEMA, mode=mode,
                             config_key=config_key, n_merged=n_merged,
                             sim_bucket=sim_bucket,
                             **dataclasses.asdict(cost))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ExploreRecord":
        schema = d.get("schema")
        if schema != RECORD_SCHEMA:
            raise ValueError(f"ExploreRecord schema {schema!r} not supported "
                             f"(this build reads schema {RECORD_SCHEMA})")
        known = {f.name for f in dataclasses.fields(ExploreRecord)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ExploreRecord fields {sorted(unknown)}")
        return ExploreRecord(**d)


def to_jsonl(records: Iterable[ExploreRecord], path: str, *,
             manifest: Dict[str, Any] = None) -> int:
    """Write one record per line; returns the row count.

    The first line is a run-manifest header (``{"schema": ...,
    "manifest": {...}}`` — what environment produced these rows; see
    :mod:`repro.obs.manifest`).  :func:`from_jsonl` skips it
    transparently; :func:`read_manifest` reads it back.  Pass
    ``manifest=None`` (the default) to capture the current process's, or
    an explicit dict to embed a foreign one.
    """
    if manifest is None:
        from ..obs.manifest import capture
        manifest = capture().to_dict()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    n = 0
    with open(path, "w") as f:
        f.write(json.dumps({"schema": RECORD_SCHEMA,
                            "manifest": manifest}) + "\n")
        for r in records:
            f.write(json.dumps(r.to_dict()) + "\n")
            n += 1
    return n


def from_jsonl(path: str) -> List[ExploreRecord]:
    """Read records back, validating the schema version per row (the
    manifest header line, when present, is skipped)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "manifest" in d:          # header line, not a record
                continue
            out.append(ExploreRecord.from_dict(d))
    return out


def read_manifest(path: str) -> Dict[str, Any]:
    """The run manifest embedded in a records jsonl ({} for pre-manifest
    files written before the trajectory layer)."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                d = json.loads(line)
                return d.get("manifest", {}) if "manifest" in d else {}
    return {}
