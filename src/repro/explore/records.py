"""Schema-versioned flat result rows for exploration sweeps.

Every (variant, app) pair an :class:`~repro.explore.Explorer` evaluates
becomes one :class:`ExploreRecord`: the full ``AppCost`` column set
(per-tile, CGRA-level, array-accurate ``fabric_*``, measured ``sim_*``)
plus exploration identity — the pipeline mode, the variant's merged-
subgraph count, and the content key of the producing config, so a row can
always be traced back to the exact exploration that made it.

Pairs that *failed* (twice — batch group, then the serial retry) become
:class:`StageFailure` rows instead: stage, pair, exception class, budget
state.  They ride the same jsonl file as ``{"kind": "stage_failure"}``
lines, so a partial run's output records both what succeeded and exactly
what degraded.

Rows round-trip through jsonl (:func:`to_jsonl` / :func:`from_jsonl` /
:func:`failures_from_jsonl`) and stay directly consumable by
``results/make_tables.py ... fabric`` (the record is a strict superset
of the AppCost dict that table reads).  Malformed input fails with a
one-line :class:`RecordFormatError` naming the file, line, and fix —
never a stack trace from deep inside a parser.
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

from ..core.costmodel import AppCost

#: bump on any field add/rename/retype; from_jsonl rejects other versions
#: (2: added sim_bucket — the batched-simulate bucket the row rode)
RECORD_SCHEMA = 2

#: schema for StageFailure rows (independent of RECORD_SCHEMA)
FAILURE_SCHEMA = 1


class RecordFormatError(ValueError):
    """A records jsonl / record dict that can't be parsed — reported as a
    one-line error by the CLI, never a stack trace."""


@dataclass
class StageFailure:
    """One structured failure row: a (variant, app) pair that failed a
    stage twice (batch group, then the serial retry), or a per-app /
    per-variant unit that failed a scalar stage.

    ``budget`` carries the budget state at exhaustion when the failure
    was a :class:`repro.errors.BudgetExceeded` (empty otherwise);
    ``retried`` records whether the serial retry path ran.
    """

    schema: int
    stage: str                 # mine|rank|merge|map|pnr|schedule|simulate
    pe_name: str               # "" for per-app stages with no variant
    app: str                   # "" for per-variant stages with no app
    error_type: str            # exception class name, e.g. "BudgetExceeded"
    error: str                 # str(exception), first line only
    retried: bool = False
    budget: Dict[str, Any] = field(default_factory=dict)

    @staticmethod
    def from_exception(stage: str, exc: BaseException, *, pe_name: str = "",
                       app: str = "", retried: bool = False) -> "StageFailure":
        budget = dict(getattr(exc, "budget", {}) or {})
        msg = str(exc).splitlines()[0] if str(exc) else type(exc).__name__
        return StageFailure(schema=FAILURE_SCHEMA, stage=stage,
                            pe_name=pe_name, app=app,
                            error_type=type(exc).__name__, error=msg,
                            retried=retried, budget=budget)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["kind"] = "stage_failure"
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "StageFailure":
        d = dict(d)
        kind = d.pop("kind", "stage_failure")
        if kind != "stage_failure":
            raise RecordFormatError(f"not a stage_failure row (kind={kind!r})")
        schema = d.get("schema")
        if schema != FAILURE_SCHEMA:
            raise RecordFormatError(
                f"StageFailure schema {schema!r} not supported (this build "
                f"reads schema {FAILURE_SCHEMA})")
        known = {f.name for f in dataclasses.fields(StageFailure)}
        unknown = set(d) - known
        if unknown:
            raise RecordFormatError(
                f"unknown StageFailure fields {sorted(unknown)} — "
                f"regenerate the jsonl or use a matching build")
        return StageFailure(**d)


def summarize_failures(failures: Iterable[StageFailure]) -> str:
    """One-line summary for the CLI: ``pnr=2 schedule=1 (3 failures)``."""
    by_stage: Dict[str, int] = {}
    total = 0
    for f in failures:
        by_stage[f.stage] = by_stage.get(f.stage, 0) + 1
        total += 1
    if not total:
        return "no failures"
    parts = " ".join(f"{s}={n}" for s, n in sorted(by_stage.items()))
    return f"{parts} ({total} failure{'s' if total != 1 else ''})"


# -- type checking for hardened parsing ----------------------------------

_FIELD_TYPES = {"schema": int, "n_merged": int, "n_pes": int,
                "total_ops": int, "unmapped": int, "fabric_wirelength": int,
                "sim_ii": int, "sim_min_ii": int, "sim_latency_cycles": int,
                "sim_verified": int,
                "mode": str, "config_key": str, "sim_bucket": str,
                "app": str, "pe_name": str}


def _check_types(d: Dict[str, Any]) -> Optional[str]:
    """First type violation as a one-line description, or None."""
    for name, want in _FIELD_TYPES.items():
        if name in d:
            v = d[name]
            if not isinstance(v, want) or isinstance(v, bool):
                return (f"field {name!r} must be {want.__name__}, "
                        f"got {type(v).__name__} ({v!r})")
    for fld in dataclasses.fields(ExploreRecord):
        if fld.name in _FIELD_TYPES or fld.name not in d:
            continue
        v = d[fld.name]          # remaining columns are float-valued
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            return (f"field {fld.name!r} must be a number, "
                    f"got {type(v).__name__} ({v!r})")
    return None


@dataclass
class ExploreRecord:
    """One flat row per (variant, app): identity + the AppCost columns."""

    schema: int
    mode: str                  # "per_app" | "domain"
    config_key: str            # content key of the producing ExploreConfig
    n_merged: int              # subgraphs merged into this variant
    sim_bucket: str            # batched-simulate bucket signature ("serial"
    # for the per-pair loop, "" when the pair was not simulated); outputs
    # are bucket-independent — this is provenance, not a result column
    # -- AppCost columns (names match costmodel.AppCost exactly) ----------
    app: str
    pe_name: str
    n_pes: int
    total_ops: int
    pe_area_um2: float
    total_area_um2: float
    energy_pj: float
    energy_per_op_pj: float
    fmax_ghz: float
    ops_per_pe: float
    unmapped: int
    cgra_area_um2: float = 0.0
    cgra_energy_pj: float = 0.0
    cgra_energy_per_op_pj: float = 0.0
    fabric_area_um2: float = 0.0
    fabric_energy_per_op_pj: float = 0.0
    fabric_fmax_ghz: float = 0.0
    fabric_wirelength: int = 0
    fabric_utilization: float = 0.0
    sim_ii: int = 0
    sim_min_ii: int = 0
    sim_latency_cycles: int = 0
    sim_active_frac: float = 0.0
    sim_throughput_gops: float = 0.0
    sim_energy_per_op_pj: float = 0.0
    sim_verified: int = -1

    @staticmethod
    def from_cost(cost: AppCost, *, mode: str, config_key: str,
                  n_merged: int = 0, sim_bucket: str = "") -> "ExploreRecord":
        return ExploreRecord(schema=RECORD_SCHEMA, mode=mode,
                             config_key=config_key, n_merged=n_merged,
                             sim_bucket=sim_bucket,
                             **dataclasses.asdict(cost))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ExploreRecord":
        if not isinstance(d, dict):
            raise RecordFormatError(
                f"ExploreRecord row must be an object, got "
                f"{type(d).__name__}")
        schema = d.get("schema")
        if schema != RECORD_SCHEMA:
            raise RecordFormatError(
                f"ExploreRecord schema {schema!r} not supported (this build "
                f"reads schema {RECORD_SCHEMA}) — regenerate with "
                f"`python -m repro.explore` or use a matching build")
        known = {f.name for f in dataclasses.fields(ExploreRecord)}
        unknown = set(d) - known
        if unknown:
            raise RecordFormatError(
                f"unknown ExploreRecord fields {sorted(unknown)} — "
                f"regenerate the jsonl or use a matching build")
        missing = {f.name for f in dataclasses.fields(ExploreRecord)
                   if f.default is dataclasses.MISSING} - set(d)
        if missing:
            raise RecordFormatError(
                f"missing ExploreRecord fields {sorted(missing)}")
        bad = _check_types(d)
        if bad:
            raise RecordFormatError(f"bad ExploreRecord row: {bad}")
        return ExploreRecord(**d)


def to_jsonl(records: Iterable[ExploreRecord], path: str, *,
             manifest: Dict[str, Any] = None,
             failures: Iterable[StageFailure] = ()) -> int:
    """Write one record per line; returns the row count.

    The first line is a run-manifest header (``{"schema": ...,
    "manifest": {...}}`` — what environment produced these rows; see
    :mod:`repro.obs.manifest`).  :func:`from_jsonl` skips it
    transparently; :func:`read_manifest` reads it back.  Pass
    ``manifest=None`` (the default) to capture the current process's, or
    an explicit dict to embed a foreign one.  ``failures`` appends one
    ``{"kind": "stage_failure"}`` line per degraded pair after the
    records (read back via :func:`failures_from_jsonl`).
    """
    if manifest is None:
        from ..obs.manifest import capture
        manifest = capture().to_dict()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    n = 0
    with open(path, "w") as f:
        f.write(json.dumps({"schema": RECORD_SCHEMA,
                            "manifest": manifest}) + "\n")
        for r in records:
            f.write(json.dumps(r.to_dict()) + "\n")
            n += 1
        for fl in failures:
            f.write(json.dumps(fl.to_dict()) + "\n")
    return n


def _rows(path: str):
    """Yield (line_number, parsed dict) with one-line decode errors."""
    with open(path) as f:
        for i, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise RecordFormatError(
                    f"{path}:{i}: not valid JSON ({e.msg} at column "
                    f"{e.colno}) — the file is corrupt or truncated")
            yield i, d


def from_jsonl(path: str) -> List[ExploreRecord]:
    """Read records back, validating the schema version per row (the
    manifest header and any stage_failure lines are skipped)."""
    out = []
    for i, d in _rows(path):
        if not isinstance(d, dict) or "manifest" in d or "kind" in d:
            continue             # header / failure line, not a record
        try:
            out.append(ExploreRecord.from_dict(d))
        except RecordFormatError as e:
            raise RecordFormatError(f"{path}:{i}: {e}")
    return out


def failures_from_jsonl(path: str) -> List[StageFailure]:
    """The StageFailure rows embedded in a records jsonl ([] when the
    run was clean)."""
    out = []
    for i, d in _rows(path):
        if not isinstance(d, dict) or d.get("kind") != "stage_failure":
            continue
        try:
            out.append(StageFailure.from_dict(d))
        except RecordFormatError as e:
            raise RecordFormatError(f"{path}:{i}: {e}")
    return out


def read_manifest(path: str) -> Dict[str, Any]:
    """The run manifest embedded in a records jsonl ({} for pre-manifest
    files written before the trajectory layer)."""
    for _i, d in _rows(path):
        if isinstance(d, dict):
            return d.get("manifest", {}) if "manifest" in d else {}
        return {}
    return {}
