"""The staged exploration pipeline: mine -> rank -> merge -> map -> pnr ->
schedule -> simulate.

:class:`Explorer` runs the paper's flow (Sec. IV, Fig. 6) as explicit,
individually invokable stages over one :class:`ExploreConfig`.  Every
stage is memoized by a *content key* — a hash of the application graph
plus exactly the upstream config fields that stage depends on — so
flipping ``simulate=True`` or changing the annealing budget reuses every
upstream artifact instead of re-mining and re-merging:

    ex = Explorer(apps, cfg)
    res = ex.run()                                   # full pipeline
    res2 = ex.with_config(fabric=replace(cfg.fabric,
                                         simulate=True)).run()
    ex.stats["mine"]     # still the first run's count: zero re-mines

The ``pnr`` stage is batch-first: all (variant, app) mappings are
gathered, lowered, grouped by :func:`repro.fabric.place.batch_signature`,
and annealed with chains spread across pairs in one JAX dispatch per
group (``pnr_batch="grouped"``).  ``pnr_batch="serial"`` runs the legacy
one-dispatch-per-pair loop and is bit-identical to the pre-``repro.
explore`` driver — it is what the deprecated ``specialize_per_app`` /
``domain_pe`` / ``evaluate_variants`` shims pin.

The ``schedule`` and ``simulate`` stages are batch-first the same way
(``sim_batch="grouped"``): modulo scheduling advances all pairs of one
fabric signature in lockstep with their slot-conflict scans stacked into
one numpy gather per round (:func:`repro.sim.modulo_schedule_batch`), and
every bucket-compatible group of scheduled programs executes in ONE
vmapped ``lax.scan`` (:func:`repro.sim.simulate_batch`) instead of
compiling one scan per program.  Golden-check inputs are seeded by a
content nonce per pair (:meth:`repro.fabric.options.FabricOptions.
input_seed`), so schedules, simulated outputs, and verification flags are
bit-identical between the grouped and serial modes and independent of
which pairs share a bucket.
"""

from __future__ import annotations

import hashlib
import json
import time
import zlib
from collections import Counter, defaultdict
from dataclasses import dataclass, replace as _dc_replace
from typing import Any, Callable, Dict, List, Optional, Tuple, TYPE_CHECKING

from .. import faultinject
from ..core.costmodel import AppCost, attach_sim, evaluate_mapping
from ..core.dse import (DSEResult, PEVariant, _dedup_keep_maximal, app_ops,
                        build_variants)
from ..core.mapper import Mapping, map_application
from ..core.merge import add_pattern, baseline_datapath, is_pe_pattern
from ..core.mining import MinedSubgraph, mine_frequent_subgraphs
from ..core.mis import rank_by_mis
from ..errors import BudgetExceeded
from ..graphir.graph import Graph
from ..obs import event as obs_event, span
from ..obs.memprof import stage_memory
from ..obs.metrics import CounterView, MetricsRegistry
from .config import ExploreConfig
from .records import ExploreRecord, StageFailure

if TYPE_CHECKING:                              # runtime import stays lazy
    from ..fabric import PnRResult
    from ..fabric.options import FabricOptions

Pair = Tuple[str, str]                         # (pe_name, app_name)

#: sentinel for a unit of work that failed twice (batch + serial retry)
#: in isolate mode — never stored in the memo, never a real stage value
_FAILED = object()


# ---------------------------------------------------------------------------
# content keys
# ---------------------------------------------------------------------------
def _digest(*parts: Any) -> str:
    return hashlib.sha256(
        json.dumps(parts, sort_keys=True, default=repr).encode()
    ).hexdigest()[:16]


def graph_key(g: Graph) -> str:
    """Stable structural fingerprint of an application graph."""
    nodes = sorted((nid, op, sorted((g.attrs.get(nid) or {}).items()))
                   for nid, op in g.nodes.items())
    return _digest(nodes, sorted(g.edges), list(g.outputs))


def _mining_fields(cfg: ExploreConfig) -> Tuple:
    m = cfg.mining
    return (m.min_support, m.max_pattern_nodes, m.max_patterns_per_level,
            m.max_embeddings, m.max_ext_embeddings, m.time_budget_s,
            m.allow_macros)


def _pnr_fields(options: "FabricOptions", pnr_batch: str,
                pnr_mode: str = "flat") -> Tuple:
    s = options.spec
    spec_sig = None if s is None else (s.rows, s.cols, s.channel_width,
                                       s.io_capacity, s.hop_energy_pj,
                                       s.hop_delay_ns, s.latch_depth)
    sig = (spec_sig, options.backend, options.hpwl_backend,
           options.score_mode, options.chains, options.sweeps,
           options.seed, pnr_batch, options.anneal_max_states)
    # flat keys keep their pre-pnr_mode shape so existing memo stores
    # stay warm across the upgrade; hierarchical results key separately
    if pnr_mode != "flat":
        sig = sig + (pnr_mode,)
    return sig


def _sched_fields(options: "FabricOptions") -> Tuple:
    return (options.sched_max_ii, options.sched_budget_factor)


def _sim_fields(options: "FabricOptions") -> Tuple:
    return (options.sim_iterations, options.sim_batch, options.sim_backend,
            options.sim_verify, options.seed, options.sim_max_cycles)


def _pair_nonce(pe_name: str, app_name: str) -> int:
    """Content nonce for one (variant, app) pair: seeds the pair's golden
    test vectors so simulated results never depend on bucket grouping."""
    return zlib.crc32(f"{pe_name}:{app_name}".encode())


# ---------------------------------------------------------------------------
# per-pair primitives (shared by the Explorer stages and the legacy shims)
# ---------------------------------------------------------------------------
def _pnr_pair(pe_name, dp, mapping, app, options,
              pnr_mode: str = "flat") -> "PnRResult":
    from ..fabric import place_and_route
    return place_and_route(dp, mapping, app, options.spec,
                           backend=options.backend, chains=options.chains,
                           sweeps=options.sweeps, seed=options.seed,
                           pe_name=pe_name,
                           hpwl_backend=options.hpwl_backend,
                           score_mode=options.score_mode,
                           max_states=options.anneal_max_states,
                           pnr_mode=pnr_mode)


def pnr_grouped(items: List[Tuple[str, Any, Mapping, Graph, int]],
                options: "FabricOptions",
                stats: Optional[Counter] = None,
                isolate: bool = False) -> List["PnRResult"]:
    """Place-and-route many (variant, app) pairs, annealing each bucket-
    compatible group in ONE JAX dispatch.

    items: (pe_name, datapath, mapping, app, nonce) per pair; the nonce
    seeds the pair's chains so its placement is reproducible regardless of
    which pairs share its dispatch.  Routing and costing stay per-pair
    (they are cheap Python); only the annealing hot loop is batched.

    ``isolate=True``: a failing pair (fault-injection site ``pnr``, an
    over-budget anneal, a lowering/routing error) yields the Exception
    object at its index instead of killing the batch.  Content-nonce
    seeding makes every surviving pair's placement bit-identical however
    the failed pair reshapes its dispatch group.
    """
    from ..fabric import PnRResult
    from ..fabric.arch import Coord, FabricSpec
    from ..fabric.cost import evaluate_fabric
    from ..fabric.netlist import extract_netlist
    from ..fabric.place import (Placement, anneal_jax_batch,
                                batch_signature, check_anneal_budget, lower)
    from ..fabric.route import route_nets
    import numpy as np

    registry = getattr(stats, "registry", None)
    spec0 = options.spec or FabricSpec()
    lowered: List[Optional[Tuple]] = []
    errors: Dict[int, Exception] = {}
    for i, (pe_name, dp, mapping, app, nonce) in enumerate(items):
        try:
            faultinject.fire("pnr", pe=pe_name, app=mapping.app_name)
            netlist = extract_netlist(mapping, app, spec0)
            spec = spec0.fit(len(netlist.pe_cells), len(netlist.io_cells))
            prob = lower(netlist, spec)
            check_anneal_budget(prob, options.chains, options.sweeps,
                                options.anneal_max_states, metrics=registry)
            lowered.append((netlist, spec, prob))
        except Exception as e:
            if not isolate:
                raise
            lowered.append(None)
            errors[i] = e

    groups: Dict[Tuple, List[int]] = defaultdict(list)
    for i, low in enumerate(lowered):
        if low is not None:
            groups[batch_signature(low[2], options.sweeps)].append(i)

    annealed: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for sig, idxs in groups.items():
        try:
            with span("pnr.dispatch", bucket="x".join(map(str, sig)),
                      pairs=len(idxs)):
                out = anneal_jax_batch([lowered[i][2] for i in idxs],
                                       chains=options.chains,
                                       seed=options.seed,
                                       sweeps=options.sweeps,
                                       score_mode=options.score_mode,
                                       nonces=[items[i][4] for i in idxs],
                                       metrics=registry)
        except Exception as e:
            if not isolate:
                raise
            for i in idxs:       # whole-dispatch failure: every rider
                errors[i] = e    # retries on the serial path
            continue
        annealed.update(zip(idxs, out))
        if registry is not None:
            registry.observe("pnr.bucket_size", len(idxs))
        if stats is not None:
            stats["pnr_dispatch"] += 1

    results: List = []
    for i, (pe_name, dp, mapping, app, _) in enumerate(items):
        if i in errors:
            results.append(errors[i])
            continue
        netlist, spec, prob = lowered[i]
        slots, costs = annealed[i]
        try:
            best = int(np.argmin(costs))
            coords: Dict[str, Coord] = {}
            for idx, name in enumerate(prob.cell_names):
                x, y = prob.slot_xy[slots[best][prob.entity_of(idx)]]
                coords[name] = (int(x), int(y))
            with span("pnr.pair", pe=pe_name, app=mapping.app_name):
                placement = Placement(coords=coords, cost=float(costs[best]),
                                      backend="jax", chains=options.chains,
                                      sweeps=options.sweeps,
                                      chain_costs=[float(c) for c in costs])
                routes = route_nets(netlist, placement, spec)
                fc = evaluate_fabric(dp, mapping, netlist, placement, routes,
                                     spec, pe_name=pe_name)
            results.append(PnRResult(spec, netlist, placement, routes, fc))
        except Exception as e:
            if not isolate:
                raise
            results.append(e)
    return results


def _verify_prog(prog, app: Graph, label: str, options, nonce: int) -> int:
    """Golden-check one SimProgram against graphir.interp (per-pair path).

    Returns 1 (bit-exact), -1 when ``options.sim_verify`` is off; raises
    on mismatch.
    """
    if not options.sim_verify:
        return -1
    from ..sim import check_against_interp, random_inputs
    from ..sim.cycle import check_cycle_budget
    check_cycle_budget(prog, options.sim_iterations, options.sim_max_cycles)
    inputs = random_inputs(prog, options.sim_iterations, options.sim_batch,
                           seed=options.input_seed(nonce))
    _, err, exact = check_against_interp(prog, app, inputs,
                                         backend=options.sim_backend)
    return _require_exact(err, exact, label)


def _require_exact(err: float, exact: bool, label: str) -> int:
    if not (exact and err == 0.0):
        raise AssertionError(f"simulated {label} diverges from "
                             f"graphir.interp (max |err|={err:.3e})")
    return 1


def _sim_pair(dp, mapping, app, pnr, options, nonce: int) -> Tuple[Any, int]:
    """(SimProgram, verified) for one placed-and-routed pair."""
    from ..sim import build_sim
    prog, _ = build_sim(dp, mapping, app, pnr=pnr,
                        max_ii=options.sched_max_ii,
                        budget_factor=options.sched_budget_factor)
    return prog, _verify_prog(prog, app, mapping.app_name, options, nonce)


def evaluate_pairs(variants, apps: Dict[str, Graph],
                   options: Optional["FabricOptions"], *,
                   pnr_batch: str = "serial") -> None:
    """Map + cost every (variant, app) pair in place; optional array-level
    PnR and time-domain simulation.  This is the engine behind the
    deprecated :func:`repro.core.dse.evaluate_variants` shim; the serial
    mode reproduces the legacy loop bit-for-bit.
    """
    from ..fabric.cost import attach_fabric

    todo = []
    for v in variants:
        for app_name, app in apps.items():
            mapping = map_application(v.datapath, app, app_name)
            cost = evaluate_mapping(v.datapath, mapping, v.name)
            v.costs[app_name] = cost
            if options is not None:
                todo.append((v, app_name, app, mapping, cost))
    if options is None:
        return

    if pnr_batch == "grouped":
        items = [(v.name, v.datapath, mapping, app,
                  _pair_nonce(v.name, app_name))
                 for v, app_name, app, mapping, _ in todo]
        pnrs = pnr_grouped(items, options)
    else:
        pnrs = [_pnr_pair(v.name, v.datapath, mapping, app, options)
                for v, app_name, app, mapping, _ in todo]

    for (v, app_name, app, mapping, cost), pnr in zip(todo, pnrs):
        v.fabric_costs[app_name] = pnr.cost
        attach_fabric(cost, pnr.cost)
        if options.simulate:
            prog, verified = _sim_pair(v.datapath, mapping, app, pnr,
                                       options,
                                       _pair_nonce(v.name, app_name))
            attach_sim(cost, v.datapath, prog.schedule,
                       fabric_cost=pnr.cost, verified=verified)


# ---------------------------------------------------------------------------
# the Explorer
# ---------------------------------------------------------------------------
@dataclass
class ExploreResult:
    """Everything one pipeline run produced, plus the flat record view."""

    config: ExploreConfig
    config_key: str
    apps: Dict[str, Graph]
    results: Dict[str, DSEResult]    # per app, or {domain_name: result}
    elapsed_s: float
    sim_buckets: Dict[Pair, str] = None   # provenance per simulated pair
    metrics: Dict[str, Any] = None        # registry snapshot at run end
    failures: List[StageFailure] = None   # degraded pairs/apps (isolate
    # mode: each failed its batch group AND the serial retry)

    @property
    def clean(self) -> bool:
        return not self.failures

    def records(self) -> List[ExploreRecord]:
        buckets = self.sim_buckets or {}
        rows: List[ExploreRecord] = []
        for res in self.results.values():
            for app_name in sorted(res.apps):
                for v in res.variants:
                    if app_name not in v.costs:
                        continue
                    rows.append(ExploreRecord.from_cost(
                        v.costs[app_name], mode=self.config.mode,
                        config_key=self.config_key,
                        n_merged=len(v.merged_subgraphs),
                        sim_bucket=buckets.get((v.name, app_name), "")))
        return rows

    def to_jsonl(self, path: str) -> int:
        from .records import to_jsonl
        return to_jsonl(self.records(), path,
                        failures=self.failures or ())

    def table(self) -> str:
        return "\n".join(r.row() for res in self.results.values()
                         for v in res.variants
                         for r in [v.costs[a] for a in sorted(v.costs)])


class Explorer:
    """Staged, memoized DSE pipeline over one config.

    Stages (each individually invokable, each memoized by content key):

    ``mine()``      raw frequent subgraphs per app (Sec. III-A)
    ``rank()``      PE-pattern filter + MIS ranking (Sec. III-B)
    ``merge()``     PE variant datapaths (Sec. III-C / V)
    ``map()``       application covers per (variant, app) (Sec. IV)
    ``pnr()``       array place-and-route — batch-first across pairs
    ``schedule()``  modulo schedules / sim programs per pair
    ``simulate()``  cycle-accurate golden verification per pair
    ``run()``       everything the config asks for -> :class:`ExploreResult`

    ``with_config(...)`` derives a new Explorer over changed options that
    *shares the memo store*, so downstream-only changes (annealing budget,
    ``simulate=True``) reuse all upstream artifacts.
    """

    def __init__(self, apps: Dict[str, Graph], config: ExploreConfig, *,
                 store: Optional[Dict] = None,
                 stats: Optional[Counter] = None,
                 metrics: Optional[MetricsRegistry] = None) -> None:
        self.apps = dict(apps)
        self.config = config
        self._store: Dict[Tuple, Any] = {} if store is None else store
        # stats is a Counter-compatible view onto the metrics registry —
        # the legacy `ex.stats["pnr_dispatch"]` reads and `stats[k] += 1`
        # write-throughs all land in (and report from) the registry
        if metrics is None and isinstance(stats, CounterView):
            metrics = stats.registry
        self.metrics: MetricsRegistry = metrics or MetricsRegistry()
        self.stats: CounterView = self.metrics.view()
        if stats is not None and not isinstance(stats, CounterView):
            for k, v in stats.items():       # seed from a legacy Counter
                self.stats[k] += v
        self._app_keys = {name: graph_key(g) for name, g in apps.items()}
        self.failures: List[StageFailure] = []
        # memo keys that degraded to a StageFailure this run: stages
        # re-invoke their upstreams freely (schedule -> pnr -> map), so
        # without this a failed unit would be silently re-attempted
        # mid-run and the stage views would disagree about which pairs
        # exist.  Per-run only — never persisted, so a later run (or a
        # crash-resume against the same DiskStore) recomputes failures.
        self._failed: set = set()

    def with_config(self, **changes: Any) -> "Explorer":
        """New Explorer over a changed config, sharing the memo store."""
        return Explorer(self.apps, self.config.replace(**changes),
                        store=self._store, metrics=self.metrics)

    def forget(self, *stages: str) -> int:
        """Drop memoized artifacts of the named stages ("pnr", "sched",
        "sim", ...); returns the number of entries evicted.

        The repeat-based benchmarks use this to re-run a stage cold N
        times from the same shared upstream artifacts — the memo would
        otherwise answer every repeat after the first from the store.
        """
        victims = [k for k in self._store
                   if isinstance(k, tuple) and k and k[0] in stages]
        for k in victims:
            del self._store[k]
        return len(victims)

    def _memo(self, key: Tuple, stage: str, thunk: Callable[[], Any],
              **attrs: Any) -> Any:
        if key not in self._store:
            self.metrics.inc(f"memo.miss.{stage}")
            with span(f"{stage}.work", **attrs):
                self._store[key] = thunk()
            self.stats[stage] += 1
        else:
            self.metrics.inc(f"memo.hit.{stage}")
        return self._store[key]

    # -- per-unit fault isolation ------------------------------------------
    def _isolating(self) -> bool:
        return self.config.on_error == "isolate"

    def _record_failure(self, stage: str, exc: BaseException, *,
                        pe: str = "", app: str = "",
                        retried: bool = False) -> StageFailure:
        f = StageFailure.from_exception(stage, exc, pe_name=pe, app=app,
                                        retried=retried)
        self.failures.append(f)
        self.metrics.inc(f"failures.{stage}")
        if isinstance(exc, BudgetExceeded):
            self.metrics.inc(f"budget_exhausted.{stage}")
        obs_event("stage.failure", stage=stage, pe=pe, app=app,
                  error=f.error_type)
        return f

    def _retry(self, stage: str, thunk: Callable[[], Any], *,
               pe: str = "", app: str = "") -> Any:
        """Serial retry after a first failure; second failure becomes a
        StageFailure row and the :data:`_FAILED` sentinel."""
        self.metrics.inc(f"isolate.retry.{stage}")
        try:
            faultinject.fire(f"{stage}.retry", pe=pe, app=app)
            return thunk()
        except Exception as e:
            self._record_failure(stage, e, pe=pe, app=app, retried=True)
            return _FAILED

    def _attempt(self, stage: str, thunk: Callable[[], Any], *,
                 pe: str = "", app: str = "") -> Any:
        """One unit of per-pair/per-app work: fire the stage's fault site,
        run; on failure (isolate mode) retry once, then degrade to a
        StageFailure + sentinel.  In ``on_error="raise"`` mode the first
        failure propagates (the legacy behavior)."""
        try:
            faultinject.fire(stage, pe=pe, app=app)
            return thunk()
        except Exception:
            if not self._isolating():
                raise
            return self._retry(stage, thunk, pe=pe, app=app)

    def _memo_iso(self, key: Tuple, stage: str, thunk: Callable[[], Any],
                  *, pe: str = "", app: str = "", **attrs: Any) -> Any:
        """:meth:`_memo` with fault isolation: a unit that fails twice is
        recorded and returns :data:`_FAILED` instead of raising; failures
        are never memoized, so a later run recomputes them."""
        if key in self._failed:              # degraded earlier this run
            return _FAILED
        if key in self._store:
            self.metrics.inc(f"memo.hit.{stage}")
            return self._store[key]
        self.metrics.inc(f"memo.miss.{stage}")
        with span(f"{stage}.work", **attrs):
            val = self._attempt(stage, thunk, pe=pe, app=app)
        if val is _FAILED:
            self._failed.add(key)
            return _FAILED
        self._store[key] = val
        self.stats[stage] += 1
        return val

    # -- stages ------------------------------------------------------------
    def mine(self) -> Dict[str, List[MinedSubgraph]]:
        """Mined subgraphs per app; a twice-failing app becomes a
        StageFailure and drops out of the run (isolate mode)."""
        cfg = self.config
        out = {}
        with span("mine"), stage_memory(self.metrics, "mine"):
            for name, app in self.apps.items():
                key = ("mine", self._app_keys[name], _mining_fields(cfg))
                v = self._memo_iso(
                    key, "mine",
                    lambda a=app: mine_frequent_subgraphs(a, cfg.mining),
                    app=name)
                if v is not _FAILED:
                    out[name] = v
        return out

    def rank(self) -> Dict[str, List[MinedSubgraph]]:
        mined = self.mine()
        out = {}
        with span("rank"), stage_memory(self.metrics, "rank"):
            for name in self.apps:
                if name not in mined:        # failed upstream
                    continue
                key = ("rank", self._app_keys[name],
                       _mining_fields(self.config))
                v = self._memo_iso(
                    key, "rank", lambda n=name: rank_by_mis(
                        [m for m in mined[n] if is_pe_pattern(m.pattern)]),
                    app=name)
                if v is not _FAILED:
                    out[name] = v
        return out

    def _merge_key(self, name: Optional[str] = None) -> Tuple:
        cfg = self.config
        if cfg.mode == "per_app":
            return ("merge", self._app_keys[name], _mining_fields(cfg),
                    cfg.max_merge, cfg.rank_mode, cfg.validate)
        return ("merge_domain", tuple(sorted(self._app_keys.items())),
                _mining_fields(cfg), cfg.per_app_subgraphs, cfg.domain_name,
                cfg.validate)

    def merge(self) -> Dict[str, List[PEVariant]]:
        """Variant templates per app name (one shared list in domain mode).

        The returned PEVariant objects are memoized templates; ``run()``
        wraps them in fresh containers before attaching costs.
        """
        ranked = self.rank()
        cfg = self.config
        with span("merge"), stage_memory(self.metrics, "merge"):
            if cfg.mode == "per_app":
                out = {}
                for name in self.apps:
                    if name not in ranked:   # failed upstream
                        continue
                    v = self._memo_iso(
                        self._merge_key(name), "merge",
                        lambda n=name: build_variants(
                            n, self.apps[n], ranked[n],
                            max_merge=cfg.max_merge,
                            rank_mode=cfg.rank_mode,
                            validate=cfg.validate),
                        app=name)
                    if v is not _FAILED:
                        out[name] = v
                return out
            variant = self._memo_iso(
                self._merge_key(), "merge",
                lambda: self._build_domain_variant(ranked),
                pe=cfg.domain_name, domain=cfg.domain_name)
        if variant is _FAILED:               # the whole domain degraded
            return {cfg.domain_name: []}
        return {cfg.domain_name: [variant]}

    def _build_domain_variant(self, ranked) -> PEVariant:
        """Cross-application PE (paper's PE IP / PE ML, Sec. V-B)."""
        cfg = self.config
        all_ops = set()
        for app in self.apps.values():
            all_ops |= app_ops(app)
        dp = baseline_datapath(all_ops)
        merged: List[str] = []
        seen_labels = set()
        for name, ranked_app in sorted(ranked.items()):
            usable = _dedup_keep_maximal(ranked_app)
            count = 0
            for m in usable:
                if count >= cfg.per_app_subgraphs:
                    break
                if m.label in seen_labels:
                    count += 1       # another app already contributed it
                    continue
                seen_labels.add(m.label)
                cfg_name = f"sg:{name}:{count}"
                add_pattern(dp, m.pattern, cfg_name, validate=cfg.validate)
                merged.append(cfg_name)
                count += 1
        return PEVariant(cfg.domain_name, dp, merged)

    def _pairs(self) -> List[Tuple[PEVariant, str, Tuple]]:
        """(variant template, app_name, map key) for every evaluated pair."""
        cfg = self.config
        variants = self.merge()
        out = []
        if cfg.mode == "per_app":
            for name in self.apps:
                if name not in variants:     # failed upstream
                    continue
                mk = self._merge_key(name)
                for v in variants[name]:
                    out.append((v, name, ("map", mk, v.name,
                                          self._app_keys[name])))
        else:
            mk = self._merge_key()
            for v in variants[cfg.domain_name]:
                for name in self.apps:
                    out.append((v, name, ("map", mk, v.name,
                                          self._app_keys[name])))
        return out

    def map(self) -> Dict[Pair, Mapping]:
        out = {}
        with span("map"), stage_memory(self.metrics, "map"):
            for v, app_name, key in self._pairs():
                m = self._memo_iso(
                    key, "map", lambda v=v, a=app_name: map_application(
                        v.datapath, self.apps[a], a),
                    pe=v.name, app=app_name)
                if m is not _FAILED:
                    out[(v.name, app_name)] = m
        return out

    def _cost(self, v: PEVariant, app_name: str, map_key: Tuple) -> AppCost:
        mapping = self._store[map_key]
        return self._memo(("cost",) + map_key[1:], "cost",
                          lambda: evaluate_mapping(v.datapath, mapping,
                                                   v.name),
                          pe=v.name, app=app_name)

    def pnr(self) -> Dict[Pair, "PnRResult"]:
        """Array-level place-and-route for every pair — batch-first.

        Gathers every pair missing from the memo, lowers all netlists,
        groups them by bucket signature, and anneals each group's chains
        in one JAX dispatch (``pnr_batch="grouped"``).  Non-"jax" backends,
        ``pnr_batch="serial"`` and ``pnr_mode="hierarchical"`` fall back
        to the per-pair loop (a hierarchical placement is itself a batched
        dispatch across its clusters, so cross-pair grouping buys nothing).
        """
        cfg = self.config
        options = cfg.fabric
        if options is None:
            raise ValueError("pnr stage requires config.fabric")
        mappings = self.map()
        sig = _pnr_fields(options, cfg.pnr_batch, cfg.pnr_mode)

        keys: Dict[Pair, Tuple] = {}
        misses = []
        for v, app_name, map_key in self._pairs():
            if (v.name, app_name) not in mappings:   # failed upstream
                continue
            key = ("pnr", map_key[1:], sig)
            if key in self._failed:          # degraded earlier this run
                continue
            keys[(v.name, app_name)] = key
            if key not in self._store:
                misses.append((v, app_name, key))
                self.metrics.inc("memo.miss.pnr")
            else:
                self.metrics.inc("memo.hit.pnr")

        grouped = (cfg.pnr_batch == "grouped" and options.backend == "jax"
                   and options.hpwl_backend == "jnp"
                   and cfg.pnr_mode == "flat")
        with span("pnr", pairs=len(keys), misses=len(misses)), \
                stage_memory(self.metrics, "pnr"):
            if misses and grouped:
                items = [(v.name, v.datapath, mappings[(v.name, a)],
                          self.apps[a], zlib.crc32(repr(key).encode()))
                         for v, a, key in misses]
                pnrs = pnr_grouped(items, options, self.stats,
                                   isolate=self._isolating())
                for (v, a, key), pnr in zip(misses, pnrs):
                    if isinstance(pnr, Exception):
                        # fell out of its batch group: one serial retry,
                        # then a StageFailure row — groupmates unaffected
                        pnr = self._retry(
                            "pnr", lambda v=v, a=a: _pnr_pair(
                                v.name, v.datapath, mappings[(v.name, a)],
                                self.apps[a], options, cfg.pnr_mode),
                            pe=v.name, app=a)
                        if pnr is _FAILED:
                            self._failed.add(key)
                            continue
                        self.stats["pnr_dispatch"] += 1
                    self._store[key] = pnr
                    self.stats["pnr"] += 1
            elif misses:
                for v, a, key in misses:
                    with span("pnr.pair", pe=v.name, app=a):
                        pnr = self._attempt(
                            "pnr", lambda v=v, a=a: _pnr_pair(
                                v.name, v.datapath, mappings[(v.name, a)],
                                self.apps[a], options, cfg.pnr_mode),
                            pe=v.name, app=a)
                    if pnr is _FAILED:
                        self._failed.add(key)
                        continue
                    self._store[key] = pnr
                    self.stats["pnr"] += 1
                    self.stats["pnr_dispatch"] += 1
        return {pair: self._store[key] for pair, key in keys.items()
                if key in self._store}

    def schedule(self) -> Dict[Pair, Any]:
        """Modulo-scheduled SimProgram per pair — batch-first.

        ``sim_batch="grouped"`` schedules every missing pair through
        :func:`repro.sim.modulo_schedule_batch`: pairs sharing a fabric
        signature advance in lockstep with their slot-conflict scans
        stacked into one numpy evaluation per round.  ``"serial"`` is the
        legacy per-pair loop; schedules are bit-identical either way.
        """
        from ..sim import build_sim, build_sim_batch
        cfg = self.config
        options = cfg.fabric
        if options is None:
            raise ValueError("schedule stage requires config.fabric")
        mappings = self.map()
        pnrs = self.pnr()
        sig = _pnr_fields(options, cfg.pnr_batch, cfg.pnr_mode)

        def serial_sched(v, a):
            return build_sim(v.datapath, mappings[(v.name, a)],
                             self.apps[a], pnr=pnrs[(v.name, a)],
                             max_ii=options.sched_max_ii,
                             budget_factor=options.sched_budget_factor)[0]

        keys: Dict[Pair, Tuple] = {}
        misses = []
        for v, app_name, map_key in self._pairs():
            if (v.name, app_name) not in pnrs:       # failed upstream
                continue
            key = ("sched", map_key[1:], sig, cfg.sim_batch,
                   _sched_fields(options))
            if key in self._failed:          # degraded earlier this run
                continue
            keys[(v.name, app_name)] = key
            if key not in self._store:
                misses.append((v, app_name, key))
                self.metrics.inc("memo.miss.sched")
            else:
                self.metrics.inc("memo.hit.sched")

        with span("schedule", pairs=len(keys), misses=len(misses)), \
                stage_memory(self.metrics, "schedule"):
            if misses and cfg.sim_batch == "grouped":
                items = [(v.datapath, mappings[(v.name, a)], self.apps[a],
                          pnrs[(v.name, a)]) for v, a, key in misses]
                progs = build_sim_batch(
                    items, stats=self.stats,
                    max_ii=options.sched_max_ii,
                    budget_factor=options.sched_budget_factor,
                    isolate=self._isolating())
                for (v, a, key), prog in zip(misses, progs):
                    if isinstance(prog, Exception):
                        prog = self._retry("schedule",
                                           lambda v=v, a=a: serial_sched(
                                               v, a),
                                           pe=v.name, app=a)
                        if prog is _FAILED:
                            self._failed.add(key)
                            continue
                    self._store[key] = prog
                    self.stats["sched"] += 1
                    obs_event("schedule.pair", pe=v.name, app=a, ii=prog.ii)
            elif misses:
                for v, a, key in misses:
                    with span("schedule.pair", pe=v.name, app=a):
                        prog = self._attempt(
                            "schedule",
                            lambda v=v, a=a: serial_sched(v, a),
                            pe=v.name, app=a)
                    if prog is _FAILED:
                        self._failed.add(key)
                        continue
                    self._store[key] = prog
                    self.stats["sched"] += 1
        return {pair: self._store[key] for pair, key in keys.items()
                if key in self._store}

    def simulate(self) -> Dict[Pair, int]:
        """Golden-verification flags per pair (−1 when verify is off) —
        batch-first.

        ``sim_batch="grouped"`` (with the "jax" tile-step backend) groups
        every missing pair's SimProgram by :func:`repro.sim.sim_signature`
        and runs each bucket through ONE vmapped ``lax.scan``
        (:func:`repro.sim.simulate_batch`); the interpreter comparison
        stays per-pair (cheap numpy).  Content-nonce input seeding makes
        each flag — and the simulated outputs behind it — independent of
        which pairs shared the dispatch, and bit-identical to the
        ``"serial"`` per-pair loop.
        """
        cfg = self.config
        options = cfg.fabric
        if options is None:
            raise ValueError("simulate stage requires config.fabric")
        progs = self.schedule()

        keys: Dict[Pair, Tuple] = {}
        misses = []
        for v, app_name, map_key in self._pairs():
            pair = (v.name, app_name)
            if pair not in progs:                    # failed upstream
                continue
            key = ("sim", map_key[1:],
                   _pnr_fields(options, cfg.pnr_batch, cfg.pnr_mode),
                   _sim_fields(options), cfg.sim_batch,
                   _sched_fields(options))
            if key in self._failed:          # degraded earlier this run
                continue
            keys[pair] = key
            if key not in self._store:
                misses.append((v, app_name, key))
                self.metrics.inc("memo.miss.sim")
            else:
                self.metrics.inc("memo.hit.sim")

        def serial_sim(v, a):
            return _verify_prog(progs[(v.name, a)], self.apps[a],
                                f"{a} on {v.name}", options,
                                _pair_nonce(v.name, a))

        grouped = (cfg.sim_batch == "grouped"
                   and options.sim_backend == "jax" and options.sim_verify)
        with span("simulate", pairs=len(keys), misses=len(misses)), \
                stage_memory(self.metrics, "simulate"):
            if misses and grouped:
                from ..sim import (compare_with_interp, random_inputs,
                                   sim_signature, simulate_batch)
                from ..sim.cycle import check_cycle_budget
                by_bucket: Dict[Tuple, List[int]] = defaultdict(list)
                inputs: Dict[int, Any] = {}
                retry: Dict[int, Exception] = {}
                for i, (v, a, key) in enumerate(misses):
                    prog = progs[(v.name, a)]
                    try:
                        faultinject.fire("simulate", pe=v.name, app=a)
                        check_cycle_budget(prog, options.sim_iterations,
                                           options.sim_max_cycles,
                                           metrics=self.metrics)
                        inputs[i] = random_inputs(
                            prog, options.sim_iterations, options.sim_batch,
                            seed=options.input_seed(_pair_nonce(v.name, a)))
                    except Exception as e:
                        if not self._isolating():
                            raise
                        retry[i] = e
                        continue
                    by_bucket[sim_signature(prog, options.sim_iterations,
                                            options.sim_batch)].append(i)
                for bucket, idxs in by_bucket.items():
                    try:
                        results = simulate_batch(
                            [progs[(misses[i][0].name, misses[i][1])]
                             for i in idxs], [inputs[i] for i in idxs],
                            metrics=self.metrics)
                    except Exception as e:
                        if not self._isolating():
                            raise
                        for i in idxs:   # whole-dispatch failure: every
                            retry[i] = e  # rider retries serially
                        continue
                    self.stats["sim_dispatch"] += 1
                    self.metrics.observe("sim.bucket_size", len(idxs))
                    for i, res in zip(idxs, results):
                        v, a, key = misses[i]
                        try:
                            with span("simulate.pair", pe=v.name, app=a):
                                err, exact = compare_with_interp(
                                    progs[(v.name, a)], self.apps[a],
                                    inputs[i], res)
                                self._store[key] = _require_exact(
                                    err, exact, f"{a} on {v.name}")
                            self.stats["sim"] += 1
                        except Exception as e:
                            if not self._isolating():
                                raise
                            retry[i] = e
                for i in sorted(retry):
                    v, a, key = misses[i]
                    flag = self._retry("simulate",
                                       lambda v=v, a=a: serial_sim(v, a),
                                       pe=v.name, app=a)
                    if flag is _FAILED:
                        self._failed.add(key)
                        continue
                    self._store[key] = flag
                    self.stats["sim"] += 1
            elif misses:
                for v, a, key in misses:
                    with span("simulate.pair", pe=v.name, app=a):
                        flag = self._attempt(
                            "simulate",
                            lambda v=v, a=a: serial_sim(v, a),
                            pe=v.name, app=a)
                    if flag is _FAILED:
                        self._failed.add(key)
                        continue
                    self._store[key] = flag
                    self.stats["sim"] += 1
        return {pair: self._store[key] for pair, key in keys.items()
                if key in self._store}

    def sim_buckets(self, progs: Dict[Pair, Any]) -> Dict[Pair, str]:
        """Provenance: the batched-simulate bucket each pair rides.

        Derived purely from each pair's own program (bucket keys are
        per-program paddings), so this is stable across runs and memo
        hits.  Mirrors the gate :meth:`simulate` applies: ``"serial"``
        when the per-pair loop runs (configured, or the fallback for
        non-"jax" tile-step backends), ``""`` when verification is off
        and no simulation executes at all.
        """
        options = self.config.fabric
        if not options.sim_verify:
            return {pair: "" for pair in progs}
        if (self.config.sim_batch != "grouped"
                or options.sim_backend != "jax"):
            return {pair: "serial" for pair in progs}
        from ..sim import sim_signature
        return {pair: "x".join(str(d) for d in sim_signature(
                    prog, options.sim_iterations, options.sim_batch))
                for pair, prog in progs.items()}

    # -- full pipeline -----------------------------------------------------
    def run(self) -> ExploreResult:
        cfg = self.config
        self.failures = []               # per-run; stages re-attempt what
        self._failed.clear()             # failed last time (never memoized)
        t0 = time.monotonic()
        with span("explore.run", mode=cfg.mode):
            ranked = self.rank()
            variants = self.merge()
            self.map()
            pnrs = self.pnr() if cfg.fabric is not None else {}
            progs = self.schedule() if cfg.simulate else {}
            verified = self.simulate() if cfg.simulate else {}
        elapsed = time.monotonic() - t0

        def fresh(v: PEVariant, app_names) -> PEVariant:
            out = PEVariant(v.name, v.datapath, list(v.merged_subgraphs))
            for a in app_names:
                mk = ("map", self._merge_key(
                    a if cfg.mode == "per_app" else None), v.name,
                    self._app_keys[a])
                if mk not in self._store:    # pair failed the map stage
                    continue
                cost = _dc_replace(self._cost(v, a, mk))
                if (v.name, a) in pnrs:
                    from ..fabric.cost import attach_fabric
                    out.fabric_costs[a] = pnrs[(v.name, a)].cost
                    attach_fabric(cost, pnrs[(v.name, a)].cost)
                if (v.name, a) in progs:
                    # a pair whose simulate stage degraded keeps its
                    # schedule columns with verified=0 (attempted, no
                    # golden proof); -1 stays "verification off"
                    attach_sim(cost, v.datapath, progs[(v.name, a)].schedule,
                               fabric_cost=pnrs[(v.name, a)].cost,
                               verified=verified.get((v.name, a), 0))
                out.costs[a] = cost
            return out

        # every DSEResult carries the whole run's elapsed time: stages are
        # batched across apps, so per-app wall time is not separable (the
        # legacy driver timed each app's serial loop individually)
        results: Dict[str, DSEResult] = {}
        if cfg.mode == "per_app":
            for name, app in self.apps.items():
                if name not in variants:     # app degraded upstream
                    continue
                results[name] = DSEResult(
                    {name: app}, {name: ranked.get(name, [])},
                    [fresh(v, [name]) for v in variants[name]], elapsed)
        else:
            results[cfg.domain_name] = DSEResult(
                dict(self.apps), ranked,
                [fresh(v, sorted(self.apps)) for v in
                 variants[cfg.domain_name]], elapsed)
        return ExploreResult(cfg, _digest(cfg.to_dict()), dict(self.apps),
                             results, elapsed,
                             self.sim_buckets(progs) if progs else {},
                             self.metrics.to_dict(), list(self.failures))
