"""Staged, batch-first design-space-exploration pipeline.

The paper's flow (Sec. IV, Fig. 6) as an explicit pipeline object over a
single config::

    from repro.explore import ExploreConfig, Explorer
    from repro.fabric import FabricOptions, FabricSpec

    cfg = ExploreConfig(mode="per_app",
                        mining=MiningConfig(min_support=3),
                        fabric=FabricOptions(spec=FabricSpec(rows=8, cols=8),
                                             simulate=True))
    res = Explorer(apps, cfg).run()
    res.to_jsonl("results/explore.jsonl")

Stages (``mine -> rank -> merge -> map -> pnr -> schedule -> simulate``)
are individually invokable and memoized by content key; the ``pnr`` stage
anneals all (variant, app) placements of a bucket signature in one JAX
dispatch.  ``python -m repro.explore --help`` drives the same pipeline
from the command line.

Robustness (see docs/pipeline-reference.md): pass a
:class:`DiskStore` as the Explorer's store for crash-safe resumption;
with ``on_error="isolate"`` (the default) a twice-failing (variant, app)
pair degrades to a structured :class:`StageFailure` row in
``ExploreResult.failures`` instead of killing the run.
"""

from .config import CONFIG_SCHEMA, ConfigFormatError, ExploreConfig
from .persist import DiskStore, FileLock, ThreadSafeStore
from .pipeline import (Explorer, ExploreResult, evaluate_pairs, graph_key,
                       pnr_grouped)
from .records import (FAILURE_SCHEMA, RECORD_SCHEMA, ExploreRecord,
                      RecordFormatError, StageFailure, failures_from_jsonl,
                      from_jsonl, read_manifest, summarize_failures,
                      to_jsonl)

__all__ = [
    "CONFIG_SCHEMA", "ConfigFormatError", "ExploreConfig",
    "DiskStore", "FileLock", "ThreadSafeStore",
    "Explorer", "ExploreResult",
    "evaluate_pairs", "graph_key", "pnr_grouped",
    "FAILURE_SCHEMA", "RECORD_SCHEMA", "ExploreRecord",
    "RecordFormatError", "StageFailure", "failures_from_jsonl",
    "from_jsonl", "to_jsonl", "read_manifest", "summarize_failures",
]
