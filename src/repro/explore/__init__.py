"""Staged, batch-first design-space-exploration pipeline.

The paper's flow (Sec. IV, Fig. 6) as an explicit pipeline object over a
single config::

    from repro.explore import ExploreConfig, Explorer
    from repro.fabric import FabricOptions, FabricSpec

    cfg = ExploreConfig(mode="per_app",
                        mining=MiningConfig(min_support=3),
                        fabric=FabricOptions(spec=FabricSpec(rows=8, cols=8),
                                             simulate=True))
    res = Explorer(apps, cfg).run()
    res.to_jsonl("results/explore.jsonl")

Stages (``mine -> rank -> merge -> map -> pnr -> schedule -> simulate``)
are individually invokable and memoized by content key; the ``pnr`` stage
anneals all (variant, app) placements of a bucket signature in one JAX
dispatch.  ``python -m repro.explore --help`` drives the same pipeline
from the command line.
"""

from .config import CONFIG_SCHEMA, ExploreConfig
from .pipeline import (Explorer, ExploreResult, evaluate_pairs, graph_key,
                       pnr_grouped)
from .records import (RECORD_SCHEMA, ExploreRecord, from_jsonl,
                      read_manifest, to_jsonl)

__all__ = [
    "CONFIG_SCHEMA", "ExploreConfig", "Explorer", "ExploreResult",
    "evaluate_pairs", "graph_key", "pnr_grouped",
    "RECORD_SCHEMA", "ExploreRecord", "from_jsonl", "to_jsonl",
    "read_manifest",
]
