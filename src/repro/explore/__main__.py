"""CLI for the exploration pipeline.

Subcommands::

    python -m repro.explore per-app --suite ml --rows 16 --cols 16 \
        --simulate --out results/explore_ml.jsonl --dump-config cfg.json
    python -m repro.explore domain --suite image --name PE_IP
    python -m repro.explore --smoke          # fast end-to-end self check

``--dump-config`` writes the resolved :class:`ExploreConfig` as JSON; the
same exploration replays later with ``--config cfg.json``.

``--trace [PATH]`` records every pipeline stage (plus jax compile
events and anneal/scheduler telemetry) and writes Chrome trace-event
JSON — load it in Perfetto, or summarize with ``python -m
repro.obs.report``.  ``--metrics PATH`` dumps the explorer's metrics
registry (memo hits/misses, dispatch counts, bucket histograms) as
JSON.  Both are off by default and never change computed results.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

from ..graphir.graph import Graph
from .config import ExploreConfig
from .pipeline import Explorer


def _suite(name: str) -> Dict[str, Graph]:
    from ..apps import image, image_graphs, ml_graphs
    if name == "ml":
        return ml_graphs()
    if name == "image":
        return image_graphs()
    if name == "camera":
        return {"camera": image.build_graph("camera")}
    raise SystemExit(f"unknown suite {name!r} (ml | image | camera)")


def _config_from_args(args, mode: str) -> ExploreConfig:
    from ..core.mining import MiningConfig
    if args.config:
        cfg = ExploreConfig.from_dict(json.load(open(args.config)))
        return cfg.replace(mode=mode)
    mining = MiningConfig(min_support=args.min_support,
                          max_pattern_nodes=args.max_pattern_nodes,
                          time_budget_s=args.mining_budget_s)
    fabric = None
    if args.fabric or args.simulate:
        from ..fabric import FabricOptions, FabricSpec
        fabric = FabricOptions(spec=FabricSpec(rows=args.rows,
                                               cols=args.cols),
                               chains=args.chains, sweeps=args.sweeps,
                               seed=args.seed, simulate=args.simulate)
    return ExploreConfig(mode=mode, mining=mining, max_merge=args.max_merge,
                         rank_mode=args.rank_mode, fabric=fabric,
                         per_app_subgraphs=args.per_app_subgraphs,
                         domain_name=args.name, pnr_batch=args.pnr_batch,
                         sim_batch=args.sim_batch)


def _add_common(sp: argparse.ArgumentParser) -> None:
    sp.add_argument("--suite", default="ml",
                    help="application suite: ml | image | camera")
    sp.add_argument("--config", default=None,
                    help="load an ExploreConfig JSON blob (overrides knobs)")
    sp.add_argument("--min-support", type=int, default=3)
    sp.add_argument("--max-pattern-nodes", type=int, default=6)
    sp.add_argument("--mining-budget-s", type=float, default=15.0)
    sp.add_argument("--max-merge", type=int, default=3)
    sp.add_argument("--rank-mode", default="mis", choices=("mis", "utility"))
    sp.add_argument("--per-app-subgraphs", type=int, default=2)
    sp.add_argument("--name", default="PE_DOM",
                    help="domain variant name (domain mode)")
    sp.add_argument("--fabric", action="store_true",
                    help="place-and-route every (variant, app) pair")
    sp.add_argument("--simulate", action="store_true",
                    help="also modulo-schedule + cycle-accurately simulate "
                         "(implies --fabric)")
    sp.add_argument("--rows", type=int, default=8)
    sp.add_argument("--cols", type=int, default=8)
    sp.add_argument("--chains", type=int, default=8)
    sp.add_argument("--sweeps", type=int, default=16)
    sp.add_argument("--seed", type=int, default=0)
    sp.add_argument("--pnr-batch", default="grouped",
                    choices=("grouped", "serial"))
    sp.add_argument("--sim-batch", default="grouped",
                    choices=("grouped", "serial"),
                    help="batch-first schedule/simulate stages (grouped) "
                         "or the per-pair loop (serial); bit-identical")
    sp.add_argument("--out", default=None, help="write records jsonl here")
    sp.add_argument("--dump-config", default=None,
                    help="write the resolved ExploreConfig JSON here")
    # also accepted after the subcommand; SUPPRESS keeps a value given
    # before the subcommand from being clobbered by a subparser default
    sp.add_argument("--trace", nargs="?", const="out.trace.json",
                    default=argparse.SUPPRESS, metavar="PATH",
                    help="write a Chrome trace of this run "
                         "(default PATH: out.trace.json)")
    sp.add_argument("--metrics", default=argparse.SUPPRESS, metavar="PATH",
                    help="write the metrics registry as JSON")


def _obs_begin(trace, metrics_path, ex):
    """Enable tracing/telemetry/compile-profiling for one CLI run."""
    if not (trace or metrics_path):
        return None
    from .. import obs
    obs.enable_tracing()
    obs.enable_telemetry()
    obs.jaxprof.enable(registry=ex.metrics)
    return (trace, metrics_path)


def _obs_end(handle, ex):
    if handle is None:
        return
    trace, metrics_path = handle
    from .. import obs
    tracer = obs.disable_tracing()
    obs.enable_telemetry(False)
    obs.jaxprof.disable()
    if trace and tracer is not None:
        tracer.write_chrome(trace)
        print(f"trace -> {trace} "
              f"({sum(1 for _ in tracer.iter_spans())} spans)")
    if metrics_path:
        ex.metrics.write_json(metrics_path)
        print(f"metrics -> {metrics_path}")


def _run(args, mode: str) -> int:
    apps = _suite(args.suite)
    cfg = _config_from_args(args, mode)
    if args.dump_config:
        with open(args.dump_config, "w") as f:
            json.dump(cfg.to_dict(), f, indent=2)
        print(f"config -> {args.dump_config}")
    ex = Explorer(apps, cfg)
    obs_handle = _obs_begin(getattr(args, "trace", None),
                            getattr(args, "metrics", None), ex)
    try:
        res = ex.run()
    finally:
        _obs_end(obs_handle, ex)
    print(res.table())
    rows = res.records()
    if args.out:
        res.to_jsonl(args.out)
        print(f"{len(rows)} records -> {args.out}")
    print(f"# {len(rows)} (variant, app) records in {res.elapsed_s:.1f}s "
          f"[mode={mode}, pnr_batch={cfg.pnr_batch}]")
    return 0


#: every stage the smoke config executes must appear as a span in its trace
_SMOKE_STAGES = ("mine", "rank", "merge", "map", "pnr", "schedule",
                 "simulate")


def smoke(trace=None, metrics_path=None) -> int:
    """Fast end-to-end self check (used by the tier-1 CI job).

    Runs the full staged pipeline — including batched PnR and the cycle-
    accurate golden check — on the paper's Fig. 3 convolution example,
    then asserts the two load-bearing API properties: stage memoization
    (a downstream-only config change performs zero re-mines) and the
    jsonl round trip.  With ``trace`` set, the run is traced and the
    exported Chrome JSON must parse and contain one span per executed
    stage (:data:`_SMOKE_STAGES`).
    """
    from dataclasses import replace
    import tempfile

    from ..core.mining import MiningConfig
    from ..fabric import FabricOptions, FabricSpec
    from ..graphir import trace_scalar
    from .records import from_jsonl

    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c

    apps = {"conv": trace_scalar(
        conv4, ["i0", "i1", "i2", "i3", "w0", "w1", "w2", "w3", "c"])}
    cfg = ExploreConfig(
        mode="per_app",
        mining=MiningConfig(min_support=2, max_pattern_nodes=5),
        max_merge=2,
        fabric=FabricOptions(spec=FabricSpec(rows=4, cols=4),
                             chains=2, sweeps=4, simulate=True))
    ex = Explorer(apps, cfg)
    obs_handle = _obs_begin(trace, metrics_path, ex)
    try:
        res = ex.run()
    finally:
        _obs_end(obs_handle, ex)
    if trace:
        with open(trace) as f:
            events = json.load(f)["traceEvents"]
        names = {e["name"] for e in events if e.get("ph") == "X"}
        missing = [s for s in _SMOKE_STAGES if s not in names]
        assert not missing, f"trace missing stage spans: {missing}"
        print(f"# trace OK: {len(events)} events cover all "
              f"{len(_SMOKE_STAGES)} stages")
    rows = res.records()
    assert rows, "no records produced"
    assert all(r.sim_verified == 1 for r in rows), "golden check failed"
    mines = ex.stats["mine"]
    assert mines == 1, f"expected 1 mine, got {mines}"

    # downstream-only change: more annealing sweeps -> zero re-mines
    ex2 = ex.with_config(fabric=replace(cfg.fabric, sweeps=6))
    res2 = ex2.run()
    assert ex2.stats["mine"] == mines, "memoization failed: re-mined"
    assert res2.records(), "second run produced no records"

    with tempfile.NamedTemporaryFile(suffix=".jsonl") as f:
        res.to_jsonl(f.name)
        back = from_jsonl(f.name)
    assert [r.to_dict() for r in back] == [r.to_dict() for r in rows], \
        "jsonl round trip diverged"

    # the batch-first schedule/simulate stages actually batched: every
    # simulated pair rode a vmapped dispatch, not a per-pair compile
    assert ex.stats["sim_dispatch"] >= 1, "no batched sim dispatch ran"
    assert ex.stats["sched_group"] >= 1, "no lockstep schedule group ran"
    assert all(r.sim_bucket not in ("", "serial") for r in rows), \
        "records missing batched sim_bucket provenance"

    print(res.table())
    print(f"# explore smoke OK: {len(rows)} records, "
          f"{ex.stats['pnr_dispatch']} batched pnr dispatch(es), "
          f"{ex.stats['sim_dispatch']} batched sim dispatch(es), "
          f"stats={dict(ex.stats)}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.explore",
                                 description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast end-to-end self check")
    ap.add_argument("--trace", nargs="?", const="out.trace.json",
                    default=None, metavar="PATH",
                    help="record a pipeline trace and write Chrome "
                         "trace-event JSON (default PATH: out.trace.json); "
                         "open in Perfetto or `python -m repro.obs.report`")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the run's metrics registry as JSON")
    sub = ap.add_subparsers(dest="cmd")
    for cmd in ("per-app", "domain"):
        _add_common(sub.add_parser(cmd))
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke(args.trace, args.metrics)
    if args.cmd is None:
        ap.print_help()
        return 2
    return _run(args, "per_app" if args.cmd == "per-app" else "domain")


if __name__ == "__main__":
    sys.exit(main())
