"""CLI for the exploration pipeline.

Subcommands::

    python -m repro.explore per-app --suite ml --rows 16 --cols 16 \
        --simulate --out results/explore_ml.jsonl --dump-config cfg.json
    python -m repro.explore domain --suite image --name PE_IP
    python -m repro.explore --smoke          # fast end-to-end self check

``--dump-config`` writes the resolved :class:`ExploreConfig` as JSON; the
same exploration replays later with ``--config cfg.json``.

``--trace [PATH]`` records every pipeline stage (plus jax compile
events and anneal/scheduler telemetry) and writes Chrome trace-event
JSON — load it in Perfetto, or summarize with ``python -m
repro.obs.report``.  ``--metrics PATH`` dumps the explorer's metrics
registry (memo hits/misses, dispatch counts, bucket histograms) as
JSON.  Both are off by default and never change computed results.

Robustness flags (see docs/pipeline-reference.md)::

    --store DIR          crash-safe on-disk memo store; a re-invocation
                         after a crash resumes from completed stages
    --on-error MODE      isolate (default): a failing pair degrades to a
                         structured failure row; raise: fail fast
    --allow-partial      exit 0 even when pairs degraded
    --inject-fault SPEC  arm a deterministic fault (site:kind:nth);
                         repeatable — test/CI harness only

Exit codes: 0 clean run; 1 degraded (StageFailures present, or a
fail-fast error) — one structured summary line on stderr, never a
traceback; 2 usage / malformed config or records file.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

from ..graphir.graph import Graph
from .config import ExploreConfig
from .pipeline import Explorer


def _suite(name: str) -> Dict[str, Graph]:
    from ..apps import image, image_graphs, ml_graphs
    if name == "ml":
        return ml_graphs()
    if name == "image":
        return image_graphs()
    if name == "camera":
        return {"camera": image.build_graph("camera")}
    raise SystemExit(f"unknown suite {name!r} (ml | image | camera)")


def _config_from_args(args, mode: str) -> ExploreConfig:
    from ..core.mining import MiningConfig
    if args.config:
        cfg = ExploreConfig.from_dict(json.load(open(args.config)))
        return cfg.replace(mode=mode)
    mining = MiningConfig(min_support=args.min_support,
                          max_pattern_nodes=args.max_pattern_nodes,
                          time_budget_s=args.mining_budget_s)
    fabric = None
    if args.fabric or args.simulate:
        from ..fabric import FabricOptions, FabricSpec
        fabric = FabricOptions(spec=FabricSpec(rows=args.rows,
                                               cols=args.cols),
                               chains=args.chains, sweeps=args.sweeps,
                               seed=args.seed, simulate=args.simulate)
    return ExploreConfig(mode=mode, mining=mining, max_merge=args.max_merge,
                         rank_mode=args.rank_mode, fabric=fabric,
                         per_app_subgraphs=args.per_app_subgraphs,
                         domain_name=args.name, pnr_batch=args.pnr_batch,
                         pnr_mode=args.pnr_mode, sim_batch=args.sim_batch,
                         on_error=args.on_error)


def _add_common(sp: argparse.ArgumentParser) -> None:
    sp.add_argument("--suite", default="ml",
                    help="application suite: ml | image | camera")
    sp.add_argument("--config", default=None,
                    help="load an ExploreConfig JSON blob (overrides knobs)")
    sp.add_argument("--min-support", type=int, default=3)
    sp.add_argument("--max-pattern-nodes", type=int, default=6)
    sp.add_argument("--mining-budget-s", type=float, default=15.0)
    sp.add_argument("--max-merge", type=int, default=3)
    sp.add_argument("--rank-mode", default="mis", choices=("mis", "utility"))
    sp.add_argument("--per-app-subgraphs", type=int, default=2)
    sp.add_argument("--name", default="PE_DOM",
                    help="domain variant name (domain mode)")
    sp.add_argument("--fabric", action="store_true",
                    help="place-and-route every (variant, app) pair")
    sp.add_argument("--simulate", action="store_true",
                    help="also modulo-schedule + cycle-accurately simulate "
                         "(implies --fabric)")
    sp.add_argument("--rows", type=int, default=8)
    sp.add_argument("--cols", type=int, default=8)
    sp.add_argument("--chains", type=int, default=8)
    sp.add_argument("--sweeps", type=int, default=16)
    sp.add_argument("--seed", type=int, default=0)
    sp.add_argument("--pnr-batch", default="grouped",
                    choices=("grouped", "serial"))
    sp.add_argument("--pnr-mode", default="flat",
                    choices=("flat", "hierarchical"),
                    help="flat: single-level anneal (default); "
                         "hierarchical: two-level cluster -> detail -> "
                         "deblock placement for large arrays "
                         "(docs/placement.md)")
    sp.add_argument("--sim-batch", default="grouped",
                    choices=("grouped", "serial"),
                    help="batch-first schedule/simulate stages (grouped) "
                         "or the per-pair loop (serial); bit-identical")
    sp.add_argument("--on-error", default="isolate",
                    choices=("isolate", "raise"),
                    help="isolate: a failing (variant, app) pair degrades "
                         "to a StageFailure row, groupmates unaffected; "
                         "raise: fail fast on the first error")
    sp.add_argument("--store", default=None, metavar="DIR",
                    help="crash-safe on-disk memo store (atomic writes, "
                         "checksummed entries); re-invoking with the same "
                         "DIR resumes from completed stages")
    sp.add_argument("--allow-partial", action="store_true",
                    help="exit 0 even when some pairs degraded to "
                         "StageFailure rows")
    sp.add_argument("--inject-fault", action="append", default=None,
                    metavar="SITE:KIND:NTH",
                    help="arm a deterministic fault (repeatable); kinds: "
                         "exc | budget | kill | truncate; e.g. "
                         "pnr:exc:0, store.write:kill:2, schedule:budget:1+")
    sp.add_argument("--out", default=None, help="write records jsonl here")
    sp.add_argument("--dump-config", default=None,
                    help="write the resolved ExploreConfig JSON here")
    # also accepted after the subcommand; SUPPRESS keeps a value given
    # before the subcommand from being clobbered by a subparser default
    sp.add_argument("--trace", nargs="?", const="out.trace.json",
                    default=argparse.SUPPRESS, metavar="PATH",
                    help="write a Chrome trace of this run "
                         "(default PATH: out.trace.json)")
    sp.add_argument("--metrics", default=argparse.SUPPRESS, metavar="PATH",
                    help="write the metrics registry as JSON")


def _obs_begin(trace, metrics_path, ex):
    """Enable tracing/telemetry/compile-profiling for one CLI run."""
    if not (trace or metrics_path):
        return None
    from .. import obs
    obs.enable_tracing()
    obs.enable_telemetry()
    obs.jaxprof.enable(registry=ex.metrics)
    return (trace, metrics_path)


def _obs_end(handle, ex):
    if handle is None:
        return
    trace, metrics_path = handle
    from .. import obs
    tracer = obs.disable_tracing()
    obs.enable_telemetry(False)
    obs.jaxprof.disable()
    if trace and tracer is not None:
        tracer.write_chrome(trace)
        print(f"trace -> {trace} "
              f"({sum(1 for _ in tracer.iter_spans())} spans)")
    if metrics_path:
        ex.metrics.write_json(metrics_path)
        print(f"metrics -> {metrics_path}")


def _run(args, mode: str) -> int:
    from .. import faultinject
    from .records import summarize_failures

    apps = _suite(args.suite)
    cfg = _config_from_args(args, mode)
    if args.dump_config:
        with open(args.dump_config, "w") as f:
            json.dump(cfg.to_dict(), f, indent=2)
        print(f"config -> {args.dump_config}")
    store = metrics = None
    if args.store:
        from ..obs.metrics import MetricsRegistry
        from .persist import DiskStore
        metrics = MetricsRegistry()       # shared so load-time events
        store = DiskStore(args.store, metrics=metrics)   # land in it too
    ex = Explorer(apps, cfg, store=store, metrics=metrics)
    obs_handle = _obs_begin(getattr(args, "trace", None),
                            getattr(args, "metrics", None), ex)
    try:
        for spec in args.inject_fault or ():
            faultinject.arm(spec)
        res = ex.run()
    finally:
        faultinject.disarm_all()
        _obs_end(obs_handle, ex)
    print(res.table())
    rows = res.records()
    if args.out:
        res.to_jsonl(args.out)
        print(f"{len(rows)} records -> {args.out}")
    print(f"# {len(rows)} (variant, app) records in {res.elapsed_s:.1f}s "
          f"[mode={mode}, pnr_batch={cfg.pnr_batch}]")
    if res.failures:
        print(f"# DEGRADED: {summarize_failures(res.failures)}",
              file=sys.stderr)
        if not args.allow_partial:
            return 1
    return 0


#: every stage the smoke config executes must appear as a span in its trace
_SMOKE_STAGES = ("mine", "rank", "merge", "map", "pnr", "schedule",
                 "simulate")


def _smoke_case():
    """The paper's Fig. 3 convolution on a 4x4 fabric — the shared
    (apps, config) case every self-check smoke runs."""
    from ..core.mining import MiningConfig
    from ..fabric import FabricOptions, FabricSpec
    from ..graphir import trace_scalar

    def conv4(i0, i1, i2, i3, w0, w1, w2, w3, c):
        return (((i0 * w0) + (i1 * w1)) + (i2 * w2)) + (i3 * w3) + c

    apps = {"conv": trace_scalar(
        conv4, ["i0", "i1", "i2", "i3", "w0", "w1", "w2", "w3", "c"])}
    cfg = ExploreConfig(
        mode="per_app",
        mining=MiningConfig(min_support=2, max_pattern_nodes=5),
        max_merge=2,
        fabric=FabricOptions(spec=FabricSpec(rows=4, cols=4),
                             chains=2, sweeps=4, simulate=True))
    return apps, cfg


def smoke(trace=None, metrics_path=None) -> int:
    """Fast end-to-end self check (used by the tier-1 CI job).

    Runs the full staged pipeline — including batched PnR and the cycle-
    accurate golden check — on the paper's Fig. 3 convolution example,
    then asserts the two load-bearing API properties: stage memoization
    (a downstream-only config change performs zero re-mines) and the
    jsonl round trip.  With ``trace`` set, the run is traced and the
    exported Chrome JSON must parse and contain one span per executed
    stage (:data:`_SMOKE_STAGES`).
    """
    from dataclasses import replace
    import tempfile

    from .records import from_jsonl

    apps, cfg = _smoke_case()
    ex = Explorer(apps, cfg)
    obs_handle = _obs_begin(trace, metrics_path, ex)
    try:
        res = ex.run()
    finally:
        _obs_end(obs_handle, ex)
    if trace:
        with open(trace) as f:
            events = json.load(f)["traceEvents"]
        names = {e["name"] for e in events if e.get("ph") == "X"}
        missing = [s for s in _SMOKE_STAGES if s not in names]
        assert not missing, f"trace missing stage spans: {missing}"
        print(f"# trace OK: {len(events)} events cover all "
              f"{len(_SMOKE_STAGES)} stages")
    rows = res.records()
    assert rows, "no records produced"
    assert all(r.sim_verified == 1 for r in rows), "golden check failed"
    mines = ex.stats["mine"]
    assert mines == 1, f"expected 1 mine, got {mines}"

    # downstream-only change: more annealing sweeps -> zero re-mines
    ex2 = ex.with_config(fabric=replace(cfg.fabric, sweeps=6))
    res2 = ex2.run()
    assert ex2.stats["mine"] == mines, "memoization failed: re-mined"
    assert res2.records(), "second run produced no records"

    with tempfile.NamedTemporaryFile(suffix=".jsonl") as f:
        res.to_jsonl(f.name)
        back = from_jsonl(f.name)
    assert [r.to_dict() for r in back] == [r.to_dict() for r in rows], \
        "jsonl round trip diverged"

    # the batch-first schedule/simulate stages actually batched: every
    # simulated pair rode a vmapped dispatch, not a per-pair compile
    assert ex.stats["sim_dispatch"] >= 1, "no batched sim dispatch ran"
    assert ex.stats["sched_group"] >= 1, "no lockstep schedule group ran"
    assert all(r.sim_bucket not in ("", "serial") for r in rows), \
        "records missing batched sim_bucket provenance"

    print(res.table())
    print(f"# explore smoke OK: {len(rows)} records, "
          f"{ex.stats['pnr_dispatch']} batched pnr dispatch(es), "
          f"{ex.stats['sim_dispatch']} batched sim dispatch(es), "
          f"stats={dict(ex.stats)}")
    return 0


def faults_smoke() -> int:
    """Fault-injection matrix (the tier-1 CI robustness job).

    One injected fault per pipeline stage, twice over:

    * transient (first attempt only) — the stage's serial retry must
      absorb it; the run stays clean and produces the full record set;
    * persistent (first attempt AND the ``.retry`` site) — the pair
      degrades to a structured :class:`StageFailure` row while every
      *untouched* pair's record stays bit-identical to a clean
      baseline (the pow2-bucket independence invariant).

    Plus a budget-exhaustion leg: an impossible scheduler II budget must
    surface as ``BudgetExceeded`` failure rows — degraded, never a hang.
    """
    from dataclasses import replace

    from .. import faultinject
    from ..errors import BudgetExceeded           # noqa: F401 (doc link)
    from .records import summarize_failures

    apps, cfg = _smoke_case()
    base = Explorer(apps, cfg).run()
    base_rows = {(r.pe_name, r.app): r.to_dict() for r in base.records()}
    assert base.clean and base_rows, "baseline run must be clean"

    for stage in _SMOKE_STAGES:
        faultinject.disarm_all()
        faultinject.arm(f"{stage}:exc:0")
        res = Explorer(apps, cfg).run()
        faultinject.disarm_all()
        assert res.clean, (f"{stage}: transient fault not absorbed by "
                           f"retry: {[f.to_dict() for f in res.failures]}")
        assert {(r.pe_name, r.app) for r in res.records()} \
            == set(base_rows), f"{stage}: transient fault lost records"

        faultinject.arm(f"{stage}:exc:0")
        faultinject.arm(f"{stage}.retry:exc:0")
        res = Explorer(apps, cfg).run()
        faultinject.disarm_all()
        assert res.failures, f"{stage}: persistent fault left run clean"
        assert all(f.stage == stage for f in res.failures), \
            f"{stage}: failure rows name wrong stage: {res.failures}"
        assert all(f.retried for f in res.failures), \
            f"{stage}: failure rows not marked retried"
        hit = {(f.pe_name, f.app) for f in res.failures}
        for r in res.records():
            k = (r.pe_name, r.app)
            if k in hit:      # the degraded pair keeps upstream columns
                continue
            assert r.to_dict() == base_rows[k], \
                f"{stage}: untouched pair {k} diverged from baseline"
        print(f"# {stage:<9} transient->retried clean; persistent->"
              f"{summarize_failures(res.failures)}")

    # budgets: an impossible cap degrades, never hangs — on both the
    # grouped dispatch AND its serial retry (the budget is content, not
    # a property of which batch path ran)
    for knob, stage in ((dict(anneal_max_states=1), "pnr"),
                        (dict(sim_max_cycles=1), "simulate")):
        cfg_b = cfg.replace(fabric=replace(cfg.fabric, **knob))
        res = Explorer(apps, cfg_b).run()
        assert res.failures, f"{knob}: exhausted budget left run clean"
        assert all(f.stage == stage for f in res.failures)
        assert all(f.error_type == "BudgetExceeded" for f in res.failures), \
            f"budget failures mistyped: {[f.to_dict() for f in res.failures]}"
        assert all(f.budget for f in res.failures), \
            "BudgetExceeded rows carry no budget state"
        print(f"# budget    {knob} -> {summarize_failures(res.failures)}")
    print("# explore faults-smoke OK: every stage degrades, none die")
    return 0


def resume_smoke() -> int:
    """Kill-resume self check (the tier-1 CI crash-safety job).

    Invokes this CLI in a subprocess with ``--store`` and an armed
    ``store.write:kill:N`` fault — the process SIGKILLs itself mid-run,
    mid-store-write.  A re-invocation against the same store directory
    must resume from the completed stages and produce records
    bit-identical to a crash-free run (manifest header excluded: it
    captures wall-clock environment).
    """
    import subprocess
    import tempfile

    def cli(extra, check=True):
        cmd = [sys.executable, "-m", "repro.explore", "per-app",
               "--suite", "camera", "--simulate", "--rows", "6",
               "--cols", "6", "--chains", "2", "--sweeps", "4",
               "--min-support", "2", "--max-pattern-nodes", "5"] + extra
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=600)
        if check and p.returncode != 0:
            raise AssertionError(
                f"{cmd} -> rc={p.returncode}\n{p.stdout}\n{p.stderr}")
        return p

    def records_of(path):
        with open(path) as f:
            return [ln for ln in f.read().splitlines()[1:] if ln]

    with tempfile.TemporaryDirectory() as tmp:
        clean_out = f"{tmp}/clean.jsonl"
        cli(["--out", clean_out])
        want = records_of(clean_out)
        assert want, "crash-free run produced no records"

        store = f"{tmp}/store"
        p = cli(["--store", store, "--inject-fault", "store.write:kill:3"],
                check=False)
        assert p.returncode != 0, "injected SIGKILL did not kill the run"
        import os as _os
        n_entries = len([f for f in _os.listdir(store)
                         if f.endswith(".entry")])
        assert n_entries >= 3, \
            f"killed run persisted only {n_entries} entries"

        resumed_out = f"{tmp}/resumed.jsonl"
        p = cli(["--store", store, "--out", resumed_out])
        got = records_of(resumed_out)
        assert got == want, (
            "resumed records diverge from crash-free run:\n"
            + "\n".join(ln for ln in got if ln not in want))
    print(f"# explore resume-smoke OK: killed mid-write after "
          f"{n_entries} persisted entries, resumed bit-identical "
          f"({len(want)} records)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.explore",
                                 description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast end-to-end self check")
    ap.add_argument("--faults-smoke", action="store_true",
                    help="fault-injection matrix: one injected fault per "
                         "stage, asserting degraded-not-dead")
    ap.add_argument("--resume-smoke", action="store_true",
                    help="kill -9 a run mid-store-write, resume from the "
                         "on-disk store, assert bit-identical records")
    ap.add_argument("--trace", nargs="?", const="out.trace.json",
                    default=None, metavar="PATH",
                    help="record a pipeline trace and write Chrome "
                         "trace-event JSON (default PATH: out.trace.json); "
                         "open in Perfetto or `python -m repro.obs.report`")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the run's metrics registry as JSON")
    sub = ap.add_subparsers(dest="cmd")
    for cmd in ("per-app", "domain"):
        _add_common(sub.add_parser(cmd))
    args = ap.parse_args(argv)
    from .config import ConfigFormatError
    from .records import RecordFormatError
    try:
        if args.smoke:
            return smoke(args.trace, args.metrics)
        if args.faults_smoke:
            return faults_smoke()
        if args.resume_smoke:
            return resume_smoke()
        if args.cmd is None:
            ap.print_help()
            return 2
        return _run(args, "per_app" if args.cmd == "per-app" else "domain")
    except (ConfigFormatError, RecordFormatError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except (ValueError, RuntimeError, OSError) as e:
        # --on-error raise (fail fast) and malformed CLI inputs land
        # here: one structured line, never an unhandled traceback
        print(f"error: {type(e).__name__}: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
