"""Crash-safe on-disk memo store behind the ``Explorer._store`` interface.

The Explorer memoizes every stage in a plain dict keyed by stable
content hashes (stage name + graph fingerprints + the config fields that
stage reads).  :class:`DiskStore` is a drop-in ``MutableMapping`` over
the same keys that write-throughs each entry to its own file, so a
``kill -9`` mid-run loses at most the stage that was executing — the
next invocation with the same store directory resumes from every
completed stage and produces bit-identical records (CI-asserted).

Entry file layout (``<dir>/<keyhash>.entry``):

* line 1 — a JSON header: ``{"magic": "repro-store", "schema": 1,
  "stage": ..., "sha256": <payload digest>, "size": <payload bytes>}``
* the raw pickled ``(key, value)`` payload.

Durability and integrity:

* writes go to a temp file in the same directory, are flushed +
  fsynced, then :func:`os.replace`'d into place — an entry is either
  fully present or absent, never half-written;
* on open, every entry is checksum-verified before being trusted;
  corrupted / truncated / undecodable files are moved to
  ``<dir>/quarantine/`` (kept for post-mortems, never read again) and
  their keys simply recompute;
* values that cannot be pickled (stale jit handles, etc.) stay
  memoized in memory only, counted by ``store.unpicklable``.

Cross-process safety (the serving layer runs multiple server processes
over one store directory):

* every mutation holds an advisory file lock (``<dir>/.lock``,
  :mod:`fcntl` ``flock``; an ``O_EXCL`` spin when flock is missing), so
  concurrent writers serialize instead of racing quarantine moves;
* a miss *read-throughs* the directory before recomputing — an entry
  another process committed after our open is verified, adopted, and
  counted as ``store.readthrough``.

For sharing one store between threads of a single process (the serving
batcher's executor thread next to its event loop), wrap it in
:class:`ThreadSafeStore`.

Metrics (on the optional registry): ``store.load`` / ``store.hit`` /
``store.miss`` / ``store.write`` / ``store.quarantined`` /
``store.unpicklable`` / ``store.delete`` / ``store.readthrough``.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import pickle
import tempfile
import threading
import time
from typing import Any, Dict, Iterator, MutableMapping, Optional, Tuple

from ..errors import StoreCorruption
from .. import faultinject

try:                                   # POSIX; the O_EXCL spin covers the rest
    import fcntl as _fcntl
except ImportError:                    # pragma: no cover - non-POSIX
    _fcntl = None

__all__ = ["DiskStore", "FileLock", "ThreadSafeStore", "MAGIC",
           "STORE_SCHEMA"]

MAGIC = "repro-store"
STORE_SCHEMA = 1
_SUFFIX = ".entry"
_WRITE_SITE = "store.write"


class FileLock:
    """Advisory cross-process mutex on a lockfile.

    ``flock``-based where available (the lock dies with the process, so
    a ``kill -9`` never wedges the store); otherwise an ``O_EXCL``
    create-spin with a staleness timeout.  Not reentrant; hold briefly
    around individual store mutations.
    """

    def __init__(self, path: str, *, timeout_s: float = 30.0) -> None:
        self.path = path
        self.timeout_s = timeout_s
        self._fd: Optional[int] = None

    def acquire(self) -> None:
        if self._fd is not None:
            raise RuntimeError(f"FileLock({self.path!r}) is not reentrant")
        if _fcntl is not None:
            fd = os.open(self.path, os.O_CREAT | os.O_RDWR, 0o644)
            try:
                _fcntl.flock(fd, _fcntl.LOCK_EX)
            except BaseException:
                os.close(fd)
                raise
            self._fd = fd
            return
        deadline = time.monotonic() + self.timeout_s
        while True:                    # pragma: no cover - non-POSIX path
            try:
                self._fd = os.open(self.path,
                                   os.O_CREAT | os.O_EXCL | os.O_RDWR, 0o644)
                return
            except FileExistsError:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"could not acquire {self.path} within "
                        f"{self.timeout_s}s (stale lock from a dead "
                        f"writer? remove it by hand)")
                time.sleep(0.005)

    def release(self) -> None:
        fd, self._fd = self._fd, None
        if fd is None:
            return
        if _fcntl is not None:
            _fcntl.flock(fd, _fcntl.LOCK_UN)
            os.close(fd)
        else:                          # pragma: no cover - non-POSIX path
            os.close(fd)
            try:
                os.unlink(self.path)
            except OSError:
                pass

    def __enter__(self) -> "FileLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


def _key_filename(key: Any) -> str:
    """Stable filename for a content key (keys are tuples of str/int
    whose ``repr`` is deterministic across processes)."""
    return hashlib.sha256(repr(key).encode("utf-8")).hexdigest()[:32] + _SUFFIX


class DiskStore(MutableMapping):
    """Persistent, checksummed, crash-safe memo store.

    Slots into ``Explorer(store=DiskStore(path))`` — the pipeline sees
    an ordinary dict.  All reads are served from memory (the directory
    is scanned once at open); writes go through to disk atomically.
    """

    def __init__(self, path: str, *, metrics: Any = None) -> None:
        self.path = str(path)
        self.quarantine_dir = os.path.join(self.path, "quarantine")
        self.lock_path = os.path.join(self.path, ".lock")
        self._metrics = metrics
        self._mem: Dict[Any, Any] = {}
        self._unpicklable: set = set()
        os.makedirs(self.path, exist_ok=True)
        self._load_all()

    def _lock(self) -> FileLock:
        """A fresh (non-nested) cross-process lock for one mutation."""
        return FileLock(self.lock_path)

    # -- metrics ---------------------------------------------------------
    def _inc(self, name: str, n: int = 1) -> None:
        if self._metrics is not None:
            self._metrics.inc(name, n)

    # -- load / verify ---------------------------------------------------
    def _load_all(self) -> None:
        for fname in sorted(os.listdir(self.path)):
            if not fname.endswith(_SUFFIX):
                continue
            fpath = os.path.join(self.path, fname)
            try:
                key, value = self._read_entry(fpath)
            except Exception as e:  # corrupt header, checksum, pickle...
                self._quarantine(fpath, reason=repr(e))
                continue
            self._mem[key] = value
            self._inc("store.load")

    def _read_entry(self, fpath: str) -> Tuple[Any, Any]:
        with open(fpath, "rb") as f:
            header_line = f.readline()
            try:
                header = json.loads(header_line)
            except Exception:
                raise StoreCorruption(f"undecodable header in {fpath}")
            if not isinstance(header, dict) or header.get("magic") != MAGIC:
                raise StoreCorruption(f"bad magic in {fpath}")
            if header.get("schema") != STORE_SCHEMA:
                raise StoreCorruption(
                    f"store schema {header.get('schema')!r} != "
                    f"{STORE_SCHEMA} in {fpath}")
            payload = f.read()
        if len(payload) != header.get("size"):
            raise StoreCorruption(
                f"truncated payload in {fpath}: "
                f"{len(payload)} != {header.get('size')} bytes")
        digest = hashlib.sha256(payload).hexdigest()
        if digest != header.get("sha256"):
            raise StoreCorruption(f"checksum mismatch in {fpath}")
        key, value = pickle.loads(payload)
        return key, value

    def _quarantine(self, fpath: str, reason: str) -> None:
        os.makedirs(self.quarantine_dir, exist_ok=True)
        dest = os.path.join(self.quarantine_dir, os.path.basename(fpath))
        try:
            os.replace(fpath, dest)
            with open(dest + ".reason", "w") as f:
                f.write(reason + "\n")
        except OSError:
            pass
        self._inc("store.quarantined")

    # -- write path ------------------------------------------------------
    def _write_entry(self, key: Any, value: Any) -> bool:
        try:
            payload = pickle.dumps((key, value), protocol=pickle.HIGHEST_PROTOCOL)
        except Exception:
            self._unpicklable.add(_key_filename(key))
            self._inc("store.unpicklable")
            return False
        header = json.dumps({
            "magic": MAGIC, "schema": STORE_SCHEMA,
            "stage": key[0] if isinstance(key, tuple) and key else None,
            "sha256": hashlib.sha256(payload).hexdigest(),
            "size": len(payload),
        }, sort_keys=True).encode("utf-8") + b"\n"
        fname = _key_filename(key)
        fpath = os.path.join(self.path, fname)
        with self._lock():
            fd, tmp = tempfile.mkstemp(prefix=fname + ".", suffix=".tmp",
                                       dir=self.path)
            try:
                with io.FileIO(fd, "wb", closefd=True) as f:
                    f.write(header)
                    f.write(payload)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, fpath)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        # Fault injection: simulate a torn write by truncating the entry
        # we just committed (the next open must quarantine + recompute).
        if faultinject.consume_flag(_WRITE_SITE):
            with open(fpath, "r+b") as f:
                f.truncate(max(0, os.path.getsize(fpath) - 7))
        self._inc("store.write")
        return True

    def _read_through(self, key: Any) -> bool:
        """Adopt an entry another process committed after our open.

        Returns True when the key is now in memory.  A corrupt file is
        quarantined (and the key recomputes); a filename-prefix
        collision with a different key is treated as a miss.
        """
        fpath = os.path.join(self.path, _key_filename(key))
        if not os.path.exists(fpath):
            return False
        try:
            k, value = self._read_entry(fpath)
        except Exception as e:
            self._quarantine(fpath, reason=repr(e))
            return False
        if k != key:
            return False
        self._mem[k] = value
        self._inc("store.readthrough")
        return True

    # -- MutableMapping --------------------------------------------------
    def __getitem__(self, key: Any) -> Any:
        if key not in self._mem and not self._read_through(key):
            self._inc("store.miss")
            raise KeyError(key)
        self._inc("store.hit")
        return self._mem[key]

    def __setitem__(self, key: Any, value: Any) -> None:
        faultinject.fire(_WRITE_SITE, key=key[0] if isinstance(key, tuple)
                         and key else key)
        self._write_entry(key, value)
        self._mem[key] = value

    def __delitem__(self, key: Any) -> None:
        del self._mem[key]
        fpath = os.path.join(self.path, _key_filename(key))
        with self._lock():
            try:
                os.unlink(fpath)
            except FileNotFoundError:
                pass
        self._inc("store.delete")

    def __contains__(self, key: Any) -> bool:
        hit = key in self._mem or self._read_through(key)
        self._inc("store.hit" if hit else "store.miss")
        return hit

    def __iter__(self) -> Iterator[Any]:
        return iter(list(self._mem))

    def __len__(self) -> int:
        return len(self._mem)

    def __repr__(self) -> str:
        return (f"DiskStore({self.path!r}, entries={len(self._mem)}, "
                f"unpicklable={len(self._unpicklable)})")


class ThreadSafeStore(MutableMapping):
    """RLock facade making any memo store shareable across threads.

    The serving layer's batcher mutates its store from an executor
    thread while the event loop (or a second batcher) may read it;
    ``ThreadSafeStore(DiskStore(path))`` gives every mapping operation
    a process-level mutex on top of DiskStore's cross-*process* file
    lock.  Wraps plain dicts just as well for in-memory services.
    """

    def __init__(self, inner: MutableMapping) -> None:
        self.inner = inner
        self._mutex = threading.RLock()

    def __getitem__(self, key: Any) -> Any:
        with self._mutex:
            return self.inner[key]

    def __setitem__(self, key: Any, value: Any) -> None:
        with self._mutex:
            self.inner[key] = value

    def __delitem__(self, key: Any) -> None:
        with self._mutex:
            del self.inner[key]

    def __contains__(self, key: Any) -> bool:
        with self._mutex:
            return key in self.inner

    def __iter__(self) -> Iterator[Any]:
        with self._mutex:
            return iter(list(self.inner))

    def __len__(self) -> int:
        with self._mutex:
            return len(self.inner)

    def __repr__(self) -> str:
        return f"ThreadSafeStore({self.inner!r})"
