from .pipeline import DataConfig, MemmapLM, Prefetcher, SyntheticLM, make_source

__all__ = ["DataConfig", "MemmapLM", "Prefetcher", "SyntheticLM",
           "make_source"]
