"""Deterministic token data pipeline (synthetic + memmap-backed).

Production shape: each host reads only its shard of the global batch
(``host_batch = global_batch / n_hosts``), steps are addressable by index
(resume = seek, no state files), and a background prefetch thread keeps one
batch ahead of the training loop.

Two sources:
* ``SyntheticLM`` — counter-seeded random tokens with a learnable bigram
  structure (so loss visibly decreases in the examples);
* ``MemmapLM`` — flat binary token file (np.uint16/uint32 memmap), sliced
  into (batch, seq+1) windows; the standard packed-corpus format.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    path: Optional[str] = None      # memmap file -> MemmapLM

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Markov-ish synthetic stream: next ~ (5*cur + noise) % vocab."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        b, s = cfg.host_batch, cfg.seq_len
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, b)
        noise = rng.integers(0, 3, (b, s))
        for t in range(1, s):
            toks[:, t] = (5 * toks[:, t - 1] + noise[:, t]) % cfg.vocab
        return {"inputs": toks, "targets": toks.copy()}


class MemmapLM:
    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        assert cfg.path is not None
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        rng = np.random.default_rng(cfg.seed * 7 + step)
        idx = rng.integers(0, self.n_windows, cfg.global_batch)
        idx = idx[cfg.host_id * b:(cfg.host_id + 1) * b]
        toks = np.stack([np.asarray(self.data[i * s: i * s + s],
                                    dtype=np.int32) for i in idx])
        return {"inputs": toks, "targets": toks.copy()}


class Prefetcher:
    """One-batch-ahead background prefetch with step-indexed resume."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def make_source(cfg: DataConfig):
    return MemmapLM(cfg) if cfg.path else SyntheticLM(cfg)
