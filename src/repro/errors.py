"""Shared exception taxonomy for failure-tolerant pipelines.

Every subsystem that can exhaust a budget or hit an injected fault
raises one of these, so the exploration pipeline's per-pair isolation
layer (:mod:`repro.explore.pipeline`) can classify a failure into a
structured :class:`repro.explore.records.StageFailure` row without
string-matching messages.  They live at the package root because both
low-level subsystems (``fabric``, ``sim``) and the pipeline above them
need the same types without circular imports.
"""

from __future__ import annotations

from typing import Any, Dict

__all__ = ["BudgetExceeded", "InjectedFault", "StoreCorruption"]


class BudgetExceeded(RuntimeError):
    """An explicit stage budget ran out — graceful degradation, not a hang.

    Raised instead of looping forever (scheduler II search / eviction
    budget) or instead of launching work known to be over budget (anneal
    state budget, simulate cycle cap).  ``budget`` carries the budget
    state at exhaustion for the failure row.
    """

    def __init__(self, message: str, **budget: Any) -> None:
        super().__init__(message)
        self.budget: Dict[str, Any] = dict(budget)


class InjectedFault(RuntimeError):
    """A deliberately injected failure (:mod:`repro.faultinject`)."""


class StoreCorruption(ValueError):
    """A persistent-store entry failed its checksum / decode — the entry
    is quarantined and recomputed, never trusted."""
