"""Iterative modulo scheduler for a placed-and-routed mapping.

The static pipeline (mine -> merge -> map -> place -> route) says nothing
about *time*: every PE instance fires once per loop iteration, and the
initiation interval (II) — how many cycles separate consecutive iterations —
is what turns a mapped design into delivered throughput.  This module
assigns each schedulable unit a start cycle under modulo resource
reservation (Rau's iterative modulo scheduling), reporting the achieved II
against the recurrence/resource-constrained minimum (MII).

Timing model (shared with :mod:`repro.sim.cycle`, which executes it):

* a producer's output register is valid one cycle after it fires
  (``L_OUT = 1``);
* every mesh hop is a pipeline register: the value reaches hop depth ``d``
  of its routed tree at ``t_producer + L_OUT + d``;
* each consumer tile latches an arriving operand into a per-(cell, signal)
  input FIFO the cycle it lands (``L_LATCH = 1``); the FIFO is
  ``spec.latch_depth`` iterations deep and refreshed every II cycles, so a
  consumer must fire inside the window
  ``arrival + 1 <= t <= arrival + latch_depth * II`` or the stream
  overwrites its operand (the classic modulo hold constraint, relaxed by
  Garnet-style input FIFOs that absorb operand-arrival skew).

Schedulable units ("ops"):

* ``("in", signal)`` — an I/O tile streaming one input word; a tile with k
  signals needs k distinct cycle slots mod II, which is what makes stencil
  apps input-bandwidth-bound (ResMII = max signals per I/O cell);
* ``("pe", instance)`` — a PE instance firing its configured invocation;
  it also reserves the output-capture slot at every io_out tile it feeds.

Application graphs here are acyclic (the tracer builds pure dataflow), so
RecMII is 1; the machinery still detects cycles and refuses them loudly.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, Generator, List, Optional, Set, Tuple

import numpy as np

from ..errors import BudgetExceeded
from ..fabric.arch import Coord, FabricSpec
from ..fabric.netlist import Netlist
from ..fabric.place import Placement
from ..fabric.route import RoutedNet, RouteResult
from ..obs import span
from ..obs.metrics import global_registry

#: output-register and input-latch latencies (cycles)
L_OUT = 1
L_LATCH = 1

OpKey = Tuple[str, int]          # ("in", signal) | ("pe", instance index)


@dataclass
class NetTiming:
    """Per-net register chain derived from the routed tree.

    ``parent[t]`` is the tile whose hop register feeds tile ``t``;
    ``depth[t]`` is the register distance from the driver.  One pipeline
    register exists per non-driver tile of the tree (per-track, so nets
    sharing a physical channel keep separate registers).
    """

    driver: Coord
    parent: Dict[Coord, Coord]
    depth: Dict[Coord, int]


def route_timing(net: RoutedNet) -> NetTiming:
    """Min-depth parent chain over the routed (tree-ish) edge set."""
    depth: Dict[Coord, int] = {net.driver: 0}
    # relax to fixpoint; edge sets are tiny and may rarely contain a
    # redundant in-edge, so pick the min-depth parent deterministically
    changed = True
    while changed:
        changed = False
        for (a, b) in sorted(net.edges):
            if a in depth and depth[a] + 1 < depth.get(b, 1 << 30):
                depth[b] = depth[a] + 1
                changed = True
    parent: Dict[Coord, Coord] = {}
    for (a, b) in sorted(net.edges):
        if a in depth and depth[a] + 1 == depth.get(b):
            parent.setdefault(b, a)
    for s in net.sinks:
        if s not in depth:
            raise ValueError(f"routed net does not reach sink {s}")
    return NetTiming(net.driver, parent, depth)


@dataclass
class DepEdge:
    src: OpKey
    dst: OpKey
    hops: int                    # register depth driver -> consumer tile
    signal: int


@dataclass
class CaptureEvent:
    """An output word landing on an io_out tile (one word/cycle/tile)."""

    producer: OpKey
    signal: int
    tile: Coord
    hops: int


@dataclass
class ModuloSchedule:
    ii: int
    rec_mii: int
    res_mii: int
    start: Dict[OpKey, int]                  # op -> fire cycle (iteration 0)
    capture: Dict[int, int]                  # leaving signal -> capture cycle
    latency: int                             # cycles to iteration-0 outputs
    attempts: int                            # IIs tried before success
    latch_depth: int = 1                     # input-FIFO depth scheduled for
    hop_time: Dict[Tuple[str, Coord], int] = field(default_factory=dict)
    # (net name, tile) -> cycle its hop register first holds iteration-0 data
    net_timing: Dict[str, NetTiming] = field(default_factory=dict)
    net_src: Dict[str, OpKey] = field(default_factory=dict)
    # per-net register chains and producer ops, published so the simulator
    # lowers against the exact timing the scheduler used (single source)

    @property
    def min_ii(self) -> int:
        return max(self.rec_mii, self.res_mii)

    def summary(self) -> str:
        return (f"ModuloSchedule[II={self.ii} (min {self.min_ii}: "
                f"rec {self.rec_mii}/res {self.res_mii}) "
                f"latency={self.latency} ops={len(self.start)}]")


@dataclass
class _Problem:
    ops: List[OpKey]
    tile_of: Dict[OpKey, Coord]
    deps: List[DepEdge]
    captures: List[CaptureEvent]
    preds: Dict[OpKey, List[DepEdge]]
    succs: Dict[OpKey, List[DepEdge]]
    caps_of: Dict[OpKey, List[CaptureEvent]]
    net_src: Dict[str, OpKey] = field(default_factory=dict)


def _build_problem(netlist: Netlist, placement: Placement,
                   routes: RouteResult) -> Tuple[_Problem,
                                                 Dict[str, NetTiming]]:
    coords = placement.coords
    cell_kind = {name: c.kind for name, c in netlist.cells.items()}
    inst_of_cell = {name: c.instance for name, c in netlist.cells.items()
                    if c.kind == "pe"}

    ops: List[OpKey] = []
    tile_of: Dict[OpKey, Coord] = {}
    for c in sorted(netlist.io_cells, key=lambda c: c.name):
        if c.kind != "io_in":
            continue
        for s in c.signals:
            ops.append(("in", s))
            tile_of[("in", s)] = coords[c.name]
    for c in sorted(netlist.pe_cells, key=lambda c: c.instance):
        ops.append(("pe", c.instance))
        tile_of[("pe", c.instance)] = coords[c.name]

    timing: Dict[str, NetTiming] = {}
    deps: List[DepEdge] = []
    captures: List[CaptureEvent] = []
    routed = {n.name: n for n in routes.nets}
    net_src: Dict[str, OpKey] = {}
    for net in sorted(netlist.nets, key=lambda n: n.name):
        nt = route_timing(routed[net.name])
        timing[net.name] = nt
        if cell_kind[net.driver] == "pe":
            src: OpKey = ("pe", inst_of_cell[net.driver])
        else:
            src = ("in", net.signal)
        net_src[net.name] = src
        for sink in net.sinks:
            d = nt.depth[coords[sink]]
            if cell_kind[sink] == "pe":
                deps.append(DepEdge(src, ("pe", inst_of_cell[sink]), d,
                                    net.signal))
            else:
                captures.append(CaptureEvent(src, net.signal, coords[sink],
                                             d))

    preds: Dict[OpKey, List[DepEdge]] = {op: [] for op in ops}
    succs: Dict[OpKey, List[DepEdge]] = {op: [] for op in ops}
    for e in deps:
        preds[e.dst].append(e)
        succs[e.src].append(e)
    caps_of: Dict[OpKey, List[CaptureEvent]] = {op: [] for op in ops}
    for ev in captures:
        caps_of[ev.producer].append(ev)
    return _Problem(ops, tile_of, deps, captures, preds, succs, caps_of,
                    net_src), timing


def min_ii(netlist: Netlist, routes: RouteResult, spec: FabricSpec,
           placement: Placement) -> Tuple[int, int]:
    """(RecMII, ResMII) lower bounds for any feasible modulo schedule."""
    p, _ = _build_problem(netlist, placement, routes)
    return _min_ii(p, routes, spec)


def _min_ii(p: "_Problem", routes: RouteResult,
            spec: FabricSpec) -> Tuple[int, int]:
    # RecMII: app dataflow graphs are acyclic; verify and refuse otherwise
    order = _topo(p)
    if order is None:
        raise NotImplementedError(
            "modulo scheduling of cyclic (loop-carried) instance graphs "
            "is not supported; application graphs are pure dataflow")
    rec = 1
    # ResMII: every tile issues at most one word per cycle
    per_tile: Dict[Coord, int] = {}
    for op in p.ops:
        t = p.tile_of[op]
        per_tile[t] = per_tile.get(t, 0) + 1
    for ev in p.captures:
        per_tile[ev.tile] = per_tile.get(ev.tile, 0) + 1
    res = max(per_tile.values(), default=1)
    # routed channels: tracks shared beyond capacity would also bound II
    caps = spec.routing_edges()
    for e, u in routes.edge_usage.items():
        res = max(res, -(-u // caps[e]))
    return rec, max(1, res)


def _topo(p: _Problem) -> Optional[List[OpKey]]:
    indeg = {op: 0 for op in p.ops}
    for e in p.deps:
        indeg[e.dst] += 1
    ready = sorted(op for op, k in indeg.items() if k == 0)
    order: List[OpKey] = []
    while ready:
        op = ready.pop(0)
        order.append(op)
        for e in sorted(p.succs[op], key=lambda e: e.dst):
            indeg[e.dst] -= 1
            if indeg[e.dst] == 0:
                ready.append(e.dst)
        ready.sort()
    return order if len(order) == len(p.ops) else None


def _heights(p: _Problem) -> Dict[OpKey, int]:
    """Longest dependence path from each op to any terminal (priority)."""
    order = _topo(p)
    assert order is not None
    h = {op: 0 for op in p.ops}
    for op in reversed(order):
        for e in p.succs[op]:
            h[op] = max(h[op], h[e.dst] + e.hops + L_OUT + L_LATCH)
        for ev in p.caps_of[op]:
            h[op] = max(h[op], ev.hops + L_OUT)
    return h


def modulo_schedule(netlist: Netlist, placement: Placement,
                    routes: RouteResult, spec: FabricSpec,
                    *, max_ii: Optional[int] = None,
                    budget_factor: int = 8) -> ModuloSchedule:
    """Schedule every I/O stream and PE instance under modulo resources.

    Tries II = MII, MII+1, ... with Rau-style scheduling (priority by
    height, bounded eviction budget per II).  Raises
    :class:`repro.errors.BudgetExceeded` (a RuntimeError) when nothing
    fits by ``max_ii`` (default: number of ops + MII, always sufficient
    for a DAG — a finite exhaustion point, so the search is a budget, not
    an open-ended loop).
    """
    p, timing = _build_problem(netlist, placement, routes)
    rec_mii, res_mii = _min_ii(p, routes, spec)
    mii = max(rec_mii, res_mii)
    if max_ii is None:
        max_ii = mii + len(p.ops) + 1
    heights = _heights(p)
    depth = spec.latch_depth

    stats = global_registry().view()
    attempts = 0
    for ii in range(mii, max_ii + 1):
        attempts += 1
        stats["sched_attempts"] += 1
        start = _try_schedule(p, ii, heights, budget_factor, depth,
                              stats=stats)
        if start is not None:
            return _finish(p, timing, ii, rec_mii, res_mii, start, attempts,
                           depth)
    stats["sched_budget_exhausted"] += 1
    raise BudgetExceeded(f"no modulo schedule found up to II={max_ii}",
                         max_ii=max_ii, mii=mii, attempts=attempts,
                         n_ops=len(p.ops), budget_factor=budget_factor)


def fabric_signature(spec: FabricSpec) -> Tuple[int, int, int, int]:
    """Key under which pairs share one lockstep scheduling group.

    Grouping is purely a batching decision — every pair's schedule is
    bit-identical however pairs are grouped (or scheduled solo); sharing
    array dimensions just keeps a round's stacked conflict scans similarly
    sized, so no pair pads the others' windows.
    """
    return (spec.rows, spec.cols, spec.io_capacity, spec.latch_depth)


class _PairSched:
    """Lockstep driver state for one pair in a scheduling group."""

    __slots__ = ("index", "p", "timing", "rec_mii", "res_mii", "heights",
                 "depth", "ii", "max_ii", "attempts", "gen", "req")


def modulo_schedule_batch(items: List[Tuple[Netlist, Placement, RouteResult,
                                            FabricSpec]],
                          *, max_ii: Optional[int] = None,
                          budget_factor: int = 8,
                          stats=None, isolate: bool = False) -> List:
    """Modulo-schedule many placed-and-routed pairs, batch-first.

    Pairs are grouped by :func:`fabric_signature`; within a group every
    pair's Rau coroutine advances in lockstep and ALL pending slot-conflict
    scans are answered by one stacked numpy gather per round
    (:func:`_feasible_scan_batch`), instead of one Python probe-loop per
    candidate cycle per pair.  Each pair's schedule is bit-identical to
    :func:`modulo_schedule` on that pair alone.  ``stats`` (a Counter, if
    given) gets one ``sched_group`` tick per lockstep group.  Returns
    schedules in ``items`` order.

    ``isolate=True`` turns per-pair failures (an unschedulable pair
    exhausting its II budget, a malformed problem) into Exception objects
    at that pair's output index instead of killing the whole group — each
    pair's coroutine trajectory depends only on its own state, so a
    dropped pair cannot change its groupmates' schedules.
    """
    out: List = [None] * len(items)
    groups: Dict[Tuple, List[int]] = {}
    for i, (_, _, _, spec) in enumerate(items):
        groups.setdefault(fabric_signature(spec), []).append(i)
    if stats is None:
        stats = global_registry().view()
    for sig, idxs in groups.items():
        stats["sched_group"] += 1
        with span("schedule.group", fabric="x".join(map(str, sig)),
                  pairs=len(idxs)):
            _schedule_group(items, idxs, out, max_ii, budget_factor,
                            stats=stats, isolate=isolate)
    return out


def _schedule_group(items, idxs: List[int], out: List,
                    max_ii: Optional[int], budget_factor: int,
                    stats=None, isolate: bool = False) -> None:
    pairs: List[_PairSched] = []
    for i in idxs:
        netlist, placement, routes, spec = items[i]
        st = _PairSched()
        st.index = i
        try:
            st.p, st.timing = _build_problem(netlist, placement, routes)
            st.rec_mii, st.res_mii = _min_ii(st.p, routes, spec)
        except Exception as e:
            if not isolate:
                raise
            out[i] = e
            continue
        st.ii = max(st.rec_mii, st.res_mii)
        st.max_ii = (st.ii + len(st.p.ops) + 1) if max_ii is None else max_ii
        st.heights = _heights(st.p)
        st.depth = spec.latch_depth
        st.attempts = 0
        pairs.append(st)

    def start(st: _PairSched) -> bool:
        """Open a new II attempt; True while the pair still wants scans."""
        st.attempts += 1
        if stats is not None:
            stats["sched_attempts"] += 1
        st.gen = _schedule_gen(st.p, st.ii, st.heights, budget_factor,
                               st.depth)
        return advance(st, None)

    def advance(st: _PairSched, ans: Optional[int]) -> bool:
        try:
            st.req = st.gen.send(ans)
            return True
        except StopIteration as stop:
            if stop.value is not None:
                out[st.index] = _finish(st.p, st.timing, st.ii, st.rec_mii,
                                        st.res_mii, stop.value, st.attempts,
                                        st.depth)
                return False
            st.ii += 1                    # this II failed; retry one higher
            if st.ii > st.max_ii:
                if stats is not None:
                    stats["sched_budget_exhausted"] += 1
                raise BudgetExceeded(
                    f"no modulo schedule found up to II={st.max_ii}",
                    max_ii=st.max_ii, mii=max(st.rec_mii, st.res_mii),
                    attempts=st.attempts, n_ops=len(st.p.ops),
                    budget_factor=budget_factor)
            return start(st)

    def safely(st: _PairSched, fn) -> bool:
        """Run start/advance, dropping (not killing) the pair's group
        when isolating — a failed pair's slot gets its exception."""
        try:
            return fn()
        except Exception as e:
            if not isolate:
                raise
            out[st.index] = e
            return False

    active = [st for st in pairs
              if safely(st, lambda st=st: start(st))]
    while active:
        answers = _feasible_scan_batch([st.req for st in active])
        if stats is not None:
            stats["sched_rounds"] += 1
            stats["sched_scans"] += len(answers)
            stats["sched_backtracks"] += sum(1 for a in answers
                                             if a is None)
        active = [st for st, ans in zip(active, answers)
                  if safely(st, lambda st=st, ans=ans: advance(st, ans))]


def _slots_needed(p: _Problem, op: OpKey, t: int,
                  ii: int) -> List[Tuple[Coord, int]]:
    slots = [(p.tile_of[op], t % ii)]
    for ev in p.caps_of[op]:
        slots.append((ev.tile, (t + L_OUT + ev.hops) % ii))
    return slots


@dataclass
class _ScanReq:
    """One first-feasible-slot query against a pair's occupancy table.

    The occupancy array mirrors the MRT dict exactly (``occ[tile, slot]``
    is true iff ``(tile coord, slot)`` is reserved); tiles are indexed by
    the pair-local table the emitting coroutine built.
    """

    occ: np.ndarray              # (n_tiles, ii) bool
    ii: int
    tiles: np.ndarray            # (S,) int64: occ row per required slot
    offs: np.ndarray             # (S,) int64: cycle offset per required slot
    early: int
    hi: int


def _feasible_scan(req: _ScanReq) -> Optional[int]:
    """First t in [early, hi] with every required slot free, else None."""
    if req.hi < req.early:
        return None
    ts = np.arange(req.early, req.hi + 1)
    slots = (ts[:, None] + req.offs[None, :]) % req.ii
    conflict = req.occ[req.tiles[None, :], slots].any(axis=1)
    if conflict.all():
        return None
    return int(req.early + int(np.argmin(conflict)))


def _feasible_scan_batch(reqs: List[_ScanReq]) -> List[Optional[int]]:
    """Answer many scan requests in ONE stacked numpy gather.

    Every pending pair's candidate window is padded to the round's widest
    window and largest slot set; per-pair occupancy tables are flattened
    into one buffer so the whole round is a single fancy-index + reduce
    instead of one Python probe-loop per candidate cycle per pair.
    Answers are identical to :func:`_feasible_scan` per request.
    """
    n = len(reqs)
    width = max(max(r.hi - r.early + 1 for r in reqs), 1)
    n_slots = max(r.tiles.shape[0] for r in reqs)
    sizes = np.asarray([r.occ.size for r in reqs])
    base = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    occ_flat = np.concatenate([r.occ.ravel() for r in reqs])
    ii = np.asarray([r.ii for r in reqs])
    early = np.asarray([r.early for r in reqs])
    hi = np.asarray([r.hi for r in reqs])
    tiles = np.zeros((n, n_slots), np.int64)
    offs = np.zeros((n, n_slots), np.int64)
    smask = np.zeros((n, n_slots), bool)
    for i, r in enumerate(reqs):
        s = r.tiles.shape[0]
        tiles[i, :s] = r.tiles
        offs[i, :s] = r.offs
        smask[i, :s] = True
    ts = early[:, None] + np.arange(width)[None, :]            # (n, W)
    wmask = ts <= hi[:, None]
    slots = (ts[:, :, None] + offs[:, None, :]) % ii[:, None, None]
    idx = (base[:, None, None] + tiles[:, None, :] * ii[:, None, None]
           + slots)                                            # (n, W, S)
    conflict = occ_flat[idx] & smask[:, None, :]
    bad = conflict.any(axis=2) | ~wmask
    out: List[Optional[int]] = []
    for i in range(n):
        w = int(np.argmin(bad[i]))
        out.append(None if bad[i, w] else int(early[i] + w))
    return out


def _schedule_gen(p: _Problem, ii: int, heights: Dict[OpKey, int],
                  budget_factor: int, depth: int
                  ) -> Generator[_ScanReq, Optional[int],
                                 Optional[Dict[OpKey, int]]]:
    """Rau's inner loop as a coroutine: yields slot-conflict scan requests
    (answered with the first feasible cycle, or None) and returns the
    start map — or None when the eviction budget is exhausted.

    Driving it solo (:func:`_try_schedule`) or in lockstep with other
    pairs (:func:`modulo_schedule_batch`) produces identical schedules:
    the trajectory depends only on this pair's own state, never on who
    answers the scans.
    """
    tix: Dict[Coord, int] = {}
    for op in p.ops:
        tix.setdefault(p.tile_of[op], len(tix))
    for ev in p.captures:
        tix.setdefault(ev.tile, len(tix))
    occ = np.zeros((max(1, len(tix)), ii), bool)
    scan_tiles: Dict[OpKey, np.ndarray] = {}
    scan_offs: Dict[OpKey, np.ndarray] = {}
    for op in p.ops:
        caps = p.caps_of[op]
        scan_tiles[op] = np.asarray(
            [tix[p.tile_of[op]]] + [tix[ev.tile] for ev in caps], np.int64)
        scan_offs[op] = np.asarray(
            [0] + [L_OUT + ev.hops for ev in caps], np.int64)

    time: Dict[OpKey, int] = {}
    mrt: Dict[Tuple[Coord, int], OpKey] = {}
    order_ix = {op: i for i, op in enumerate(p.ops)}
    heap: List[Tuple[int, int, OpKey]] = []
    for op in p.ops:
        heapq.heappush(heap, (-heights[op], order_ix[op], op))
    last_placed: Dict[OpKey, int] = {}
    budget = budget_factor * len(p.ops) + 64
    hold = depth * ii

    def occupy(op: OpKey, t: int) -> None:
        time[op] = t
        for s in _slots_needed(p, op, t, ii):
            mrt[s] = op
            occ[tix[s[0]], s[1]] = True
        last_placed[op] = t

    def unschedule(op: OpKey) -> None:
        t = time.pop(op)
        for slot in _slots_needed(p, op, t, ii):
            if mrt.get(slot) == op:
                del mrt[slot]
                occ[tix[slot[0]], slot[1]] = False
        heapq.heappush(heap, (-heights[op], order_ix[op], op))

    while heap:
        _, _, op = heapq.heappop(heap)
        if op in time:
            continue                      # stale heap entry
        # dependence window w.r.t. already-scheduled neighbors
        early, late = 0, 1 << 30
        for e in p.preds[op]:
            if e.src in time:
                arr = time[e.src] + L_OUT + e.hops
                early = max(early, arr + L_LATCH)
                late = min(late, arr + hold)
        for e in p.succs[op]:
            if e.dst in time:
                # consumer window: arr + L_LATCH <= t_dst <= arr + hold
                early = max(early, time[e.dst] - e.hops - L_OUT - hold)
                late = min(late, time[e.dst] - e.hops - L_OUT - L_LATCH)
        early = max(early, 0)

        t = yield _ScanReq(occ, ii, scan_tiles[op], scan_offs[op],
                           early, min(late, early + ii - 1))
        if t is not None:
            occupy(op, t)
            continue

        # forced placement with eviction (Rau)
        budget -= 1
        if budget <= 0:
            return None
        t = max(early, last_placed.get(op, -1) + 1)
        evict: Set[OpKey] = set()
        for s in _slots_needed(p, op, t, ii):
            if s in mrt:
                evict.add(mrt[s])
        for e in p.preds[op]:
            if e.src in time:
                arr = time[e.src] + L_OUT + e.hops
                if not (arr + L_LATCH <= t <= arr + hold):
                    evict.add(e.src)
        for e in p.succs[op]:
            if e.dst in time:
                arr = t + L_OUT + e.hops
                if not (arr + L_LATCH <= time[e.dst] <= arr + hold):
                    evict.add(e.dst)
        for other in sorted(evict, key=lambda o: order_ix[o]):
            unschedule(other)
        occupy(op, t)
    return time


def _try_schedule(p: _Problem, ii: int, heights: Dict[OpKey, int],
                  budget_factor: int, depth: int, *, stats=None
                  ) -> Optional[Dict[OpKey, int]]:
    """Drive one pair's scheduling coroutine solo."""
    gen = _schedule_gen(p, ii, heights, budget_factor, depth)
    ans: Optional[int] = None
    while True:
        try:
            req = gen.send(ans)
        except StopIteration as stop:
            return stop.value
        ans = _feasible_scan(req)
        if stats is not None:
            stats["sched_rounds"] += 1
            stats["sched_scans"] += 1
            if ans is None:
                stats["sched_backtracks"] += 1


def _finish(p: _Problem, timing: Dict[str, NetTiming], ii: int,
            rec_mii: int, res_mii: int, start: Dict[OpKey, int],
            attempts: int, depth: int) -> ModuloSchedule:
    capture: Dict[int, int] = {}
    latest = 0
    for ev in p.captures:
        capture[ev.signal] = start[ev.producer] + L_OUT + ev.hops
        latest = max(latest, capture[ev.signal])
    for op, t in start.items():
        latest = max(latest, t)
    hop_time: Dict[Tuple[str, Coord], int] = {}
    for net_name, nt in sorted(timing.items()):
        src = p.net_src[net_name]
        for tile, d in sorted(nt.depth.items()):
            if tile != nt.driver:
                hop_time[(net_name, tile)] = start[src] + L_OUT + d
    sched = ModuloSchedule(ii=ii, rec_mii=rec_mii, res_mii=res_mii,
                           start=dict(sorted(start.items())),
                           capture=capture, latency=latest + 1,
                           attempts=attempts, hop_time=hop_time,
                           latch_depth=depth, net_timing=dict(timing),
                           net_src=dict(p.net_src))
    _check(p, sched)
    return sched


def _check(p: _Problem, s: ModuloSchedule) -> None:
    """Assert the invariants the simulator relies on."""
    hold = s.latch_depth * s.ii
    for e in p.deps:
        arr = s.start[e.src] + L_OUT + e.hops
        t = s.start[e.dst]
        if not (arr + L_LATCH <= t <= arr + hold):
            raise AssertionError(
                f"dependence window violated: {e.src}->{e.dst} "
                f"arr={arr} t={t} II={s.ii} depth={s.latch_depth}")
    mrt: Dict[Tuple[Coord, int], OpKey] = {}
    for op, t in s.start.items():
        for slot in _slots_needed(p, op, t, s.ii):
            if slot in mrt:
                raise AssertionError(f"modulo resource conflict at {slot}: "
                                     f"{mrt[slot]} vs {op}")
            mrt[slot] = op
