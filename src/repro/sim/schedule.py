"""Iterative modulo scheduler for a placed-and-routed mapping.

The static pipeline (mine -> merge -> map -> place -> route) says nothing
about *time*: every PE instance fires once per loop iteration, and the
initiation interval (II) — how many cycles separate consecutive iterations —
is what turns a mapped design into delivered throughput.  This module
assigns each schedulable unit a start cycle under modulo resource
reservation (Rau's iterative modulo scheduling), reporting the achieved II
against the recurrence/resource-constrained minimum (MII).

Timing model (shared with :mod:`repro.sim.cycle`, which executes it):

* a producer's output register is valid one cycle after it fires
  (``L_OUT = 1``);
* every mesh hop is a pipeline register: the value reaches hop depth ``d``
  of its routed tree at ``t_producer + L_OUT + d``;
* each consumer tile latches an arriving operand into a per-(cell, signal)
  input FIFO the cycle it lands (``L_LATCH = 1``); the FIFO is
  ``spec.latch_depth`` iterations deep and refreshed every II cycles, so a
  consumer must fire inside the window
  ``arrival + 1 <= t <= arrival + latch_depth * II`` or the stream
  overwrites its operand (the classic modulo hold constraint, relaxed by
  Garnet-style input FIFOs that absorb operand-arrival skew).

Schedulable units ("ops"):

* ``("in", signal)`` — an I/O tile streaming one input word; a tile with k
  signals needs k distinct cycle slots mod II, which is what makes stencil
  apps input-bandwidth-bound (ResMII = max signals per I/O cell);
* ``("pe", instance)`` — a PE instance firing its configured invocation;
  it also reserves the output-capture slot at every io_out tile it feeds.

Application graphs here are acyclic (the tracer builds pure dataflow), so
RecMII is 1; the machinery still detects cycles and refuses them loudly.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from ..fabric.arch import Coord, FabricSpec
from ..fabric.netlist import Netlist
from ..fabric.place import Placement
from ..fabric.route import RoutedNet, RouteResult

#: output-register and input-latch latencies (cycles)
L_OUT = 1
L_LATCH = 1

OpKey = Tuple[str, int]          # ("in", signal) | ("pe", instance index)


@dataclass
class NetTiming:
    """Per-net register chain derived from the routed tree.

    ``parent[t]`` is the tile whose hop register feeds tile ``t``;
    ``depth[t]`` is the register distance from the driver.  One pipeline
    register exists per non-driver tile of the tree (per-track, so nets
    sharing a physical channel keep separate registers).
    """

    driver: Coord
    parent: Dict[Coord, Coord]
    depth: Dict[Coord, int]


def route_timing(net: RoutedNet) -> NetTiming:
    """Min-depth parent chain over the routed (tree-ish) edge set."""
    depth: Dict[Coord, int] = {net.driver: 0}
    # relax to fixpoint; edge sets are tiny and may rarely contain a
    # redundant in-edge, so pick the min-depth parent deterministically
    changed = True
    while changed:
        changed = False
        for (a, b) in sorted(net.edges):
            if a in depth and depth[a] + 1 < depth.get(b, 1 << 30):
                depth[b] = depth[a] + 1
                changed = True
    parent: Dict[Coord, Coord] = {}
    for (a, b) in sorted(net.edges):
        if a in depth and depth[a] + 1 == depth.get(b):
            parent.setdefault(b, a)
    for s in net.sinks:
        if s not in depth:
            raise ValueError(f"routed net does not reach sink {s}")
    return NetTiming(net.driver, parent, depth)


@dataclass
class DepEdge:
    src: OpKey
    dst: OpKey
    hops: int                    # register depth driver -> consumer tile
    signal: int


@dataclass
class CaptureEvent:
    """An output word landing on an io_out tile (one word/cycle/tile)."""

    producer: OpKey
    signal: int
    tile: Coord
    hops: int


@dataclass
class ModuloSchedule:
    ii: int
    rec_mii: int
    res_mii: int
    start: Dict[OpKey, int]                  # op -> fire cycle (iteration 0)
    capture: Dict[int, int]                  # leaving signal -> capture cycle
    latency: int                             # cycles to iteration-0 outputs
    attempts: int                            # IIs tried before success
    latch_depth: int = 1                     # input-FIFO depth scheduled for
    hop_time: Dict[Tuple[str, Coord], int] = field(default_factory=dict)
    # (net name, tile) -> cycle its hop register first holds iteration-0 data
    net_timing: Dict[str, NetTiming] = field(default_factory=dict)
    net_src: Dict[str, OpKey] = field(default_factory=dict)
    # per-net register chains and producer ops, published so the simulator
    # lowers against the exact timing the scheduler used (single source)

    @property
    def min_ii(self) -> int:
        return max(self.rec_mii, self.res_mii)

    def summary(self) -> str:
        return (f"ModuloSchedule[II={self.ii} (min {self.min_ii}: "
                f"rec {self.rec_mii}/res {self.res_mii}) "
                f"latency={self.latency} ops={len(self.start)}]")


@dataclass
class _Problem:
    ops: List[OpKey]
    tile_of: Dict[OpKey, Coord]
    deps: List[DepEdge]
    captures: List[CaptureEvent]
    preds: Dict[OpKey, List[DepEdge]]
    succs: Dict[OpKey, List[DepEdge]]
    caps_of: Dict[OpKey, List[CaptureEvent]]
    net_src: Dict[str, OpKey] = field(default_factory=dict)


def _build_problem(netlist: Netlist, placement: Placement,
                   routes: RouteResult) -> Tuple[_Problem,
                                                 Dict[str, NetTiming]]:
    coords = placement.coords
    cell_kind = {name: c.kind for name, c in netlist.cells.items()}
    inst_of_cell = {name: c.instance for name, c in netlist.cells.items()
                    if c.kind == "pe"}

    ops: List[OpKey] = []
    tile_of: Dict[OpKey, Coord] = {}
    for c in sorted(netlist.io_cells, key=lambda c: c.name):
        if c.kind != "io_in":
            continue
        for s in c.signals:
            ops.append(("in", s))
            tile_of[("in", s)] = coords[c.name]
    for c in sorted(netlist.pe_cells, key=lambda c: c.instance):
        ops.append(("pe", c.instance))
        tile_of[("pe", c.instance)] = coords[c.name]

    timing: Dict[str, NetTiming] = {}
    deps: List[DepEdge] = []
    captures: List[CaptureEvent] = []
    routed = {n.name: n for n in routes.nets}
    net_src: Dict[str, OpKey] = {}
    for net in sorted(netlist.nets, key=lambda n: n.name):
        nt = route_timing(routed[net.name])
        timing[net.name] = nt
        if cell_kind[net.driver] == "pe":
            src: OpKey = ("pe", inst_of_cell[net.driver])
        else:
            src = ("in", net.signal)
        net_src[net.name] = src
        for sink in net.sinks:
            d = nt.depth[coords[sink]]
            if cell_kind[sink] == "pe":
                deps.append(DepEdge(src, ("pe", inst_of_cell[sink]), d,
                                    net.signal))
            else:
                captures.append(CaptureEvent(src, net.signal, coords[sink],
                                             d))

    preds: Dict[OpKey, List[DepEdge]] = {op: [] for op in ops}
    succs: Dict[OpKey, List[DepEdge]] = {op: [] for op in ops}
    for e in deps:
        preds[e.dst].append(e)
        succs[e.src].append(e)
    caps_of: Dict[OpKey, List[CaptureEvent]] = {op: [] for op in ops}
    for ev in captures:
        caps_of[ev.producer].append(ev)
    return _Problem(ops, tile_of, deps, captures, preds, succs, caps_of,
                    net_src), timing


def min_ii(netlist: Netlist, routes: RouteResult, spec: FabricSpec,
           placement: Placement) -> Tuple[int, int]:
    """(RecMII, ResMII) lower bounds for any feasible modulo schedule."""
    p, _ = _build_problem(netlist, placement, routes)
    return _min_ii(p, routes, spec)


def _min_ii(p: "_Problem", routes: RouteResult,
            spec: FabricSpec) -> Tuple[int, int]:
    # RecMII: app dataflow graphs are acyclic; verify and refuse otherwise
    order = _topo(p)
    if order is None:
        raise NotImplementedError(
            "modulo scheduling of cyclic (loop-carried) instance graphs "
            "is not supported; application graphs are pure dataflow")
    rec = 1
    # ResMII: every tile issues at most one word per cycle
    per_tile: Dict[Coord, int] = {}
    for op in p.ops:
        t = p.tile_of[op]
        per_tile[t] = per_tile.get(t, 0) + 1
    for ev in p.captures:
        per_tile[ev.tile] = per_tile.get(ev.tile, 0) + 1
    res = max(per_tile.values(), default=1)
    # routed channels: tracks shared beyond capacity would also bound II
    caps = spec.routing_edges()
    for e, u in routes.edge_usage.items():
        res = max(res, -(-u // caps[e]))
    return rec, max(1, res)


def _topo(p: _Problem) -> Optional[List[OpKey]]:
    indeg = {op: 0 for op in p.ops}
    for e in p.deps:
        indeg[e.dst] += 1
    ready = sorted(op for op, k in indeg.items() if k == 0)
    order: List[OpKey] = []
    while ready:
        op = ready.pop(0)
        order.append(op)
        for e in sorted(p.succs[op], key=lambda e: e.dst):
            indeg[e.dst] -= 1
            if indeg[e.dst] == 0:
                ready.append(e.dst)
        ready.sort()
    return order if len(order) == len(p.ops) else None


def _heights(p: _Problem) -> Dict[OpKey, int]:
    """Longest dependence path from each op to any terminal (priority)."""
    order = _topo(p)
    assert order is not None
    h = {op: 0 for op in p.ops}
    for op in reversed(order):
        for e in p.succs[op]:
            h[op] = max(h[op], h[e.dst] + e.hops + L_OUT + L_LATCH)
        for ev in p.caps_of[op]:
            h[op] = max(h[op], ev.hops + L_OUT)
    return h


def modulo_schedule(netlist: Netlist, placement: Placement,
                    routes: RouteResult, spec: FabricSpec,
                    *, max_ii: Optional[int] = None,
                    budget_factor: int = 8) -> ModuloSchedule:
    """Schedule every I/O stream and PE instance under modulo resources.

    Tries II = MII, MII+1, ... with Rau-style scheduling (priority by
    height, bounded eviction budget per II).  Raises if nothing fits by
    ``max_ii`` (default: number of ops + MII, always sufficient for a DAG).
    """
    p, timing = _build_problem(netlist, placement, routes)
    rec_mii, res_mii = _min_ii(p, routes, spec)
    mii = max(rec_mii, res_mii)
    if max_ii is None:
        max_ii = mii + len(p.ops) + 1
    heights = _heights(p)
    depth = spec.latch_depth

    attempts = 0
    for ii in range(mii, max_ii + 1):
        attempts += 1
        start = _try_schedule(p, ii, heights, budget_factor, depth)
        if start is not None:
            return _finish(p, timing, ii, rec_mii, res_mii, start, attempts,
                           depth)
    raise RuntimeError(f"no modulo schedule found up to II={max_ii}")


def _slots_needed(p: _Problem, op: OpKey, t: int,
                  ii: int) -> List[Tuple[Coord, int]]:
    slots = [(p.tile_of[op], t % ii)]
    for ev in p.caps_of[op]:
        slots.append((ev.tile, (t + L_OUT + ev.hops) % ii))
    return slots


def _try_schedule(p: _Problem, ii: int, heights: Dict[OpKey, int],
                  budget_factor: int, depth: int
                  ) -> Optional[Dict[OpKey, int]]:
    time: Dict[OpKey, int] = {}
    mrt: Dict[Tuple[Coord, int], OpKey] = {}
    order_ix = {op: i for i, op in enumerate(p.ops)}
    heap: List[Tuple[int, int, OpKey]] = []
    for op in p.ops:
        heapq.heappush(heap, (-heights[op], order_ix[op], op))
    last_placed: Dict[OpKey, int] = {}
    budget = budget_factor * len(p.ops) + 64

    def unschedule(op: OpKey) -> None:
        t = time.pop(op)
        for slot in _slots_needed(p, op, t, ii):
            if mrt.get(slot) == op:
                del mrt[slot]
        heapq.heappush(heap, (-heights[op], order_ix[op], op))

    while heap:
        _, _, op = heapq.heappop(heap)
        if op in time:
            continue                      # stale heap entry
        # dependence window w.r.t. already-scheduled neighbors
        hold = depth * ii
        early, late = 0, 1 << 30
        for e in p.preds[op]:
            if e.src in time:
                arr = time[e.src] + L_OUT + e.hops
                early = max(early, arr + L_LATCH)
                late = min(late, arr + hold)
        for e in p.succs[op]:
            if e.dst in time:
                # consumer window: arr + L_LATCH <= t_dst <= arr + hold
                early = max(early, time[e.dst] - e.hops - L_OUT - hold)
                late = min(late, time[e.dst] - e.hops - L_OUT - L_LATCH)
        early = max(early, 0)

        placed = False
        hi = min(late, early + ii - 1)
        for t in range(early, hi + 1):
            if all(s not in mrt for s in _slots_needed(p, op, t, ii)):
                time[op] = t
                for s in _slots_needed(p, op, t, ii):
                    mrt[s] = op
                last_placed[op] = t
                placed = True
                break
        if placed:
            continue

        # forced placement with eviction (Rau)
        budget -= 1
        if budget <= 0:
            return None
        t = max(early, last_placed.get(op, -1) + 1)
        evict: Set[OpKey] = set()
        for s in _slots_needed(p, op, t, ii):
            if s in mrt:
                evict.add(mrt[s])
        for e in p.preds[op]:
            if e.src in time:
                arr = time[e.src] + L_OUT + e.hops
                if not (arr + L_LATCH <= t <= arr + hold):
                    evict.add(e.src)
        for e in p.succs[op]:
            if e.dst in time:
                arr = t + L_OUT + e.hops
                if not (arr + L_LATCH <= time[e.dst] <= arr + hold):
                    evict.add(e.dst)
        for other in sorted(evict, key=lambda o: order_ix[o]):
            unschedule(other)
        time[op] = t
        for s in _slots_needed(p, op, t, ii):
            mrt[s] = op
        last_placed[op] = t
    return time


def _finish(p: _Problem, timing: Dict[str, NetTiming], ii: int,
            rec_mii: int, res_mii: int, start: Dict[OpKey, int],
            attempts: int, depth: int) -> ModuloSchedule:
    capture: Dict[int, int] = {}
    latest = 0
    for ev in p.captures:
        capture[ev.signal] = start[ev.producer] + L_OUT + ev.hops
        latest = max(latest, capture[ev.signal])
    for op, t in start.items():
        latest = max(latest, t)
    hop_time: Dict[Tuple[str, Coord], int] = {}
    for net_name, nt in sorted(timing.items()):
        src = p.net_src[net_name]
        for tile, d in sorted(nt.depth.items()):
            if tile != nt.driver:
                hop_time[(net_name, tile)] = start[src] + L_OUT + d
    sched = ModuloSchedule(ii=ii, rec_mii=rec_mii, res_mii=res_mii,
                           start=dict(sorted(start.items())),
                           capture=capture, latency=latest + 1,
                           attempts=attempts, hop_time=hop_time,
                           latch_depth=depth, net_timing=dict(timing),
                           net_src=dict(p.net_src))
    _check(p, sched)
    return sched


def _check(p: _Problem, s: ModuloSchedule) -> None:
    """Assert the invariants the simulator relies on."""
    hold = s.latch_depth * s.ii
    for e in p.deps:
        arr = s.start[e.src] + L_OUT + e.hops
        t = s.start[e.dst]
        if not (arr + L_LATCH <= t <= arr + hold):
            raise AssertionError(
                f"dependence window violated: {e.src}->{e.dst} "
                f"arr={arr} t={t} II={s.ii} depth={s.latch_depth}")
    mrt: Dict[Tuple[Coord, int], OpKey] = {}
    for op, t in s.start.items():
        for slot in _slots_needed(p, op, t, s.ii):
            if slot in mrt:
                raise AssertionError(f"modulo resource conflict at {slot}: "
                                     f"{mrt[slot]} vs {op}")
            mrt[slot] = op
