"""Golden verification: the simulated array must bit-match the interpreter.

The static pipeline proves merged datapaths correct per-config
(core/merge validation); nothing before this subsystem proved that the
*composition* — cover, placement, routing, modulo schedule — still computes
the application.  :func:`verify_mapping` closes that loop: it runs the full
time-domain flow on random inputs and compares, bit for bit, against
:func:`repro.graphir.interp.interpret`.

All paper-suite apps use IEEE-exact ops (add/sub/mul/shift/compare/
min/max/select), so float32 equality is exact, not approximate: any
nonzero error is a real bug somewhere in the stack.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.mapper import Mapping
from ..core.pe import Datapath
from ..graphir.graph import Graph
from ..graphir.interp import interpret
from ..fabric import FabricSpec, PnRResult, place_and_route
from .cycle import SimProgram, SimResult, lower_program, simulate
from .schedule import modulo_schedule


def build_sim(dp: Datapath, mapping: Mapping, app: Graph,
              spec: Optional[FabricSpec] = None, *,
              place_backend: str = "jax", chains: int = 8,
              sweeps: int = 24, seed: int = 0,
              hpwl_backend: str = "jnp",
              pnr: Optional[PnRResult] = None,
              max_ii: Optional[int] = None,
              budget_factor: int = 8
              ) -> Tuple[SimProgram, PnRResult]:
    """Place, route, schedule, and lower a mapping into a SimProgram.

    ``max_ii`` / ``budget_factor`` bound the scheduler's II search and
    eviction budget (:func:`repro.sim.schedule.modulo_schedule`); on
    exhaustion the scheduler raises :class:`repro.errors.BudgetExceeded`.
    """
    if pnr is None:
        pnr = place_and_route(dp, mapping, app, spec,
                              backend=place_backend, chains=chains,
                              sweeps=sweeps, seed=seed,
                              hpwl_backend=hpwl_backend)
    sched = modulo_schedule(pnr.netlist, pnr.placement, pnr.routes,
                            pnr.spec, max_ii=max_ii,
                            budget_factor=budget_factor)
    prog = lower_program(mapping, app, pnr.netlist, pnr.placement, sched)
    return prog, pnr


@dataclass
class GoldenReport:
    app: str
    ok: bool
    bit_exact: bool
    max_abs_err: float
    ii: int
    min_ii: int
    latency: int
    iterations: int
    batch: int
    n_outputs: int

    def row(self) -> str:
        status = "BIT-EXACT" if self.bit_exact else (
            "ok" if self.ok else "MISMATCH")
        return (f"{self.app:<16} II={self.ii:<3d} (min {self.min_ii}) "
                f"lat={self.latency:<4d} outs={self.n_outputs:<3d} "
                f"iters={self.iterations}x{self.batch} "
                f"err={self.max_abs_err:.3e} {status}")


def random_inputs(prog: SimProgram, iterations: int, batch: int,
                  seed: int = 0, lo: float = 0.0, hi: float = 256.0
                  ) -> np.ndarray:
    """(B, K, n_ext) float32 pixel-range test vectors."""
    rng = np.random.default_rng(seed)
    vals = rng.uniform(lo, hi, (batch, iterations, prog.n_ext))
    return np.round(vals).astype(np.float32)   # integral: exact in f32


def build_sim_batch(items, *, stats=None, max_ii: Optional[int] = None,
                    budget_factor: int = 8, isolate: bool = False) -> list:
    """Schedule and lower many placed-and-routed pairs, batch-first.

    ``items``: one ``(dp, mapping, app, pnr)`` per pair.  Modulo
    scheduling runs through
    :func:`repro.sim.schedule.modulo_schedule_batch` (one lockstep
    conflict-scan group per fabric signature); lowering stays per-pair
    (cheap Python).  Returns :class:`SimProgram` objects in ``items``
    order, bit-identical to ``build_sim(..., pnr=pnr)[0]`` per pair.

    ``isolate=True``: a failing pair (fault-injection site ``schedule``,
    an exhausted II budget, a lowering error) yields the Exception object
    at its index instead of killing the batch; groupmates' schedules are
    unaffected (each pair's coroutine trajectory is its own).
    """
    from .. import faultinject
    from .schedule import modulo_schedule_batch

    n = len(items)
    failed: dict = {}
    todo = []                        # indices still scheduling
    for i, (_, mapping, _, _) in enumerate(items):
        try:
            faultinject.fire("schedule", app=mapping.app_name)
            todo.append(i)
        except Exception as e:
            if not isolate:
                raise
            failed[i] = e
    scheds = modulo_schedule_batch(
        [(items[i][3].netlist, items[i][3].placement, items[i][3].routes,
          items[i][3].spec) for i in todo],
        stats=stats, max_ii=max_ii, budget_factor=budget_factor,
        isolate=isolate)
    out: list = [None] * n
    for i, sched in zip(todo, scheds):
        _, mapping, app, pnr = items[i]
        if isinstance(sched, Exception):
            out[i] = sched
            continue
        try:
            out[i] = lower_program(mapping, app, pnr.netlist,
                                   pnr.placement, sched)
        except Exception as e:
            if not isolate:
                raise
            out[i] = e
    for i, e in failed.items():
        out[i] = e
    return out


def compare_with_interp(prog: SimProgram, app: Graph, inputs: np.ndarray,
                        res: SimResult) -> Tuple[float, bool]:
    """(max |err| vs interpreter, bit-exact?) for a precomputed result."""
    B, K, _ = inputs.shape
    feed: Dict[str, np.ndarray] = {
        name: inputs[:, :, j].reshape(-1)
        for j, name in enumerate(prog.input_names)}
    # inputs the computation never consumes don't reach the array; the
    # interpreter still wants a value for their dangling input nodes
    for n, op in app.nodes.items():
        if op == "input":
            feed.setdefault(str(app.attr(n, "name")),
                            np.zeros(B * K, np.float32))
    want = interpret(app, feed)
    err = 0.0
    exact = True
    for j in range(len(app.outputs)):
        got = res.outputs[:, :, j].reshape(-1)
        expect = np.asarray(want[j], np.float32)
        exact = exact and np.array_equal(got, expect)
        err = max(err, float(np.max(np.abs(got - expect), initial=0.0)))
    return err, exact


def check_against_interp(prog: SimProgram, app: Graph,
                         inputs: np.ndarray, *, backend: str = "jax",
                         interpret_mode: Optional[bool] = None
                         ) -> Tuple[SimResult, float, bool]:
    """(sim result, max |err| vs interpreter, bit-exact?)."""
    res = simulate(prog, inputs, backend=backend, interpret=interpret_mode)
    err, exact = compare_with_interp(prog, app, inputs, res)
    return res, err, exact


def verify_mapping(dp: Datapath, mapping: Mapping, app: Graph,
                   spec: Optional[FabricSpec] = None, *,
                   iterations: int = 3, batch: int = 2, seed: int = 0,
                   backend: str = "jax",
                   place_backend: str = "jax", chains: int = 8,
                   sweeps: int = 24,
                   pnr: Optional[PnRResult] = None) -> GoldenReport:
    """End-to-end golden check of a mapping on the fabric."""
    prog, pnr = build_sim(dp, mapping, app, spec,
                          place_backend=place_backend, chains=chains,
                          sweeps=sweeps, seed=seed, pnr=pnr)
    inputs = random_inputs(prog, iterations, batch, seed=seed)
    res, err, exact = check_against_interp(prog, app, inputs,
                                           backend=backend)
    return GoldenReport(
        app=mapping.app_name, ok=err == 0.0, bit_exact=exact,
        max_abs_err=err, ii=res.ii, min_ii=res.min_ii,
        latency=res.latency, iterations=iterations, batch=batch,
        n_outputs=len(app.outputs))
