"""Time-domain subsystem: modulo scheduling + cycle-accurate simulation.

The static DSE pipeline (mine -> merge -> map -> place -> route) prices a
design; this subsystem *executes* it over time:

* :mod:`repro.sim.schedule` — iterative modulo scheduler assigning every
  PE instance, I/O stream, and routed hop a (cycle, II) slot, with the
  achieved initiation interval reported against the recurrence/resource
  minimum;
* :mod:`repro.sim.cycle` — cycle-accurate functional simulator running all
  tiles in lockstep as a ``jax.lax.scan`` over cycles, batched over input
  sets, with the inner tile-step dispatched through
  :mod:`repro.kernels.sim_step` (``backend="jax"`` or ``"pallas"``);
* :mod:`repro.sim.golden` — bit-exact verification of simulated outputs
  against :func:`repro.graphir.interp.interpret`.

Quick start::

    from repro.sim import build_sim, simulate, verify_mapping
    prog, pnr = build_sim(dp, mapping, app, FabricSpec(rows=8, cols=8))
    print(prog.summary())                    # II, latency, tiles, wires
    print(verify_mapping(dp, mapping, app).row())
"""

from .cycle import (SimProgram, SimResult, lower_program, sim_signature,
                    simulate, simulate_batch)
from .golden import (GoldenReport, build_sim, build_sim_batch,
                     check_against_interp, compare_with_interp,
                     random_inputs, verify_mapping)
from .schedule import (ModuloSchedule, fabric_signature, min_ii,
                       modulo_schedule, modulo_schedule_batch, route_timing)

__all__ = [
    "SimProgram", "SimResult", "lower_program", "sim_signature", "simulate",
    "simulate_batch", "GoldenReport", "build_sim", "build_sim_batch",
    "check_against_interp", "compare_with_interp", "random_inputs",
    "verify_mapping", "ModuloSchedule", "fabric_signature", "min_ii",
    "modulo_schedule", "modulo_schedule_batch", "route_timing",
]
