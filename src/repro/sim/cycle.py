"""Cycle-accurate functional simulator of a scheduled CGRA array.

Executes a placed, routed, modulo-scheduled mapping over time: every tile
runs in lockstep, one ``jax.lax.scan`` step per clock cycle, with the whole
machine state held in dense arrays so input batches vectorize for free.

Machine model (the register set the scheduler's arithmetic assumes —
see :mod:`repro.sim.schedule`):

* ``ext``   — one streaming register per array input signal, refreshed with
  the next iteration's word every II cycles by its io_in tile;
* ``sig``   — one output register per PE-produced signal, loaded when the
  producing instance fires;
* ``wire``  — one pipeline register per (net, tile) hop of every routed
  tree (per-track: nets sharing a channel keep separate registers), shifted
  unconditionally every cycle — a value physically ripples down its route;
* ``latch`` — one input FIFO per (consumer tile, signal),
  ``spec.latch_depth`` iterations deep, capturing the arriving word the
  cycle it lands (slot = iteration mod depth) while the consumer reads the
  slot of the iteration it is executing — operand skew up to
  ``depth x II`` survives, exactly what the scheduler assumed;
* ``tmp``   — combinational values inside a firing tile: each instance's
  covered app nodes execute as a short micro-op program (topological order,
  at most ``n_steps`` per tile), all tiles dispatching their step-``u``
  opcode simultaneously through :mod:`repro.kernels.sim_step`.

Because instances execute the *application* nodes they cover (not the
merged-PE pattern — the datapath validator already proved those equal),
simulated outputs must bit-match :func:`repro.graphir.interp.interpret`
whenever the op set is IEEE-exact, which is the entire paper suite.  A
mismatch means the mapping, placement, routing, or schedule is wrong —
this simulator is the end-to-end correctness oracle the static pipeline
never had.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.mapper import Mapping
from ..graphir.graph import Graph
from ..graphir.ops import OPS
from ..fabric.netlist import Netlist
from ..fabric.place import Placement
from .schedule import ModuloSchedule

_ARITY_PAD = 3


@dataclass
class SimProgram:
    """A scheduled design lowered to the dense arrays the scan consumes."""

    app_name: str
    ii: int
    latency: int
    n_inst: int
    n_steps: int                      # micro-ops per tile (padded)
    ops: Tuple[str, ...]              # opcode table (0 = nop)
    # tile micro-code
    opcodes: np.ndarray               # (n_inst, n_steps) int32
    op_src: np.ndarray                # (n_inst, n_steps, 3) int32 (operand ix)
    # operand space = [latch | const | tmp]
    n_latch: int
    n_const: int
    const_pool: np.ndarray            # (n_const,) float32
    # schedule times
    fire_time: np.ndarray             # (n_inst,) int32
    ext_time: np.ndarray              # (n_ext,) int32
    # wires: src space = [sig | ext | wire]
    n_sig: int
    n_ext: int
    n_wire: int
    wire_src: np.ndarray              # (n_wire,) int32
    # producers
    sig_tmp: np.ndarray               # (n_sig,) int32 into tmp-flat
    sig_owner: np.ndarray             # (n_sig,) int32 instance index
    # latches
    latch_wire: np.ndarray            # (n_latch,) int32 wire index
    latch_time: np.ndarray            # (n_latch,) int32 first capture cycle
    latch_owner: np.ndarray           # (n_latch,) int32 consumer instance
    latch_depth: int                  # FIFO slots per latch
    # outputs
    out_wire: np.ndarray              # (n_out,) int32 wire index
    out_time: np.ndarray              # (n_out,) int32 first capture cycle
    out_cols: List[int]               # graph.outputs -> capture column
    input_names: List[str]            # per ext index
    schedule: ModuloSchedule = None
    _cache: Dict[Tuple, Any] = field(default_factory=dict, repr=False)

    @property
    def n_out(self) -> int:
        return len(self.out_wire)

    def total_cycles(self, iterations: int) -> int:
        return self.latency + (iterations - 1) * self.ii

    def summary(self) -> str:
        return (f"SimProgram[{self.app_name}: II={self.ii} "
                f"latency={self.latency} tiles={self.n_inst} "
                f"steps={self.n_steps} wires={self.n_wire} "
                f"latches={self.n_latch}]")

    # _cache holds jitted steppers — process-local, unpicklable.  Dropping
    # it on pickle makes SimPrograms storable in the explore DiskStore; a
    # restored program just recompiles its stepper on first simulate().
    def __getstate__(self) -> Dict[str, Any]:
        state = dict(self.__dict__)
        state["_cache"] = {}
        return state

    def __setstate__(self, state: Dict[str, Any]) -> None:
        self.__dict__.update(state)


def check_cycle_budget(prog: SimProgram, iterations: int,
                       max_cycles: Optional[int], *,
                       metrics=None) -> None:
    """Refuse (pre-dispatch) to simulate a program over its cycle cap.

    Raises :class:`repro.errors.BudgetExceeded` when ``max_cycles`` is
    set and ``prog.total_cycles(iterations)`` exceeds it — checked before
    any scan launches, so an over-budget program degrades to a structured
    failure instead of burning the budget it already exceeds.  No-op when
    ``max_cycles`` is None (the default).
    """
    if max_cycles is None:
        return
    total = prog.total_cycles(iterations)
    if total > max_cycles:
        if metrics is not None:
            metrics.inc("sim.budget_exhausted")
        from ..errors import BudgetExceeded
        raise BudgetExceeded(
            f"simulation of {prog.app_name} needs {total} cycles "
            f"(> sim_max_cycles={max_cycles})",
            total_cycles=total, max_cycles=max_cycles,
            iterations=iterations, ii=prog.ii, latency=prog.latency)


@dataclass
class SimResult:
    outputs: np.ndarray               # (B, K, n_graph_outputs) float32
    ii: int
    min_ii: int
    latency: int
    cycles: int
    iterations: int
    n_fires: int                      # PE invocations actually issued
    active_frac: float                # fires / (cycles * tiles)
    backend: str

    def throughput_ops_per_cycle(self, total_ops: int) -> float:
        return total_ops / self.ii


def lower_program(mapping: Mapping, app: Graph, netlist: Netlist,
                  placement: Placement,
                  schedule: ModuloSchedule) -> SimProgram:
    """Lower a scheduled design into a :class:`SimProgram`.

    Route timing comes from the schedule itself
    (:attr:`ModuloSchedule.net_timing` / ``hop_time``), so the simulator
    executes exactly the register chains the scheduler reasoned about.
    """
    if mapping.unmapped:
        raise ValueError(f"cannot simulate: unmapped nodes {mapping.unmapped}")
    if mapping.offloaded:
        raise NotImplementedError(
            "time-domain simulation requires fully PE-mapped graphs "
            f"(offloaded macros: {mapping.offloaded})")

    from ..kernels.sim_step import op_table

    coords = placement.coords
    cell_kind = {name: c.kind for name, c in netlist.cells.items()}
    inst_of_cell = {name: c.instance for name, c in netlist.cells.items()
                    if c.kind == "pe"}

    # -- signal spaces ------------------------------------------------------
    ext_sigs: List[int] = []
    for c in sorted(netlist.io_cells, key=lambda c: c.name):
        if c.kind == "io_in":
            ext_sigs.extend(c.signals)
    ext_sigs.sort()
    ext_ix = {s: i for i, s in enumerate(ext_sigs)}
    pe_sigs = sorted(n.signal for n in netlist.nets
                     if cell_kind[n.driver] == "pe")
    sig_ix = {s: i for i, s in enumerate(pe_sigs)}
    n_sig, n_ext = len(pe_sigs), len(ext_sigs)

    # -- wires: one register per (net, non-driver tile), timed exactly as
    # the scheduler published (ModuloSchedule.net_timing/net_src) ----------
    wire_ix: Dict[Tuple[str, Tuple[int, int]], int] = {}
    wire_src: List[int] = []
    timings = schedule.net_timing
    for net in sorted(netlist.nets, key=lambda n: n.name):
        nt = timings[net.name]
        drv_src = (sig_ix[net.signal]
                   if schedule.net_src[net.name][0] == "pe"
                   else n_sig + ext_ix[net.signal])
        for tile in sorted(nt.depth, key=lambda t: (nt.depth[t], t)):
            if tile == nt.driver:
                continue
            wire_ix[(net.name, tile)] = len(wire_src)
            parent = nt.parent[tile]
            if parent == nt.driver:
                wire_src.append(drv_src)
            else:
                wire_src.append(n_sig + n_ext
                                + wire_ix[(net.name, parent)])
    n_wire = len(wire_src)

    # -- latches: one per (consumer pe cell, signal) ------------------------
    latch_ix: Dict[Tuple[str, int], int] = {}
    latch_wire: List[int] = []
    latch_time: List[int] = []
    latch_owner: List[int] = []
    for net in sorted(netlist.nets, key=lambda n: n.name):
        nt = timings[net.name]
        for sink in net.sinks:
            if cell_kind[sink] != "pe":
                continue
            tile = coords[sink]
            latch_ix[(sink, net.signal)] = len(latch_wire)
            latch_wire.append(wire_ix[(net.name, tile)])
            latch_time.append(schedule.hop_time[(net.name, tile)])
            latch_owner.append(inst_of_cell[sink])
    n_latch = len(latch_wire)

    # -- constants -----------------------------------------------------------
    const_nodes = sorted(n for n, op in app.nodes.items() if op == "const")
    const_ix = {n: i for i, n in enumerate(const_nodes)}
    const_pool = np.asarray([float(app.attr(n, "value", 0.0))
                             for n in const_nodes], np.float32)
    n_const = len(const_nodes)

    # -- per-instance micro-code --------------------------------------------
    topo_pos = {n: i for i, n in enumerate(app.topo_order())}
    n_inst = mapping.n_pes
    per_inst_nodes = [sorted(inst.covered, key=topo_pos.get)
                      for inst in mapping.instances]
    n_steps = max((len(ns) for ns in per_inst_nodes), default=1)
    used_ops = sorted({app.nodes[n] for ns in per_inst_nodes for n in ns})
    ops = op_table(used_ops)
    code_of = {name: k for k, name in enumerate(ops)}

    def operand(i: int, tmp_of: Dict[int, int], cell: str,
                node: int, port: int) -> int:
        src = app.in_edges(node)[port]
        if src in tmp_of:
            return n_latch + n_const + i * n_steps + tmp_of[src]
        op = app.nodes[src]
        if op == "const":
            return n_latch + const_ix[src]
        # external operand (graph input or another tile's value)
        if (cell, src) not in latch_ix:
            raise AssertionError(
                f"no latch for signal {src} at {cell}: netlist/route mismatch")
        return latch_ix[(cell, src)]

    opcodes = np.zeros((n_inst, n_steps), np.int32)
    op_src = np.zeros((n_inst, n_steps, _ARITY_PAD), np.int32)
    for i, nodes in enumerate(per_inst_nodes):
        cell = f"pe{i}"
        tmp_of: Dict[int, int] = {}
        for u, node in enumerate(nodes):
            op = app.nodes[node]
            opcodes[i, u] = code_of[op]
            for port in range(OPS[op].arity):
                op_src[i, u, port] = operand(i, tmp_of, cell, node, port)
            tmp_of[node] = u

    # -- producers -----------------------------------------------------------
    sig_tmp = np.zeros((n_sig,), np.int32)
    sig_owner = np.zeros((n_sig,), np.int32)
    home = {}
    for i, inst in enumerate(mapping.instances):
        for n in inst.covered:
            home[n] = i
    for s, ix in sig_ix.items():
        i = home[s]
        sig_owner[ix] = i
        sig_tmp[ix] = i * n_steps + per_inst_nodes[i].index(s)

    # -- schedule times ------------------------------------------------------
    fire_time = np.asarray([schedule.start[("pe", i)]
                            for i in range(n_inst)], np.int32)
    ext_time = np.asarray([schedule.start[("in", s)] for s in ext_sigs],
                          np.int32)

    # -- output captures ----------------------------------------------------
    out_wire: List[int] = []
    out_time: List[int] = []
    cap_col: Dict[int, int] = {}
    for net in sorted(netlist.nets, key=lambda n: n.name):
        for sink in net.sinks:
            if cell_kind[sink] != "io_out":
                continue
            cap_col[net.signal] = len(out_wire)
            out_wire.append(wire_ix[(net.name, coords[sink])])
            out_time.append(schedule.hop_time[(net.name, coords[sink])])
    missing = [o for o in app.outputs if o not in cap_col]
    if missing:
        raise ValueError(f"graph outputs with no io_out capture: {missing} "
                         "(pass-through inputs/consts are not simulable)")
    out_cols = [cap_col[o] for o in app.outputs]

    input_names = [str(app.attr(s, "name", f"in{s}")) for s in ext_sigs]
    return SimProgram(
        app_name=mapping.app_name, ii=schedule.ii, latency=schedule.latency,
        n_inst=n_inst, n_steps=n_steps, ops=ops,
        opcodes=opcodes, op_src=op_src,
        n_latch=n_latch, n_const=n_const, const_pool=const_pool,
        fire_time=fire_time, ext_time=ext_time,
        n_sig=n_sig, n_ext=n_ext, n_wire=n_wire,
        wire_src=np.asarray(wire_src, np.int32),
        sig_tmp=sig_tmp, sig_owner=sig_owner,
        latch_wire=np.asarray(latch_wire, np.int32),
        latch_time=np.asarray(latch_time, np.int32),
        latch_owner=np.asarray(latch_owner, np.int32),
        latch_depth=schedule.latch_depth,
        out_wire=np.asarray(out_wire, np.int32),
        out_time=np.asarray(out_time, np.int32),
        out_cols=out_cols, input_names=input_names, schedule=schedule)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------
def _coerce_inputs(prog: SimProgram, inputs) -> np.ndarray:
    """Normalize to (B, K, n_ext) float32 in ext-signal order."""
    if isinstance(inputs, dict):
        cols = []
        for name in prog.input_names:
            if name not in inputs:
                raise KeyError(f"missing input {name!r}")
            cols.append(np.asarray(inputs[name], np.float32))
        arr = np.stack(cols, axis=-1)
    else:
        arr = np.asarray(inputs, np.float32)
    if arr.ndim == 2:
        arr = arr[None]
    if arr.ndim != 3 or arr.shape[-1] != prog.n_ext:
        raise ValueError(f"inputs must be (B, K, {prog.n_ext}); "
                         f"got {arr.shape}")
    return arr


def _build_stepper(prog: SimProgram, iterations: int, backend: str,
                   interpret: Optional[bool]):
    import jax
    import jax.numpy as jnp

    from ..kernels.sim_step import alu_step_jnp, alu_step_pallas

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    K = iterations
    ii = prog.ii
    U = prog.n_steps
    opcodes = jnp.asarray(prog.opcodes)
    op_src = jnp.asarray(prog.op_src)
    const_pool = jnp.asarray(prog.const_pool)
    fire_time = jnp.asarray(prog.fire_time)
    ext_time = jnp.asarray(prog.ext_time)
    wire_src = jnp.asarray(prog.wire_src)
    sig_tmp = jnp.asarray(prog.sig_tmp)
    sig_owner = jnp.asarray(prog.sig_owner)
    latch_wire = jnp.asarray(prog.latch_wire)
    latch_time = jnp.asarray(prog.latch_time)
    latch_owner = jnp.asarray(prog.latch_owner)
    out_wire = jnp.asarray(prog.out_wire)
    out_time = jnp.asarray(prog.out_time)
    n_out = prog.n_out
    D = prog.latch_depth
    # tmp-flat positions written at micro-op step u: instance i -> i*U + u
    step_slots = jnp.asarray(
        np.arange(prog.n_inst, dtype=np.int32)[None, :] * U
        + np.arange(U, dtype=np.int32)[:, None])          # (U, n_inst)

    def periodic(c, t0):
        """(active now, iteration index) for a period-II event train."""
        d = c - t0
        k = d // ii
        live = (d >= 0) & (d % ii == 0) & (k < K)
        return live, jnp.clip(k, 0, K - 1)

    def dispatch(codes, a, b, c3):
        if backend == "pallas":
            return alu_step_pallas(codes, a, b, c3, prog.ops,
                                   interpret=interpret)
        return alu_step_jnp(codes, a, b, c3, prog.ops)

    def step(carry, c):
        ext, sig, wire, latch, outbuf, inputs = carry
        B = ext.shape[0]

        # each consumer reads the FIFO slot of the iteration it executes
        fire, fire_k = periodic(c, fire_time)                 # (n_inst,)
        rd = fire_k[latch_owner] % D                          # (n_latch,)
        latch_view = jnp.take_along_axis(
            latch, rd[None, :, None], axis=2)[:, :, 0]        # (B, n_latch)

        # tiles compute (all in lockstep; results committed only on fire).
        # one operand buffer [latch | const | tmp] per cycle: each micro-op
        # step writes its results into the tmp slice in place
        constb = jnp.broadcast_to(const_pool, (B, prog.n_const))
        operands = jnp.concatenate(
            [latch_view, constb,
             jnp.zeros((B, prog.n_inst * U), jnp.float32)], axis=1)
        tmp_off = prog.n_latch + prog.n_const
        for u in range(U):
            a = operands[:, op_src[:, u, 0]]
            b = operands[:, op_src[:, u, 1]]
            c3 = operands[:, op_src[:, u, 2]]
            r = dispatch(opcodes[:, u], a, b, c3)
            operands = operands.at[:, tmp_off + step_slots[u]].set(r)

        sig_new = jnp.where(fire[sig_owner],
                            operands[:, tmp_off + sig_tmp], sig)

        ext_live, ext_k = periodic(c, ext_time)               # (n_ext,)
        stream = inputs[:, ext_k, jnp.arange(prog.n_ext)]     # (B, n_ext)
        ext_new = jnp.where(ext_live, stream, ext)

        src_vec = jnp.concatenate([sig, ext, wire], axis=1)
        wire_new = src_vec[:, wire_src]

        l_live, l_k = periodic(c, latch_time)
        wr = l_k % D                                          # (n_latch,)
        arriving = wire[:, latch_wire]                        # (B, n_latch)
        cur = jnp.take_along_axis(latch, wr[None, :, None], axis=2)[:, :, 0]
        written = jnp.where(l_live, arriving, cur)
        latch_new = latch.at[:, jnp.arange(prog.n_latch), wr].set(written)

        o_live, o_k = periodic(c, out_time)
        vals = wire[:, out_wire]
        cols = jnp.arange(n_out)
        prev = outbuf[:, o_k, cols]
        outbuf = outbuf.at[:, o_k, cols].set(jnp.where(o_live, vals, prev))

        return (ext_new, sig_new, wire_new, latch_new, outbuf, inputs), None

    cycles = prog.total_cycles(K)

    def run(inputs):
        import jax.numpy as jnp
        B = inputs.shape[0]
        carry = (jnp.zeros((B, prog.n_ext), jnp.float32),
                 jnp.zeros((B, prog.n_sig), jnp.float32),
                 jnp.zeros((B, prog.n_wire), jnp.float32),
                 jnp.zeros((B, prog.n_latch, D), jnp.float32),
                 jnp.zeros((B, K, n_out), jnp.float32),
                 inputs)
        carry, _ = jax.lax.scan(step, carry, jnp.arange(cycles))
        return carry[4]

    return jax.jit(run), cycles


# ---------------------------------------------------------------------------
# cross-program batching: many (variant, app) simulations in one dispatch
# ---------------------------------------------------------------------------
#: sentinel start time for padded periodic events — they never fire
_NEVER = 1 << 30


#: per-dimension lower bounds for the bucket key, sized so the programs a
#: 16x16-class array typically produces all land in ONE bucket: compile
#: count — not padded-lane arithmetic — dominates wall clock on a sweep,
#: so small programs trade padding for sharing the compiled scan.  Floors
#: are static constants, so a program's bucket (and therefore its padded
#: lowering and outputs) still depends only on the program itself.
_SIG_FLOORS = (64, 4, 32, 64, 512, 64, 32, 1, 256)


def sim_signature(prog: SimProgram, iterations: int,
                  batch: int) -> Tuple[int, ...]:
    """Static shape key two programs must share to ride one vmapped scan.

    Every dimension pads to its power-of-two bucket
    (:func:`repro.kernels.tiling.pow2_bucket`), floored by
    :data:`_SIG_FLOORS` — tiles, micro-op steps, I/O streams,
    signal/wire/latch registers, output captures, and the total cycle
    count — so the key (and therefore both the compiled program and a
    program's simulated outputs) depends only on the program itself,
    never on its groupmates.
    """
    from ..kernels.tiling import pow2_bucket as b

    dims = (prog.n_inst, prog.n_steps, prog.n_ext, prog.n_sig, prog.n_wire,
            prog.n_latch, prog.n_const, prog.n_out,
            prog.total_cycles(iterations))
    return tuple(max(b(d), f) for d, f in zip(dims, _SIG_FLOORS)) \
        + (prog.latch_depth, iterations, batch)


def _pad_program(prog: SimProgram, sig: Tuple[int, ...],
                 code_of: Dict[str, int]) -> Dict[str, np.ndarray]:
    """Lower one program onto the bucket shapes of ``sig``.

    Operand/wire indices are remapped into the padded address spaces,
    opcodes into the group's shared table; padded periodic events start at
    ``_NEVER`` so they never fire, and padded register slots are only ever
    read by other padding (real index tables reference real entries only).
    """
    ip, up, ep, sp, wp, lp, cp, op_, _, _, _, _ = sig
    n_l, n_c, n_s = prog.n_latch, prog.n_const, prog.n_steps

    lut = np.asarray([code_of[name] for name in prog.ops], np.int32)
    opcodes = np.zeros((ip, up), np.int32)
    opcodes[:prog.n_inst, :n_s] = lut[prog.opcodes]

    # operand space [latch | const | tmp] -> [latch(lp) | const(cp) | tmp]
    v = prog.op_src
    tmp_off = v - n_l - n_c
    remapped = np.where(
        v < n_l, v,
        np.where(v < n_l + n_c, lp + (v - n_l),
                 lp + cp + (tmp_off // n_s) * up + tmp_off % n_s))
    op_src = np.zeros((ip, up, _ARITY_PAD), np.int32)
    op_src[:prog.n_inst, :n_s] = remapped

    # wire sources [sig | ext | wire] -> [sig(sp) | ext(ep) | wire]
    w = prog.wire_src
    wire_src = np.zeros((wp,), np.int32)
    wire_src[:prog.n_wire] = np.where(
        w < prog.n_sig, w,
        np.where(w < prog.n_sig + prog.n_ext, sp + (w - prog.n_sig),
                 sp + ep + (w - prog.n_sig - prog.n_ext)))

    sig_tmp = np.zeros((sp,), np.int32)
    sig_tmp[:prog.n_sig] = ((prog.sig_tmp // n_s) * up + prog.sig_tmp % n_s)
    sig_owner = np.zeros((sp,), np.int32)   # padded sigs may latch tile 0's
    sig_owner[:prog.n_sig] = prog.sig_owner  # value; nothing ever reads them

    def pad_time(src: np.ndarray, n: int) -> np.ndarray:
        out = np.full((n,), _NEVER, np.int32)
        out[:src.shape[0]] = src
        return out

    def pad_ix(src: np.ndarray, n: int) -> np.ndarray:
        out = np.zeros((n,), np.int32)
        out[:src.shape[0]] = src
        return out

    const_pool = np.zeros((cp,), np.float32)
    const_pool[:n_c] = prog.const_pool
    return dict(
        ii=np.int32(prog.ii),
        dims=np.asarray([n_s, prog.n_inst], np.int32),
        opcodes=opcodes, op_src=op_src, const_pool=const_pool,
        fire_time=pad_time(prog.fire_time, ip),
        ext_time=pad_time(prog.ext_time, ep),
        wire_src=wire_src, sig_tmp=sig_tmp, sig_owner=sig_owner,
        latch_wire=pad_ix(prog.latch_wire, lp),
        latch_time=pad_time(prog.latch_time, lp),
        latch_owner=pad_ix(prog.latch_owner, lp),
        out_wire=pad_ix(prog.out_wire, op_),
        out_time=pad_time(prog.out_time, op_))


#: field order of the stacked arrays fed to the batched stepper
_BATCH_FIELDS = ("ii", "dims", "opcodes", "op_src", "const_pool",
                 "fire_time", "ext_time", "wire_src", "sig_tmp", "sig_owner",
                 "latch_wire", "latch_time", "latch_owner", "out_wire",
                 "out_time")


@functools.lru_cache(maxsize=64)
def _build_batch_stepper(sig: Tuple[int, ...], ops: Tuple[str, ...]):
    """One compiled vmapped scan for every program of one bucket signature.

    Unlike :func:`_build_stepper` (which bakes one program's register
    counts, II, and schedule times into the compiled code as constants),
    the batched step takes them all as *data*: II drives the periodic
    event trains, the schedule-time tables are gathered arrays, and the
    per-program micro-op/tile counts mask the padded dispatch lanes
    (:func:`repro.kernels.sim_step.alu_step_masked`).  Real lanes execute
    exactly the arithmetic of the per-program stepper, so outputs are
    bit-identical to :func:`simulate` per program.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.sim_step import alu_step_masked

    ip, up, ep, sp, wp, lp, cp, op_, cycles, D, K, B = sig
    tmp_off = lp + cp
    step_slots = jnp.asarray(
        np.arange(ip, dtype=np.int32)[None, :] * up
        + np.arange(up, dtype=np.int32)[:, None])             # (up, ip)

    def one(ii, dims, opcodes, op_src, const_pool, fire_time, ext_time,
            wire_src, sig_tmp, sig_owner, latch_wire, latch_time,
            latch_owner, out_wire, out_time, inputs):
        n_steps, n_inst = dims[0], dims[1]
        lane_act = jnp.arange(ip) < n_inst                    # (ip,)

        def periodic(c, t0):
            d = c - t0
            k = d // ii
            live = (d >= 0) & (d % ii == 0) & (k < K)
            return live, jnp.clip(k, 0, K - 1)

        def step(carry, c):
            ext, sig, wire, latch, outbuf = carry
            fire, fire_k = periodic(c, fire_time)             # (ip,)
            rd = fire_k[latch_owner] % D                      # (lp,)
            latch_view = jnp.take_along_axis(
                latch, rd[None, :, None], axis=2)[:, :, 0]    # (B, lp)

            constb = jnp.broadcast_to(const_pool, (B, cp))
            operands = jnp.concatenate(
                [latch_view, constb,
                 jnp.zeros((B, ip * up), jnp.float32)], axis=1)
            for u in range(up):
                a = operands[:, op_src[:, u, 0]]
                b = operands[:, op_src[:, u, 1]]
                c3 = operands[:, op_src[:, u, 2]]
                r = alu_step_masked(opcodes[:, u], a, b, c3, ops,
                                    lane_act & (u < n_steps))
                operands = operands.at[:, tmp_off + step_slots[u]].set(r)

            sig_new = jnp.where(fire[sig_owner],
                                operands[:, tmp_off + sig_tmp], sig)

            ext_live, ext_k = periodic(c, ext_time)           # (ep,)
            stream = inputs[:, ext_k, jnp.arange(ep)]         # (B, ep)
            ext_new = jnp.where(ext_live, stream, ext)

            src_vec = jnp.concatenate([sig, ext, wire], axis=1)
            wire_new = src_vec[:, wire_src]

            l_live, l_k = periodic(c, latch_time)
            wr = l_k % D                                      # (lp,)
            arriving = wire[:, latch_wire]                    # (B, lp)
            cur = jnp.take_along_axis(
                latch, wr[None, :, None], axis=2)[:, :, 0]
            written = jnp.where(l_live, arriving, cur)
            latch_new = latch.at[:, jnp.arange(lp), wr].set(written)

            o_live, o_k = periodic(c, out_time)
            vals = wire[:, out_wire]
            cols = jnp.arange(op_)
            prev = outbuf[:, o_k, cols]
            outbuf = outbuf.at[:, o_k, cols].set(
                jnp.where(o_live, vals, prev))

            return (ext_new, sig_new, wire_new, latch_new, outbuf), None

        carry = (jnp.zeros((B, ep), jnp.float32),
                 jnp.zeros((B, sp), jnp.float32),
                 jnp.zeros((B, wp), jnp.float32),
                 jnp.zeros((B, lp, D), jnp.float32),
                 jnp.zeros((B, K, op_), jnp.float32))
        carry, _ = jax.lax.scan(step, carry, jnp.arange(cycles))
        return carry[4]

    return jax.jit(jax.vmap(one))


def simulate_batch(progs: List[SimProgram], inputs_list,
                   *, backend: str = "jax",
                   metrics=None) -> List[SimResult]:
    """Simulate many programs in ONE vmapped ``lax.scan`` dispatch.

    All programs must share one :func:`sim_signature` (group by it first)
    and all input sets one (batch, iterations) shape; the union of the
    group's opcode tables drives one shared ALU dispatch.  Cycles beyond a
    program's real count execute harmlessly (no capture fires past
    iteration K-1), padded events never fire, and padded lanes retire
    zeros — so per-program outputs are bit-identical to :func:`simulate`
    on that program alone, regardless of which programs share the
    dispatch.

    Bucket provenance lands in ``metrics`` (default: the global registry):
    one ``sim.dispatch`` tick plus ``sim.bucket_programs`` /
    ``sim.bucket_cycles`` histogram observations per call, and the
    dispatch runs under a ``sim.dispatch`` span naming the bucket.
    """
    import jax.numpy as jnp

    from ..kernels.sim_step import op_table
    from ..obs import span
    from ..obs.metrics import global_registry

    if backend != "jax":
        raise ValueError("simulate_batch supports backend='jax' only "
                         "(the pallas tile-step kernel is per-program)")
    if len(progs) != len(inputs_list):
        raise ValueError("inputs_list must match progs 1:1")
    arrs = [_coerce_inputs(p, x) for p, x in zip(progs, inputs_list)]
    B, K, _ = arrs[0].shape
    for a in arrs:
        if a.shape[:2] != (B, K):
            raise ValueError("all input sets must share one (B, K) shape; "
                             f"got {a.shape[:2]} vs {(B, K)}")
    sigs = {sim_signature(p, K, B) for p in progs}
    if len(sigs) != 1:
        raise ValueError(f"programs span {len(sigs)} sim signatures; "
                         "group by sim_signature() first")
    sig = next(iter(sigs))

    reg = metrics if metrics is not None else global_registry()
    reg.inc("sim.dispatch")
    reg.observe("sim.bucket_programs", len(progs))
    reg.observe("sim.bucket_cycles", sig[8])

    ops = op_table(sorted(set().union(*(p.ops for p in progs)) - {"nop"}))
    code_of = {name: k for k, name in enumerate(ops)}
    padded = [_pad_program(p, sig, code_of) for p in progs]
    stacked = [jnp.asarray(np.stack([d[k] for d in padded]))
               for k in _BATCH_FIELDS]
    inputs = np.zeros((len(progs), B, K, sig[2]), np.float32)
    for i, (p, a) in enumerate(zip(progs, arrs)):
        inputs[i, :, :, :p.n_ext] = a

    with span("sim.dispatch", bucket="x".join(str(d) for d in sig),
              programs=len(progs)):
        run = _build_batch_stepper(sig, ops)
        outbuf = np.asarray(run(*stacked, jnp.asarray(inputs)))

    results = []
    for i, p in enumerate(progs):
        cycles = p.total_cycles(K)
        n_fires = K * p.n_inst
        results.append(SimResult(
            outputs=outbuf[i][:, :, p.out_cols], ii=p.ii,
            min_ii=p.schedule.min_ii, latency=p.latency, cycles=cycles,
            iterations=K, n_fires=n_fires,
            active_frac=n_fires / max(1, cycles * p.n_inst),
            backend="jax-batch"))
    return results


def simulate(prog: SimProgram, inputs, *, backend: str = "jax",
             interpret: Optional[bool] = None) -> SimResult:
    """Run `prog` over `inputs` and return per-iteration outputs.

    inputs: dict name -> (K,) or (B, K) arrays, or an (B, K, n_ext) /
    (K, n_ext) array in ext-signal order.  K = loop iterations; new
    iterations are issued every II cycles (software pipelining), so the
    run itself verifies the modulo schedule is hazard-free.
    backend: ``"jax"`` (vmapped ``lax.switch`` dispatch) or ``"pallas"``
    (tile-step kernel from :mod:`repro.kernels.sim_step`).
    """
    import jax.numpy as jnp

    arr = _coerce_inputs(prog, inputs)
    B, K, _ = arr.shape
    key = (K, backend, interpret)
    if key not in prog._cache:
        prog._cache[key] = _build_stepper(prog, K, backend, interpret)
    run, cycles = prog._cache[key]
    outbuf = np.asarray(run(jnp.asarray(arr)))
    outputs = outbuf[:, :, prog.out_cols]
    n_fires = K * prog.n_inst
    return SimResult(
        outputs=outputs, ii=prog.ii, min_ii=prog.schedule.min_ii,
        latency=prog.latency, cycles=cycles, iterations=K,
        n_fires=n_fires,
        active_frac=n_fires / max(1, cycles * prog.n_inst),
        backend=backend)
