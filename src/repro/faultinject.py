"""Deterministic fault injection for the exploration pipeline.

Robustness claims are only real when every degradation path runs in CI.
This module is the mechanism: production code calls :func:`fire` at its
named fault sites (one call per unit of per-pair/per-app work), and a
test — or ``python -m repro.explore --inject-fault`` — arms injections
that deterministically fail the *nth* occurrence of a site.

An injection spec is ``site:kind:nth``:

* ``site`` — a fault-site name (``mine``, ``map``, ``pnr``, ``schedule``,
  ``simulate``, ``store.write`` — see the call sites);
* ``kind`` — what happens when it fires:
  - ``exc``      raise :class:`repro.errors.InjectedFault`,
  - ``budget``   raise :class:`repro.errors.BudgetExceeded`,
  - ``kill``     ``SIGKILL`` the current process (crash-resume testing),
  - ``truncate`` non-raising: flags the site (the DiskStore write path
    checks :func:`consume_flag` and truncates its just-committed entry,
    simulating a torn write);
* ``nth`` — fire on the nth occurrence only (0-based), or ``N+`` to fire
  on the nth and every later occurrence (persistent fault).

An optional fourth part scopes the injection to matching fire-site
context: ``site:kind:nth:key=val`` (e.g. ``mine:exc:0+:app=poison``)
only counts — and only fails — occurrences whose :func:`fire` call
carried ``key=val`` in its ``ctx``.  This is how the serving tests
poison one client's request while its batchmates stay healthy.

State is process-global and explicitly armed/cleared; nothing here runs
unless a spec was armed, so the zero-injection fast path is one dict
lookup on an empty dict.
"""

from __future__ import annotations

import os
import signal
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .errors import BudgetExceeded, InjectedFault

__all__ = ["arm", "disarm_all", "active", "fire", "consume_flag",
           "FaultSpec"]

KINDS = ("exc", "budget", "kill", "truncate")


@dataclass
class FaultSpec:
    """One armed injection, counting occurrences of its site."""

    site: str
    kind: str
    nth: int
    persistent: bool = False      # "N+" specs keep firing past nth
    match: Dict[str, str] = field(default_factory=dict)  # ctx filter
    count: int = field(default=0)

    def matches(self, ctx: Dict[str, object]) -> bool:
        return all(str(ctx.get(k)) == v for k, v in self.match.items())

    def should_fire(self) -> bool:
        n = self.count
        self.count += 1
        return n == self.nth or (self.persistent and n >= self.nth)

    @staticmethod
    def parse(spec: str) -> "FaultSpec":
        parts = spec.split(":")
        if len(parts) not in (3, 4):
            raise ValueError(
                f"bad fault spec {spec!r}: expected site:kind:nth or "
                f"site:kind:nth:key=val (e.g. pnr:exc:0, "
                f"schedule:budget:1+, mine:exc:0+:app=poison)")
        site, kind, nth = parts[:3]
        match: Dict[str, str] = {}
        if len(parts) == 4:
            k, sep, v = parts[3].partition("=")
            if not sep or not k:
                raise ValueError(
                    f"bad fault context {parts[3]!r}: expected key=val")
            match[k] = v
        if kind not in KINDS:
            raise ValueError(f"bad fault kind {kind!r}: one of {KINDS}")
        persistent = nth.endswith("+")
        try:
            n = int(nth[:-1] if persistent else nth)
        except ValueError:
            raise ValueError(f"bad fault occurrence {nth!r}: an int or N+")
        return FaultSpec(site=site, kind=kind, nth=n, persistent=persistent,
                         match=match)


_ARMED: Dict[str, List[FaultSpec]] = {}
_FLAGS: Dict[str, int] = {}           # non-raising fired kinds per site


def arm(spec: str) -> FaultSpec:
    """Arm one ``site:kind:nth`` injection; returns the parsed spec."""
    fs = FaultSpec.parse(spec)
    _ARMED.setdefault(fs.site, []).append(fs)
    return fs


def disarm_all() -> None:
    """Clear every armed injection and pending flag."""
    _ARMED.clear()
    _FLAGS.clear()


def active() -> bool:
    return bool(_ARMED)


def fire(site: str, **ctx: object) -> None:
    """Count one occurrence of ``site``; fail if an armed spec matches.

    ``kind="exc"`` raises :class:`InjectedFault`, ``"budget"`` raises
    :class:`BudgetExceeded`, ``"kill"`` SIGKILLs the process (the
    crash-resume harness), ``"truncate"`` raises nothing but sets a flag
    for :func:`consume_flag`.  ``ctx`` decorates the message and feeds
    each spec's optional ``key=val`` filter: a spec with a filter only
    counts (and only fails) occurrences whose ctx matches.
    """
    specs = _ARMED.get(site)
    if not specs:
        return
    where = site if not ctx else (
        site + "[" + ",".join(f"{k}={v}" for k, v in sorted(ctx.items()))
        + "]")
    for fs in specs:
        if fs.match and not fs.matches(ctx):
            continue
        if not fs.should_fire():
            continue
        if fs.kind == "exc":
            raise InjectedFault(f"injected fault at {where} "
                                f"(occurrence {fs.count - 1})")
        if fs.kind == "budget":
            raise BudgetExceeded(f"injected budget exhaustion at {where}",
                                 injected=True, occurrence=fs.count - 1)
        if fs.kind == "kill":
            os.kill(os.getpid(), signal.SIGKILL)    # never returns
        if fs.kind == "truncate":
            _FLAGS[site] = _FLAGS.get(site, 0) + 1


def consume_flag(site: str) -> bool:
    """True once per non-raising injection fired at ``site`` (used by the
    DiskStore write path to corrupt its just-committed entry)."""
    n = _FLAGS.get(site, 0)
    if n <= 0:
        return False
    _FLAGS[site] = n - 1
    return True
