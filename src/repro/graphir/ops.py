"""Primitive operation vocabulary for dataflow graphs.

This is the analogue of the CoreIR primitive library in the paper: every node
of an application dataflow graph carries one of these ops.  Each op belongs to
a *hardware unit* (``hw_unit``) — the paper merges two nodes iff they "are the
same operation, or can both be implemented on the same hardware block"
(Sec. III-C), so the unit partition drives subgraph merging.

Area/energy numbers are 16 nm-class analytical estimates for 16-bit datapaths,
scaled from the Horowitz ISSCC'14 energy survey (45 nm) by ~3x energy / ~4x
area per node generation.  Absolute values are NOT the reproduction target —
the paper's claims are ratios (baseline PE vs. specialized PE), and ratios are
insensitive to the calibration constant.  See DESIGN.md §7.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class OpInfo:
    """Static description of a primitive op."""

    name: str
    arity: int
    hw_unit: str          # hardware block that implements the op
    area_um2: float       # 16 nm, 16-bit datapath, um^2
    energy_pj: float      # pJ per 16-bit operation
    commutative: bool = False
    flops: int = 1        # useful-work accounting (mac counts as 2)


# ---------------------------------------------------------------------------
# Hardware units.  The paper's baseline PE (Fig. 7) contains an ALU
# (add/sub/shift/compare), a multiplier, and a LUT for bit ops.  We keep that
# partition and add a "special" unit for transcendental ops that only appear
# in the ML/LM-domain graphs (piecewise-linear unit in hardware terms).
# ---------------------------------------------------------------------------
U_ADD = "adder"
U_MUL = "multiplier"
U_MAC = "mac"            # fused multiply-add block (mult + adder)
U_SHIFT = "shifter"
U_CMP = "comparator"
U_LOGIC = "lut"
U_MUX = "mux"
U_CONST = "const_reg"
U_DIV = "divider"
U_SPECIAL = "special"    # exp / tanh / sigmoid / rsqrt / sqrt / recip
U_REDUCE = "reduce"      # tensor-level reduction macro-node (LM graphs)
U_MATMUL = "matmul"      # tensor-level matmul macro-node (LM graphs)
U_IO = "io"              # graph inputs / outputs — never merged, zero cost

# Area (um^2) and energy (pJ/op) per hardware unit at 16 nm / 16-bit.
UNIT_AREA: Dict[str, float] = {
    U_ADD: 62.0,
    U_MUL: 558.0,
    U_MAC: 602.0,         # multiplier + final adder, shared partial products
    U_SHIFT: 78.0,
    U_CMP: 36.0,
    U_LOGIC: 24.0,
    U_MUX: 11.0,          # 2:1, 16-bit
    U_CONST: 46.0,        # 16 flops + config decode
    U_DIV: 1240.0,
    U_SPECIAL: 2210.0,    # piecewise-linear transcendental unit
    U_REDUCE: 0.0,
    U_MATMUL: 0.0,
    U_IO: 0.0,
}

UNIT_ENERGY: Dict[str, float] = {
    U_ADD: 0.018,
    U_MUL: 0.24,
    U_MAC: 0.25,
    U_SHIFT: 0.021,
    U_CMP: 0.012,
    U_LOGIC: 0.008,
    U_MUX: 0.003,
    U_CONST: 0.002,
    U_DIV: 0.60,
    U_SPECIAL: 0.85,
    U_REDUCE: 0.0,
    U_MATMUL: 0.0,
    U_IO: 0.0,
}


def _op(name: str, arity: int, unit: str, *, commutative: bool = False,
        flops: int = 1) -> OpInfo:
    return OpInfo(
        name=name,
        arity=arity,
        hw_unit=unit,
        area_um2=UNIT_AREA[unit],
        energy_pj=UNIT_ENERGY[unit],
        commutative=commutative,
        flops=flops,
    )


OPS: Dict[str, OpInfo] = {
    info.name: info
    for info in [
        # ALU family ------------------------------------------------------
        _op("add", 2, U_ADD, commutative=True),
        _op("sub", 2, U_ADD),
        _op("neg", 1, U_ADD),
        _op("abs", 1, U_ADD),
        # multiplier family -----------------------------------------------
        _op("mul", 2, U_MUL, commutative=True),
        _op("mac", 3, U_MAC, flops=2),        # a*b + c  (ports: 0=a,1=b,2=c)
        # shifter -----------------------------------------------------------
        _op("shl", 2, U_SHIFT),
        _op("shr", 2, U_SHIFT),
        _op("ashr", 2, U_SHIFT),
        # comparator family --------------------------------------------------
        _op("eq", 2, U_CMP, commutative=True),
        _op("neq", 2, U_CMP, commutative=True),
        _op("lt", 2, U_CMP),
        _op("lte", 2, U_CMP),
        _op("gt", 2, U_CMP),
        _op("gte", 2, U_CMP),
        _op("min", 2, U_CMP, commutative=True),
        _op("max", 2, U_CMP, commutative=True),
        # LUT / bit ops -------------------------------------------------------
        _op("and", 2, U_LOGIC, commutative=True),
        _op("or", 2, U_LOGIC, commutative=True),
        _op("xor", 2, U_LOGIC, commutative=True),
        _op("not", 1, U_LOGIC),
        _op("sign", 1, U_LOGIC),
        # mux / select --------------------------------------------------------
        _op("sel", 3, U_MUX),                 # ports: 0=cond, 1=false, 2=true
        _op("cmux", 2, U_MUX),                # config-register mux (merged PEs);
                                              # variadic data ports 0..k-1
        # divider / special ---------------------------------------------------
        _op("div", 2, U_DIV),
        _op("recip", 1, U_DIV),
        _op("exp", 1, U_SPECIAL),
        _op("log", 1, U_SPECIAL),
        _op("tanh", 1, U_SPECIAL),
        _op("sigmoid", 1, U_SPECIAL),
        _op("rsqrt", 1, U_SPECIAL),
        _op("sqrt", 1, U_SPECIAL),
        _op("erf", 1, U_SPECIAL),
        _op("pow", 2, U_SPECIAL),
        _op("floor", 1, U_SHIFT),
        _op("round", 1, U_SHIFT),
        # structural ----------------------------------------------------------
        _op("const", 0, U_CONST),
        _op("input", 0, U_IO),
        _op("output", 1, U_IO),
        # tensor-level macro nodes (LM-layer graphs; zero PE-cost, they map
        # to the MXU / reductions and are costed by the roofline model) -----
        _op("matmul", 2, U_MATMUL, flops=2),
        _op("rsum", 1, U_REDUCE),
        _op("rmax", 1, U_REDUCE),
        _op("rmean", 1, U_REDUCE),
        _op("cat", 2, U_IO),
        _op("iota", 0, U_IO),
        _op("gather", 2, U_IO),
        _op("scatter", 3, U_IO),
        _op("cumsum", 1, U_REDUCE),
        _op("sort", 1, U_REDUCE),
        _op("argmax", 1, U_REDUCE),
        _op("top_k", 1, U_REDUCE),
        _op("rmin", 1, U_REDUCE),
        _op("opaque", 0, U_IO),   # unmapped structural primitive (jaxpr path)
    ]
}


# Ops that may be *merged* onto the same hardware block even though the op
# names differ (paper Sec. III-C: "can both be implemented on the same
# hardware block").  The unit partition above already encodes this; helper
# below answers the mergeability question used by core/merge.py.
def mergeable(op_a: str, op_b: str) -> bool:
    """True iff two ops can share one hardware block in a merged PE."""
    ia, ib = OPS[op_a], OPS[op_b]
    if ia.hw_unit in (U_IO,):
        return False
    if ia.hw_unit == ib.hw_unit:
        return True
    # a MAC block subsumes a lone multiplier or a lone adder
    pair = {ia.hw_unit, ib.hw_unit}
    if pair <= {U_MAC, U_MUL} or pair <= {U_MAC, U_ADD}:
        return True
    return False


def merged_unit(op_a: str, op_b: str) -> str:
    """Hardware unit implementing both ops (call only if mergeable)."""
    ia, ib = OPS[op_a], OPS[op_b]
    if ia.hw_unit == ib.hw_unit:
        return ia.hw_unit
    return U_MAC  # only cross-unit merge allowed is into a MAC block


def unit_of(op: str) -> str:
    return OPS[op].hw_unit


def area_of(op: str) -> float:
    return OPS[op].area_um2


def energy_of(op: str) -> float:
    return OPS[op].energy_pj


#: ops excluded from mined patterns (pattern interiors must be real compute)
NON_COMPUTE = {"input", "output"}

#: number of PE data inputs each op consumes when standing alone
def op_arity(op: str) -> int:
    return OPS[op].arity
