"""Dataflow-graph IR: the CoreIR analogue (see DESIGN.md §2)."""

from .graph import Graph, free_in_ports, pattern_from_spec, sink_nodes
from .interp import interpret, interpret_pattern, pattern_outputs
from .ops import OPS, OpInfo, area_of, energy_of, mergeable, merged_unit, unit_of
from .trace import from_jaxpr, trace_fn
from .symtrace import Sym, Tracer
from .symtrace import trace as trace_scalar

__all__ = [
    "Graph", "free_in_ports", "pattern_from_spec", "sink_nodes",
    "interpret", "interpret_pattern", "pattern_outputs",
    "OPS", "OpInfo", "area_of", "energy_of", "mergeable", "merged_unit",
    "unit_of", "Sym", "Tracer", "trace_scalar", "from_jaxpr", "trace_fn",
]
