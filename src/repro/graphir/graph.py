"""Directed, port-labeled dataflow graph — the CoreIR analogue.

Nodes carry a primitive op name (see :mod:`repro.graphir.ops`); edges carry
the *destination port* (operand index), because operand order matters for
non-commutative ops (paper Sec. II-B).  A node has at most one producer per
input port.

The same structure is used for full application graphs, mined subgraph
patterns, and merged PE datapaths (which additionally contain ``sel``/mux
nodes inserted by :mod:`repro.core.merge`).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

from .ops import OPS, NON_COMPUTE

Edge = Tuple[int, int, int]  # (src_node, dst_node, dst_port)


@dataclass
class Graph:
    """Mutable dataflow graph.

    nodes: node id -> op name
    attrs: node id -> free-form attributes (const value, input index, ...)
    edges: set of (src, dst, dst_port)
    outputs: ordered node ids whose values are graph results
    """

    nodes: Dict[int, str] = field(default_factory=dict)
    attrs: Dict[int, Dict[str, Any]] = field(default_factory=dict)
    edges: set = field(default_factory=set)
    outputs: List[int] = field(default_factory=list)
    _next_id: int = 0

    # -- construction ------------------------------------------------------
    def add_node(self, op: str, **attrs: Any) -> int:
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}")
        nid = self._next_id
        self._next_id += 1
        self.nodes[nid] = op
        if attrs:
            self.attrs[nid] = dict(attrs)
        return nid

    def add_edge(self, src: int, dst: int, port: int) -> None:
        if src not in self.nodes or dst not in self.nodes:
            raise KeyError("edge endpoints must exist")
        # one producer per (dst, port)
        for (s, d, p) in self.edges:
            if d == dst and p == port:
                raise ValueError(f"port {port} of node {dst} already driven by {s}")
        self.edges.add((src, dst, port))

    def mark_output(self, nid: int) -> None:
        self.outputs.append(nid)

    # -- views -------------------------------------------------------------
    def op(self, nid: int) -> str:
        return self.nodes[nid]

    def attr(self, nid: int, key: str, default: Any = None) -> Any:
        return self.attrs.get(nid, {}).get(key, default)

    def in_edges(self, nid: int) -> Dict[int, int]:
        """port -> src node id."""
        return {p: s for (s, d, p) in self.edges if d == nid}

    def out_edges(self, nid: int) -> List[Tuple[int, int]]:
        """[(dst, port)] sorted for determinism."""
        return sorted((d, p) for (s, d, p) in self.edges if s == nid)

    def fanout(self, nid: int) -> int:
        return sum(1 for (s, _, _) in self.edges if s == nid)

    def num_nodes(self) -> int:
        return len(self.nodes)

    def num_compute_nodes(self) -> int:
        return sum(1 for op in self.nodes.values() if op not in NON_COMPUTE)

    def compute_nodes(self) -> List[int]:
        return [n for n, op in sorted(self.nodes.items()) if op not in NON_COMPUTE]

    def op_histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for op in self.nodes.values():
            hist[op] = hist.get(op, 0) + 1
        return hist

    # -- algorithms ----------------------------------------------------------
    def topo_order(self) -> List[int]:
        indeg = {n: 0 for n in self.nodes}
        for (_, d, _) in self.edges:
            indeg[d] += 1
        ready = sorted(n for n, k in indeg.items() if k == 0)
        order: List[int] = []
        succs: Dict[int, List[int]] = {n: [] for n in self.nodes}
        for (s, d, _) in self.edges:
            succs[s].append(d)
        seen_edge: Dict[int, int] = dict(indeg)
        while ready:
            n = ready.pop()
            order.append(n)
            for d in succs[n]:
                seen_edge[d] -= 1
                if seen_edge[d] == 0:
                    ready.append(d)
            ready.sort()
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return order

    def induced_subgraph(self, keep: Iterable[int]) -> "Graph":
        """Subgraph on `keep` nodes with all edges among them (ids preserved)."""
        keep_set = set(keep)
        g = Graph()
        g.nodes = {n: self.nodes[n] for n in keep_set}
        g.attrs = {n: dict(self.attrs[n]) for n in keep_set if n in self.attrs}
        g.edges = {(s, d, p) for (s, d, p) in self.edges
                   if s in keep_set and d in keep_set}
        g.outputs = [n for n in self.outputs if n in keep_set]
        g._next_id = max(keep_set, default=-1) + 1
        return g

    def copy(self) -> "Graph":
        g = Graph()
        g.nodes = dict(self.nodes)
        g.attrs = {n: dict(a) for n, a in self.attrs.items()}
        g.edges = set(self.edges)
        g.outputs = list(self.outputs)
        g._next_id = self._next_id
        return g

    # -- JSON round trip ------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable dict; :meth:`from_dict` restores a graph whose
        :func:`repro.explore.graph_key` fingerprint matches the original's
        (node ids, attrs, edge set, and output order all preserved)."""
        return {
            "nodes": {str(n): op for n, op in sorted(self.nodes.items())},
            "attrs": {str(n): dict(a)
                      for n, a in sorted(self.attrs.items()) if a},
            "edges": sorted(list(e) for e in self.edges),
            "outputs": list(self.outputs),
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Graph":
        """Rebuild a graph from :meth:`to_dict` output, validating ops and
        edge endpoints (raises ``ValueError`` on malformed input)."""
        if not isinstance(d, dict):
            raise ValueError(f"graph blob must be an object, "
                             f"got {type(d).__name__}")
        g = Graph()
        try:
            nodes = {int(n): str(op)
                     for n, op in dict(d.get("nodes", {})).items()}
        except (TypeError, ValueError):
            raise ValueError("graph nodes must map int ids to op names")
        for nid, op in nodes.items():
            if op not in OPS:
                raise ValueError(f"unknown op {op!r} at node {nid}")
        g.nodes = nodes
        g.attrs = {int(n): dict(a)
                   for n, a in dict(d.get("attrs", {})).items()}
        for (s, dst, p) in d.get("edges", []):
            g.add_edge(int(s), int(dst), int(p))
        for nid in d.get("outputs", []):
            if int(nid) not in g.nodes:
                raise ValueError(f"output node {nid} does not exist")
            g.outputs.append(int(nid))
        g._next_id = max(g.nodes, default=-1) + 1
        return g

    def relabeled(self) -> "Graph":
        """Copy with node ids renumbered 0..n-1 in topological order."""
        mapping = {old: i for i, old in enumerate(self.topo_order())}
        g = Graph()
        g.nodes = {mapping[n]: op for n, op in self.nodes.items()}
        g.attrs = {mapping[n]: dict(a) for n, a in self.attrs.items()}
        g.edges = {(mapping[s], mapping[d], p) for (s, d, p) in self.edges}
        g.outputs = [mapping[n] for n in self.outputs]
        g._next_id = len(g.nodes)
        return g

    # -- canonical form -------------------------------------------------------
    def _eff_port(self, dst: int, port: int) -> int:
        """Effective port label: commutative ops' operand order is immaterial
        (PE input muxes make order configurable, paper Sec. II-B)."""
        if OPS[self.nodes[dst]].commutative:
            return -1
        return port

    def canonical_label(self) -> str:
        """Canonical string; equal iff graphs are isomorphic (op labels +
        effective-port labels — commutative operand order collapsed).

        Weisfeiler-Lehman color refinement, then exhaustive permutation within
        residual color classes.  Intended for small graphs (mined patterns,
        <= ~12 nodes); raises for graphs where the residual search would blow up.
        """
        nodes = sorted(self.nodes)
        if not nodes:
            return "()"
        in_adj: Dict[int, List[Tuple[int, int]]] = {n: [] for n in nodes}
        out_adj: Dict[int, List[Tuple[int, int]]] = {n: [] for n in nodes}
        for (s, d, p) in self.edges:
            ep = self._eff_port(d, p)
            out_adj[s].append((d, ep))
            in_adj[d].append((s, ep))

        # WL refinement
        color: Dict[int, Any] = {n: self.nodes[n] for n in nodes}
        for _ in range(len(nodes)):
            new_color = {}
            for n in nodes:
                ins = tuple(sorted((color[s], p) for (s, p) in in_adj[n]))
                outs = tuple(sorted((color[d], p) for (d, p) in out_adj[n]))
                new_color[n] = (color[n], ins, outs)
            # compress
            uniq = sorted(set(new_color.values()), key=repr)
            remap = {c: i for i, c in enumerate(uniq)}
            compressed = {n: (self.nodes[n], remap[new_color[n]]) for n in nodes}
            if len(set(compressed.values())) == len(set(color.values())):
                color = compressed
                break
            color = compressed

        # group into classes
        classes: Dict[Any, List[int]] = {}
        for n in nodes:
            classes.setdefault(color[n], []).append(n)
        class_list = sorted(classes.items(), key=lambda kv: repr(kv[0]))
        # bound the permutation search
        perm_count = 1
        for _, members in class_list:
            for k in range(2, len(members) + 1):
                perm_count *= k
            if perm_count > 40320:
                raise ValueError(
                    f"canonical_label: residual automorphism search too large "
                    f"({self.num_nodes()} nodes)")

        best: Optional[str] = None
        member_perms = [list(itertools.permutations(m)) for _, m in class_list]
        for combo in itertools.product(*member_perms):
            mapping: Dict[int, int] = {}
            i = 0
            for perm in combo:
                for n in perm:
                    mapping[n] = i
                    i += 1
            sig_nodes = tuple(
                self.nodes[n] for n in sorted(mapping, key=mapping.get))
            sig_edges = tuple(sorted(
                (mapping[s], mapping[d], self._eff_port(d, p))
                for (s, d, p) in self.edges))
            sig = repr((sig_nodes, sig_edges))
            if best is None or sig < best:
                best = sig
        assert best is not None
        return best

    # -- IO ---------------------------------------------------------------------
    def to_dot(self, name: str = "g") -> str:
        lines = [f"digraph {name} {{"]
        for n, op in sorted(self.nodes.items()):
            extra = ""
            if op == "const":
                extra = f"={self.attr(n, 'value')}"
            shape = "box" if op not in NON_COMPUTE else "ellipse"
            lines.append(f'  n{n} [label="{op}{extra}\\n#{n}", shape={shape}];')
        for (s, d, p) in sorted(self.edges):
            lines.append(f'  n{s} -> n{d} [label="{p}"];')
        lines.append("}")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Graph(nodes={len(self.nodes)}, edges={len(self.edges)}, "
                f"outputs={len(self.outputs)})")


def pattern_from_spec(spec: Sequence[Tuple[str, Sequence[int]]]) -> Graph:
    """Build a small pattern graph from a compact spec.

    spec[i] = (op, (operand_node_indices...)); operand index -1 means the port
    is fed from outside the pattern (left dangling).  Example — the paper's
    Fig. 3b ``mul -> add``::

        pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1))])
    """
    g = Graph()
    ids: List[int] = []
    for op, operands in spec:
        nid = g.add_node(op, value=0.0) if op == "const" else g.add_node(op)
        ids.append(nid)
        for port, operand in enumerate(operands):
            if operand >= 0:
                g.add_edge(ids[operand], nid, port)
    return g


def free_in_ports(g: Graph) -> List[Tuple[int, int]]:
    """(node, port) pairs not driven inside the graph = PE data inputs."""
    driven = {(d, p) for (_, d, p) in g.edges}
    out: List[Tuple[int, int]] = []
    for n in sorted(g.nodes):
        op = g.nodes[n]
        if op in NON_COMPUTE:
            continue
        for port in range(OPS[op].arity):
            if (n, port) not in driven:
                out.append((n, port))
    return out


def sink_nodes(g: Graph) -> List[int]:
    """Nodes exposed as PE outputs: no consumer inside the graph, or an
    explicitly marked graph output."""
    srcs = {s for (s, _, _) in g.edges}
    sinks = [n for n in sorted(g.nodes)
             if g.nodes[n] not in NON_COMPUTE
             and (n not in srcs or n in g.outputs)]
    if not sinks:
        sinks = sorted(g.nodes)[-1:]
    return sinks
