"""Symbolic scalar tracer: builds CoreIR-style dataflow graphs.

The paper lowers Halide apps to per-output-pixel dataflow graphs of primitive
ops (Fig. 3 shows an unrolled convolution).  We reproduce that front-end with
an operator-overloading tracer: application code is written once against the
functional API below and executes either on plain numpy values (the oracle
path) or on :class:`Sym` values (the graph-building path).

Hash-consing is applied so shared subexpressions become shared nodes — the
paper's overlap analysis (Sec. III-B) is only meaningful on graphs with
sharing.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from .graph import Graph

Number = Union[int, float]


class Tracer:
    """Builds a :class:`Graph` from traced scalar arithmetic."""

    def __init__(self) -> None:
        self.graph = Graph()
        self._cse: Dict[Tuple, int] = {}

    # -- leaves -------------------------------------------------------------
    def input(self, name: str) -> "Sym":
        key = ("input", name)
        if key not in self._cse:
            self._cse[key] = self.graph.add_node("input", name=name)
        return Sym(self, self._cse[key])

    def const(self, value: Number) -> "Sym":
        key = ("const", float(value))
        if key not in self._cse:
            self._cse[key] = self.graph.add_node("const", value=value)
        return Sym(self, self._cse[key])

    def output(self, sym: "Sym", name: Optional[str] = None) -> None:
        out = self.graph.add_node("output", name=name)
        self.graph.add_edge(sym.nid, out, 0)
        self.graph.mark_output(sym.nid)

    # -- interior -------------------------------------------------------------
    def emit(self, op: str, *operands: "Sym") -> "Sym":
        key = (op,) + tuple(o.nid for o in operands)
        if key in self._cse:
            return Sym(self, self._cse[key])
        nid = self.graph.add_node(op)
        for port, o in enumerate(operands):
            self.graph.add_edge(o.nid, nid, port)
        self._cse[key] = nid
        return Sym(self, nid)

    def lift(self, v: Union["Sym", Number]) -> "Sym":
        if isinstance(v, Sym):
            return v
        return self.const(v)


class Sym:
    """A traced scalar value (node reference)."""

    __slots__ = ("tracer", "nid")
    __array_priority__ = 100  # beat numpy broadcasting

    def __init__(self, tracer: Tracer, nid: int) -> None:
        self.tracer = tracer
        self.nid = nid

    # binary arithmetic -------------------------------------------------------
    def _bin(self, op: str, other: Union["Sym", Number],
             swap: bool = False) -> "Sym":
        other = self.tracer.lift(other)
        a, b = (other, self) if swap else (self, other)
        return self.tracer.emit(op, a, b)

    def __add__(self, o):   return self._bin("add", o)
    def __radd__(self, o):  return self._bin("add", o, swap=True)
    def __sub__(self, o):   return self._bin("sub", o)
    def __rsub__(self, o):  return self._bin("sub", o, swap=True)
    def __mul__(self, o):   return self._bin("mul", o)
    def __rmul__(self, o):  return self._bin("mul", o, swap=True)
    def __truediv__(self, o):  return self._bin("div", o)
    def __rtruediv__(self, o): return self._bin("div", o, swap=True)
    def __lshift__(self, o):   return self._bin("shl", o)
    def __rshift__(self, o):   return self._bin("ashr", o)
    def __and__(self, o):   return self._bin("and", o)
    def __or__(self, o):    return self._bin("or", o)
    def __xor__(self, o):   return self._bin("xor", o)
    def __neg__(self):      return self.tracer.emit("neg", self)
    def __abs__(self):      return self.tracer.emit("abs", self)

    # comparisons ---------------------------------------------------------------
    def __lt__(self, o):  return self._bin("lt", o)
    def __le__(self, o):  return self._bin("lte", o)
    def __gt__(self, o):  return self._bin("gt", o)
    def __ge__(self, o):  return self._bin("gte", o)

    def eq(self, o):  return self._bin("eq", o)
    def neq(self, o): return self._bin("neq", o)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Sym(#{self.nid}:{self.tracer.graph.nodes[self.nid]})"


# ---------------------------------------------------------------------------
# Functional API — dispatches on Sym vs. numeric so the same application code
# runs under the tracer and under numpy (oracle).
# ---------------------------------------------------------------------------

def _is_sym(*vs: Any) -> Optional[Tracer]:
    for v in vs:
        if isinstance(v, Sym):
            return v.tracer
    return None


def _emit_or_eval(op: str, fallback: Callable, *vs: Any):
    t = _is_sym(*vs)
    if t is None:
        return fallback(*vs)
    return t.emit(op, *(t.lift(v) for v in vs))


def fmax(a, b):   return _emit_or_eval("max", lambda x, y: np.maximum(x, y), a, b)
def fmin(a, b):   return _emit_or_eval("min", lambda x, y: np.minimum(x, y), a, b)
def fabs_(a):     return _emit_or_eval("abs", abs, a)
def fexp(a):      return _emit_or_eval("exp", np.exp, a)
def flog(a):      return _emit_or_eval("log", np.log, a)
def fsqrt(a):     return _emit_or_eval("sqrt", np.sqrt, a)
def frsqrt(a):    return _emit_or_eval("rsqrt", lambda x: 1.0 / np.sqrt(x), a)
def ftanh(a):     return _emit_or_eval("tanh", np.tanh, a)
def fsigmoid(a):  return _emit_or_eval("sigmoid", lambda x: 1.0 / (1.0 + np.exp(-x)), a)
def fsign(a):     return _emit_or_eval("sign", np.sign, a)
def ffloor(a):    return _emit_or_eval("floor", np.floor, a)


def fsel(cond, if_false, if_true):
    """select: cond ? if_true : if_false (port order matches select_n)."""
    return _emit_or_eval(
        "sel", lambda c, f, t: np.where(c, t, f), cond, if_false, if_true)


def fshr(a, bits):
    # NOTE: matches interp/kernel semantics (scale by 2^-b, no floor) —
    # the fixed-point truncation is a quantization detail the float
    # dataflow graphs do not model
    return _emit_or_eval(
        "ashr", lambda x, b: x / (2 ** b), a, bits)


def fshl(a, bits):
    return _emit_or_eval("shl", lambda x, b: x * (2 ** b), a, bits)


def fclamp(x, lo, hi):
    return fmin(fmax(x, lo), hi)


def frelu(x):
    return fmax(x, 0.0)


def trace(fn: Callable[..., Any], input_names: List[str]) -> Graph:
    """Trace `fn(tracer_inputs...) -> value or list of values` into a Graph."""
    t = Tracer()
    args = [t.input(n) for n in input_names]
    out = fn(*args)
    outs = out if isinstance(out, (tuple, list)) else [out]
    for i, o in enumerate(outs):
        t.output(o, name=f"out{i}")
    return t.graph
