"""Dataflow-graph interpreter — the pure-numpy oracle for everything.

Used to (a) validate traced graphs against the original function, (b) prove a
merged PE datapath can execute each source subgraph under some configuration
(core/merge.py tests), and (c) serve as the reference implementation for the
generated fused Pallas kernel (kernels/ref.py delegates here).

All ops execute elementwise over numpy (or jnp) arrays; ``sel`` follows
``select_n`` port order (port0 = predicate, port1 = false, port2 = true).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .graph import Graph, free_in_ports, sink_nodes

SEMANTICS: Dict[str, Callable] = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "neg": lambda a: -a,
    "abs": lambda a: abs(a) if np.isscalar(a) else np.abs(a),
    "mul": lambda a, b: a * b,
    "mac": lambda a, b, c: a * b + c,
    "div": lambda a, b: a / b,
    "recip": lambda a: 1.0 / a,
    "shl": lambda a, b: a * (2.0 ** b),
    "shr": lambda a, b: a / (2.0 ** b),
    "ashr": lambda a, b: a / (2.0 ** b),
    "eq": lambda a, b: a == b,
    "neq": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "lte": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "gte": lambda a, b: a >= b,
    "min": lambda a, b: np.minimum(a, b),
    "max": lambda a, b: np.maximum(a, b),
    "and": lambda a, b: np.logical_and(a, b),
    "or": lambda a, b: np.logical_or(a, b),
    "xor": lambda a, b: np.logical_xor(a, b),
    "not": lambda a: np.logical_not(a),
    "sign": lambda a: np.sign(a),
    "sel": lambda c, f, t: np.where(c, t, f),
    "exp": lambda a: np.exp(a),
    "log": lambda a: np.log(a),
    "tanh": lambda a: np.tanh(a),
    "sigmoid": lambda a: 1.0 / (1.0 + np.exp(-a)),
    "rsqrt": lambda a: 1.0 / np.sqrt(a),
    "sqrt": lambda a: np.sqrt(a),
    "erf": lambda a: _erf(a),
    "pow": lambda a, b: a ** b,
    "floor": lambda a: np.floor(a),
    "round": lambda a: np.round(a),
}


def _erf(a):
    try:
        from scipy.special import erf  # pragma: no cover - optional
        return erf(a)
    except Exception:
        # Abramowitz-Stegun rational approx, good to ~1.5e-7
        x = np.asarray(a, dtype=np.float64)
        s = np.sign(x)
        x = np.abs(x)
        t = 1.0 / (1.0 + 0.3275911 * x)
        y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741)
                    * t - 0.284496736) * t + 0.254829592) * t * np.exp(-x * x)
        return s * y


def interpret(graph: Graph, inputs: Dict[str, Any],
              consts_override: Optional[Dict[int, Any]] = None) -> List[Any]:
    """Execute a full application graph.

    inputs: name -> value for every ``input`` node.
    Returns values of ``graph.outputs`` in order.
    """
    values: Dict[int, Any] = {}
    for n in graph.topo_order():
        op = graph.nodes[n]
        if op == "input":
            name = graph.attr(n, "name")
            if name not in inputs:
                raise KeyError(f"missing input {name!r}")
            values[n] = inputs[name]
        elif op == "const":
            if consts_override and n in consts_override:
                values[n] = consts_override[n]
            else:
                values[n] = graph.attr(n, "value")
        elif op == "output":
            src = graph.in_edges(n)[0]
            values[n] = values[src]
        else:
            ins = graph.in_edges(n)
            args = [values[ins[p]] for p in range(len(ins))]
            if op not in SEMANTICS:
                raise NotImplementedError(f"interpret: op {op!r}")
            values[n] = SEMANTICS[op](*args)
    return [values[o] for o in graph.outputs]


def interpret_pattern(pattern: Graph,
                      port_values: Dict[Tuple[int, int], Any],
                      consts_override: Optional[Dict[int, Any]] = None,
                      ) -> Dict[int, Any]:
    """Execute a pattern graph whose free in-ports are fed externally.

    port_values: (node, port) -> value for every free in-port.
    Returns node -> value for every node (sinks are the PE outputs).
    """
    free = set(free_in_ports(pattern))
    missing = free - set(port_values)
    if missing:
        raise KeyError(f"missing free-port values: {sorted(missing)}")
    values: Dict[int, Any] = {}
    for n in pattern.topo_order():
        op = pattern.nodes[n]
        if op == "const":
            if consts_override and n in consts_override:
                values[n] = consts_override[n]
            else:
                values[n] = pattern.attr(n, "value")
            continue
        if op == "input":
            raise ValueError("pattern graphs must not contain input nodes")
        ins = pattern.in_edges(n)
        from .ops import OPS
        args = []
        for p in range(OPS[op].arity):
            if p in ins:
                args.append(values[ins[p]])
            else:
                args.append(port_values[(n, p)])
        values[n] = SEMANTICS[op](*args)
    return values


def pattern_outputs(pattern: Graph,
                    port_values: Dict[Tuple[int, int], Any],
                    consts_override: Optional[Dict[int, Any]] = None,
                    ) -> List[Any]:
    vals = interpret_pattern(pattern, port_values, consts_override)
    return [vals[s] for s in sink_nodes(pattern)]
