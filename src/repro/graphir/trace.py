"""jaxpr -> dataflow-graph front-end (tensor level).

The paper's front-end lowers Halide to CoreIR.  For the LM architectures we
trace a *single transformer layer* (tiny dims) through ``jax.make_jaxpr`` and
convert each equation into a graph node at the tensor level.  Elementwise
primitives map 1:1 onto the PE op vocabulary; matmuls/reductions become
zero-PE-cost macro nodes (they run on the MXU, not the mined PE datapath);
structural primitives (reshape/broadcast/convert/...) are elided so mined
patterns see the *compute* idioms (RMSNorm, SwiGLU, RoPE, softcap, router).

Scalar unrolled graphs (MAC chains a la the paper's Fig. 3) come from the
:mod:`repro.graphir.symtrace` front-end instead.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.extend import core as jcore

from .graph import Graph

# primitive name -> op name (1:1 compute primitives)
PRIM2OP: Dict[str, str] = {
    "add": "add", "add_any": "add",
    "sub": "sub",
    "mul": "mul",
    "div": "div",
    "neg": "neg",
    "abs": "abs",
    "sign": "sign",
    "exp": "exp", "exp2": "exp",
    "log": "log", "log1p": "log",
    "tanh": "tanh",
    "logistic": "sigmoid",
    "rsqrt": "rsqrt",
    "sqrt": "sqrt",
    "erf": "erf",
    "pow": "pow",
    "max": "max",
    "min": "min",
    "and": "and",
    "or": "or",
    "xor": "xor",
    "not": "not",
    "eq": "eq",
    "ne": "neq",
    "lt": "lt",
    "le": "lte",
    "gt": "gt",
    "ge": "gte",
    "select_n": "sel",
    "shift_left": "shl",
    "shift_right_logical": "shr",
    "shift_right_arithmetic": "ashr",
    "floor": "floor",
    "round": "round",
    "nextafter": "add",
    "dot_general": "matmul",
    "reduce_sum": "rsum",
    "reduce_max": "rmax",
    "reduce_min": "rmin",
    "reduce_and": "rmax",
    "reduce_or": "rmax",
    "cumsum": "cumsum",
    "cumlogsumexp": "cumsum",
    "argmax": "argmax",
    "argmin": "argmax",
    "sort": "sort",
    "top_k": "top_k",
    "concatenate": "cat",
    "gather": "gather",
    "dynamic_update_slice": "scatter",
    "scatter": "scatter", "scatter-add": "scatter", "scatter_add": "scatter",
    "iota": "iota",
    "clamp": "max",  # clamp(lo, x, hi): comparator-unit op
    "integer_pow": "pow",
    "square": "mul",
    "atan2": "pow",
    "rem": "div",
    "cos": "exp", "sin": "exp",  # transcendental unit (RoPE tables)
    "expm1": "exp",
}

# primitives forwarded to their first operand (no compute)
PASSTHROUGH = {
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "expand_dims",
    "convert_element_type", "stop_gradient", "slice", "dynamic_slice",
    "rev", "copy", "copy_p", "reduce_precision", "real", "device_put",
    "pad", "bitcast_convert_type", "optimization_barrier", "split",
}

# params-carrying call primitives to inline
_CALL_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def from_jaxpr(jaxpr: jcore.Jaxpr, *, graph: Optional[Graph] = None,
               env: Optional[Dict[Any, int]] = None,
               strict: bool = False) -> Graph:
    """Convert an (open) jaxpr into a tensor-level dataflow Graph."""
    g = graph if graph is not None else Graph()
    env = env if env is not None else {}

    def read(atom) -> int:
        if isinstance(atom, jcore.Literal):
            val = np.asarray(atom.val)
            scalar = float(val.reshape(-1)[0]) if val.size else 0.0
            return g.add_node("const", value=scalar)
        return env[atom]

    def write(var, nid: int) -> None:
        env[var] = nid

    for var in jaxpr.invars + jaxpr.constvars:
        if var not in env:
            name = f"in{len([n for n, op in g.nodes.items() if op == 'input'])}"
            write(var, g.add_node("input", name=name))

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name

        if prim in ("scan", "while", "cond"):
            raise NotImplementedError(
                f"trace single-layer functions without {prim!r}; got {prim}")

        # inline nested jaxprs (jit/pjit, remat, custom_jvp/vjp, closed_call)
        sub = None
        for key in _CALL_JAXPR_PARAMS:
            if key in eqn.params:
                sub = eqn.params[key]
                break
        if sub is not None and hasattr(sub, "eqns") or (
                sub is not None and hasattr(sub, "jaxpr")):
            closed = sub
            inner = closed.jaxpr if hasattr(closed, "jaxpr") else closed
            consts = getattr(closed, "consts", [])
            inner_env: Dict[Any, int] = {}
            for iv, atom in zip(inner.invars, eqn.invars):
                inner_env[iv] = read(atom)
            for cv, c in zip(inner.constvars, consts):
                val = np.asarray(c)
                scalar = float(val.reshape(-1)[0]) if val.size else 0.0
                inner_env[cv] = g.add_node("const", value=scalar)
            from_jaxpr(inner, graph=g, env=inner_env, strict=strict)
            for ov, inner_ov in zip(eqn.outvars, inner.outvars):
                write(ov, inner_env[inner_ov]
                      if not isinstance(inner_ov, jcore.Literal)
                      else read(inner_ov))
            continue

        if prim in PASSTHROUGH:
            src = read(eqn.invars[0])
            for ov in eqn.outvars:
                write(ov, src)
            continue

        op = PRIM2OP.get(prim)
        if op is None:
            if strict:
                raise NotImplementedError(f"unmapped primitive {prim!r}")
            op = "opaque"
        nid = g.add_node(op, prim=prim)
        for port, iv in enumerate(eqn.invars):
            g.add_edge(read(iv), nid, port)
        for ov in eqn.outvars:
            write(ov, nid)

    if graph is None:
        for ov in jaxpr.outvars:
            nid = read(ov)
            out = g.add_node("output")
            g.add_edge(nid, out, 0)
            g.mark_output(nid)
    return g


def trace_fn(fn: Callable, *example_args, strict: bool = False) -> Graph:
    """Trace a JAX function on example args into a dataflow Graph."""
    closed = jax.make_jaxpr(fn)(*example_args)
    g = Graph()
    env: Dict[Any, int] = {}
    for cv, c in zip(closed.jaxpr.constvars, closed.consts):
        val = np.asarray(c)
        scalar = float(val.reshape(-1)[0]) if val.size else 0.0
        env[cv] = g.add_node("const", value=scalar)
    for iv in closed.jaxpr.invars:
        name = f"in{len([n for n, op in g.nodes.items() if op == 'input'])}"
        env[iv] = g.add_node("input", name=name)
    from_jaxpr(closed.jaxpr, graph=g, env=env, strict=strict)
    for ov in closed.jaxpr.outvars:
        if isinstance(ov, jcore.Literal):
            continue
        nid = env[ov]
        out = g.add_node("output")
        g.add_edge(nid, out, 0)
        g.mark_output(nid)
    return g
