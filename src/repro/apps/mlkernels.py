"""Machine-learning kernel suite (paper Sec. V-B).

The paper analyzes ResNet-50 and U-Net and specializes PEs for the common
kernels of both: multi-channel convolution (Conv), residual block (Block),
strided convolution (StrC) and down-sample (DS).  As in Sec. V-A, each
function is the per-output-element computation (unrolled MAC chains over a
stencil x input channels) with constant weights.

Channel/taps counts are kept small (the paper mines *patterns*, not full
layers; frequency is what matters and repeats are already present).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from ..graphir.graph import Graph
from ..graphir.symtrace import fclamp, fmax, fshr, trace

# 3x3 window x 2 input channels
CONV_IN = [f"x{ch}_{r}{c}" for ch in range(2) for r in range(3) for c in range(3)]
# deterministic pseudo-weights (constants in the graph)
_RNG = np.random.default_rng(7)
_W = {name: round(float(_RNG.uniform(-2, 2)), 3) for name in CONV_IN}


def _conv_acc(args: List, names: List[str]):
    w = dict(zip(names, args))
    acc = None
    for name in names:
        term = w[name] * _W[name]
        acc = term if acc is None else acc + term
    return acc


def conv_pixel(*p):
    """Multi-channel conv + bias + ReLU (the Conv kernel)."""
    acc = _conv_acc(list(p), CONV_IN)
    acc = acc + 0.5                       # bias
    return fmax(acc, 0.0)                 # ReLU


def residual_block_pixel(*p):
    """Conv + bias + skip-add + ReLU (the Block kernel).

    Inputs: conv window + the skip-path activation ``skip``.
    """
    *win, skip = p
    acc = _conv_acc(list(win), CONV_IN)
    acc = acc + 0.5
    acc = acc + skip
    return fmax(acc, 0.0)


def strided_conv_pixel(*p):
    """Stride-2 conv: same MAC structure, decimated sampling + requant."""
    acc = _conv_acc(list(p), CONV_IN)
    acc = acc + 0.5
    acc = fshr(acc, 1.0)                  # requantize after stride
    return fmax(acc, 0.0)


def downsample_pixel(*p):
    """2x2 average-pool over 2 channels + channel mix (the DS kernel)."""
    x0 = list(p[:4])
    x1 = list(p[4:8])
    a0 = fshr(x0[0] + x0[1] + x0[2] + x0[3], 2.0)
    a1 = fshr(x1[0] + x1[1] + x1[2] + x1[3], 2.0)
    mixed = a0 * 0.7 + a1 * 0.3
    return fmax(mixed, 0.0)


DS_IN = [f"x{ch}_{i}" for ch in range(2) for i in range(4)]

ML_APPS: Dict[str, Dict] = {
    "conv": {"fn": conv_pixel, "inputs": CONV_IN},
    "block": {"fn": residual_block_pixel, "inputs": CONV_IN + ["skip"]},
    "strc": {"fn": strided_conv_pixel, "inputs": CONV_IN},
    "ds": {"fn": downsample_pixel, "inputs": DS_IN},
}


def build_graph(name: str) -> Graph:
    spec = ML_APPS[name]
    return trace(spec["fn"], spec["inputs"])


def run_reference(name: str, inputs: np.ndarray) -> float:
    spec = ML_APPS[name]
    return spec["fn"](*[float(v) for v in inputs])
