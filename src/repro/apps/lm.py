"""LM-architecture idiom graphs: the assigned archs as DSE applications.

Each function traces (via jaxpr) the elementwise/compute structure of one
transformer-layer family at tiny dims; the DSE pipeline mines them exactly
like the paper's image apps.  Matmuls stay macro nodes (they live on the
MXU); the mined patterns are the *elementwise idioms* — RMSNorm cores,
SwiGLU gates, RoPE rotations, softcaps, router chains, SSM updates — i.e.
the chains the generated fused-PE kernels (kernels/pe_fused.py) remove from
HBM on TPU.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from ..graphir.graph import Graph
from ..graphir.trace import trace_fn

_D, _F, _H, _N = 8, 16, 2, 4


def dense_layer(x, wq, wk, wo, wg, wu, wd, ln1, ln2):
    """llama-family: rmsnorm -> qk rope-ish mix -> swiglu."""
    h = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6) * ln1
    q = h @ wq
    k = h @ wk
    mix = jnp.tanh(q * 0.5) * k          # stand-in for the attention mix
    x = x + mix @ wo
    h2 = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6) * ln2
    return x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd


def gemma_layer(x, wq, wk, wo, wg, wu, wd, ln1, ln2):
    """gemma-family: softcap + geglu."""
    h = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6) * ln1
    s = (h @ wq) * (h @ wk).sum(-1, keepdims=True)
    s = 50.0 * jnp.tanh(s / 50.0)        # attn logit softcap
    x = x + (s * h) @ wo
    h2 = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6) * ln2
    return x + (jax.nn.gelu(h2 @ wg, approximate=True) * (h2 @ wu)) @ wd


def moe_router(x, wr):
    """qwen-family router: softmax -> top-k -> renormalize."""
    logits = x @ wr
    p = jax.nn.softmax(logits, axis=-1)
    v, i = jax.lax.top_k(p, 2)
    return v / (v.sum(-1, keepdims=True) + 1e-9)


def ssm_update(dt, a, b, x, h, c):
    """mamba-family state update: the per-step chain the Pallas kernel fuses."""
    da = jnp.exp(jax.nn.softplus(dt)[..., None] * a)
    h2 = da * h + (dt * x)[..., None] * b[..., None, :]
    return (h2 * c[..., None, :]).sum(-1) * jax.nn.silu(x)


def lm_idiom_graphs() -> Dict[str, Graph]:
    key = jax.random.PRNGKey(0)
    w = lambda *s: jnp.ones(s, jnp.float32)
    return {
        "lm_dense": trace_fn(dense_layer, w(2, _D), w(_D, _D), w(_D, _D),
                             w(_D, _D), w(_D, _F), w(_D, _F), w(_F, _D),
                             w(_D), w(_D)),
        "lm_gemma": trace_fn(gemma_layer, w(2, _D), w(_D, _D), w(_D, _D),
                             w(_D, _D), w(_D, _F), w(_D, _F), w(_F, _D),
                             w(_D), w(_D)),
        "lm_router": trace_fn(moe_router, w(2, _D), w(_D, 8)),
        "lm_ssm": trace_fn(ssm_update, w(2, _D), w(_D, _N), w(2, _N),
                           w(2, _D), w(2, _D, _N), w(2, _N)),
    }
