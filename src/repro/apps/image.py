"""Image-processing application suite (paper Sec. V-A).

The paper specializes PEs across four Halide apps: Harris corner detection,
Gaussian blur, camera pipeline, and Laplacian pyramid.  Each function below
describes the per-output-pixel computation over a stencil window of named
scalar inputs — exactly the shape of graph the Halide->CoreIR flow produces
(unrolled convolutions, Fig. 3).  The same code executes on numpy scalars
(oracle) and on the symbolic tracer (graph building).

Kernel weights are constants (Fig. 2c: constant registers), written as
Python literals so the tracer lowers them to ``const`` nodes.
"""

from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from ..graphir.graph import Graph
from ..graphir.symtrace import (Tracer, fclamp, fmax, fmin, fsel, fshl, fshr,
                                trace)

# 3x3 window input names, row-major
W33 = [f"p{r}{c}" for r in range(3) for c in range(3)]
W55 = [f"p{r}{c}" for r in range(5) for c in range(5)]


def _w(args: List, names: List[str]) -> Dict[str, object]:
    return dict(zip(names, args))


# ---------------------------------------------------------------------------
# Gaussian blur — 3x3 binomial kernel [1 2 1; 2 4 2; 1 2 1] / 16.
# Fixed-point friendly: weights realized with shifts and adds.
# ---------------------------------------------------------------------------
def gaussian_blur_pixel(*p):
    w = _w(list(p), W33)
    acc = w["p00"] * 1.0
    acc = acc + w["p01"] * 2.0
    acc = acc + w["p02"] * 1.0
    acc = acc + w["p10"] * 2.0
    acc = acc + w["p11"] * 4.0
    acc = acc + w["p12"] * 2.0
    acc = acc + w["p20"] * 1.0
    acc = acc + w["p21"] * 2.0
    acc = acc + w["p22"] * 1.0
    return fshr(acc, 4.0)          # / 16


# ---------------------------------------------------------------------------
# Harris corner detection: Sobel gradients, structure tensor, response.
# ---------------------------------------------------------------------------
def harris_pixel(*p):
    w = _w(list(p), W33)
    gx = (w["p02"] + w["p12"] * 2.0 + w["p22"]) \
        - (w["p00"] + w["p10"] * 2.0 + w["p20"])
    gy = (w["p20"] + w["p21"] * 2.0 + w["p22"]) \
        - (w["p00"] + w["p01"] * 2.0 + w["p02"])
    gxx = gx * gx
    gyy = gy * gy
    gxy = gx * gy
    det = gxx * gyy - gxy * gxy
    tr = gxx + gyy
    resp = det - (tr * tr) * 0.04          # k = 0.04
    thresh = resp > 1000.0
    return fsel(thresh, 0.0, resp)


# ---------------------------------------------------------------------------
# Camera pipeline: denoise -> demosaic (bilinear) -> white balance ->
# color-correction matrix -> luma sharpen -> tone curve, per output RGB
# pixel.  This is the most complex app (paper: 221 ops per output pixel;
# this unrolled graph is the same order of magnitude).
# ---------------------------------------------------------------------------
def camera_pipeline_pixel(*p):
    w = _w(list(p), W55)

    def raw(r, c):
        return w[f"p{r}{c}"]

    # --- denoise: 3x3 thresholded smoothing on the raw mosaic ------------
    # (same-color neighbors are 2 apart on a Bayer mosaic)
    def at(r, c):
        if not (1 <= r <= 3 and 1 <= c <= 3):
            return raw(r, c)
        center = raw(r, c)
        acc = center * 4.0
        for dr, dc in ((-1, -1), (-1, 1), (1, -1), (1, 1)):
            n = raw(r + dr, c + dc)
            d = n - center
            # reject outliers: keep neighbor only if |d| small
            keep = abs(d) < 64.0
            acc = acc + fsel(keep, center, n)
        return fshr(acc, 3.0)

    # --- demosaic around center (2,2), GRBG pattern assumed -------------
    # green at center
    g_c = at(2, 2)
    # red: average of horizontal neighbors; blue: vertical
    r_c = fshr(at(2, 1) + at(2, 3), 1.0)
    b_c = fshr(at(1, 2) + at(3, 2), 1.0)
    # refine green with laplacian correction
    g_h = fshr(at(2, 0) + at(2, 4), 1.0)
    g_v = fshr(at(0, 2) + at(4, 2), 1.0)
    lap = g_c * 2.0 - fshr(g_h + g_v, 1.0)
    g_ref = g_c + fshr(lap, 2.0)

    # neighbor demosaics for a 3-tap cross sharpen on luma ----------------
    def demosaic_at(r, c):
        g = at(r, c)
        rr = fshr(at(r, c - 1) + at(r, c + 1), 1.0)
        bb = fshr(at(r - 1, c) + at(r + 1, c), 1.0)
        return rr, g, bb

    r_l, g_l, b_l = demosaic_at(2, 1)
    r_r, g_r, b_r = demosaic_at(2, 3)
    r_u, g_u, b_u = demosaic_at(1, 2)
    r_d, g_d, b_d = demosaic_at(3, 2)

    # --- white balance ----------------------------------------------------
    r_wb = r_c * 1.4
    g_wb = g_ref * 1.0
    b_wb = b_c * 1.6

    # --- color correction matrix (3x3) --------------------------------------
    r_cc = r_wb * 1.66 + g_wb * -0.44 + b_wb * -0.22
    g_cc = r_wb * -0.36 + g_wb * 1.42 + b_wb * -0.06
    b_cc = r_wb * -0.12 + g_wb * -0.52 + b_wb * 1.64

    # --- luma sharpen using neighbor demosaics -----------------------------
    def luma(r, g, b):
        return fshr(r + g * 2.0 + b, 2.0)

    l_c = luma(r_cc, g_cc, b_cc)
    l_n = fshr(luma(r_l, g_l, b_l) + luma(r_r, g_r, b_r)
               + luma(r_u, g_u, b_u) + luma(r_d, g_d, b_d), 2.0)
    sharp = l_c * 2.0 - fshr(l_n, 1.0)
    gain = sharp - l_c
    r_sh = r_cc + fshr(gain, 1.0)
    g_sh = g_cc + fshr(gain, 1.0)
    b_sh = b_cc + fshr(gain, 1.0)

    # --- two-segment tone curve (gamma approx), clamp to range --------------
    def tone(x):
        lo = x * 2.0                      # boost shadows
        hi = x * 0.5 + 384.0              # compress highlights
        y = fsel(x > 256.0, lo, hi)
        return fclamp(y, 0.0, 1023.0)

    return tone(r_sh), tone(g_sh), tone(b_sh)


# ---------------------------------------------------------------------------
# Laplacian pyramid: one level — band = center - upsampled(blur(decimate)).
# Per-pixel: gaussian blur at coarse level + bilinear upsample + subtract,
# followed by a remap curve (local contrast).
# ---------------------------------------------------------------------------
def laplacian_pyramid_pixel(*p):
    w = _w(list(p), W55)

    def at(r, c):
        return w[f"p{r}{c}"]

    # coarse = blur(5x5 center region) (decimated grid sample)
    def blur3(r, c):
        acc = at(r - 1, c - 1) + at(r - 1, c + 1) \
            + at(r + 1, c - 1) + at(r + 1, c + 1)
        acc = acc + (at(r - 1, c) + at(r + 1, c)
                     + at(r, c - 1) + at(r, c + 1)) * 2.0
        acc = acc + at(r, c) * 4.0
        return fshr(acc, 4.0)

    c00 = blur3(1, 1)
    c01 = blur3(1, 3)
    c10 = blur3(3, 1)
    c11 = blur3(3, 3)
    up = fshr(c00 + c01 + c10 + c11, 2.0)    # bilinear upsample at center
    band = at(2, 2) - up
    # remap: alpha * band with soft knee
    mag = abs(band)
    knee = fsel(mag > 64.0, band * 2.0, band * 0.5)
    out = up + knee
    return fclamp(out, 0.0, 1023.0)


APPS: Dict[str, Dict] = {
    "gaussian": {"fn": gaussian_blur_pixel, "inputs": W33, "window": 3},
    "harris": {"fn": harris_pixel, "inputs": W33, "window": 3},
    "camera": {"fn": camera_pipeline_pixel, "inputs": W55, "window": 5},
    "laplacian": {"fn": laplacian_pyramid_pixel, "inputs": W55, "window": 5},
}


def build_graph(name: str) -> Graph:
    spec = APPS[name]
    return trace(spec["fn"], spec["inputs"])


def run_reference(name: str, image: np.ndarray) -> np.ndarray:
    """Run the scalar oracle over an image (valid region only)."""
    spec = APPS[name]
    k = spec["window"]
    h, w = image.shape
    outs = []
    for r in range(h - k + 1):
        row = []
        for c in range(w - k + 1):
            window = [float(image[r + dr, c + dc])
                      for dr in range(k) for dc in range(k)]
            v = spec["fn"](*window)
            row.append(v[0] if isinstance(v, tuple) else v)
        outs.append(row)
    return np.array(outs)
