"""Application suites: the paper's image/ML domains + LM idioms."""

from . import image, mlkernels
from .image import APPS as IMAGE_APPS
from .mlkernels import ML_APPS


def image_graphs():
    return {name: image.build_graph(name) for name in IMAGE_APPS}


def ml_graphs():
    return {name: mlkernels.build_graph(name) for name in ML_APPS}
