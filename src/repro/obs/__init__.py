"""Observability for the exploration pipeline: traces, metrics, reports.

Zero-dependency and off by default — instrumented code paths cost ~one
dict lookup when nothing is enabled, and enabling them never changes a
computed bit (CI-tested).  Three cooperating pieces:

* :mod:`repro.obs.trace` — nested span tree, Chrome trace-event /
  flat-jsonl export (``span("pnr", variant=..., app=...)``);
* :mod:`repro.obs.metrics` — process-local counters/gauges/histograms;
  ``Explorer.stats`` is a :class:`~repro.obs.metrics.CounterView` over
  an explorer-owned registry;
* :mod:`repro.obs.jaxprof` — forwards ``jax.monitoring`` compile events
  into both, so a timeline separates compile from dispatch time.

Post-pnr utilization / operand-skew reports live in
:mod:`repro.obs.analyzer`; ``python -m repro.obs.report`` summarizes
exported artifacts.

The performance *trajectory* is first-class on top of these
(:mod:`repro.obs.manifest` / :mod:`repro.obs.diff` /
:mod:`repro.obs.history` / ``python -m repro.obs.regress``): every
artifact embeds a run manifest, benchmarks record median+IQR over
repeats instead of lone samples, two artifacts diff with noise-aware
thresholds (exact series: zero tolerance), and per-commit history rows
under ``results/history/`` back a CI-wired regression detector.
:mod:`repro.obs.memprof` adds per-stage host-peak / device-byte gauges
when telemetry is on.  Typical session::

    from repro import obs
    tracer = obs.enable_tracing()
    obs.jaxprof.enable()
    ...                       # run the pipeline
    tracer.write_chrome("out.trace.json")     # load in Perfetto
"""

from . import jaxprof
from .analyzer import OperandSkew, PnrReport, analyze_pnr

# process-wide switch for heavier instrumentation (anneal acceptance/cost
# curves need a differently-compiled kernel; results stay bit-identical,
# but the extra outputs are only materialized when this is on)
_TELEMETRY = False


def enable_telemetry(on: bool = True) -> None:
    global _TELEMETRY
    _TELEMETRY = bool(on)


def telemetry_enabled() -> bool:
    return _TELEMETRY


from .metrics import (CounterView, Histogram, MetricsRegistry,
                      global_registry, reset_global_registry)
from .trace import (Span, Tracer, current as current_tracer,
                    disable as disable_tracing, enable as enable_tracing,
                    event, span)
from .manifest import RunManifest, capture as capture_manifest
from .diff import (NoiseModel, StageDelta, diff_metrics, diff_traces,
                   summarize_repeats)
from . import diff, history, manifest, memprof

__all__ = [
    "span", "event", "enable_tracing", "disable_tracing", "current_tracer",
    "Span", "Tracer",
    "MetricsRegistry", "CounterView", "Histogram", "global_registry",
    "reset_global_registry",
    "jaxprof", "enable_telemetry", "telemetry_enabled",
    "analyze_pnr", "PnrReport", "OperandSkew",
    "RunManifest", "capture_manifest",
    "NoiseModel", "StageDelta", "diff_metrics", "diff_traces",
    "summarize_repeats",
    "diff", "history", "manifest", "memprof",
]
