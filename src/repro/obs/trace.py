"""Nested span tracing with Chrome trace-event / flat-jsonl export.

Zero-dependency, off by default.  Call :func:`enable` to install a
process-global :class:`Tracer`; instrumented code wraps work in

    with span("pnr", variant="PE_3x3", app="conv4"):
        ...

When tracing is disabled, :func:`span` returns a shared no-op context
manager singleton — no allocation, no clock reads — so instrumentation
left in hot paths costs ~nothing.  When enabled, spans collect into a
tree (exception-safe: a raising body still closes its span and records
the error) and export as

* Chrome trace-event JSON (``{"traceEvents": [...]}``) loadable in
  Perfetto / ``chrome://tracing``; nesting is encoded by time
  containment on a single track, with extra tracks (``tid``) for
  out-of-band events such as XLA compiles (see :mod:`repro.obs.jaxprof`);
* flat jsonl — one object per span with its slash-joined ``path``,
  depth, start, duration, and attrs (consumed by
  ``results/make_tables.py stages`` and ``python -m repro.obs.report``).

The tracer is single-process, single-thread by design (the pipeline is);
timestamps come from ``time.perf_counter`` relative to tracer creation.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["Span", "Tracer", "span", "event", "enable", "disable",
           "current"]


class Span:
    """One timed region; ``children`` makes the tree."""

    __slots__ = ("name", "t0", "t1", "attrs", "children", "error")

    def __init__(self, name: str, t0: float,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.t0 = t0
        self.t1 = t0
        self.attrs: Dict[str, Any] = attrs or {}
        self.children: List[Span] = []
        self.error: str = ""

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, dur={self.dur:.6f}, "
                f"children={len(self.children)})")


class _SpanCtx:
    """Context manager that opens/closes one span on the tracer stack."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", sp: Span):
        self._tracer = tracer
        self._span = sp

    def __enter__(self) -> Span:
        self._tracer._push(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._span.error = f"{exc_type.__name__}: {exc}"
        self._tracer._pop(self._span)
        return False            # never suppress


class _NullCtx:
    """Shared do-nothing context manager used while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_CTX = _NullCtx()


class Tracer:
    """Collects a forest of spans; exports Chrome JSON and flat jsonl."""

    def __init__(self):
        self._origin = time.perf_counter()
        self.roots: List[Span] = []
        self._stack: List[Span] = []
        # out-of-band complete events (e.g. XLA compiles): extra tracks
        self._tracks: Dict[str, List[Span]] = {}

    # -- recording ---------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._origin

    def span(self, name: str, **attrs: Any) -> _SpanCtx:
        return _SpanCtx(self, Span(name, self.now(), attrs or None))

    def event(self, name: str, **attrs: Any) -> Span:
        """Zero-duration marker attached at the current tree position."""
        sp = Span(name, self.now(), attrs or None)
        (self._stack[-1].children if self._stack else self.roots).append(sp)
        return sp

    def add_complete(self, name: str, t0: float, dur: float,
                     track: str = "main", **attrs: Any) -> Span:
        """Record an already-finished region on a named side track."""
        sp = Span(name, t0, attrs or None)
        sp.t1 = t0 + dur
        self._tracks.setdefault(track, []).append(sp)
        return sp

    def _push(self, sp: Span) -> None:
        sp.t0 = sp.t1 = self.now()
        (self._stack[-1].children if self._stack else self.roots).append(sp)
        self._stack.append(sp)

    def _pop(self, sp: Span) -> None:
        sp.t1 = self.now()
        # exception-safe even if an inner span leaked: unwind to `sp`
        while self._stack:
            top = self._stack.pop()
            if top is sp:
                break
            top.t1 = sp.t1

    # -- queries -----------------------------------------------------------
    def iter_spans(self) -> Iterator[tuple]:
        """Yield ``(span, depth, path)`` depth-first over the main tree."""

        def walk(sp: Span, depth: int, prefix: str):
            path = f"{prefix}/{sp.name}" if prefix else sp.name
            yield sp, depth, path
            for ch in sp.children:
                yield from walk(ch, depth + 1, path)

        for root in self.roots:
            yield from walk(root, 0, "")

    def span_names(self) -> set:
        names = {sp.name for sp, _, _ in self.iter_spans()}
        for track in self._tracks.values():
            names.update(sp.name for sp in track)
        return names

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (``ph: "X"`` complete events)."""
        events: List[Dict[str, Any]] = [
            {"ph": "M", "pid": 1, "tid": 1, "name": "thread_name",
             "args": {"name": "pipeline"}}]

        def emit(sp: Span, tid: int) -> None:
            args = dict(sp.attrs)
            if sp.error:
                args["error"] = sp.error
            events.append({
                "ph": "X", "name": sp.name, "cat": "repro",
                "ts": round(sp.t0 * 1e6, 3),
                "dur": round(max(sp.dur, 0.0) * 1e6, 3),
                "pid": 1, "tid": tid, "args": args})

        for sp, _, _ in self.iter_spans():
            emit(sp, 1)
        for i, (track, spans) in enumerate(sorted(self._tracks.items())):
            tid = 2 + i
            events.append({"ph": "M", "pid": 1, "tid": tid,
                           "name": "thread_name", "args": {"name": track}})
            for sp in spans:
                emit(sp, tid)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        doc = self.to_chrome()
        # every exported trace records what environment produced it
        from .manifest import capture
        doc["metadata"] = {"manifest": capture().to_dict()}
        with open(path, "w") as fh:
            json.dump(doc, fh)

    def to_rows(self) -> List[Dict[str, Any]]:
        """Flat rows for jsonl export (main tree + side tracks)."""
        rows = [{"name": sp.name, "path": path, "depth": depth,
                 "t0_s": round(sp.t0, 9), "dur_s": round(sp.dur, 9),
                 "error": sp.error, "attrs": sp.attrs}
                for sp, depth, path in self.iter_spans()]
        for track, spans in sorted(self._tracks.items()):
            rows.extend({"name": sp.name, "path": f"{track}/{sp.name}",
                         "depth": 1, "t0_s": round(sp.t0, 9),
                         "dur_s": round(sp.dur, 9), "error": sp.error,
                         "attrs": sp.attrs, "track": track}
                        for sp in spans)
        return rows

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as fh:
            for row in self.to_rows():
                fh.write(json.dumps(row) + "\n")


# ---------------------------------------------------------------------------
# process-global switch
# ---------------------------------------------------------------------------
_TRACER: Optional[Tracer] = None


def enable() -> Tracer:
    """Install (or return) the process-global tracer."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer()
    return _TRACER


def disable() -> Optional[Tracer]:
    """Stop tracing; returns the tracer so callers can still export it."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def current() -> Optional[Tracer]:
    return _TRACER


def span(name: str, **attrs: Any):
    """Open a span on the global tracer, or a shared no-op when off."""
    t = _TRACER
    if t is None:
        return _NULL_CTX
    return t.span(name, **attrs)


def event(name: str, **attrs: Any) -> Optional[Span]:
    """Zero-duration marker on the global tracer (no-op when off)."""
    t = _TRACER
    if t is None:
        return None
    return t.event(name, **attrs)
