"""Memory observability: per-stage host peaks + live JAX device bytes.

Wall-clock is only half of a stage's cost on a shared runner; the other
half is footprint.  :func:`stage_memory` wraps a pipeline stage and —
only while :func:`repro.obs.enable_telemetry` is on, because tracemalloc
is far too expensive to leave armed — records two registry gauges:

* ``mem.host_peak_bytes.<stage>`` — peak traced host allocation inside
  the stage (``tracemalloc``; the peak counter is reset at stage entry,
  so nested stages report their own region);
* ``mem.device_bytes.<stage>`` — live JAX device-buffer bytes at stage
  exit (the sum of ``jax.live_arrays()`` sizes), i.e. what the stage
  left resident.

Both flow into the benchmarks' ``metrics`` blocks (``host_peak_bytes`` /
``device_bytes`` in ``results/check_bench.py``'s METRIC_KEYS) and from
there into the ``results/history/`` trajectory.  Observation only — no
computed bit depends on any of it.
"""

from __future__ import annotations

import tracemalloc
from contextlib import contextmanager
from typing import Optional

from .metrics import MetricsRegistry

__all__ = ["stage_memory", "device_bytes", "host_peak_gauges"]


def device_bytes() -> int:
    """Total bytes of live JAX arrays on device (0 if unmeasurable)."""
    try:
        import jax
        return int(sum(getattr(a, "nbytes", 0) for a in jax.live_arrays()))
    except Exception:
        return 0


@contextmanager
def stage_memory(registry: Optional[MetricsRegistry], stage: str):
    """Record host-peak / device-byte gauges for one stage.

    A no-op (one function call, no clock or allocator work) unless
    telemetry is enabled and a registry is given.
    """
    from . import telemetry_enabled
    if registry is None or not telemetry_enabled():
        yield
        return
    started_here = not tracemalloc.is_tracing()
    if started_here:
        tracemalloc.start()
    else:
        tracemalloc.reset_peak()
    try:
        yield
    finally:
        _, peak = tracemalloc.get_traced_memory()
        registry.set_gauge(f"mem.host_peak_bytes.{stage}", int(peak))
        registry.set_gauge(f"mem.device_bytes.{stage}", device_bytes())
        if started_here:
            tracemalloc.stop()


def host_peak_gauges(registry: MetricsRegistry) -> dict:
    """{stage: peak bytes} for every recorded host-peak gauge."""
    prefix = "mem.host_peak_bytes."
    doc = registry.to_dict()["gauges"]
    return {k[len(prefix):]: v for k, v in doc.items()
            if k.startswith(prefix)}
