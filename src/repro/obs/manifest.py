"""Run manifests: what environment produced a performance number.

A single wall-clock is meaningless without provenance — the ROADMAP's
fabric-DSE sweeps and the future TPU column can only be compared against
numbers whose producing environment is on record.  :func:`capture`
collects that record once per process (git SHA, python/jax/jaxlib
versions, platform + device kind, CPU count, XLA-compilation-cache
cold/warm state) and every performance artifact embeds it:

* ``results/BENCH_*.json`` carry a top-level ``manifest`` block
  (validated by ``results/check_bench.py`` — a BENCH file without one
  fails the gate);
* Chrome traces written by :meth:`repro.obs.trace.Tracer.write_chrome`
  carry it under ``metadata.manifest``;
* ``ExploreRecord`` jsonl files start with a manifest header line
  (skipped transparently by ``repro.explore.from_jsonl``).

Capture is deterministic modulo the environment fields themselves: two
captures in one process (or on one machine at one commit) are equal,
except ``xla_cache`` which reflects the cache directory's state at call
time — pass ``refresh=True`` to re-inspect.
"""

from __future__ import annotations

import dataclasses
import os
import platform
import subprocess
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

__all__ = ["MANIFEST_SCHEMA", "RunManifest", "capture", "validate_manifest"]

#: bump on any field add/rename/retype; validators reject other versions
MANIFEST_SCHEMA = 1

#: legal xla_cache states: "off" (no cache dir configured), "cold" (dir
#: configured but absent/empty at capture time), "warm" (dir has entries)
XLA_CACHE_STATES = ("off", "cold", "warm")


@dataclass(frozen=True)
class RunManifest:
    """The environment fingerprint embedded in every perf artifact."""

    schema: int
    git_sha: str          # full SHA, or "unknown" outside a checkout
    python: str           # e.g. "3.10.13"
    jax: str              # jax.__version__, or "unavailable"
    jaxlib: str
    platform: str         # platform.platform()
    device_kind: str      # jax.devices()[0].device_kind, e.g. "cpu"/"TPU v4"
    backend: str          # jax.default_backend()
    cpu_count: int
    xla_cache: str        # "off" | "cold" | "warm"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "RunManifest":
        errors = validate_manifest(d)
        if errors:
            raise ValueError(f"invalid manifest: {'; '.join(errors)}")
        return RunManifest(**d)


def _git_sha() -> str:
    """Full commit SHA: CI env var first, then the checkout, else unknown."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


def _jax_fields() -> Dict[str, str]:
    try:
        import jax
        import jaxlib
        dev = jax.devices()[0]
        return {"jax": jax.__version__, "jaxlib": jaxlib.__version__,
                "device_kind": getattr(dev, "device_kind", str(dev)),
                "backend": jax.default_backend()}
    except Exception:           # pragma: no cover - jax is baked in
        return {"jax": "unavailable", "jaxlib": "unavailable",
                "device_kind": "unavailable", "backend": "unavailable"}


def _xla_cache_state() -> str:
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if not cache_dir:
        return "off"
    try:
        return "warm" if os.listdir(cache_dir) else "cold"
    except OSError:
        return "cold"


_CACHED: Optional[RunManifest] = None


def capture(refresh: bool = False) -> RunManifest:
    """The process's run manifest (captured once, then cached).

    ``refresh=True`` re-inspects the mutable fields (the XLA cache state
    can flip cold -> warm mid-process); everything else is stable for the
    life of the process by construction.
    """
    global _CACHED
    if _CACHED is None or refresh:
        _CACHED = RunManifest(
            schema=MANIFEST_SCHEMA,
            git_sha=_git_sha(),
            python=platform.python_version(),
            platform=platform.platform(),
            cpu_count=os.cpu_count() or 1,
            xla_cache=_xla_cache_state(),
            **_jax_fields())
    return _CACHED


def validate_manifest(d: Any) -> List[str]:
    """Structural validation shared by regress/history; mirrors the
    stdlib-only copy in ``results/check_bench.py`` (kept separate so the
    gate never needs ``repro`` importable)."""
    errors: List[str] = []
    if not isinstance(d, dict):
        return [f"manifest is {type(d).__name__}, expected a dict"]
    fields = {f.name for f in dataclasses.fields(RunManifest)}
    for name in sorted(fields - set(d)):
        errors.append(f"manifest missing field {name!r}")
    for name in sorted(set(d) - fields):
        errors.append(f"manifest has unknown field {name!r}")
    if d.get("schema") != MANIFEST_SCHEMA:
        errors.append(f"manifest schema {d.get('schema')!r}, expected "
                      f"{MANIFEST_SCHEMA}")
    if "cpu_count" in d and (not isinstance(d["cpu_count"], int)
                             or d["cpu_count"] < 1):
        errors.append(f"manifest cpu_count={d['cpu_count']!r}, expected a "
                      f"positive int")
    if "xla_cache" in d and d["xla_cache"] not in XLA_CACHE_STATES:
        errors.append(f"manifest xla_cache={d['xla_cache']!r}, expected one "
                      f"of {XLA_CACHE_STATES}")
    for name in fields - {"schema", "cpu_count"}:
        if name in d and not isinstance(d[name], str):
            errors.append(f"manifest {name}={d[name]!r}, expected a string")
    return errors
