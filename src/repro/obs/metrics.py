"""Process-local metrics: counters, gauges, and pow2-bucket histograms.

Zero-dependency.  A :class:`MetricsRegistry` is a plain dict-backed
store; counters are always-on (a dict increment is the whole cost), so
pipeline accounting — memo hit/miss per stage, batched dispatch counts,
scheduler rounds/backtracks — always flows through a registry instead of
ad-hoc ``collections.Counter`` plumbing.

Each :class:`repro.explore.pipeline.Explorer` owns a registry (shared
across ``with_config`` clones, like the memo store); code outside an
explorer — a bare ``modulo_schedule`` call, the jaxprof compile hooks —
falls back to the process-global registry from :func:`global_registry`.

:meth:`MetricsRegistry.view` returns a ``Counter``-compatible mutable
mapping over a key prefix, which is what ``Explorer.stats`` now is: the
legacy ``stats["pnr_dispatch"] += 1`` call sites keep working, but the
numbers live in (and are reported from) the registry.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterator, MutableMapping

__all__ = ["Histogram", "MetricsRegistry", "CounterView",
           "global_registry", "reset_global_registry"]


class Histogram:
    """Scalar distribution: count/sum/min/max + power-of-two buckets."""

    __slots__ = ("count", "total", "vmin", "vmax", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.buckets: Dict[int, int] = {}   # bucket upper bound -> count

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        # bucket = smallest power of two >= |v| (0 gets its own bucket)
        mag = abs(v)
        ub = 0
        if mag > 0:
            ub = 1
            while ub < mag:
                ub *= 2
        self.buckets[ub] = self.buckets.get(ub, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else None,
                "max": self.vmax if self.count else None,
                "mean": self.mean,
                "buckets": {str(k): v
                            for k, v in sorted(self.buckets.items())}}


class MetricsRegistry:
    """Counters + gauges + histograms under dotted string names."""

    def __init__(self):
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, Any] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- counters ----------------------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + n

    def counter(self, name: str) -> int:
        return self._counters.get(name, 0)

    def counters(self, prefix: str = "") -> Dict[str, int]:
        return {k: v for k, v in self._counters.items()
                if k.startswith(prefix)}

    # -- gauges ------------------------------------------------------------
    def set_gauge(self, name: str, value: Any) -> None:
        """Last-write-wins; value may be any JSON-serializable object
        (cost-curve snapshots are stored as lists of floats)."""
        self._gauges[name] = value

    def gauge(self, name: str, default: Any = None) -> Any:
        return self._gauges.get(name, default)

    # -- histograms --------------------------------------------------------
    def observe(self, name: str, value: float) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        h.observe(value)

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    # -- views / export ----------------------------------------------------
    def view(self, prefix: str = "") -> "CounterView":
        return CounterView(self, prefix)

    def to_dict(self) -> Dict[str, Any]:
        return {"counters": dict(sorted(self._counters.items())),
                "gauges": dict(sorted(self._gauges.items())),
                "histograms": {k: h.to_dict()
                               for k, h in sorted(self._hists.items())}}

    def write_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=True)

    def merge_from(self, other: "MetricsRegistry") -> None:
        """Fold another registry's contents into this one."""
        for k, v in other._counters.items():
            self.inc(k, v)
        self._gauges.update(other._gauges)
        for k, h in other._hists.items():
            mine = self.histogram(k)
            mine.count += h.count
            mine.total += h.total
            mine.vmin = min(mine.vmin, h.vmin)
            mine.vmax = max(mine.vmax, h.vmax)
            for ub, c in h.buckets.items():
                mine.buckets[ub] = mine.buckets.get(ub, 0) + c


class CounterView(MutableMapping):
    """``collections.Counter``-compatible window onto registry counters.

    ``view[k]`` reads ``prefix + k`` (missing keys read 0, like Counter);
    ``view[k] += n`` writes through.  ``dict(view)`` / iteration cover
    every registry counter under the prefix.
    """

    __slots__ = ("registry", "_prefix")

    def __init__(self, registry: MetricsRegistry, prefix: str = ""):
        self.registry = registry
        self._prefix = prefix

    def __getitem__(self, key: str) -> int:
        return self.registry.counter(self._prefix + key)

    def __setitem__(self, key: str, value: int) -> None:
        self.registry._counters[self._prefix + key] = int(value)

    def __delitem__(self, key: str) -> None:
        del self.registry._counters[self._prefix + key]

    def __iter__(self) -> Iterator[str]:
        p = self._prefix
        return (k[len(p):] for k in list(self.registry._counters)
                if k.startswith(p))

    def __len__(self) -> int:
        return sum(1 for _ in iter(self))

    def __contains__(self, key: object) -> bool:
        return self._prefix + str(key) in self.registry._counters

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"CounterView({dict(self)!r})"


# ---------------------------------------------------------------------------
# process-global fallback registry
# ---------------------------------------------------------------------------
_GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    return _GLOBAL


def reset_global_registry() -> MetricsRegistry:
    global _GLOBAL
    _GLOBAL = MetricsRegistry()
    return _GLOBAL
