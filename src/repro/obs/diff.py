"""Diff two performance artifacts with noise-aware thresholds.

``python -m repro.obs.diff a.trace.json b.trace.json`` (or two metrics
registry dumps) aligns the artifacts and reports per-stage deltas.  The
load-bearing rule, shared with :mod:`repro.obs.regress`:

* **exact-valued series** — counters (dispatch counts, memo hits,
  scheduler rounds), gauges, flags — diff with **zero tolerance**: any
  change is significant, because these numbers are deterministic and a
  drift means behavior changed;
* **wall-clocks** — span durations, histogram sums of seconds — diff
  with **noise-aware thresholds**: a delta is significant only when it
  clears ``max(abs_floor, rel_floor * base, iqr_k * IQR)``, where the
  IQR comes from repeated measurement (:func:`summarize_repeats` — the
  benchmarks' ``--repeats N`` blocks) when available.

Traces align by slash-joined span *path* (aggregated: total seconds and
count per path), so a renamed or newly nested stage shows up as one
removed and one added row instead of silently matching by position.
"""

from __future__ import annotations

import argparse
import json
import statistics
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["NoiseModel", "StageDelta", "summarize_repeats",
           "diff_stage_rows", "diff_traces", "diff_metrics",
           "render_deltas", "main"]


def summarize_repeats(samples: Sequence[float]) -> Dict[str, Any]:
    """Median + IQR summary of repeated measurements.

    This is the shape the benchmarks' ``repeats`` blocks carry: artifacts
    record a distribution, never a lone sample, so downstream comparisons
    know how noisy the number is.
    """
    xs = sorted(float(s) for s in samples)
    if not xs:
        raise ValueError("summarize_repeats needs at least one sample")
    med = statistics.median(xs)
    if len(xs) >= 2:
        q = statistics.quantiles(xs, n=4, method="inclusive")
        iqr = q[2] - q[0]
    else:
        iqr = 0.0
    return {"n": len(xs), "median": med, "iqr": iqr,
            "min": xs[0], "max": xs[-1]}


@dataclass(frozen=True)
class NoiseModel:
    """Significance thresholds for wall-clock deltas.

    ``threshold(base, iqr)`` is the smallest absolute delta considered
    real: an absolute floor (timer/runner jitter), a relative floor
    (shared-runner variance scales with the measurement), and an IQR
    multiple when repeated measurement supplied one.
    """

    abs_floor_s: float = 0.005
    rel_floor: float = 0.10
    iqr_k: float = 3.0

    def threshold(self, base: float, iqr: float = 0.0) -> float:
        return max(self.abs_floor_s, self.rel_floor * abs(base),
                   self.iqr_k * iqr)


@dataclass
class StageDelta:
    """One aligned row of a diff: a -> b for a path/metric."""

    path: str
    kind: str                 # "time" | "exact"
    a: Optional[float]        # None: only present in b
    b: Optional[float]        # None: only present in a
    delta: float = 0.0
    rel: float = 0.0          # delta / a (0 when a is 0/None)
    significant: bool = False
    noise_s: float = 0.0      # the threshold the delta was held against
    detail: str = ""

    def row(self) -> str:
        mark = "!" if self.significant else " "
        a = "-" if self.a is None else f"{self.a:.6g}"
        b = "-" if self.b is None else f"{self.b:.6g}"
        return (f"{mark} {self.kind:<5} {self.path:<40} {a:>12} {b:>12} "
                f"{self.delta:>+12.6g} {100 * self.rel:>+8.1f}% "
                f"{self.detail}")


def _mk_delta(path: str, kind: str, a: Optional[float], b: Optional[float],
              noise: float = 0.0, detail: str = "") -> StageDelta:
    if a is None or b is None:
        # appearing/disappearing series are always significant
        return StageDelta(path, kind, a, b, significant=True,
                          noise_s=noise, detail=detail or "added/removed")
    delta = b - a
    rel = delta / a if a else 0.0
    if kind == "exact":
        sig = delta != 0
    else:
        sig = abs(delta) > noise
    return StageDelta(path, kind, a, b, delta, rel, sig, noise, detail)


def diff_stage_rows(rows_a: List[Dict[str, Any]],
                    rows_b: List[Dict[str, Any]], *,
                    noise: Optional[NoiseModel] = None,
                    iqr: Dict[str, float] = None) -> List[StageDelta]:
    """Align two flat trace-row lists by span path; per-path total-seconds
    deltas (noise-aware) plus span-count deltas (exact)."""
    noise = noise or NoiseModel()
    iqr = iqr or {}

    def agg(rows):
        by_path: Dict[str, Dict[str, float]] = {}
        for r in rows:
            a = by_path.setdefault(r.get("path", r["name"]),
                                   {"total_s": 0.0, "count": 0})
            a["total_s"] += r.get("dur_s", 0.0)
            a["count"] += 1
        return by_path

    agg_a, agg_b = agg(rows_a), agg(rows_b)
    out: List[StageDelta] = []
    for path in sorted(set(agg_a) | set(agg_b)):
        a, b = agg_a.get(path), agg_b.get(path)
        ta = a["total_s"] if a else None
        tb = b["total_s"] if b else None
        thr = noise.threshold(ta or 0.0, iqr.get(path, 0.0))
        out.append(_mk_delta(path, "time", ta, tb, thr))
        ca = float(a["count"]) if a else None
        cb = float(b["count"]) if b else None
        out.append(_mk_delta(f"{path}#count", "exact", ca, cb))
    return out


def diff_traces(path_a: str, path_b: str, *,
                noise: Optional[NoiseModel] = None) -> List[StageDelta]:
    """Diff two trace files (Chrome JSON or flat jsonl) by span path."""
    from .report import load_trace_rows
    return diff_stage_rows(load_trace_rows(path_a), load_trace_rows(path_b),
                           noise=noise)


def diff_metrics(doc_a: Dict[str, Any], doc_b: Dict[str, Any], *,
                 noise: Optional[NoiseModel] = None) -> List[StageDelta]:
    """Diff two metrics-registry dumps (``MetricsRegistry.to_dict``).

    Counters and numeric gauges are exact-valued (zero tolerance);
    histogram sums are wall-clock-like only for second-valued series
    (name ends in ``secs``/``_s``), exact otherwise.
    """
    noise = noise or NoiseModel()
    out: List[StageDelta] = []

    def num(v):
        return float(v) if isinstance(v, (int, float)) else None

    for section, kind in (("counters", "exact"), ("gauges", "exact")):
        sa = doc_a.get(section, {})
        sb = doc_b.get(section, {})
        for k in sorted(set(sa) | set(sb)):
            a, b = num(sa.get(k)) if k in sa else None, \
                num(sb.get(k)) if k in sb else None
            if (k in sa and a is None) or (k in sb and b is None):
                # non-numeric gauge (lists, strings): compare by equality
                eq = sa.get(k) == sb.get(k) and k in sa and k in sb
                out.append(StageDelta(f"{section}/{k}", "exact", None, None,
                                      significant=not eq,
                                      detail="equal" if eq else "changed"))
                continue
            out.append(_mk_delta(f"{section}/{k}", kind, a, b))
    ha = doc_a.get("histograms", {})
    hb = doc_b.get("histograms", {})
    for k in sorted(set(ha) | set(hb)):
        a = ha.get(k, {}).get("sum") if k in ha else None
        b = hb.get(k, {}).get("sum") if k in hb else None
        timelike = k.endswith("secs") or k.endswith("_s")
        thr = noise.threshold(a or 0.0) if timelike else 0.0
        out.append(_mk_delta(f"histograms/{k}.sum",
                             "time" if timelike else "exact", a, b, thr))
        ca = float(ha[k]["count"]) if k in ha else None
        cb = float(hb[k]["count"]) if k in hb else None
        out.append(_mk_delta(f"histograms/{k}.count", "exact", ca, cb))
    return out


def render_deltas(deltas: List[StageDelta], *,
                  only_significant: bool = False) -> str:
    shown = [d for d in deltas if d.significant or not only_significant]
    header = (f"  {'kind':<5} {'path':<40} {'a':>12} {'b':>12} "
              f"{'delta':>12} {'rel':>9}")
    lines = [header] + [d.row() for d in shown]
    n_sig = sum(1 for d in deltas if d.significant)
    lines.append(f"-- {len(deltas)} aligned series, {n_sig} significant "
                 f"(! = beyond noise bound; exact series have zero "
                 f"tolerance)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.diff",
        description="Diff two traces or metrics dumps with noise-aware "
                    "thresholds (exact series: zero tolerance).")
    ap.add_argument("a", help="baseline artifact")
    ap.add_argument("b", help="fresh artifact")
    ap.add_argument("--metrics", action="store_true",
                    help="inputs are metrics-registry JSON dumps, not traces")
    ap.add_argument("--all", action="store_true",
                    help="show every aligned row, not only significant ones")
    ap.add_argument("--rel-floor", type=float, default=NoiseModel.rel_floor)
    ap.add_argument("--abs-floor-s", type=float,
                    default=NoiseModel.abs_floor_s)
    args = ap.parse_args(argv)
    noise = NoiseModel(abs_floor_s=args.abs_floor_s,
                       rel_floor=args.rel_floor)
    if args.metrics:
        with open(args.a) as fa, open(args.b) as fb:
            deltas = diff_metrics(json.load(fa), json.load(fb), noise=noise)
    else:
        deltas = diff_traces(args.a, args.b, noise=noise)
    print(render_deltas(deltas, only_significant=not args.all))
    return 1 if any(d.significant and d.kind == "exact" for d in deltas) \
        else 0


if __name__ == "__main__":      # pragma: no cover - exercised via CLI
    raise SystemExit(main())
