"""Hook JAX compile events into the obs tracer/metrics.

Batch-first dispatch makes compile count the number that matters: a
grouped pnr or sim stage should trigger ONE ``jax.jit`` compile per
bucket signature, after which dispatches are cache hits.  ``jax.monitoring``
fires named duration events around every tracing/lowering/backend-compile
step; this module forwards them — when enabled — to

* the global :class:`~repro.obs.metrics.MetricsRegistry` (or one given
  to :func:`enable`): counters ``jax.compile.events`` /
  ``jax.compile.<leaf>`` and histogram ``jax.compile.secs``;
* the active tracer, as completed spans on a ``jax-compile`` side track,
  so a Perfetto timeline visually separates compile time from dispatch
  time (the span *ends* when the listener fires; its start is backdated
  by the reported duration).

``jax.monitoring`` has no per-listener unregister (only a global
``clear_event_listeners``), so the listener is installed once and
consults a module flag — :func:`disable` flips the flag, it does not
touch other listeners.
"""

from __future__ import annotations

from typing import Optional

from .metrics import MetricsRegistry, global_registry
from .trace import current as current_tracer

__all__ = ["enable", "disable", "is_enabled"]

_INSTALLED = False
_ENABLED = False
_REGISTRY: Optional[MetricsRegistry] = None

# substrings of jax.monitoring event names worth accounting for
_COMPILE_MARK = "compile"


def _on_duration(event: str, duration: float, **kwargs) -> None:
    if not _ENABLED or _COMPILE_MARK not in event:
        return
    reg = _REGISTRY or global_registry()
    leaf = event.rstrip("/").rsplit("/", 1)[-1]
    reg.inc("jax.compile.events")
    reg.inc(f"jax.compile.{leaf}")
    reg.observe("jax.compile.secs", duration)
    tracer = current_tracer()
    if tracer is not None:
        t1 = tracer.now()
        tracer.add_complete(leaf, max(t1 - duration, 0.0), duration,
                            track="jax-compile", event=event)


def enable(registry: Optional[MetricsRegistry] = None) -> bool:
    """Start forwarding jax compile events; returns False if jax is
    missing (the subsystem stays a no-op)."""
    global _INSTALLED, _ENABLED, _REGISTRY
    _REGISTRY = registry
    if not _INSTALLED:
        try:
            from jax import monitoring
        except Exception:       # pragma: no cover - jax is baked in
            return False
        monitoring.register_event_duration_secs_listener(_on_duration)
        _INSTALLED = True
    _ENABLED = True
    return True


def disable() -> None:
    global _ENABLED, _REGISTRY
    _ENABLED = False
    _REGISTRY = None


def is_enabled() -> bool:
    return _ENABLED
