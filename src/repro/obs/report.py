"""Summarize obs artifacts: ``python -m repro.obs.report trace.json``.

Accepts either export format of :class:`repro.obs.trace.Tracer` —
Chrome trace-event JSON (``*.trace.json``) or flat jsonl — plus an
optional ``--metrics out.metrics.json`` registry dump, and prints a
stage-timing table (per span name: count, total/mean/max wall time)
with the top individual spans.  ``results/make_tables.py stages``
reuses :func:`load_trace_rows` / :func:`aggregate_stages` to emit the
same table as markdown.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

__all__ = ["TraceFormatError", "load_trace_rows", "aggregate_stages",
           "stage_table", "main"]


class TraceFormatError(ValueError):
    """A trace file that is empty, truncated, or not a trace at all —
    reported as a one-line error by the CLI, never a stack trace."""


def load_trace_rows(path: str) -> List[Dict[str, Any]]:
    """Normalize a trace file (Chrome JSON or flat jsonl) to flat rows
    with ``name`` / ``dur_s`` / ``depth`` / ``attrs``.

    Raises :class:`TraceFormatError` on empty or truncated input.
    """
    with open(path) as fh:
        text = fh.read()
    if not text.strip():
        raise TraceFormatError(f"{path}: empty trace file")
    # Chrome export is one JSON document with "traceEvents"; jsonl lines
    # also start with "{", so detect by parsing, not by first character
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None
    if isinstance(doc, dict) and "traceEvents" in doc:
        rows = []
        for ev in doc["traceEvents"]:
            if ev.get("ph") != "X":
                continue
            rows.append({"name": ev["name"],
                         "dur_s": ev.get("dur", 0.0) / 1e6,
                         "t0_s": ev.get("ts", 0.0) / 1e6,
                         "depth": 0 if ev.get("tid") == 1 else 1,
                         "attrs": ev.get("args", {})})
        return rows
    rows = []
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError:
            raise TraceFormatError(
                f"{path}: line {i} is not valid JSON — not a Chrome "
                f"trace or spans jsonl (truncated write?)") from None
    return rows


def aggregate_stages(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Per-span-name aggregate: count, total/mean/max seconds."""
    agg: Dict[str, Dict[str, Any]] = {}
    for r in rows:
        a = agg.setdefault(r["name"], {"name": r["name"], "count": 0,
                                       "total_s": 0.0, "max_s": 0.0})
        a["count"] += 1
        a["total_s"] += r.get("dur_s", 0.0)
        a["max_s"] = max(a["max_s"], r.get("dur_s", 0.0))
    # name breaks total_s ties so the table order is deterministic even
    # when durations collide (common for sub-ms spans rounded in export)
    out = sorted(agg.values(), key=lambda a: (-a["total_s"], a["name"]))
    for a in out:
        a["mean_s"] = a["total_s"] / a["count"]
    return out


def stage_table(rows: List[Dict[str, Any]], *, markdown: bool = False,
                limit: int = 0) -> str:
    """Render the stage-timing table (plain text or markdown)."""
    stages = aggregate_stages(rows)
    if limit:
        stages = stages[:limit]
    if markdown:
        lines = ["| span | count | total (s) | mean (ms) | max (ms) |",
                 "|---|---:|---:|---:|---:|"]
        for a in stages:
            lines.append(f"| {a['name']} | {a['count']} "
                         f"| {a['total_s']:.3f} | {1e3 * a['mean_s']:.2f} "
                         f"| {1e3 * a['max_s']:.2f} |")
        return "\n".join(lines)
    lines = [f"{'span':<28} {'count':>6} {'total s':>9} {'mean ms':>9} "
             f"{'max ms':>9}"]
    for a in stages:
        lines.append(f"{a['name']:<28} {a['count']:>6} "
                     f"{a['total_s']:>9.3f} {1e3 * a['mean_s']:>9.2f} "
                     f"{1e3 * a['max_s']:>9.2f}")
    return "\n".join(lines)


def _metrics_summary(path: str) -> str:
    with open(path) as fh:
        doc = json.load(fh)
    lines = ["-- metrics --"]
    for k, v in sorted(doc.get("counters", {}).items()):
        lines.append(f"  counter    {k:<40} {v}")
    for k, v in sorted(doc.get("gauges", {}).items()):
        sv = json.dumps(v)
        if len(sv) > 48:
            sv = sv[:45] + "..."
        lines.append(f"  gauge      {k:<40} {sv}")
    for k, h in sorted(doc.get("histograms", {}).items()):
        lines.append(f"  histogram  {k:<40} n={h['count']} "
                     f"mean={h['mean']:.4g} min={h['min']} max={h['max']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro trace / metrics artifact.")
    ap.add_argument("trace", nargs="?",
                    help="trace file (Chrome JSON or flat jsonl)")
    ap.add_argument("--metrics", help="metrics registry JSON dump")
    ap.add_argument("--markdown", action="store_true",
                    help="emit the stage table as markdown")
    ap.add_argument("--limit", type=int, default=0,
                    help="show only the top N span names")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("give a trace file and/or --metrics")
    if args.trace:
        try:
            rows = load_trace_rows(args.trace)
        except TraceFormatError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        except OSError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        print(f"-- stage timing ({len(rows)} spans) --")
        print(stage_table(rows, markdown=args.markdown, limit=args.limit))
    if args.metrics:
        print(_metrics_summary(args.metrics))
    return 0


if __name__ == "__main__":       # pragma: no cover - exercised via CLI
    raise SystemExit(main())
