"""History-backed regression detector: ``python -m repro.obs.regress``.

Compares fresh ``BENCH_*.json`` artifacts against the rolling baseline in
``results/history/`` (see :mod:`repro.obs.history`).  Where
``results/check_bench.py`` gates *invariants* (ratios >= 1, bit-identity
flags), this gates the *trajectory*: a wall-clock that drifted past its
noise bound, a speedup that eroded, a dispatch count that grew.

Per-metric rules (the :mod:`repro.obs.diff` discipline):

* ``time``  — regression when the fresh median exceeds the rolling
  baseline median by ``max(abs_floor, rel_tol * median, iqr_k * IQR)``;
  the IQR comes from the baseline window *and* the fresh ``repeats``
  block, so both run-to-run and commit-to-commit noise are priced in.
* ``ratio`` — same rule, inverted (lower is worse).
* ``count`` — zero-tolerance upward: fresh must not exceed the window
  maximum (dispatch counts are deterministic; growth means batching
  broke).
* ``flag``  — must be true (hard fail, no baseline needed).

A metric with no baseline rows passes with a note — the first run of a
new benchmark (or mode) bootstraps its own trajectory.  ``--smoke``
downgrades time/ratio regressions to warnings (tier-1 CI runs on shared
runners whose absolute wall-clocks are not trustworthy enough to block a
merge; the nightly runs full-strength).  ``--append`` records the fresh
artifacts into the history store after checking (idempotent per
commit+bench+mode).

Run::

    PYTHONPATH=src python -m repro.obs.regress results/BENCH_*.json
    PYTHONPATH=src python -m repro.obs.regress --smoke  # tier-1 CI
    PYTHONPATH=src python -m repro.obs.regress --append # nightly
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from . import history as history_mod
from .diff import NoiseModel
from .manifest import validate_manifest

__all__ = ["flatten_bench", "check_artifact", "Finding", "main"]


# ---------------------------------------------------------------------------
# flattening: one scalar-metric view per benchmark schema
# ---------------------------------------------------------------------------
def _flatten_explore(doc: Dict[str, Any], times: Tuple[str, ...],
                     counts: Tuple[str, ...],
                     flags: Tuple[str, ...]) -> Dict[str, Tuple[float, str]]:
    out: Dict[str, Tuple[float, str]] = {}
    for k in times:
        if isinstance(doc.get(k), (int, float)):
            out[k] = (float(doc[k]), "time")
    if isinstance(doc.get("speedup"), (int, float)):
        out["speedup"] = (float(doc["speedup"]), "ratio")
    for k in counts:
        if isinstance(doc.get(k), (int, float)):
            out[k] = (float(doc[k]), "count")
    for k in flags:
        out[k] = (1.0 if doc.get(k) is True else 0.0, "flag")
    for k, v in sorted(doc.get("metrics", {}).items()):
        if isinstance(v, (int, float)):
            kind = "count" if k in ("pnr_dispatch", "sim_dispatch",
                                    "sched_group") else "info"
            out[f"metrics.{k}"] = (float(v), kind)
    return out


def _flatten_pnr(doc: Dict[str, Any]) -> Dict[str, Tuple[float, str]]:
    out: Dict[str, Tuple[float, str]] = {}
    for s in doc.get("sizes", []):
        tag = f"{s.get('rows')}x{s.get('cols')}"
        for k in ("delta_wall_s", "full_wall_s"):
            if isinstance(s.get(k), (int, float)):
                out[f"{tag}.{k}"] = (float(s[k]), "time")
        if isinstance(s.get("speedup"), (int, float)):
            out[f"{tag}.speedup"] = (float(s["speedup"]), "ratio")
        out[f"{tag}.bit_identical"] = (
            1.0 if s.get("bit_identical") is True else 0.0, "flag")
    a64 = doc.get("anneal64")
    if a64:
        if isinstance(a64.get("wall_s"), (int, float)):
            out["64x64.anneal_wall_s"] = (float(a64["wall_s"]), "time")
        out["64x64.completed"] = (
            1.0 if a64.get("completed") is True else 0.0, "flag")
    return out


def _flatten_pnr_v3(doc: Dict[str, Any]) -> Dict[str, Tuple[float, str]]:
    """v2's metrics plus the hierarchical section (hier.<tag>.*)."""
    out = _flatten_pnr(doc)
    for h in doc.get("hier", []):
        tag = f"hier.{h.get('rows')}x{h.get('cols')}"
        for k in ("hier_wall_s", "flat_wall_s"):
            if isinstance(h.get(k), (int, float)):
                out[f"{tag}.{k}"] = (float(h[k]), "time")
        if isinstance(h.get("speedup_vs_flat"), (int, float)):
            out[f"{tag}.speedup_vs_flat"] = (
                float(h["speedup_vs_flat"]), "ratio")
        levels = h.get("bit_identical_levels")
        ok = (isinstance(levels, dict) and levels
              and all(v is True for v in levels.values()))
        out[f"{tag}.levels_identical"] = (1.0 if ok else 0.0, "flag")
        out[f"{tag}.completed"] = (
            1.0 if h.get("completed") is True else 0.0, "flag")
    c1 = doc.get("hier_cluster1")
    if c1 is not None:
        out["hier.cluster1_identical"] = (
            1.0 if c1.get("cluster1_identical") is True else 0.0, "flag")
    return out


#: benchmark id -> flattener returning {metric: (value, kind)} with kind
#: in {"time", "ratio", "count", "flag", "info"}
_FLATTENERS = {
    "explore_pnr_batch": lambda d: _flatten_explore(
        d, ("serial_s", "grouped_s"),
        ("serial_dispatches", "grouped_dispatches"), ()),
    "explore_sim_batch": lambda d: _flatten_explore(
        d, ("serial_s", "grouped_s"),
        ("serial_compiles", "grouped_sim_dispatches",
         "grouped_sched_groups"),
        ("bit_identical", "ii_identical", "verified")),
    "pnr_bench/v2": _flatten_pnr,
    "pnr_bench/v3": _flatten_pnr_v3,
    "serve_bench/v1": lambda d: _flatten_explore(
        d, ("serial_s", "batched_s", "cache_hit_ms"),
        ("serial_dispatches", "batched_dispatches", "single_dispatches",
         "n_clients"),
        ("bit_identical",)),
}


def flatten_bench(doc: Dict[str, Any]) -> Tuple[str, str,
                                                Dict[str, float],
                                                Dict[str, str]]:
    """(bench id, mode, {metric: value}, {metric: kind}) for one artifact.

    Raises on unknown benchmark kinds — like the bench gate, adding an
    artifact forces teaching the trajectory layer how to read it.
    """
    kind = doc.get("bench") or doc.get("schema")
    fl = _FLATTENERS.get(kind)
    if fl is None:
        raise ValueError(f"unknown benchmark kind {kind!r} — add a "
                         f"flattener to repro/obs/regress.py")
    mode = doc.get("mode") or ("smoke" if doc.get("smoke") else "full")
    flat = fl(doc)
    return (kind, mode, {k: v for k, (v, _) in flat.items()},
            {k: kd for k, (_, kd) in flat.items()})


def _fresh_iqr(doc: Dict[str, Any], metric: str) -> float:
    """IQR of a time metric from the artifact's own repeats block."""
    rep = doc.get("repeats")
    if not isinstance(rep, dict):
        return 0.0
    # explore benches: repeats[metric]; pnr bench: sizes/hier entries carry
    # their own repeats blocks, flattened metric names are "<tag>.<key>"
    # (hier entries flatten as "hier.<tag>.<key>")
    entry = rep.get(metric)
    if entry is None and metric.startswith("hier."):
        tag, key = metric[len("hier."):].split(".", 1)
        for h in doc.get("hier", []):
            if f"{h.get('rows')}x{h.get('cols')}" == tag:
                entry = (h.get("repeats") or {}).get(key)
    elif entry is None and "." in metric:
        tag, key = metric.split(".", 1)
        for s in doc.get("sizes", []):
            if f"{s.get('rows')}x{s.get('cols')}" == tag:
                entry = (s.get("repeats") or {}).get(key)
    if isinstance(entry, dict) and isinstance(entry.get("iqr"),
                                              (int, float)):
        return float(entry["iqr"])
    return 0.0


# ---------------------------------------------------------------------------
# the detector
# ---------------------------------------------------------------------------
@dataclass
class Finding:
    """One per-metric verdict."""

    path: str
    bench: str
    metric: str
    kind: str
    status: str          # "ok" | "regress" | "warn" | "no-baseline" | "info"
    detail: str

    def line(self) -> str:
        mark = {"ok": "OK  ", "regress": "FAIL", "warn": "WARN",
                "no-baseline": "NEW ", "info": "    "}[self.status]
        return f"  {mark} {self.bench:<20} {self.metric:<28} {self.detail}"


def _structural(doc: Dict[str, Any], path: str) -> List[Finding]:
    """Manifest + repeats shape checks (hard failures)."""
    out = []
    bench = doc.get("bench") or doc.get("schema") or "?"
    errors = validate_manifest(doc.get("manifest"))
    if doc.get("manifest") is None:
        errors = ["missing manifest block (regenerate the artifact)"]
    for e in errors:
        out.append(Finding(path, bench, "manifest", "flag", "regress", e))
    if not errors:
        out.append(Finding(path, bench, "manifest", "flag", "ok",
                           f"sha={doc['manifest']['git_sha'][:9]} "
                           f"xla_cache={doc['manifest']['xla_cache']}"))
    rep = doc.get("repeats")
    if rep is not None:
        if not isinstance(rep.get("n"), int) or rep["n"] < 1:
            out.append(Finding(path, bench, "repeats", "flag", "regress",
                               f"repeats.n={rep.get('n')!r}, expected a "
                               f"positive int"))
        else:
            out.append(Finding(path, bench, "repeats", "flag", "ok",
                               f"n={rep['n']}"))
    return out


def check_artifact(doc: Dict[str, Any], path: str, *,
                   history_dir: str = history_mod.DEFAULT_DIR,
                   noise: Optional[NoiseModel] = None,
                   rel_tol: float = 0.35, window: int = 8,
                   smoke: bool = False) -> List[Finding]:
    """Every Finding for one BENCH artifact vs its rolling baseline."""
    noise = noise or NoiseModel(rel_floor=rel_tol)
    findings = _structural(doc, path)
    bench, mode, metrics, kinds = flatten_bench(doc)
    rows = history_mod.load(history_dir, bench)

    for metric in sorted(metrics):
        kind = kinds[metric]
        val = metrics[metric]
        if kind == "flag":
            ok = val == 1.0
            findings.append(Finding(
                path, bench, metric, kind, "ok" if ok else "regress",
                "true" if ok else "flag is false"))
            continue
        if kind == "info":
            continue
        base = history_mod.rolling_stats(rows, metric, mode=mode,
                                         window=window)
        if base is None:
            findings.append(Finding(path, bench, metric, kind,
                                    "no-baseline",
                                    f"{val:.6g} (bootstrapping trajectory)"))
            continue
        med, iqr = base["median"], base["iqr"]
        if kind == "count":
            worst = base["max"]
            if val > worst:
                findings.append(Finding(
                    path, bench, metric, kind, "regress",
                    f"{val:.6g} > window max {worst:.6g} (count grew — "
                    f"batching regressed)"))
            else:
                findings.append(Finding(path, bench, metric, kind, "ok",
                                        f"{val:.6g} <= {worst:.6g}"))
            continue
        thr = noise.threshold(med, max(iqr, _fresh_iqr(doc, metric)))
        if kind == "time":
            bad = val > med + thr
            detail = (f"{val:.4g}s vs median {med:.4g}s "
                      f"(+{val - med:.4g}s, bound {thr:.4g}s, "
                      f"n={base['n']})")
        else:                    # ratio: lower is worse
            bad = val < med - thr
            detail = (f"{val:.4g}x vs median {med:.4g}x "
                      f"(bound {thr:.4g}, n={base['n']})")
        status = "regress" if bad else "ok"
        if bad and smoke:
            status = "warn"
            detail += " [smoke: advisory]"
        findings.append(Finding(path, bench, metric, kind, status, detail))
    return findings


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Compare fresh BENCH_*.json artifacts against the "
                    "rolling results/history/ baseline.")
    ap.add_argument("artifacts", nargs="*",
                    help="BENCH_*.json files (default: results/BENCH_*.json)")
    ap.add_argument("--history", default=history_mod.DEFAULT_DIR,
                    help="history store directory")
    ap.add_argument("--smoke", action="store_true",
                    help="wall-clock/ratio drifts warn instead of fail "
                         "(tier-1 CI on shared runners)")
    ap.add_argument("--append", action="store_true",
                    help="record the fresh artifacts into the history "
                         "store after checking (nightly)")
    ap.add_argument("--rel-tol", type=float, default=0.35,
                    help="relative drift floor before a wall-clock counts "
                         "as a regression")
    ap.add_argument("--window", type=int, default=8,
                    help="rolling-baseline window (history rows)")
    args = ap.parse_args(argv)

    paths = args.artifacts or sorted(glob.glob(
        os.path.join("results", "BENCH_*.json")))
    if not paths:
        print("regress: no BENCH_*.json artifacts found", file=sys.stderr)
        return 2

    failures = 0
    print(f"regress: {len(paths)} artifact(s) vs history in "
          f"{args.history!r} (window={args.window}, "
          f"rel_tol={args.rel_tol}{', smoke' if args.smoke else ''})")
    for path in paths:
        with open(path) as fh:
            doc = json.load(fh)
        findings = check_artifact(
            doc, path, history_dir=args.history, rel_tol=args.rel_tol,
            window=args.window, smoke=args.smoke)
        print(f"{path}:")
        for f in findings:
            if f.status != "info":
                print(f.line())
        failures += sum(1 for f in findings if f.status == "regress")
        if args.append:
            bench, mode, metrics, _ = flatten_bench(doc)
            row = history_mod.make_row(bench, mode, metrics,
                                       manifest=doc.get("manifest"))
            wrote = history_mod.append(row, directory=args.history)
            print(f"  {'APPEND' if wrote else 'DUP   '} "
                  f"history[{bench}] sha={row['sha'][:9]} mode={mode}"
                  + ("" if wrote else " (already recorded)"))
    if failures:
        print(f"\nregress FAILED: {failures} regression(s)",
              file=sys.stderr)
        return 1
    print("regress passed")
    return 0


if __name__ == "__main__":      # pragma: no cover - exercised via CLI
    sys.exit(main())
