"""Append-only per-commit performance history under ``results/history/``.

One jsonl file per benchmark (``results/history/<bench>.jsonl``); each
row is one measured trajectory point: the producing commit (manifest
``git_sha``), the bench mode (smoke/full budgets are different
populations and never compared against each other), a manifest subset,
and the flattened scalar metrics of that run.  Rows are appended by the
nightly workflow (``python -m repro.obs.regress --append``) and consumed
by :mod:`repro.obs.regress` (rolling baselines) and
``results/make_tables.py <dir> trend`` (trend tables).

The store is **append-only** and **idempotent per (sha, bench, mode)**:
re-running the nightly on the same commit does not duplicate rows, and
nothing ever rewrites an existing line — a corrupted trajectory would be
indistinguishable from a real regression.
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .diff import summarize_repeats
from .manifest import capture

__all__ = ["HISTORY_SCHEMA", "DEFAULT_DIR", "history_path", "make_row",
           "append", "load", "rolling_stats"]

#: bump on any row-shape change; load() rejects other versions
HISTORY_SCHEMA = 1

DEFAULT_DIR = os.path.join("results", "history")

#: manifest fields worth carrying per row (enough to explain a step in
#: the trajectory without bloating every line with the full manifest)
_MANIFEST_SUBSET = ("python", "jax", "platform", "device_kind", "backend",
                    "cpu_count", "xla_cache")


def history_path(directory: str, bench: str) -> str:
    """File for one benchmark's trajectory (slashes in schema-style bench
    ids like ``pnr_bench/v2`` become filename-safe underscores)."""
    safe = bench.replace("/", "_").replace(os.sep, "_")
    return os.path.join(directory, f"{safe}.jsonl")


def make_row(bench: str, mode: str, metrics: Dict[str, float], *,
             manifest: Optional[Dict[str, Any]] = None,
             ts: Optional[float] = None) -> Dict[str, Any]:
    """One history row; ``metrics`` is the flattened scalar view of a
    BENCH artifact (see :func:`repro.obs.regress.flatten_bench`)."""
    man = manifest if manifest is not None else capture().to_dict()
    return {"schema": HISTORY_SCHEMA,
            "bench": bench,
            "mode": mode,
            "sha": man.get("git_sha", "unknown"),
            "ts": float(ts if ts is not None else time.time()),
            "env": {k: man[k] for k in _MANIFEST_SUBSET if k in man},
            "metrics": {k: metrics[k] for k in sorted(metrics)}}


def _key(row: Dict[str, Any]) -> Tuple[str, str, str]:
    return (str(row.get("sha")), str(row.get("bench")),
            str(row.get("mode")))


def append(row: Dict[str, Any], *, directory: str = DEFAULT_DIR) -> bool:
    """Append one row to its bench's history file.

    Idempotent per (sha, bench, mode): if the trajectory already has a
    point for that key, nothing is written and False is returned — the
    first measurement of a commit wins, later re-runs never silently
    replace it.
    """
    path = history_path(directory, row["bench"])
    existing = {_key(r) for r in load(directory, row["bench"])}
    if _key(row) in existing:
        return False
    os.makedirs(directory, exist_ok=True)
    with open(path, "a") as fh:
        fh.write(json.dumps(row, sort_keys=True) + "\n")
    return True


def load(directory: str, bench: str) -> List[Dict[str, Any]]:
    """All trajectory rows for one bench, oldest first (file order).

    A corrupted line (truncated write, merge damage) is skipped with a
    warning on stderr rather than failing the whole regression gate —
    one bad trajectory point must not block every future nightly.  A
    *parseable* row with a foreign schema still raises: that is a build
    mismatch, not corruption.
    """
    path = history_path(directory, bench)
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as fh:
        for i, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except ValueError as e:
                print(f"warning: {path}:{i}: skipping corrupted history "
                      f"row ({e})", file=sys.stderr)
                continue
            if not isinstance(row, dict):
                print(f"warning: {path}:{i}: skipping non-object history "
                      f"row", file=sys.stderr)
                continue
            if row.get("schema") != HISTORY_SCHEMA:
                raise ValueError(
                    f"{path}:{i}: history schema {row.get('schema')!r} "
                    f"not supported (this build reads {HISTORY_SCHEMA})")
            rows.append(row)
    return rows


def rolling_stats(rows: Sequence[Dict[str, Any]], metric: str, *,
                  mode: Optional[str] = None,
                  window: int = 8) -> Optional[Dict[str, Any]]:
    """Median/IQR of ``metric`` over the last ``window`` rows (optionally
    restricted to one mode); None when no row carries the metric — the
    caller treats that as "no baseline yet"."""
    vals = [r["metrics"][metric] for r in rows
            if (mode is None or r.get("mode") == mode)
            and metric in r.get("metrics", {})
            and isinstance(r["metrics"][metric], (int, float))]
    if not vals:
        return None
    return summarize_repeats(vals[-window:])
