"""Post-PnR utilization / timing reports (the Kuree `analyzer.py` idiom).

Answers the "why does camera converge to II=17?" class of question from a
finished :class:`~repro.fabric.PnRResult` (and, when available, its
:class:`~repro.sim.schedule.ModuloSchedule`):

* **PE / IO / channel / latch utilization** — how full the array is and
  how hard the mesh works;
* **per-net route depth histogram** — the register distances the modulo
  scheduler has to absorb;
* **operand-skew table** — per dependence edge, when the operand arrives
  vs when the consumer fires; the hold window is
  ``arrival + 1 <= t_fire <= arrival + latch_depth*II``, so each edge
  implies a minimum II of ``ceil(wait / latch_depth)``.  Edges whose
  implied II equals the achieved II are the **skew-critical nets**: they
  are why the schedule could not close at a smaller II.

Pure-Python over existing result objects; imports nothing from jax and
nothing at module scope from the pipeline (no import cycles with
``repro.obs``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["OperandSkew", "PnrReport", "analyze_pnr"]


@dataclass
class OperandSkew:
    """One scheduled dependence edge net -> consuming PE tile."""

    net: str
    src: Tuple[str, int]         # producing op ("pe", inst) | ("in", signal)
    dst: Tuple[str, int]         # consuming op
    tile: Tuple[int, int]        # consumer tile
    hops: int                    # register depth driver -> consumer tile
    arrival: int                 # cycle the operand lands at the tile
    fire: int                    # cycle the consumer fires
    wait: int                    # fire - arrival (>= 1)
    hold: int                    # latch_depth * II: max legal wait
    implied_ii: int              # ceil(wait / latch_depth)

    @property
    def slack(self) -> int:
        return self.hold - self.wait

    def row(self) -> str:
        return (f"{self.net:<12} {str(self.src):<12} -> {str(self.dst):<12}"
                f" hops={self.hops:<3d} arr={self.arrival:<4d}"
                f" fire={self.fire:<4d} wait={self.wait:<4d}"
                f" slack={self.slack:<4d} impliedII={self.implied_ii}")


@dataclass
class PnrReport:
    app: str
    rows: int
    cols: int
    # utilization
    n_pe_cells: int
    n_pe_tiles: int
    n_io_cells: int
    n_io_sites: int
    used_edges: int
    total_edges: int
    mean_channel_util: float
    max_channel_util: float
    overflow: int
    # routes
    route_depth_hist: Dict[int, int]
    # schedule-dependent (None without a schedule)
    ii: Optional[int] = None
    min_ii: Optional[int] = None
    latch_depth: Optional[int] = None
    mean_latch_util: Optional[float] = None
    max_latch_util: Optional[float] = None
    skews: List[OperandSkew] = field(default_factory=list)

    @property
    def pe_util(self) -> float:
        return self.n_pe_cells / max(1, self.n_pe_tiles)

    @property
    def io_util(self) -> float:
        return self.n_io_cells / max(1, self.n_io_sites)

    @property
    def skew_critical(self) -> List[OperandSkew]:
        """Edges whose implied II equals the achieved II — the nets that
        pin the schedule (empty when II is purely resource-bound and no
        edge individually requires it)."""
        if self.ii is None:
            return []
        return [s for s in self.skews if s.implied_ii >= self.ii]

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "app": self.app, "fabric": f"{self.cols}x{self.rows}",
            "pe_util": round(self.pe_util, 4),
            "io_util": round(self.io_util, 4),
            "used_edges": self.used_edges, "total_edges": self.total_edges,
            "mean_channel_util": round(self.mean_channel_util, 4),
            "max_channel_util": round(self.max_channel_util, 4),
            "overflow": self.overflow,
            "route_depth_hist": {str(k): v for k, v
                                 in sorted(self.route_depth_hist.items())},
        }
        if self.ii is not None:
            d.update({
                "ii": self.ii, "min_ii": self.min_ii,
                "latch_depth": self.latch_depth,
                "mean_latch_util": round(self.mean_latch_util or 0.0, 4),
                "max_latch_util": round(self.max_latch_util or 0.0, 4),
                "skew_critical": [s.net for s in self.skew_critical],
            })
        return d

    def render(self) -> str:
        out = [f"== post-pnr report: {self.app} "
               f"({self.cols}x{self.rows} fabric) =="]
        out.append(f"  PE tiles   {self.n_pe_cells}/{self.n_pe_tiles} "
                   f"({100 * self.pe_util:.1f}%)   "
                   f"IO sites {self.n_io_cells}/{self.n_io_sites} "
                   f"({100 * self.io_util:.1f}%)")
        out.append(f"  channels   {self.used_edges}/{self.total_edges} used, "
                   f"mean util {100 * self.mean_channel_util:.1f}%, "
                   f"max {100 * self.max_channel_util:.1f}%, "
                   f"overflow {self.overflow}")
        depth = ", ".join(f"{k}:{v}" for k, v
                          in sorted(self.route_depth_hist.items()))
        out.append(f"  route depth histogram (max hops per net)  {depth}")
        if self.ii is not None:
            out.append(f"  schedule   II={self.ii} (min {self.min_ii}), "
                       f"latch_depth={self.latch_depth}, "
                       f"latch util mean {100 * (self.mean_latch_util or 0):.1f}% "
                       f"max {100 * (self.max_latch_util or 0):.1f}%")
            crit = self.skew_critical
            out.append(f"  operand-skew table ({len(self.skews)} edges, "
                       f"{len(crit)} skew-critical):")
            shown = sorted(self.skews, key=lambda s: (-s.implied_ii,
                                                      -s.wait, s.net))
            for s in shown[:12]:
                mark = " <- skew-critical" if s.implied_ii >= self.ii else ""
                out.append(f"    {s.row()}{mark}")
            if len(shown) > 12:
                out.append(f"    ... {len(shown) - 12} more")
        return "\n".join(out)


def analyze_pnr(pnr, sched=None) -> PnrReport:
    """Build a :class:`PnrReport` from a PnRResult (+ ModuloSchedule)."""
    spec, netlist, routes = pnr.spec, pnr.netlist, pnr.routes
    caps = spec.routing_edges()
    used = {e: u for e, u in routes.edge_usage.items() if u}
    mean_util = (sum(u / caps[e] for e, u in used.items()) / len(used)
                 if used else 0.0)

    depth_hist: Dict[int, int] = {}
    for net in routes.nets:
        d = net.max_hops
        depth_hist[d] = depth_hist.get(d, 0) + 1

    report = PnrReport(
        app=netlist.app_name, rows=spec.rows, cols=spec.cols,
        n_pe_cells=len(netlist.pe_cells), n_pe_tiles=spec.n_pe_tiles,
        n_io_cells=len(netlist.io_cells), n_io_sites=spec.n_io_sites,
        used_edges=len(used), total_edges=len(caps),
        mean_channel_util=mean_util, max_channel_util=routes.max_util,
        overflow=routes.overflow, route_depth_hist=depth_hist)

    if sched is None:
        return report

    from ..sim.schedule import L_OUT

    coords = pnr.placement.coords
    inst_of_cell = {name: c.instance for name, c in netlist.cells.items()
                    if c.kind == "pe"}
    cell_kind = {name: c.kind for name, c in netlist.cells.items()}
    hold = sched.latch_depth * sched.ii
    skews: List[OperandSkew] = []
    for net in sorted(netlist.nets, key=lambda n: n.name):
        src = sched.net_src.get(net.name)
        nt = sched.net_timing.get(net.name)
        if src is None or nt is None:
            continue
        for sink in net.sinks:
            if cell_kind[sink] != "pe":
                continue                      # io_out capture, not an operand
            tile = coords[sink]
            hops = nt.depth[tile]
            arrival = sched.start[src] + L_OUT + hops
            dst = ("pe", inst_of_cell[sink])
            fire = sched.start[dst]
            wait = fire - arrival
            implied = max(1, -(-wait // sched.latch_depth))
            skews.append(OperandSkew(
                net=net.name, src=src, dst=dst, tile=tile, hops=hops,
                arrival=arrival, fire=fire, wait=wait, hold=hold,
                implied_ii=implied))

    report.ii = sched.ii
    report.min_ii = sched.min_ii
    report.latch_depth = sched.latch_depth
    report.skews = skews
    if skews:
        utils = [s.wait / s.hold for s in skews]
        report.mean_latch_util = sum(utils) / len(utils)
        report.max_latch_util = max(utils)
    else:
        report.mean_latch_util = report.max_latch_util = 0.0
    return report
