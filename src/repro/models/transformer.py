"""Unified decoder LM covering all assigned architectures.

One parameter pytree + one forward implementation, specialized by
:class:`repro.models.config.ArchConfig`:

* mixer = attn (GQA + RoPE, optional sliding-window/global alternation,
  logit softcap, QK-norm), mamba (attn-free), or hymba (parallel attn+SSM
  heads averaged);
* MLP = SwiGLU / GELU / GEGLU, or MoE (sort-based capacity dispatch, EP);
* optional cross-attention layers every N layers (VLM backbone) fed by a
  stub encoder sequence; optional embeddings-input mode (audio backbone);
* layers run under ``lax.scan`` over stacked parameters with per-layer
  local/global flags, each layer body wrapped in ``jax.checkpoint`` (remat);
* three entry points: ``forward`` (teacher-forced logits), ``prefill``
  (returns KV/SSM caches), ``decode_step`` (one token, updates caches).

Sharding is injected via an optional ``shard`` callback dict so the same
code runs unsharded on CPU smoke tests and fully sharded under the
production mesh (see repro/sharding/specs.py).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import (apply_rope, blockwise_attention, mlp_gelu, mlp_geglu,
                     mlp_swiglu, rms_norm, rope_tables, soft_cap)
from .moe import moe_mlp
from .ssm import mamba_mixer

Params = Dict[str, Any]
ShardFn = Callable[[jax.Array, str], jax.Array]


def _noshard(x: jax.Array, name: str) -> jax.Array:
    return x


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    d, hd = cfg.d_model, cfg.head_dim_of
    shapes = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv * hd),
        "wv": (d, cfg.n_kv * hd),
        "wo": (cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        shapes["q_norm"] = (hd,)
        shapes["k_norm"] = (hd,)
    return shapes


def _mlp_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "gelu":
        return {"wi": (d, f), "wom": (f, d)}
    return {"wg": (d, f), "wu": (d, f), "wd": (f, d)}


def _moe_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    moe = cfg.moe
    d = cfg.d_model
    e = moe.n_experts_padded
    shapes = {
        "w_router": (d, e),
        "wg": (e, d, moe.d_expert),
        "wu": (e, d, moe.d_expert),
        "wd": (e, moe.d_expert, d),
    }
    if moe.n_shared:
        shapes.update({
            "sg": (d, moe.d_shared), "su": (d, moe.d_shared),
            "sd": (moe.d_shared, d), "shared_gate": (d,),
        })
    return shapes


def _ssm_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.expand * d
    r = ssm.dt_rank_of(d)
    n = ssm.d_state
    return {
        "in_proj": (d, 2 * di),
        "conv_w": (ssm.d_conv, di),
        "conv_b": (di,),
        "x_proj": (di, r + 2 * n),
        "dt_proj": (r, di),
        "dt_bias": (di,),
        "A_log": (di, n),
        "D": (di,),
        "out_proj": (di, d),
    }


def layer_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    """Per-layer parameter shapes (without the stacked L dim)."""
    shapes: Dict[str, Tuple[int, ...]] = {"ln1": (cfg.d_model,)}
    if cfg.mixer in ("attn", "hymba"):
        shapes.update(_attn_shapes(cfg))
    if cfg.mixer in ("mamba", "hymba"):
        shapes.update({f"ssm_{k}": v for k, v in _ssm_shapes(cfg).items()})
    if cfg.moe is not None:
        shapes["ln2"] = (cfg.d_model,)
        shapes.update(_moe_shapes(cfg))
    elif cfg.d_ff:
        shapes["ln2"] = (cfg.d_model,)
        shapes.update(_mlp_shapes(cfg))
    return shapes


def cross_layer_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    shapes = {"ln1": (cfg.d_model,), "ln2": (cfg.d_model,),
              "gate_attn": (), "gate_mlp": ()}
    shapes.update(_attn_shapes(cfg))
    shapes.update(_mlp_shapes(cfg))
    return shapes


def param_shapes(cfg: ArchConfig, dtype=jnp.float32) -> Params:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    def leaf(shape):
        return jax.ShapeDtypeStruct(shape, dtype)

    n_self = cfg.n_self_layers if cfg.mixer != "mamba" else cfg.n_layers
    p: Params = {
        "embed": leaf((cfg.vocab, cfg.d_model)),
        "final_norm": leaf((cfg.d_model,)),
        "layers": {k: leaf((n_self,) + s)
                   for k, s in layer_shapes(cfg).items()},
    }
    if cfg.n_cross_layers:
        p["cross_layers"] = {k: leaf((cfg.n_cross_layers,) + s)
                             for k, s in cross_layer_shapes(cfg).items()}
    if not cfg.tie_embeddings:
        p["lm_head"] = leaf((cfg.d_model, cfg.vocab))
    return p


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    """Random init (smoke tests / examples; full configs are dry-run only)."""
    shapes = param_shapes(cfg, dtype)
    flat, tree = jax.tree_util.tree_flatten_with_path(shapes)
    leaves = []
    for path, sds in flat:
        key, sub = jax.random.split(key)
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = sds.shape
        if name.startswith("ln") or name in ("final_norm", "conv_b", "D",
                                             "dt_bias", "q_norm", "k_norm"):
            leaf = jnp.ones(shape, dtype) if name in ("final_norm", "D") \
                else jnp.ones(shape, dtype)
        elif name.endswith("A_log") or name == "ssm_A_log":
            n = shape[-1]
            leaf = jnp.log(jnp.broadcast_to(
                jnp.arange(1, n + 1, dtype=jnp.float32), shape)).astype(dtype)
        elif name.startswith("gate"):
            leaf = jnp.zeros(shape, dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            leaf = (jax.random.normal(sub, shape, jnp.float32)
                    * (1.0 / math.sqrt(max(1, fan_in)))).astype(dtype)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(tree, leaves)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _attention(x, lp, cfg: ArchConfig, *, q_pos, kv_pos, is_global,
               kv_override=None, cache=None, cache_len=None,
               compute_dtype=jnp.bfloat16, shard: ShardFn = _noshard):
    """Self/cross attention.  Returns (out, (k, v) used)."""
    b, s, d = x.shape
    hd = cfg.head_dim_of
    hq, hkv = cfg.n_heads, cfg.n_kv
    xq = jnp.einsum("bsd,dh->bsh", x, lp["wq"].astype(compute_dtype))
    q = xq.reshape(b, s, hq, hd)
    if kv_override is not None:
        src = kv_override
        src_pos = jnp.broadcast_to(
            jnp.arange(src.shape[1], dtype=jnp.int32)[None], src.shape[:2])
        causal = False
    else:
        src = x
        src_pos = q_pos
        causal = True
    k = jnp.einsum("bsd,dh->bsh", src,
                   lp["wk"].astype(compute_dtype)).reshape(b, -1, hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", src,
                   lp["wv"].astype(compute_dtype)).reshape(b, -1, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    if kv_override is None:                         # RoPE on self-attn only
        cos_q, sin_q = rope_tables(q_pos, hd, cfg.rope_theta)
        cos_k, sin_k = rope_tables(src_pos, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_k, sin_k)

    kv_pos_eff = src_pos
    if cache is not None:                            # decode: append to cache
        k_cache, v_cache = cache                     # (B, Smax, Hkv, hd)
        k_cache = lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, cache_len, 0, 0))
        v_cache = lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, cache_len, 0, 0))
        k, v = k_cache, v_cache
        smax = k_cache.shape[1]
        pos = jnp.arange(smax, dtype=jnp.int32)[None]
        kv_pos_eff = jnp.broadcast_to(
            jnp.where(pos <= cache_len + s - 1, pos, jnp.int32(2 ** 30)),
            (b, smax))
        cache = (k_cache, v_cache)

    # per-layer local/global: traced is_global becomes a traced window size
    # (2**30 = effectively unmasked) so ONE blockwise pass serves both.
    if cfg.window:
        if isinstance(is_global, (bool, int)):
            win = None if is_global else cfg.window
        else:
            win = jnp.where(is_global, jnp.int32(2 ** 30),
                            jnp.int32(cfg.window))
    else:
        win = None
    from .perf_flags import get_flags
    flags = get_flags()
    if flags.attention_impl == "q_outer" and s > flags.attn_q_chunk:
        from .layers import blockwise_attention_qouter
        out = blockwise_attention_qouter(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos_eff, causal=causal,
            window=win, softcap=cfg.attn_softcap, scale=cfg.attn_scale,
            q_chunk=flags.attn_q_chunk, kv_chunk=flags.attn_kv_chunk)
    else:
        out = blockwise_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos_eff,
                                  causal=causal, window=win,
                                  softcap=cfg.attn_softcap,
                                  scale=cfg.attn_scale,
                                  chunk=flags.attn_kv_chunk)
    out = out.reshape(b, s, hq * hd)
    out = jnp.einsum("bsh,hd->bsd", out, lp["wo"].astype(compute_dtype))
    return out, cache


def _mlp(x, lp, cfg: ArchConfig, compute_dtype=jnp.bfloat16,
         shard: ShardFn = _noshard):
    if cfg.moe is not None:
        mp = {k: lp[k].astype(compute_dtype)
              for k in _moe_shapes(cfg) if k in lp}
        from .perf_flags import get_flags, get_mesh
        if get_flags().moe_impl == "shard_map" and get_mesh() is not None:
            from .moe import moe_mlp_shardmap
            mesh, bp_axes = get_mesh()
            return moe_mlp_shardmap(x, mp, cfg.moe, mesh, bp_axes)
        return moe_mlp(x, mp, cfg.moe, shard=shard)
    if not cfg.d_ff:
        return jnp.zeros_like(x)
    if cfg.mlp == "gelu":
        return mlp_gelu(x, lp["wi"].astype(compute_dtype),
                        lp["wom"].astype(compute_dtype))
    if cfg.mlp == "geglu":
        return mlp_geglu(x, lp["wg"].astype(compute_dtype),
                         lp["wu"].astype(compute_dtype),
                         lp["wd"].astype(compute_dtype))
    return mlp_swiglu(x, lp["wg"].astype(compute_dtype),
                      lp["wu"].astype(compute_dtype),
                      lp["wd"].astype(compute_dtype))


def _ssm_params(lp):
    return {k[len("ssm_"):]: v for k, v in lp.items()
            if k.startswith("ssm_")}


def layer_body(x, lp, cfg: ArchConfig, *, q_pos, is_global,
               cache=None, cache_len=None, ssm_state=None,
               compute_dtype=jnp.bfloat16, shard: ShardFn = _noshard):
    """One decoder layer.  Returns (x, new_cache, new_ssm_state)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_cache = None
    new_state = None
    if cfg.mixer == "attn":
        mix, new_cache = _attention(
            h, lp, cfg, q_pos=q_pos, kv_pos=q_pos, is_global=is_global,
            cache=cache, cache_len=cache_len, compute_dtype=compute_dtype,
            shard=shard)
    elif cfg.mixer == "mamba":
        sp = {k: v.astype(compute_dtype) if v.dtype == jnp.float32 and
              k not in ("A_log", "D") else v for k, v in _ssm_params(lp).items()}
        if ssm_state is not None:
            mix, new_state = mamba_mixer(h, sp, cfg.ssm, state=ssm_state,
                                         return_state=True)
        else:
            mix = mamba_mixer(h, sp, cfg.ssm)
    else:                                            # hymba: parallel heads
        attn_out, new_cache = _attention(
            h, lp, cfg, q_pos=q_pos, kv_pos=q_pos, is_global=is_global,
            cache=cache, cache_len=cache_len, compute_dtype=compute_dtype,
            shard=shard)
        sp = _ssm_params(lp)
        if ssm_state is not None:
            ssm_out, new_state = mamba_mixer(h, sp, cfg.ssm, state=ssm_state,
                                             return_state=True)
        else:
            ssm_out = mamba_mixer(h, sp, cfg.ssm)
        mix = 0.5 * (attn_out + ssm_out)
    x = x + mix.astype(x.dtype)
    x = shard(x, "hidden")

    if "ln2" in lp:                                 # attn-free mamba: no MLP
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + _mlp(h2, lp, cfg, compute_dtype, shard).astype(x.dtype)
        x = shard(x, "hidden")
    return x, new_cache, new_state


def cross_layer_body(x, lp, cfg: ArchConfig, enc, *, q_pos,
                     compute_dtype=jnp.bfloat16, shard: ShardFn = _noshard):
    """Gated cross-attention layer (llama-3.2-vision style)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn, _ = _attention(h, lp, cfg, q_pos=q_pos, kv_pos=None,
                         is_global=True, kv_override=enc,
                         compute_dtype=compute_dtype, shard=shard)
    x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * attn.astype(x.dtype)
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + jnp.tanh(lp["gate_mlp"]).astype(x.dtype) * _mlp(
        h2, lp, cfg, compute_dtype).astype(x.dtype)
    return shard(x, "hidden")
