"""Mixture-of-Experts layer with row-local capacity dispatch.

Production MoE under GSPMD needs the dispatch combinatorics (sort /
position-in-expert / scatter) to stay *local to each data shard* — a global
argsort over all tokens forces the partitioner to gather the whole token
stream onto every device (observed: ~PB-scale all-reduce per step).  The
trick: do the dispatch per *sequence* (batched over the leading B axis that
is sharded over ``data``):

1. router top-k per token;
2. per-row counting sort: position-in-expert via a cumulative one-hot count
   along the row, capacity per (row, expert) = ceil(S*k/E * cf) — tokens
   beyond capacity are dropped (static shapes, standard practice);
3. batched scatter into a (B, E, C, d) buffer — B sharded over ``data``,
   E over ``model`` (expert parallelism), so the scatter is shard-local and
   the only cross-device movement is the B x E resharding all-to-all that
   GSPMD inserts at the expert-compute boundary;
4. batched expert SwiGLU einsum over (B, E, C, d);
5. per-row gather-combine weighted by router probs;
6. optional dense shared experts (qwen2-moe).

No (T, E, C) one-hot dispatch tensor is ever materialized.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import MoEConfig


def router_topk(x: jax.Array, w_router: jax.Array, moe: MoEConfig
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (weights (B,S,k), expert ids (B,S,k))."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    e_pad = w_router.shape[1]
    if e_pad > moe.n_experts:                      # mask padded experts
        pad = jnp.full((1, 1, e_pad - moe.n_experts), -1e30, logits.dtype)
        logits = jnp.concatenate(
            [logits[..., :moe.n_experts],
             jnp.broadcast_to(pad, logits.shape[:2] + (e_pad - moe.n_experts,))],
            axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = lax.top_k(probs, moe.top_k)        # (B,S,k)
    if moe.router_norm_topk:
        vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    return vals, idx


def moe_mlp_shardmap(x: jax.Array, params: Dict[str, jax.Array],
                     moe: MoEConfig, mesh, bp_axes) -> jax.Array:
    """Explicit expert parallelism under shard_map (§Perf hillclimb).

    GSPMD's partitioning of the pjit dispatch replicates the (B, E, C, d)
    buffer across `model` and pays an O(E x C x d) f32 all-reduce in the
    backward pass (observed: the dominant collective for qwen3).  Here each
    model-rank dispatches its *local* tokens to its E/16 *local* experts —
    all combinatorics (top-k, counting sort, scatter) are rank-local and
    sized E_loc — and the only collective is a psum of the partial token
    outputs over `model` (plus its identity-cost transpose in backward):
    per layer ~|activations| bytes instead of ~|dispatch buffer| bytes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    e_pad = params["w_router"].shape[1]
    n_model = mesh.shape["model"]
    assert e_pad % n_model == 0, (e_pad, n_model)
    e_loc = e_pad // n_model
    k = moe.top_k

    def local_fn(x_l, wr, wg, wu, wd):
        b_l, s, d = x_l.shape
        t = b_l * s
        xt = x_l.reshape(t, d)
        weights, experts = router_topk(x_l, wr, moe)       # (B_l,S,k)
        flat_e = experts.reshape(t * k)
        flat_w = weights.reshape(t * k)
        flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

        rank = lax.axis_index("model")
        local_e = flat_e - rank * e_loc
        mine = (local_e >= 0) & (local_e < e_loc)
        local_e_c = jnp.where(mine, local_e, 0)
        # position within each local expert: exclusive running count
        oh = (local_e_c[:, None] == jnp.arange(e_loc)[None]) & mine[:, None]
        oh = oh.astype(jnp.int32)
        pos_all = jnp.cumsum(oh, axis=0) - oh
        pos = jnp.take_along_axis(pos_all, local_e_c[:, None],
                                  axis=1)[:, 0]
        capacity = int(max(k, round(t * k / moe.n_experts
                                    * moe.capacity_factor)))
        keep = mine & (pos < capacity)

        gathered = jnp.where(keep[:, None], xt[flat_t], 0).astype(x_l.dtype)
        buf = jnp.zeros((e_loc, capacity, d), x_l.dtype)
        buf = buf.at[local_e_c, jnp.where(keep, pos, capacity)].set(
            gathered, mode="drop")

        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = (jax.nn.silu(g) * u).astype(x_l.dtype)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd)

        part = out_buf[local_e_c, jnp.where(keep, pos, 0)]
        part = part.astype(jnp.float32) * (flat_w * keep)[:, None]
        y = jnp.zeros((t, d), jnp.float32).at[flat_t].add(part)
        y = lax.psum(y, "model")
        return y.reshape(b_l, s, d).astype(x_l.dtype)

    bp = P(bp_axes, None, None)
    y = shard_map(
        local_fn, mesh=mesh,
        in_specs=(bp, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=bp,
        check_rep=False,
    )(x, params["w_router"], params["wg"], params["wu"], params["wd"])

    # shared experts stay on the plain pjit path (dense, replicated weights)
    if moe.n_shared and "sg" in params:
        sg = jnp.einsum("bsd,df->bsf", x, params["sg"])
        su = jnp.einsum("bsd,df->bsf", x, params["su"])
        shared = jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * su,
                            params["sd"])
        gate = jax.nn.sigmoid(jnp.einsum(
            "bsd,d->bs", x.astype(jnp.float32),
            params["shared_gate"].astype(jnp.float32)))
        y = y + (shared.astype(jnp.float32)
                 * gate[..., None]).astype(y.dtype)
    return y


def moe_mlp(x: jax.Array, params: Dict[str, jax.Array], moe: MoEConfig,
            shard=lambda x, name: x) -> jax.Array:
    """x: (B, S, d).  params:
      w_router (d, E_pad); wg/wu (E_pad, d, d_expert); wd (E_pad, d_expert, d)
      optional shared experts: sg/su (d, d_shared), sd (d_shared, d),
      shared_gate (d,)

    `shard` pins the (B, E, C, d) buffers to (data, model, -, -): without the
    constraint GSPMD un-shards B for the expert einsum, replicating expert
    compute across the whole data axis (observed 16x flops).
    """
    b, s, d = x.shape
    e_pad = params["w_router"].shape[1]
    k = moe.top_k
    sk = s * k

    weights, experts = router_topk(x, params["w_router"], moe)   # (B,S,k)

    # ---- row-local dispatch ------------------------------------------------
    flat_e = experts.reshape(b, sk)                                # (B, S*k)
    flat_w = weights.reshape(b, sk)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None], (b, sk))

    # position of each (token, choice) within its expert's row-local queue:
    # count same-expert entries strictly before it along the row.
    onehot = jax.nn.one_hot(flat_e, e_pad, dtype=jnp.int32)        # (B,S*k,E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot                  # exclusive
    pos = jnp.take_along_axis(pos_all, flat_e[..., None],
                              axis=-1)[..., 0]                     # (B, S*k)

    capacity = int(max(k, round(sk / moe.n_experts * moe.capacity_factor)))
    keep = pos < capacity

    xt = x                                                          # (B,S,d)
    gathered = jnp.take_along_axis(
        xt, flat_t[..., None], axis=1)                              # (B,S*k,d)
    gathered = jnp.where(keep[..., None], gathered, 0).astype(x.dtype)

    rows = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None], (b, sk))
    buf = jnp.zeros((b, e_pad, capacity, d), x.dtype)
    buf = buf.at[rows, flat_e, jnp.where(keep, pos, capacity)].set(
        gathered, mode="drop")                                      # (B,E,C,d)
    buf = shard(buf, "moe_buf")

    # ---- expert compute (E sharded over `model` => expert parallel) --------
    g = jnp.einsum("becd,edf->becf", buf, params["wg"])
    u = jnp.einsum("becd,edf->becf", buf, params["wu"])
    h = shard((jax.nn.silu(g) * u).astype(x.dtype), "moe_h")
    out_buf = jnp.einsum("becf,efd->becd", h, params["wd"]).astype(x.dtype)
    from .perf_flags import get_flags
    if get_flags().moe_combine == "sharded":
        # keep expert outputs E-sharded; the combine gather pays a forward
        # all-gather but the backward stays sharded (§Perf hillclimb)
        out_buf = shard(out_buf, "moe_h")
    else:
        out_buf = shard(out_buf, "moe_buf")       # replicate E (baseline)

    # ---- combine -------------------------------------------------------------
    expert_out = out_buf[rows, flat_e, jnp.where(keep, pos, 0)]    # (B,S*k,d)
    expert_out = expert_out * (flat_w * keep).astype(jnp.float32)[..., None]
    y = jnp.zeros((b, s, d), jnp.float32)
    y = y.at[rows, flat_t].add(expert_out.astype(jnp.float32))

    # ---- shared experts (qwen2-moe) --------------------------------------------
    if moe.n_shared and "sg" in params:
        sg = jnp.einsum("bsd,df->bsf", x, params["sg"])
        su = jnp.einsum("bsd,df->bsf", x, params["su"])
        shared = jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * su,
                            params["sd"])
        gate = jax.nn.sigmoid(jnp.einsum(
            "bsd,d->bs", x.astype(jnp.float32),
            params["shared_gate"].astype(jnp.float32)))
        y = y + shared.astype(jnp.float32) * gate[..., None]

    return y.astype(x.dtype)
